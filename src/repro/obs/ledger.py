"""Append-only per-tenant ε-spend audit ledger (DESIGN.md §12).

A DP system's observability obligation is domain-specific: every charge
against a tenant's privacy budget, and every charge-free refusal, must
leave an auditable trail (the same concern that makes Khanna et al. account
explicitly for screening queries).  The ledger records, per entry, the
accountant state *before and after*, so the whole spend history is
replayable: ``replay()`` re-walks the chain, checks every transition
(``after.spent_steps == before.spent_steps + steps``, monotone, gap-free),
and recomputes each tenant's composed ε **through the accountant's own
formula** — the audit cannot drift from the implementation because it runs
the implementation.

Entry kinds (JSONL, one object per line, ``ev: "ledger"``):

  * ``open``    — accountant attached: its parameters + current state
                  (the chain base, so pre-spent accountants audit cleanly);
  * ``charge``  — ε-budget consumed: steps charged, request facts
                  (uid, ε, δ, T, queue, backend), state before/after;
  * ``refusal`` — request refused charge-free: the reason, and the state
                  (unchanged) when the tenant has an accountant.

The ledger is always-on (it is the DP audit trail, not diagnostics); when
the obs collector is active each entry is mirrored as a ``ledger`` event so
one artifact can carry the whole run.  Accountant state snapshots persist
through the existing ``repro.checkpoint`` machinery (atomic npz + metadata)
so a restarted service resumes from audited state instead of resetting
spent ε.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Mapping, Optional

from repro.core.dp.accountant import PrivacyAccountant


def _acct_params(acct: PrivacyAccountant) -> dict:
    return {"epsilon": acct.epsilon, "delta": acct.delta,
            "total_steps": acct.total_steps}


def _acct_state(acct: PrivacyAccountant) -> dict:
    return {"spent_steps": acct.spent_steps,
            "remaining_steps": acct.remaining_steps,
            "spent_epsilon": acct.spent_epsilon()}


class AuditLedger:
    """Append-only ε-spend ledger, optionally mirrored to a JSONL file."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: List[dict] = []
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            # append-only contract: an existing ledger is continued, never
            # truncated (a restarted service keeps one audit trail)
            self.entries = self.load(path) if os.path.exists(path) else []

    # ------------------------------------------------------------- appenders
    def _append(self, entry: dict) -> None:
        entry = {"ev": "ledger", "wall_unix": time.time(), **entry}
        self.entries.append(entry)
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(entry) + "\n")
        from repro import obs
        if obs.enabled():
            obs.event("ledger", **{k: v for k, v in entry.items()
                                   if k != "ev"})
            obs.count("ledger.entries", kind=entry["kind"])

    def open_tenant(self, tenant: str, acct: PrivacyAccountant) -> None:
        """Record the chain base for ``tenant`` (called once at attach)."""
        self._append({"kind": "open", "tenant": tenant,
                      "acct": _acct_params(acct), "state": _acct_state(acct)})

    def charge(self, *, tenant: str, uid: int, steps: int, before: dict,
               acct: PrivacyAccountant,
               request: Optional[dict] = None) -> None:
        """One budget charge: ``before`` is ``state_of(acct)`` captured just
        before ``acct.spend(steps)``; the after-state is read live."""
        self._append({"kind": "charge", "tenant": tenant, "uid": uid,
                      "steps": steps, "before": before,
                      "after": _acct_state(acct),
                      "acct": _acct_params(acct),
                      "request": request or {}})

    def refusal(self, *, tenant: str, uid: int, reason: str,
                acct: Optional[PrivacyAccountant] = None,
                request: Optional[dict] = None) -> None:
        """A charge-free rejection; state recorded when the tenant has an
        accountant (unknown tenants have no state to attest)."""
        entry = {"kind": "refusal", "tenant": tenant, "uid": uid,
                 "reason": reason, "steps": 0, "request": request or {}}
        if acct is not None:
            entry["acct"] = _acct_params(acct)
            entry["state"] = _acct_state(acct)
        self._append(entry)

    state_of = staticmethod(_acct_state)

    # --------------------------------------------------------------- replay
    @staticmethod
    def load(path: str) -> List[dict]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]

    @staticmethod
    def replay(entries: List[dict]) -> Dict[str, dict]:
        """Re-walk the ledger; per-tenant totals with chain verification.

        Returns ``{tenant: {"spent_steps", "spent_epsilon", "charges",
        "refusals", "charged_steps"}}`` where ``spent_epsilon`` is
        *recomputed* from the accountant parameters via
        ``PrivacyAccountant.spent_epsilon`` — bit-identical to what the live
        accountant reports, or the ledger is corrupt.  Raises ``ValueError``
        on any broken transition (skipped/negative/inconsistent spend).
        """
        out: Dict[str, dict] = {}
        last_spent: Dict[str, int] = {}
        params: Dict[str, dict] = {}
        for i, e in enumerate(entries):
            if e.get("ev") not in (None, "ledger") or "kind" not in e:
                continue
            t = e["tenant"]
            rec = out.setdefault(t, {"charges": 0, "refusals": 0,
                                     "charged_steps": 0})
            if e["kind"] == "open":
                params[t] = e["acct"]
                last_spent[t] = int(e["state"]["spent_steps"])
            elif e["kind"] == "charge":
                params.setdefault(t, e["acct"])
                before = int(e["before"]["spent_steps"])
                after = int(e["after"]["spent_steps"])
                base = last_spent.get(t, before)
                if before != base:
                    raise ValueError(
                        f"ledger entry {i}: tenant {t!r} before-state "
                        f"{before} != last known spend {base}")
                if after != before + int(e["steps"]):
                    raise ValueError(
                        f"ledger entry {i}: tenant {t!r} charge of "
                        f"{e['steps']} steps moved {before} -> {after}")
                last_spent[t] = after
                rec["charges"] += 1
                rec["charged_steps"] += int(e["steps"])
            elif e["kind"] == "refusal":
                rec["refusals"] += 1
                if "state" in e:
                    st = int(e["state"]["spent_steps"])
                    base = last_spent.setdefault(t, st)
                    if st != base:
                        raise ValueError(
                            f"ledger entry {i}: refusal for tenant {t!r} "
                            f"attests spend {st} != last known {base}")
        for t, rec in out.items():
            spent = last_spent.get(t, 0)
            rec["spent_steps"] = spent
            if t in params:
                acct = PrivacyAccountant(spent_steps=spent, **params[t])
                rec["spent_epsilon"] = acct.spent_epsilon()
            else:
                rec["spent_epsilon"] = None
        return out

    def totals(self) -> Dict[str, dict]:
        return self.replay(self.entries)

    def verify(self, accountants: Mapping[str, PrivacyAccountant]
               ) -> Dict[str, dict]:
        """Audit the ledger against live accountants.

        Exactness contract: for every tenant with ledger entries, the
        replayed ``spent_steps`` must equal the accountant's, and the
        recomputed ε must equal ``spent_epsilon()`` bit-for-bit.  Raises
        ``ValueError`` on the first mismatch; returns the per-tenant audit
        report otherwise.
        """
        totals = self.totals()
        for tenant, rec in totals.items():
            acct = accountants.get(tenant)
            if acct is None:
                raise ValueError(f"ledger names unknown tenant {tenant!r}")
            if rec["spent_steps"] != acct.spent_steps:
                raise ValueError(
                    f"tenant {tenant!r}: ledger replays {rec['spent_steps']} "
                    f"spent steps, accountant holds {acct.spent_steps}")
            live_eps = acct.spent_epsilon()
            if rec["spent_epsilon"] != live_eps:
                raise ValueError(
                    f"tenant {tenant!r}: ledger ε {rec['spent_epsilon']} != "
                    f"accountant ε {live_eps}")
            rec["accountant_epsilon"] = live_eps
            rec["exact"] = True
        return totals

    # ----------------------------------------------------------- checkpoint
    def checkpoint(self, directory: str,
                   accountants: Mapping[str, PrivacyAccountant]) -> str:
        """Persist accountant state atomically via ``repro.checkpoint``.

        The snapshot is keyed by ledger length (monotone, so rotation keeps
        the newest) and carries the ledger path in its metadata; a restart
        restores accountants that agree with the audit trail instead of
        silently resetting spent ε.
        """
        import numpy as np

        from repro.checkpoint.checkpointer import save_pytree
        tree = {t: {k: np.asarray(v) for k, v in a.to_state().items()}
                for t, a in accountants.items()}
        path = os.path.join(directory, f"accountants_{len(self.entries)}.npz")
        save_pytree(tree, path, metadata={
            "ledger_entries": len(self.entries),
            "ledger_path": self.path or "", "kind": "privacy_accountants"})
        return path

    @staticmethod
    def restore_accountants(path: str) -> Dict[str, PrivacyAccountant]:
        """Rebuild ``{tenant: PrivacyAccountant}`` from a checkpoint file."""
        import numpy as np
        out: Dict[str, Dict[str, float]] = {}
        with np.load(path) as z:
            for key in z.files:
                tenant, field = key.rsplit("/", 1)
                out.setdefault(tenant, {})[field] = z[key].item()
        return {t: PrivacyAccountant.from_state(state)
                for t, state in out.items()}
