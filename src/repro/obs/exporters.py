"""Exporters: JSONL event log and Prometheus-style text exposition.

The JSONL log is the durable artifact (what CI uploads next to the
BENCH_*.json files and what ``python -m repro.obs.report`` renders): one
meta line, every span/event in close order, then one line per metric
instrument.  The Prometheus text form is for scrape-style consumption —
counters as ``_total`` series, histograms as summary quantiles.
"""
from __future__ import annotations

import json
import re
from typing import List, Optional

from repro.obs.core import Telemetry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def to_records(tel: Telemetry) -> List[dict]:
    """The full run as ordered JSON-ready records (meta, events, metrics)."""
    out = [{"ev": "meta", "wall_start_unix": tel.wall_start,
            "duration_s": round(tel.now(), 6), **tel.meta}]
    out.extend(tel.events)
    for rec in tel.metrics.snapshot():
        out.append({"ev": "metric", **rec})
    return out


def write_jsonl(tel: Telemetry, path: str) -> str:
    with open(path, "w") as f:
        for rec in to_records(tel):
            f.write(json.dumps(rec) + "\n")
    return path


def read_jsonl(path: str) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def _prom_name(name: str, suffix: str = "") -> str:
    return "repro_" + _NAME_RE.sub("_", name) + suffix


def _prom_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(f'{_NAME_RE.sub("_", k)}="{v}"'
                    for k, v in sorted(items.items()))
    return "{" + body + "}"


def prometheus_text(tel: Telemetry) -> str:
    """Counters/gauges/histograms in the Prometheus text format (0.0.4).

    Histograms expose the summary form: interpolated p50/p90/p99 quantile
    series plus ``_sum``/``_count`` — matching what ``FitService.stats()``
    reports, because both go through the same estimator.
    """
    lines: List[str] = []
    seen_types = set()
    for rec in tel.metrics.snapshot():
        kind, name, labels = rec["type"], rec["name"], rec["labels"]
        if kind == "counter":
            pname = _prom_name(name, "_total")
            if pname not in seen_types:
                seen_types.add(pname)
                lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname}{_prom_labels(labels)} {rec['value']}")
        elif kind == "gauge":
            pname = _prom_name(name)
            if pname not in seen_types:
                seen_types.add(pname)
                lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname}{_prom_labels(labels)} {rec['value']}")
        else:
            pname = _prom_name(name)
            if pname not in seen_types:
                seen_types.add(pname)
                lines.append(f"# TYPE {pname} summary")
            for q_key, q in (("p50", "0.5"), ("p90", "0.9"), ("p99", "0.99")):
                lines.append(
                    f"{pname}{_prom_labels(labels, {'quantile': q})} "
                    f"{rec[q_key]}")
            lines.append(f"{pname}_sum{_prom_labels(labels)} {rec['sum']}")
            lines.append(f"{pname}_count{_prom_labels(labels)} "
                         f"{rec['count']}")
    return "\n".join(lines) + ("\n" if lines else "")
