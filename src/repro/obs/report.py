"""Run-summary renderer for telemetry JSONL artifacts.

    python -m repro.obs.report run-events.jsonl [--ledger ledger.jsonl]

Renders the span tree (aggregated by path: count, total seconds), hot
counters, gauges, histogram percentiles, and — when the artifact carries
ledger events (or ``--ledger`` names a ledger JSONL) — the per-tenant
ε-spend audit table, replay-verified.

This module is an explicit output sink: it is the one place in
``repro.obs`` allowed to print (the repo-wide lint gate bans bare
``print`` elsewhere in ``src/repro``).
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Tuple


def _span_tree(records: List[dict]) -> List[Tuple[Tuple[str, ...], int, float]]:
    """Aggregate spans by name-path → (path, count, total seconds).

    Paths are rebuilt from id/parent links (spans are recorded at close, so
    the full record list resolves every parent).  Sibling order is
    first-seen; each node precedes its children."""
    spans = {r["id"]: r for r in records if r.get("ev") == "span"}

    def path_of(r) -> Tuple[str, ...]:
        names = [r["name"]]
        while r["parent"] in spans:
            r = spans[r["parent"]]
            names.append(r["name"])
        return tuple(reversed(names))

    # nested {name: [count, total_s, children]} in first-seen order
    root: Dict[str, list] = {}
    for r in records:
        if r.get("ev") != "span":
            continue
        node, children = None, root
        for name in path_of(r):
            node = children.setdefault(name, [0, 0.0, {}])
            children = node[2]
        node[0] += 1
        node[1] += r["dur_s"]

    out: List[Tuple[Tuple[str, ...], int, float]] = []

    def walk(children: Dict[str, list], prefix: Tuple[str, ...]) -> None:
        for name, (n, total, kids) in children.items():
            path = prefix + (name,)
            out.append((path, n, total))
            walk(kids, path)

    walk(root, ())
    return out


def render(records: List[dict],
           ledger_entries: Optional[List[dict]] = None,
           top: int = 20) -> str:
    """The human-readable run summary of one telemetry JSONL artifact."""
    lines: List[str] = []
    meta = next((r for r in records if r.get("ev") == "meta"), {})
    extra = {k: v for k, v in meta.items()
             if k not in ("ev", "wall_start_unix", "duration_s")}
    lines.append("=== telemetry run summary ===")
    if meta:
        lines.append(f"run duration: {meta.get('duration_s', 0.0):.3f}s"
                     + (f"  meta: {extra}" if extra else ""))

    tree = _span_tree(records)
    if tree:
        lines.append("")
        lines.append("span tree (count, total seconds):")
        for path, n, total in tree:
            indent = "  " * len(path)
            lines.append(f"{indent}{path[-1]:<40s} {n:>6d}x {total:>10.4f}s")

    events: Dict[str, int] = {}
    for r in records:
        if r.get("ev") == "event":
            events[r["name"]] = events.get(r["name"], 0) + 1
    if events:
        lines.append("")
        lines.append("events:")
        for name, n in sorted(events.items(), key=lambda kv: -kv[1]):
            lines.append(f"  {name:<46s} {n:>6d}")

    metrics = [r for r in records if r.get("ev") == "metric"]
    counters = [m for m in metrics if m["type"] == "counter"]
    gauges = [m for m in metrics if m["type"] == "gauge"]
    hists = [m for m in metrics if m["type"] == "histogram"]

    def label_str(m) -> str:
        lbl = ",".join(f"{k}={v}" for k, v in sorted(m["labels"].items()))
        return f"{m['name']}{{{lbl}}}" if lbl else m["name"]

    if counters:
        lines.append("")
        lines.append(f"hot counters (top {top}):")
        for m in sorted(counters, key=lambda m: -m["value"])[:top]:
            lines.append(f"  {label_str(m):<52s} {m['value']:>10d}")
    if gauges:
        lines.append("")
        lines.append("gauges:")
        for m in gauges:
            lines.append(f"  {label_str(m):<52s} {m['value']:>14.6g}")
    if hists:
        lines.append("")
        lines.append("histograms (count / p50 / p90 / p99 / max):")
        for m in hists:
            lines.append(
                f"  {label_str(m):<44s} {m['count']:>6d}  "
                f"{m['p50']:.6g} / {m['p90']:.6g} / {m['p99']:.6g} / "
                f"{m['max']:.6g}")

    if ledger_entries is None:
        ledger_entries = [dict(r["attrs"]) for r in records
                          if r.get("ev") == "event" and r["name"] == "ledger"]
    if ledger_entries:
        from repro.obs.ledger import AuditLedger
        lines.append("")
        lines.append("tenant ε-spend ledger (replay-verified):")
        lines.append(f"  {'tenant':<16s} {'charges':>8s} {'refused':>8s} "
                     f"{'steps':>8s} {'spent ε':>12s}")
        for tenant, rec in sorted(AuditLedger.replay(ledger_entries).items()):
            eps = rec["spent_epsilon"]
            lines.append(
                f"  {tenant:<16s} {rec['charges']:>8d} "
                f"{rec['refusals']:>8d} {rec['spent_steps']:>8d} "
                f"{eps if eps is None else format(eps, '>12.6g')}")
    return "\n".join(lines)


def render_path(path: str, ledger_path: Optional[str] = None,
                top: int = 20) -> str:
    from repro.obs.exporters import read_jsonl
    from repro.obs.ledger import AuditLedger
    ledger = AuditLedger.load(ledger_path) if ledger_path else None
    return render(read_jsonl(path), ledger_entries=ledger, top=top)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a telemetry JSONL artifact as a run summary")
    ap.add_argument("events", help="telemetry JSONL (obs.write_jsonl output)")
    ap.add_argument("--ledger", default=None,
                    help="ε-spend ledger JSONL (defaults to ledger events "
                         "embedded in the artifact)")
    ap.add_argument("--top", type=int, default=20,
                    help="how many hot counters to show")
    args = ap.parse_args(argv)
    print(render_path(args.events, args.ledger, args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
