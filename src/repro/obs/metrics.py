"""Metric instruments: counters, gauges, histograms with interpolated
quantiles, and the registry that names them.

The histogram quantile is the shared percentile helper of the repo — the
``FitService.stats()`` p50/p90/p99 go through :func:`quantile` rather than
an index into a sorted list (``lat[len(lat)//2]`` is not a median on
even-length samples; the interpolated estimator is exact on them).
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Sequence, Tuple

# Raw samples retained per histogram for quantile estimation; past the cap
# count/sum/min/max stay exact and quantiles are computed over the retained
# prefix (host-side run telemetry stays far below this in practice).
HIST_MAX_SAMPLES = 65536

LabelItems = Tuple[Tuple[str, str], ...]


def quantile(values: Sequence[float], q: float) -> float:
    """Interpolated quantile of ``values`` (numpy's "linear" method).

    ``q`` in [0, 1].  Empty input returns 0.0; a single sample is every
    quantile of itself.  ``quantile(x, 0.5)`` of an even-length sample is
    the mean of the two middle order statistics — the textbook median.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q}")
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    pos = q * (len(vals) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Sample accumulator with interpolated percentile estimation."""

    __slots__ = ("samples", "count", "sum", "min", "max")

    def __init__(self):
        self.samples: List[float] = []
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self.samples) < HIST_MAX_SAMPLES:
            self.samples.append(v)

    def quantile(self, q: float) -> float:
        return quantile(self.samples, q)

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Named, labeled instruments of one telemetry run.

    ``counter("store.cache", cache="padded", outcome="hit")`` returns the
    same :class:`Counter` on every call with identical labels; label values
    are stringified so any scalar is a valid label.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, LabelItems], object] = {}

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, name,
               tuple(sorted((k, str(v)) for k, v in labels.items())))
        got = self._metrics.get(key)
        if got is None:
            with self._lock:
                got = self._metrics.setdefault(key, factory())
        return got

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels, Histogram)

    def snapshot(self) -> List[dict]:
        """Exporter-facing view: one record per instrument, sorted by name."""
        out = []
        for (kind, name, labels), inst in sorted(self._metrics.items()):
            rec = {"type": kind, "name": name, "labels": dict(labels)}
            if kind == "histogram":
                rec.update(inst.summary())
            else:
                rec["value"] = inst.value
            out.append(rec)
        return out
