"""Host-side telemetry: solve-lifecycle tracing, metrics, DP audit ledger.

One rule governs everything in this package (DESIGN.md §12): telemetry is
**host-side only and a true no-op when disabled**.  Instrumentation never
enters traced/jitted code, never touches a PRNG key, and never changes a
control-flow decision — solver iterates are bit-identical with telemetry
on or off, which tier-1 tests pin on all five backends, private and
non-private.

Call sites use the module-level helpers, which cost one global read plus a
``None`` check when no collector is active::

    from repro import obs

    with obs.session(jsonl_path="run-events.jsonl"):
        res = solve(X, y, config)          # spans/counters recorded
    # disabled again here: the same call records nothing

    obs.count("my.counter", 3, kind="demo")
    with obs.span("my.phase", size=n):
        ...
    obs.observe("my.latency_s", dt)        # histogram w/ interpolated p50/90/99

Exporters (``repro.obs.exporters``) render a run as a JSONL event log or
Prometheus-style text exposition; ``python -m repro.obs.report`` pretty-
prints the span tree, hot counters, and the per-tenant ε ledger.
"""
from repro.obs.core import (Telemetry, count, disable, enable,  # noqa: F401
                            enabled, event, gauge, get, observe, session,
                            span)
from repro.obs.exporters import prometheus_text, write_jsonl  # noqa: F401
from repro.obs.ledger import AuditLedger  # noqa: F401
from repro.obs.metrics import quantile  # noqa: F401
