"""The telemetry runtime: structured events, spans, and the no-op contract.

A single process-wide collector (:class:`Telemetry`) is either installed or
not.  Every module-level helper (``span``/``event``/``count``/``gauge``/
``observe``) reads one global and returns immediately when it is ``None`` —
the disabled path allocates nothing beyond the kwargs dict of the call
itself, which is why instrumentation may sit on per-solve and per-chunk
host paths (never per-iteration device paths; those are traced code and
off-limits by the host-side-only rule, DESIGN.md §12).

Event records are plain dicts, one of:

    {"ev": "span",  "name", "id", "parent", "ts", "dur_s", "attrs": {...}}
    {"ev": "event", "name", "ts", "attrs": {...}}

``ts`` is seconds since the collector was enabled (monotonic clock); spans
are recorded at *close*, children before parents, so an ordered replay can
rebuild the tree from ``id``/``parent`` alone (``repro.obs.report`` does).
"""
from __future__ import annotations

import contextlib
import itertools
import threading
import time
from typing import Iterator, List, Optional

from repro.obs.metrics import MetricsRegistry

_ACTIVE: Optional["Telemetry"] = None


class Span:
    """One timed, attributed region; records an event when it exits."""

    __slots__ = ("_tel", "name", "attrs", "id", "parent", "_t0")

    def __init__(self, tel: "Telemetry", name: str, attrs: dict):
        self._tel = tel
        self.name = name
        self.attrs = attrs
        self.id = 0
        self.parent = 0
        self._t0 = 0.0

    def set(self, **attrs) -> None:
        """Attach/overwrite attributes after entry (e.g. a resolved
        backend name known only mid-span)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        tel = self._tel
        self.id = next(tel._ids)
        stack = tel._stack_of()
        self.parent = stack[-1] if stack else 0
        stack.append(self.id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tel = self._tel
        dur = time.perf_counter() - self._t0
        stack = tel._stack_of()
        if stack and stack[-1] == self.id:
            stack.pop()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        tel.events.append({
            "ev": "span", "name": self.name, "id": self.id,
            "parent": self.parent,
            "ts": round(self._t0 - tel._t0, 6), "dur_s": round(dur, 6),
            "attrs": self.attrs})
        return False


class _NoopSpan:
    """Shared do-nothing span returned by every helper while disabled."""

    __slots__ = ()

    def set(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class Telemetry:
    """One run's collector: ordered event list + metrics registry."""

    def __init__(self, meta: Optional[dict] = None):
        self._t0 = time.perf_counter()
        self.wall_start = time.time()
        self.meta = dict(meta or {})
        self.events: List[dict] = []
        self.metrics = MetricsRegistry()
        self._ids = itertools.count(1)
        self._local = threading.local()

    def _stack_of(self) -> List[int]:
        stack = getattr(self._local, "spans", None)
        if stack is None:
            stack = self._local.spans = []
        return stack

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        self.events.append({"ev": "event", "name": name,
                            "ts": round(self.now(), 6), "attrs": attrs})


# ---------------------------------------------------------------------------
# module-level API — the only thing instrumentation call sites touch
# ---------------------------------------------------------------------------


def get() -> Optional[Telemetry]:
    """The active collector, or None when telemetry is disabled."""
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


def enable(meta: Optional[dict] = None) -> Telemetry:
    """Install (and return) a fresh process-wide collector."""
    global _ACTIVE
    _ACTIVE = Telemetry(meta)
    return _ACTIVE


def disable() -> Optional[Telemetry]:
    """Uninstall the collector; returns it for export/inspection."""
    global _ACTIVE
    tel, _ACTIVE = _ACTIVE, None
    return tel


@contextlib.contextmanager
def session(jsonl_path: Optional[str] = None,
            meta: Optional[dict] = None) -> Iterator[Telemetry]:
    """Scoped telemetry: enabled inside the block, restored after.

    ``jsonl_path`` writes the JSONL event log on exit (also on error — a
    crashed run still leaves its trace).  The previously active collector,
    if any, is reinstalled afterwards, so sessions nest safely.
    """
    global _ACTIVE
    prev = _ACTIVE
    tel = Telemetry(meta)
    _ACTIVE = tel
    try:
        yield tel
    finally:
        _ACTIVE = prev
        if jsonl_path is not None:
            from repro.obs.exporters import write_jsonl
            write_jsonl(tel, jsonl_path)


def span(name: str, **attrs):
    """A context-manager span, or the shared no-op when disabled."""
    tel = _ACTIVE
    return tel.span(name, **attrs) if tel is not None else _NOOP_SPAN


def event(name: str, **attrs) -> None:
    tel = _ACTIVE
    if tel is not None:
        tel.event(name, **attrs)


def count(name: str, n: int = 1, **labels) -> None:
    tel = _ACTIVE
    if tel is not None:
        tel.metrics.counter(name, **labels).inc(n)


def gauge(name: str, value: float, **labels) -> None:
    tel = _ACTIVE
    if tel is not None:
        tel.metrics.gauge(name, **labels).set(value)


def observe(name: str, value: float, **labels) -> None:
    tel = _ACTIVE
    if tel is not None:
        tel.metrics.histogram(name, **labels).observe(value)
