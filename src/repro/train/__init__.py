from repro.train.optimizer import adafactor, adamw, make_schedule  # noqa: F401
from repro.train.trainer import TrainState, make_train_step  # noqa: F401
