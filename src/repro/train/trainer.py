"""Training step builder + host-side training loop.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for jit/pjit: loss → grad (with remat inside the model) → global-norm
clip → optimizer update → schedule.  Micro-batching (gradient accumulation)
runs as a ``lax.scan`` over microbatch slices so the memory high-water mark is
one microbatch of activations.

The host loop (`fit`) adds the production concerns: checkpoint/rotation via
``repro.checkpoint``, privacy-accountant persistence for DP-FW runs, a
per-step watchdog (straggler logging), and NaN-step skipping (fault
tolerance: a bad batch or flipped bit does not poison the run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.train.optimizer import Optimizer, clip_by_global_norm, get_optimizer, make_schedule

Pytree = Any


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    params: Pytree
    opt_state: Pytree

    def tree_flatten(self):
        return (self.step, self.params, self.opt_state), None

    def tree_flatten_with_keys(self):
        # named keys so sharding rules can tell params from optimizer state
        # (launch/sharding.py zero-2 shards only "opt_state/...")
        k = jax.tree_util.GetAttrKey
        return (((k("step"), self.step), (k("params"), self.params),
                 (k("opt_state"), self.opt_state)), None)

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    schedule: str = "cosine"     # cosine | wsd | constant
    total_steps: int = 10_000
    warmup: int = 100
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient-accumulation factor
    remat: bool = True
    # cast grads to bf16 before the cross-replica reduction (halves the
    # gradient all-reduce/reduce-scatter bytes; optimizer math stays f32).
    # Error-feedback is unnecessary at this precision for Adam-family
    # optimizers (update is normalized); §Perf thread-1 next-step knob.
    grad_reduce_dtype: str = ""  # "" = keep native; "bfloat16" to compress


def make_train_state(init_params_fn, opt: Optimizer, key) -> TrainState:
    params = init_params_fn(key)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params))


def make_train_step(loss_fn: Callable, tc: TrainConfig) -> Callable:
    """loss_fn(params, batch, remat=...) -> scalar.  Returns step fn."""
    opt = get_optimizer(tc.optimizer)
    schedule = make_schedule(tc.schedule, tc.peak_lr, tc.total_steps, tc.warmup)

    def grads_of(params, batch):
        loss, g = jax.value_and_grad(
            lambda p: loss_fn(p, batch, remat=tc.remat))(params)
        if tc.grad_reduce_dtype:
            dt = jnp.dtype(tc.grad_reduce_dtype)
            g = jax.tree.map(lambda x: x.astype(dt), g)
        return loss, g

    def step_fn(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if tc.microbatches > 1:
            def slice_mb(x, i):
                mb = x.shape[0] // tc.microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_body(carry, i):
                loss_sum, g_sum = carry
                mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, g = grads_of(state.params, mb_batch)
                g_sum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (loss_sum + loss, g_sum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0),
                jnp.arange(tc.microbatches))
            loss = loss_sum / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        else:
            loss, grads = grads_of(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = schedule(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, lr)

        # fault tolerance: skip the update if the step produced non-finite
        # grads (bad batch / hardware bit-flip) — keeps long runs alive.
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_params, state.params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_opt, state.opt_state)

        new_state = TrainState(step=state.step + 1, params=new_params, opt_state=new_opt)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "skipped": (~ok).astype(jnp.float32)}
        return new_state, metrics

    return step_fn


def fit(state: TrainState, step_fn: Callable, batches, *,
        steps: int, checkpointer=None, ckpt_every: int = 200,
        log_every: int = 10, watchdog_s: float = 600.0,
        log: Callable[[str], None] = print) -> Tuple[TrainState, list]:
    """Host training loop with checkpoint rotation and straggler watchdog.

    ``log=`` is the text sink (a callable, ``print`` by default — the loop
    itself never prints); step timings, straggler detections and skipped
    steps also flow to ``repro.obs`` when a collector is active, so a run
    artifact carries the loop's telemetry without parsing log lines.
    """
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    history = []
    with obs.span("train.fit", steps=steps):
        for i in range(steps):
            t0 = time.time()
            batch = next(batches)
            state, metrics = jit_step(state, batch)
            dt = time.time() - t0
            obs.observe("train.step_seconds", dt)
            if dt > watchdog_s:
                obs.event("train.straggler", step=int(state.step), sec=dt,
                          watchdog_s=watchdog_s)
                obs.count("train.stragglers")
                log(f"[watchdog] step {int(state.step)} took {dt:.1f}s "
                    f"(> {watchdog_s}s) — straggler detected; continuing")
            if i % log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": int(state.step), **m, "sec": dt})
                if m.get("skipped"):
                    obs.count("train.skipped_steps")
                log(f"step {int(state.step):>6d}  loss={m['loss']:.4f}  "
                    f"gnorm={m['grad_norm']:.3f}  lr={m['lr']:.2e}  "
                    f"{dt*1e3:.0f}ms")
            if checkpointer is not None and int(state.step) % ckpt_every == 0:
                with obs.span("train.checkpoint", step=int(state.step)):
                    checkpointer.save(state)
    return state, history
