"""Optimizers and LR schedules (substrate — no optax offline).

* ``adamw``     — f32 moments; standard for ≤34B archs.
* ``adafactor`` — factored second moments (rank-1 row/col stats for ≥2-D
  leaves), no first moment: the memory plan that lets the 1T kimi-k2 cell fit
  v5e HBM (DESIGN.md §5 memory notes — PaLM-style large-scale practice).
* ``make_schedule`` — wsd (minicpm's warmup-stable-decay), cosine, constant.

All optimizers are pure (init/update) over pytrees and donate-friendly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jnp.ndarray], Tuple[Pytree, Pytree]]
    name: str = ""


def _global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init=init, update=update, name="adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_rate: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(leaf, params,
                                      is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** (-decay_rate)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                r = (vr / denom)[..., None]
                u = g * jax.lax.rsqrt(r * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["stats"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, {"stats": new_s, "count": count}

    return Optimizer(init=init, update=update, name="adafactor")


def get_optimizer(name: str) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[name]()


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def make_schedule(kind: str, peak_lr: float, total_steps: int,
                  warmup: int = 0, decay_frac: float = 0.1) -> Callable:
    """Returns step → lr.  ``wsd`` = warmup / stable / decay (MiniCPM)."""
    warmup = max(warmup, 1)

    def wsd(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        decay_start = total_steps * (1.0 - decay_frac)
        warm = peak_lr * jnp.minimum((s + 1.0) / warmup, 1.0)
        decay = peak_lr * jnp.maximum(
            0.0, 1.0 - (s - decay_start) / jnp.maximum(total_steps - decay_start, 1.0))
        return jnp.where(s < decay_start, warm, jnp.minimum(warm, decay))

    def cosine(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum((s + 1.0) / warmup, 1.0)
        prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = peak_lr * 0.5 * (1.0 + jnp.cos(math.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    def constant(step):
        s = jnp.asarray(step, jnp.float32)
        return peak_lr * jnp.minimum((s + 1.0) / warmup, 1.0)

    return {"wsd": wsd, "cosine": cosine, "constant": constant}[kind]
