"""Single-device straight-line oracle for the ``jax_shard`` schedule.

``reference_fw`` replays the distributed Frank-Wolfe iteration of
``fw_shard`` on a 1×1 block grid with *direct global indexing* — no
shard_map, no collectives, no winner masking: every ``psum`` becomes the
identity, the shard-then-member Gumbel-max collapses to one in-shard draw
(the B=1 big step is a no-op by construction), and the same
``jax.random`` key schedule is consumed, so the selected coordinates are
bit-identical when the collective schedule is correct.

This is the "host oracle" the 1×1-mesh parity tests pin the registered
backend against for the *private* path, where cross-implementation parity
with ``fw_sparse`` is impossible (different RNG realizations of the same
exponential-mechanism law).  The non-private path is additionally pinned
against ``fw_sparse``'s exact fib-heap argmax in the same tests — a true
cross-implementation check.

Runs eagerly (Python loop over T) on purpose: a separately-compiled replay
would share XLA's op fusion with the scan under test; eager execution gives
an independently-rounded trajectory, and coords must still match exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.losses import get_loss
from repro.distributed.block_sparse import BlockSparse


def reference_fw(blocks: BlockSparse, y_pad: jnp.ndarray, *, lam: float,
                 steps: int, selection: str = "gumbel",
                 em_scale: float = 1.0, seed: int = 0,
                 loss: str = "logistic"):
    """(w, gaps, coords) of the fw_shard schedule on a 1×1 grid, eagerly."""
    if blocks.grid != (1, 1):
        raise ValueError("reference_fw replays the single-device schedule; "
                         f"got a {blocks.grid} grid")
    loss_fn = get_loss(loss)
    csc_r, csc_v = blocks.csc_rows[0, 0], blocks.csc_vals[0, 0]
    csr_c, csr_v = blocks.csr_cols[0, 0], blocks.csr_vals[0, 0]
    n, d = blocks.shape
    n_pad, d_pad = blocks.padded
    col_valid = jnp.arange(d_pad) < d
    lam = jnp.float32(lam)
    em_scale = jnp.float32(em_scale)

    # setup (Alg 2 lines 8-14); label-coupled objectives carry the full row
    # gradient in q̄ (no ȳ residual), mirroring fw_shard.setup_body
    vbar = jnp.zeros((n_pad,), jnp.float32)
    if loss_fn.separable:
        qbar = loss_fn.split_grad(vbar)
        resid_q = (qbar - y_pad) / n
    else:
        qbar = loss_fn.grad(vbar, y_pad)
        resid_q = qbar / n
    alpha = jnp.zeros((d_pad,), jnp.float32).at[csr_c.reshape(-1)].add(
        (resid_q[:, None] * csr_v).reshape(-1))

    w = jnp.zeros((d_pad,), jnp.float32)
    w_m = jnp.float32(1.0)
    g_t = jnp.float32(0.0)
    key = jax.random.PRNGKey(seed)
    gaps, coords = [], []
    for step in range(1, steps + 1):
        t = jnp.float32(step)
        key, key_t = jax.random.split(key)
        logits = jnp.where(col_valid, em_scale * jnp.abs(alpha), -jnp.inf)
        if selection == "gumbel":
            _, km = jax.random.split(key_t)       # kg draws the B=1 big step
            km = jax.random.fold_in(km, 0)
            j = jnp.argmax(logits + jax.random.gumbel(km, (d_pad,)))
        else:
            j = jnp.argmax(logits)
        a_j = alpha[j]

        d_tilde = jnp.where(a_j == 0, lam, -lam * jnp.sign(a_j))
        gaps.append(g_t - d_tilde * a_j)
        coords.append(j)
        eta = 2.0 / (t + 2.0)
        w_m = w_m * (1.0 - eta)
        w = w.at[j].add(eta * d_tilde / w_m)
        g_t = g_t * (1.0 - eta) + eta * d_tilde * a_j

        rows_j, val_j = csc_r[j], csc_v[j]
        lane_ok = val_j != 0.0
        dv = jnp.where(lane_ok, eta * d_tilde * val_j / w_m, 0.0)
        vbar = vbar.at[rows_j].add(dv)
        margins = w_m * vbar[rows_j]
        hm = (loss_fn.split_grad(margins) if loss_fn.separable
              else loss_fn.grad(margins, y_pad[rows_j]))
        gamma = jnp.where(lane_ok, hm - qbar[rows_j], 0.0)
        qbar = qbar.at[rows_j].add(gamma)

        gsc = gamma / n
        cols = csr_c[rows_j]
        vals = jnp.where(lane_ok[:, None], csr_v[rows_j], 0.0)
        delta = jnp.zeros((d_pad,), jnp.float32).at[cols.reshape(-1)].add(
            (gsc[:, None] * vals).reshape(-1))
        alpha = alpha + delta

        dots = jnp.sum(vals * w[cols], axis=1)
        g_t = g_t + jnp.sum(gsc * dots) * w_m
    return w * w_m, jnp.stack(gaps), jnp.stack(coords)
