"""Block ingestion for the ``jax_shard`` backend (DESIGN.md §8).

``ShardSource`` is what the solver registry's ``blocks`` coercion returns:
a thin handle over the user's data that defers the (a × b) block build until
the mesh geometry is known (it lives on ``FWConfig.mesh``, not on the data),
then memoizes one ``BlockSparse`` per grid so sweeps, the fit service and
repeated solves never re-bucket.

Two construction paths:

  * **in-memory** — any matrix the registry can turn into a ``HostCSR``
    (dense, padded pair, HostCSR) goes through the vectorized
    ``build_block_sparse``;
  * **dataset store** — shards stream one mmap ``HostCSR`` view at a time
    into ``BlockAssembler`` (two passes: lane counts, then fills with
    running per-column/row pointers), so the store's npy shards map onto
    device blocks **without densifying through one concatenated host
    matrix**.  The finished layout persists under the store's ``cache/``
    guarded by its content hash (alongside the padded/setup caches) and is
    mmap-read on warm opens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.sparse.formats import HostCSR
from repro.distributed.block_sparse import (BlockAssembler, BlockSparse,
                                            build_block_sparse)


def _shard_coo(row_start: int, csr: HostCSR):
    """(global rows, cols, vals) COO view of one store shard."""
    rows = np.repeat(
        np.arange(csr.shape[0], dtype=np.int64) + row_start,
        np.diff(csr.indptr))
    return rows, csr.indices, csr.data


def blocks_from_store(store, a: int, b: int) -> BlockSparse:
    """Map a ``DatasetStore``'s shards onto an (a × b) ``BlockSparse``.

    Streams the mmap shard views through ``BlockAssembler`` (one shard
    resident per pass) and persists the result in the store's content-hash-
    guarded block-layout cache; warm calls read the padded block arrays
    straight off mmap.  Lane order is identical to
    ``build_block_sparse(store.to_host_csr(), a, b)`` by the assembler's
    running-fill-pointer construction.
    """
    cached = store.blocks_load(a, b)
    if cached is not None:
        return cached
    n, d = store.shape
    asm = BlockAssembler(n, d, a, b)
    for row_start, csr, _ in store.iter_shards():
        rows, cols, _ = _shard_coo(row_start, csr)
        asm.count(rows, cols)
    asm.alloc()
    for row_start, csr, _ in store.iter_shards():
        asm.fill(*_shard_coo(row_start, csr))
    blocks = asm.finish()
    store.blocks_save(a, b, blocks)
    return blocks


@dataclasses.dataclass
class ShardSource:
    """Deferred block coercion: one of (csr, store) + a per-grid memo."""

    shape: Tuple[int, int]
    csr: Optional[HostCSR] = None
    store: Optional[object] = None            # repro.data.store.DatasetStore
    _blocks: Dict[Tuple[int, int], BlockSparse] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def from_any(cls, X) -> "ShardSource":
        """Coerce any registry-accepted ``X`` into a ``ShardSource``."""
        if isinstance(X, cls):
            return X
        from repro.data.store import DatasetStore
        if isinstance(X, DatasetStore):
            return cls(shape=X.shape, store=X)
        from repro.core.solvers.registry import as_host_csr
        csr = as_host_csr(X)
        return cls(shape=csr.shape, csr=csr)

    def blocks(self, a: int, b: int) -> BlockSparse:
        key = (int(a), int(b))
        if key not in self._blocks:
            if self.store is not None:
                self._blocks[key] = blocks_from_store(self.store, a, b)
            else:
                self._blocks[key] = build_block_sparse(self.csr, a, b)
        return self._blocks[key]
