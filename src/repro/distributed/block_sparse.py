"""2-D block-sharded padded sparse design matrix.

The distributed Frank-Wolfe (DESIGN.md §8) shards the design matrix over the
production mesh: **rows → ("pod","data"), features → "model"**.  Each device
(a, b) holds the (N/A × D/B) block X[rows_a, cols_b] in both padded layouts:

  * block CSC — for the selected column j's local rows (v̄/q̄ updates);
  * block CSR — for the touched rows' local columns (α-shard updates).

Row ids inside a block are *local* (0..N_loc) and column ids are *local*
(0..D_loc): every per-device kernel indexes only its own shards, so the only
cross-device traffic left in the FW step is the γ/dv lane exchange and the
α-delta reduction (see fw_shard.py).

Padding is per-layout-global (one static Kc/Kr for every block) because XLA
needs one shape; ``waste`` reports the padded/true-nnz ratio so benchmarks
can audit the overhead the same way PaddedCSR.padding_overhead does.

Construction is a vectorized two-pass COO bucketing (``BlockAssembler``):
pass 1 counts lanes per block column/row (fixing Kc/Kr), pass 2 scatters
values into the preallocated padded arrays.  Because the assembler consumes
COO fragments incrementally with running fill pointers, a sharded on-disk
``DatasetStore`` maps straight onto device blocks one mmap shard at a time
(``repro.distributed.ingest``) — no concatenation into one host matrix, and
lane order is identical to feeding the whole matrix at once.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse.formats import HostCSR


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockSparse:
    """All leaves lead with (A, B) = (data shards, model shards)."""

    csc_rows: jnp.ndarray   # (A, B, D_loc, Kc) int32 local row ids
    csc_vals: jnp.ndarray   # (A, B, D_loc, Kc) f32
    csr_cols: jnp.ndarray   # (A, B, N_loc, Kr) int32 local col ids
    csr_vals: jnp.ndarray   # (A, B, N_loc, Kr) f32
    shape: Tuple[int, int]  # global (N, D) — static
    padded: Tuple[int, int]  # (N_pad, D_pad) — static

    def tree_flatten(self):
        return ((self.csc_rows, self.csc_vals, self.csr_cols, self.csr_vals),
                (self.shape, self.padded))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, shape=aux[0], padded=aux[1])

    @property
    def grid(self) -> Tuple[int, int]:
        return self.csc_rows.shape[0], self.csc_rows.shape[1]

    @property
    def waste(self) -> float:
        true = float(jnp.sum(self.csc_vals != 0))
        return float(self.csc_vals.size) / max(true, 1.0)


def block_layout(n: int, d: int, a: int, b: int) -> Tuple[int, int]:
    """Per-device block shape (N_loc, D_loc) of an (a × b) grid."""
    return -(-n // a), -(-d // b)


def _run_ranks(sorted_key: np.ndarray) -> np.ndarray:
    """Rank of each element within its equal-key run (key already sorted)."""
    m = sorted_key.size
    if m == 0:
        return np.zeros(0, np.int64)
    run_start = np.zeros(m, np.int64)
    new_run = np.flatnonzero(sorted_key[1:] != sorted_key[:-1]) + 1
    run_start[new_run] = new_run
    return np.arange(m, dtype=np.int64) - np.maximum.accumulate(run_start)


class BlockAssembler:
    """Streaming COO → (a × b) padded block grid, in two vectorized passes.

    Feed COO fragments in global row order (``count`` them all, ``alloc``,
    then ``fill`` the same fragments in the same order).  Lane order inside
    each block column (row) is the global row (stored column) order — the
    running fill pointers carry it across fragments, so shard-at-a-time
    assembly is bit-identical to whole-matrix assembly.
    """

    def __init__(self, n: int, d: int, a: int, b: int):
        self.n, self.d, self.a, self.b = n, d, a, b
        self.n_loc, self.d_loc = block_layout(n, d, a, b)
        self._col_counts = np.zeros(a * b * self.d_loc, np.int64)
        self._row_counts = np.zeros(a * b * self.n_loc, np.int64)
        self._arrays = None

    def _keys(self, rows: np.ndarray, cols: np.ndarray):
        ai, il = np.divmod(np.asarray(rows, np.int64), self.n_loc)
        bj, jl = np.divmod(np.asarray(cols, np.int64), self.d_loc)
        block = ai * self.b + bj
        return block * self.d_loc + jl, block * self.n_loc + il, il, jl

    def count(self, rows: np.ndarray, cols: np.ndarray) -> None:
        col_key, row_key, _, _ = self._keys(rows, cols)
        self._col_counts += np.bincount(col_key,
                                        minlength=self._col_counts.size)
        self._row_counts += np.bincount(row_key,
                                        minlength=self._row_counts.size)

    def alloc(self) -> None:
        """Fix (Kc, Kr) from the counts and allocate the padded arrays."""
        a, b = self.a, self.b
        self.kc = max(1, int(self._col_counts.max(initial=0)))
        self.kr = max(1, int(self._row_counts.max(initial=0)))
        self._arrays = (
            np.zeros((a, b, self.d_loc, self.kc), np.int32),
            np.zeros((a, b, self.d_loc, self.kc), np.float32),
            np.zeros((a, b, self.n_loc, self.kr), np.int32),
            np.zeros((a, b, self.n_loc, self.kr), np.float32),
        )
        self._col_fill = np.zeros_like(self._col_counts)
        self._row_fill = np.zeros_like(self._row_counts)

    def fill(self, rows: np.ndarray, cols: np.ndarray,
             vals: np.ndarray) -> None:
        if self._arrays is None:
            raise RuntimeError("call alloc() after the counting pass")
        col_key, row_key, il, jl = self._keys(rows, cols)
        vals = np.asarray(vals, np.float64)
        for key, fill, lane_k, dest_i, dest_v, local in (
            (col_key, self._col_fill, self.kc,
             self._arrays[0], self._arrays[1], il),
            (row_key, self._row_fill, self.kr,
             self._arrays[2], self._arrays[3], jl),
        ):
            order = np.argsort(key, kind="stable")   # keep arrival order
            k_sorted = key[order]
            lane = fill[k_sorted] + _run_ranks(k_sorted)
            flat = k_sorted * lane_k + lane
            dest_i.reshape(-1)[flat] = local[order]
            dest_v.reshape(-1)[flat] = vals[order]
            fill += np.bincount(key, minlength=fill.size)

    def finish(self) -> BlockSparse:
        csc_rows, csc_vals, csr_cols, csr_vals = self._arrays
        return BlockSparse(
            csc_rows=jnp.asarray(csc_rows), csc_vals=jnp.asarray(csc_vals),
            csr_cols=jnp.asarray(csr_cols), csr_vals=jnp.asarray(csr_vals),
            shape=(self.n, self.d),
            padded=(self.n_loc * self.a, self.d_loc * self.b),
        )


def build_block_sparse(X: HostCSR, a: int, b: int) -> BlockSparse:
    """Split a HostCSR into an (a × b) block grid of padded layouts."""
    n, d = X.shape
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(X.indptr))
    asm = BlockAssembler(n, d, a, b)
    asm.count(rows, X.indices)
    asm.alloc()
    asm.fill(rows, X.indices, X.data)
    return asm.finish()


def block_specs(n: int, d: int, a: int, b: int, kc: int, kr: int) -> BlockSparse:
    """ShapeDtypeStruct stand-in for dry-runs (no allocation)."""
    n_loc, d_loc = block_layout(n, d, a, b)
    f = jax.ShapeDtypeStruct
    return BlockSparse(
        csc_rows=f((a, b, d_loc, kc), jnp.int32),
        csc_vals=f((a, b, d_loc, kc), jnp.float32),
        csr_cols=f((a, b, n_loc, kr), jnp.int32),
        csr_vals=f((a, b, n_loc, kr), jnp.float32),
        shape=(n, d), padded=(n_loc * a, d_loc * b),
    )
