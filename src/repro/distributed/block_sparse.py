"""2-D block-sharded padded sparse design matrix.

The distributed Frank-Wolfe (DESIGN.md §5) shards the design matrix over the
production mesh: **rows → ("pod","data"), features → "model"**.  Each device
(a, b) holds the (N/A × D/B) block X[rows_a, cols_b] in both padded layouts:

  * block CSC — for the selected column j's local rows (v̄/q̄ updates);
  * block CSR — for the touched rows' local columns (α-shard updates).

Row ids inside a block are *local* (0..N_loc) and column ids are *local*
(0..D_loc): every per-device kernel indexes only its own shards, so the only
cross-device traffic left in the FW step is the γ/dv lane exchange and the
α-delta reduction (see fw_shard.py).

Padding is per-layout-global (one static Kc/Kr for every block) because XLA
needs one shape; ``waste`` reports the padded/true-nnz ratio so benchmarks
can audit the overhead the same way PaddedCSR.padding_overhead does.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse.formats import HostCSR


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockSparse:
    """All leaves lead with (A, B) = (data shards, model shards)."""

    csc_rows: jnp.ndarray   # (A, B, D_loc, Kc) int32 local row ids
    csc_vals: jnp.ndarray   # (A, B, D_loc, Kc) f32
    csr_cols: jnp.ndarray   # (A, B, N_loc, Kr) int32 local col ids
    csr_vals: jnp.ndarray   # (A, B, N_loc, Kr) f32
    shape: Tuple[int, int]  # global (N, D) — static
    padded: Tuple[int, int]  # (N_pad, D_pad) — static

    def tree_flatten(self):
        return ((self.csc_rows, self.csc_vals, self.csr_cols, self.csr_vals),
                (self.shape, self.padded))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, shape=aux[0], padded=aux[1])

    @property
    def grid(self) -> Tuple[int, int]:
        return self.csc_rows.shape[0], self.csc_rows.shape[1]

    @property
    def waste(self) -> float:
        true = float(jnp.sum(self.csc_vals != 0))
        return float(self.csc_vals.size) / max(true, 1.0)


def build_block_sparse(X: HostCSR, a: int, b: int) -> BlockSparse:
    """Split a HostCSR into an (a × b) block grid of padded layouts."""
    n, d = X.shape
    n_loc = -(-n // a)
    d_loc = -(-d // b)
    n_pad, d_pad = n_loc * a, d_loc * b

    # bucket nnz per block
    csc_lists = [[[[] for _ in range(d_loc)] for _ in range(b)] for _ in range(a)]
    csr_lists = [[[[] for _ in range(n_loc)] for _ in range(b)] for _ in range(a)]
    for i in range(n):
        ai, il = divmod(i, n_loc)
        idx, val = X.row(i)
        for j, v in zip(idx, val):
            bj, jl = divmod(int(j), d_loc)
            csc_lists[ai][bj][jl].append((il, v))
            csr_lists[ai][bj][il].append((jl, v))

    kc = max(1, max(len(c) for ab in csc_lists for blk in ab for c in blk))
    kr = max(1, max(len(r) for ab in csr_lists for blk in ab for r in blk))

    csc_rows = np.zeros((a, b, d_loc, kc), np.int32)
    csc_vals = np.zeros((a, b, d_loc, kc), np.float32)
    csr_cols = np.zeros((a, b, n_loc, kr), np.int32)
    csr_vals = np.zeros((a, b, n_loc, kr), np.float32)
    for ai in range(a):
        for bj in range(b):
            for jl in range(d_loc):
                for p, (il, v) in enumerate(csc_lists[ai][bj][jl]):
                    csc_rows[ai, bj, jl, p] = il
                    csc_vals[ai, bj, jl, p] = v
            for il in range(n_loc):
                for p, (jl, v) in enumerate(csr_lists[ai][bj][il]):
                    csr_cols[ai, bj, il, p] = jl
                    csr_vals[ai, bj, il, p] = v
    return BlockSparse(
        csc_rows=jnp.asarray(csc_rows), csc_vals=jnp.asarray(csc_vals),
        csr_cols=jnp.asarray(csr_cols), csr_vals=jnp.asarray(csr_vals),
        shape=(n, d), padded=(n_pad, d_pad),
    )


def block_specs(n: int, d: int, a: int, b: int, kc: int, kr: int) -> BlockSparse:
    """ShapeDtypeStruct stand-in for dry-runs (no allocation)."""
    n_loc, d_loc = -(-n // a), -(-d // b)
    f = jax.ShapeDtypeStruct
    return BlockSparse(
        csc_rows=f((a, b, d_loc, kc), jnp.int32),
        csc_vals=f((a, b, d_loc, kc), jnp.float32),
        csr_cols=f((a, b, n_loc, kr), jnp.int32),
        csr_vals=f((a, b, n_loc, kr), jnp.float32),
        shape=(n, d), padded=(n_loc * a, d_loc * b),
    )
