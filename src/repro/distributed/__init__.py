from repro.distributed.block_sparse import BlockSparse, build_block_sparse  # noqa: F401
from repro.distributed.fw_shard import DistFWConfig, distributed_fw  # noqa: F401
