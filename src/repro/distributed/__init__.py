from repro.distributed.block_sparse import (BlockAssembler,  # noqa: F401
                                            BlockSparse, build_block_sparse)
from repro.distributed.fw_shard import (DistFWConfig,  # noqa: F401
                                        build_dist_fw, distributed_fw)
