"""Distributed DP Frank-Wolfe via shard_map — the paper's mechanism at pod scale.

Layout (DESIGN.md §8): rows → ("pod","data"), features → "model".  Every
device (a, b) holds one BlockSparse block plus:

  state        sharding                size/device
  w, α         P("model")  (replicated over rows)   D/B
  v̄, q̄         P(rows)     (replicated over model)  N/A
  w_m, g̃, key  replicated  scalars

The coordinate selection is the paper's Big-Step-Little-Step **promoted to a
collective schedule**: each feature shard's log-sum-exp mass is the "big
step" table (now one scalar *per device column*), the winning shard is drawn
by Gumbel-max over the B gathered masses, and only the winner runs its
in-shard ("little step") draw.  Per-iteration communication:

  selection   all_gather of B scalars over "model"       (paper's √D groups)
  dv/γ lanes  psum of 3 (Kc,) lanes over "model"
  α delta     psum of D/B floats over rows — or, with ``compress_topk`` > 0,
              an all_gather of 2k floats (error-feedback top-k, the gradient
              compression hook; the residual stays on-device and is re-added
              next iteration, so nothing is lost, only delayed)
  g̃ dot      1 scalar psum over both axes

versus the O(D) gradient gather a dense DP-FW would need.  The exponential
mechanism's DP guarantee is a statement about the *law* of the selected
index; shard-then-member Gumbel-max samples exactly softmax(all logits)
(law of total probability), so the accounting in core/dp applies unchanged.
With top-k compression the selection scores lag by the residuals — the same
stale-but-bounded regime as the paper's Alg-3 queue (documented §Perf).

Like the single-device ``jax_sparse`` engine, the program is split into

  ``setup``   the first-iteration dense pass (Alg 2 lines 8-14): one local
              scatter + one α psum over the row axes — depends only on
              (X, y, loss), shared by every (λ, ε) problem;
  ``scan``    T iterations as one lax.scan with **λ, the EM log-weight scale
              and the PRNG key as traced scalars** — a λ/ε grid re-enters the
              same compiled executable, and ``solvers.batched`` can vmap the
              whole sweep where the mesh allows.

``build_dist_fw`` returns both stages (plus their jitted composition) for a
given abstract block layout; everything is jit-able and dry-runnable — the
16×16 and 2×16×16 production lowerings are exercised through the registered
``jax_shard`` backend by launch/dryrun.py --arch paper-lasso.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dp.accountant import em_log_weight_scale
from repro.core.losses import get_loss
from repro.distributed.block_sparse import BlockSparse


@dataclasses.dataclass(frozen=True)
class DistFWConfig:
    """Native config of the distributed engine (the registry's ``jax_shard``
    backend builds the same program from an ``FWConfig`` instead).

    Private selection draws the exponential mechanism at the per-step budget
    ``per_step_epsilon(ε, δ, T)`` — the same ``core.dp.accountant`` semantics
    every other backend uses (equivalence pinned in tests/test_jax_shard.py).
    """

    lam: float = 50.0
    steps: int = 1000
    loss: str = "logistic"
    selection: str = "gumbel"     # gumbel (DP exponential mech) | argmax
    epsilon: float = 1.0
    delta: float = 1e-6
    seed: int = 0
    compress_topk: int = 0        # 0 = dense α-delta psum; k = EF-top-k exchange
    gap_tol: float = 0.0          # §9: freeze the scan once g_t ≤ gap_tol

    def em_scale(self, n_rows: int) -> float:
        if self.selection != "gumbel":
            return 1.0
        return em_log_weight_scale(
            epsilon=self.epsilon, delta=self.delta, steps=self.steps,
            n_rows=n_rows, lipschitz=get_loss(self.loss).lipschitz)


class DistFW(NamedTuple):
    """The two jitted stages of one distributed FW program + composition.

    ``setup(blocks, y_pad) -> (v̄₀, q̄₀, α₀)`` — sharded P(rows)/P(rows)/
    P("model"); ``scan(blocks, y_pad, v̄₀, q̄₀, α₀, lam, em_scale, gap_tol,
    key) -> (w, gaps, coords, stop_step)``; ``whole`` is ``scan ∘ setup`` in one jit
    (what the dry-run lowers so setup's psum is in the collective audit too).
    """

    setup: Any
    scan: Any
    whole: Any


def _row_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def build_dist_fw(blocks_abs, mesh: Mesh, *, steps: int,
                  loss: str = "logistic", selection: str = "gumbel",
                  compress_topk: int = 0, early_stop: bool = False) -> DistFW:
    """Build the (setup, scan, whole) program for one abstract block layout.

    λ, the EM scale, the gap tolerance and the PRNG key are *traced*
    arguments of ``scan`` — the whole (λ, ε)-grid shares one compile.
    Shapes, ``steps``, ``selection``, ``compress_topk`` and ``early_stop``
    are baked in.  With ``early_stop`` the scan is masked (DESIGN.md §9):
    the gap is a replicated scalar, so every device freezes its carry —
    local w/v̄/q̄/α shards, the EF-top-k residual and the PRNG key — on the
    same step, bit-for-bit, and the frozen steps' collectives exchange
    discarded values; ``gap_tol <= 0`` never triggers.
    """
    rows = _row_axes(mesh)
    b_sz = blocks_abs.csc_rows.shape[1]
    n, d = blocks_abs.shape
    n_pad, d_pad = blocks_abs.padded
    a_sz = blocks_abs.csc_rows.shape[0]
    n_loc, d_loc = n_pad // a_sz, d_pad // b_sz
    loss_fn = get_loss(loss)

    block_spec = P(rows, "model", None, None)
    blocks_spec = BlockSparse(csc_rows=block_spec, csc_vals=block_spec,
                              csr_cols=block_spec, csr_vals=block_spec,
                              shape=blocks_abs.shape, padded=blocks_abs.padded)

    # ---- setup: first-iteration dense pass (Alg 2 lines 8-14) -------------
    # Separable objectives fold the label into the residual (q̄ − y);
    # label-coupled ones carry the full row gradient in q̄ directly.
    def setup_body(blocks: BlockSparse, y_loc: jnp.ndarray):
        csr_c = blocks.csr_cols.reshape(n_loc, -1)     # (N_loc, Kr)
        csr_v = blocks.csr_vals.reshape(n_loc, -1)
        vbar0 = jnp.zeros((n_loc,), jnp.float32)
        if loss_fn.separable:
            qbar0 = loss_fn.split_grad(vbar0)
            resid_q = (qbar0 - y_loc) / n              # (N_loc,)
        else:
            qbar0 = loss_fn.grad(vbar0, y_loc)
            resid_q = qbar0 / n
        alpha_part = jnp.zeros((d_loc,), jnp.float32).at[csr_c.reshape(-1)].add(
            (resid_q[:, None] * csr_v).reshape(-1))
        alpha0 = jax.lax.psum(alpha_part, rows)
        return vbar0, qbar0, alpha0

    setup_sm = shard_map(
        setup_body, mesh=mesh, in_specs=(blocks_spec, P(rows)),
        out_specs=(P(rows), P(rows), P("model")), check_rep=False)

    # ---- scan: T iterations, (λ, em_scale, gap_tol, key) traced -----------
    # ``y_loc`` is the local row shard's labels — read only by label-coupled
    # objectives (dead for separable ones, whose programs are unchanged).
    def scan_body(blocks: BlockSparse, y_loc, vbar0, qbar0, alpha0,
                  lam, em_scale, gap_tol, key):
        csc_r = blocks.csc_rows.reshape(d_loc, -1)     # (D_loc, Kc)
        csc_v = blocks.csc_vals.reshape(d_loc, -1)
        csr_c = blocks.csr_cols.reshape(n_loc, -1)     # (N_loc, Kr)
        csr_v = blocks.csr_vals.reshape(n_loc, -1)
        my_b = jax.lax.axis_index("model")
        col_valid = (my_b * d_loc + jnp.arange(d_loc)) < d
        lam = jnp.asarray(lam, jnp.float32)
        em_scale = jnp.asarray(em_scale, jnp.float32)
        gap_tol = jnp.asarray(gap_tol, jnp.float32)

        def selection_fn(alpha, key_t):
            logits = jnp.where(col_valid, em_scale * jnp.abs(alpha), -jnp.inf)
            if selection == "gumbel":
                c_me = jax.scipy.special.logsumexp(logits)
                c_all = jax.lax.all_gather(c_me, "model", tiled=False)  # (B,)
                kg, km = jax.random.split(key_t)
                bw = jnp.argmax(c_all + jax.random.gumbel(kg, (b_sz,)))
                km = jax.random.fold_in(km, my_b)
                j_self = jnp.argmax(logits + jax.random.gumbel(km, (d_loc,)))
            else:
                c_me = jnp.max(logits)
                c_all = jax.lax.all_gather(c_me, "model", tiled=False)
                bw = jnp.argmax(c_all)
                j_self = jnp.argmax(logits)
            mine = (my_b == bw)
            j_loc = jax.lax.psum(jnp.where(mine, j_self, 0), "model")
            alpha_j = jax.lax.psum(jnp.where(mine, alpha[j_self], 0.0), "model")
            return mine, j_loc, alpha_j

        def iteration(carry, t_int):
            (w_loc, w_m, g_t, vbar, qbar, alpha, resid, key,
             done, stop_at) = carry
            t = t_int.astype(jnp.float32)
            old = (w_loc, w_m, g_t, vbar, qbar, alpha, resid, key)
            key_next, key_t = jax.random.split(key)
            mine, j_loc, alpha_j = selection_fn(alpha, key_t)

            # ---- Alg 2 lines 16-21 (replicated scalar math)
            d_tilde = jnp.where(alpha_j == 0, lam, -lam * jnp.sign(alpha_j))
            gap = g_t - d_tilde * alpha_j
            eta = 2.0 / (t + 2.0)
            w_m = w_m * (1.0 - eta)
            w_loc = jnp.where(
                mine, w_loc.at[j_loc].add(eta * d_tilde / w_m), w_loc)
            g_t = g_t * (1.0 - eta) + eta * d_tilde * alpha_j

            # ---- winner broadcasts its column's lanes over "model"
            rows_j = jnp.where(mine, csc_r[j_loc], 0)
            val_j = jnp.where(mine, csc_v[j_loc], 0.0)
            rows_j = jax.lax.psum(rows_j, "model")              # (Kc,)
            val_j = jax.lax.psum(val_j, "model")
            lane_ok = val_j != 0.0

            # ---- v̄/q̄ updates (replicated over model within each row shard)
            dv = jnp.where(lane_ok, eta * d_tilde * val_j / w_m, 0.0)
            vbar = vbar.at[rows_j].add(dv)
            margins = w_m * vbar[rows_j]
            hm = (loss_fn.split_grad(margins) if loss_fn.separable
                  else loss_fn.grad(margins, y_loc[rows_j]))
            gamma = jnp.where(lane_ok, hm - qbar[rows_j], 0.0)
            qbar = qbar.at[rows_j].add(gamma)

            # ---- α-shard delta from the touched rows' local columns
            gsc = gamma / n
            cols = csr_c[rows_j]                                # (Kc, Kr)
            vals = jnp.where(lane_ok[:, None], csr_v[rows_j], 0.0)
            delta = jnp.zeros((d_loc,), jnp.float32).at[cols.reshape(-1)].add(
                (gsc[:, None] * vals).reshape(-1))
            if compress_topk:
                resid = resid + delta
                k = compress_topk
                topv, topi = jax.lax.top_k(jnp.abs(resid), k)
                sent = resid[topi]
                resid = resid.at[topi].set(0.0)
                gi = jax.lax.all_gather(topi, rows, tiled=False)   # (R, k)
                gv = jax.lax.all_gather(sent, rows, tiled=False)
                delta_sum = jnp.zeros((d_loc,), jnp.float32).at[
                    gi.reshape(-1)].add(gv.reshape(-1))
            else:
                delta_sum = jax.lax.psum(delta, rows)
            alpha = alpha + delta_sum

            # ---- g̃ line 27: partial dots reduced over both axes
            dots = jnp.sum(vals * w_loc[cols], axis=1)          # (Kc,)
            g_dot = jax.lax.psum(jnp.sum(gsc * dots),
                                 rows + ("model",)) * w_m
            g_t = g_t + g_dot

            j_global = jax.lax.psum(
                jnp.where(mine, my_b * d_loc + j_loc, 0), "model")
            j_global = j_global.astype(jnp.int32)
            new = (w_loc, w_m, g_t, vbar, qbar, alpha, resid, key_next)
            if not early_stop:
                return new + (done, stop_at), (gap, j_global)
            # ---- §9 masked stopping: gap is replicated, so all devices
            # freeze the same step and the frozen lanes stay bit-identical.
            newly = jnp.logical_and(~done, jnp.logical_and(gap_tol > 0,
                                                           gap <= gap_tol))
            kept = jax.tree_util.tree_map(
                lambda o, fresh: jnp.where(done, o, fresh), old, new)
            out = (jnp.where(done, jnp.float32(0.0), gap),
                   jnp.where(done, -1, j_global))
            return kept + (jnp.logical_or(done, newly),
                           jnp.where(newly, t_int, stop_at)), out

        carry0 = (
            jnp.zeros((d_loc,), jnp.float32), jnp.float32(1.0),
            jnp.float32(0.0), vbar0, qbar0, alpha0,
            jnp.zeros((d_loc,), jnp.float32), key,
            jnp.asarray(False), jnp.asarray(0, jnp.int32),
        )
        ts = jnp.arange(1, steps + 1, dtype=jnp.int32)
        ((w_loc, w_m, *rest), (gaps, coords)) = jax.lax.scan(
            iteration, carry0, ts)
        done, stop_at = rest[-2], rest[-1]
        stop_step = jnp.where(done, stop_at, jnp.asarray(steps, jnp.int32))
        return w_loc * w_m, gaps, coords, stop_step

    scalar = P()
    scan_sm = shard_map(
        scan_body, mesh=mesh,
        in_specs=(blocks_spec, P(rows), P(rows), P(rows), P("model"),
                  scalar, scalar, scalar, scalar),
        out_specs=(P("model"), P(), P(), P()), check_rep=False)

    def whole(blocks, y_pad, lam, em_scale, gap_tol, key):
        return scan_sm(blocks, y_pad, *setup_sm(blocks, y_pad), lam, em_scale,
                       gap_tol, key)

    return DistFW(setup=jax.jit(setup_sm), scan=jax.jit(scan_sm),
                  whole=jax.jit(whole))


def distributed_fw(blocks: BlockSparse, y: jnp.ndarray, cfg: DistFWConfig,
                   mesh: Mesh):
    """Run T distributed FW iterations. y: (N_pad,) f32 padded with zeros.

    Returns (w, gaps, coords, stop_step) with w sharded over "model".
    """
    prog = build_dist_fw(blocks, mesh, steps=cfg.steps, loss=cfg.loss,
                         selection=cfg.selection,
                         compress_topk=cfg.compress_topk,
                         early_stop=cfg.gap_tol > 0)
    n = blocks.shape[0]
    return prog.whole(blocks, y, jnp.float32(cfg.lam),
                      jnp.float32(cfg.em_scale(n)),
                      jnp.float32(cfg.gap_tol),
                      jax.random.PRNGKey(cfg.seed))


def dist_fw_shardings(blocks_abs, mesh: Mesh):
    """NamedShardings matching build_dist_fw's block/label in_specs (dry-run)."""
    rows = _row_axes(mesh)
    bs = NamedSharding(mesh, P(rows, "model", None, None))
    return (
        BlockSparse(csc_rows=bs, csc_vals=bs, csr_cols=bs, csr_vals=bs,
                    shape=blocks_abs.shape, padded=blocks_abs.padded),
        NamedSharding(mesh, P(rows)),
    )
