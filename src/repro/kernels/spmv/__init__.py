from repro.kernels.spmv.ops import ell_matvec, ell_rmatvec  # noqa: F401
