"""Pallas TPU kernels for padded-ELL sparse mat-vec (X·w and Xᵀ·q).

Hardware adaptation (DESIGN.md §2): the paper's CSR loops are pointer-chasing
CPU code.  On TPU we tile the fixed-shape padded layout into VMEM:

  * ``matvec`` — grid over row tiles.  Each step holds an
    (TR, K) index/value tile plus the feature vector ``w`` in VMEM and runs a
    vectorized gather + lane reduction on the VPU.  ``w`` here is the
    *per-device feature shard* (D_shard = D / model-parallel degree); at the
    production mesh D_shard·4B ≈ 20M/256·4 ≈ 316 KB — comfortably inside the
    ~16 MB VMEM budget, which is exactly why the feature-sharded layout was
    chosen (launch/sharding.py "FW/LASSO" rules).

  * ``rmatvec`` — same row tiling, but the output is the D_shard-sized
    gradient accumulator.  TPU grid steps execute **sequentially**, so the
    read-modify-write scatter-add into the single output block is race-free;
    the block stays resident in VMEM across steps (same block index every
    step → Pallas does not flush it).

VMEM working set per step (f32, defaults TR=256, K=128, D_shard≤512K):
  tile idx+val 2·256·128·4 B = 256 KB, w/out ≤ 2 MB, total < 3 MB.
Block shapes keep the lane dim a multiple of 128 (VPU lane width) and the
sublane dim a multiple of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_TR = 256  # rows per tile (sublane-aligned: multiple of 8)


def _matvec_kernel(idx_ref, val_ref, w_ref, out_ref):
    idx = idx_ref[...]                      # (TR, K) int32
    val = val_ref[...]                      # (TR, K)
    w = w_ref[...]                          # (D,)
    out_ref[...] = jnp.sum(val * w[idx], axis=1)


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def ell_matvec_pallas(indices: jnp.ndarray, values: jnp.ndarray, w: jnp.ndarray,
                      *, tile_rows: int = DEF_TR, interpret: bool = True) -> jnp.ndarray:
    n, k = indices.shape
    tr = min(tile_rows, n)
    if n % tr:  # pad rows to a tile multiple (padding rows are all-zero lanes)
        pad = tr - n % tr
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        values = jnp.pad(values, ((0, pad), (0, 0)))
    np_, _ = indices.shape
    grid = (np_ // tr,)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, k), lambda i: (i, 0)),
            pl.BlockSpec((tr, k), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tr,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), values.dtype),
        interpret=interpret,
    )(indices, values, w)
    return out[:n]


def _rmatvec_kernel(idx_ref, val_ref, q_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    contrib = val_ref[...] * q_ref[...][:, None]        # (TR, K)
    flat_idx = idx_ref[...].reshape(-1)
    # sequential grid → accumulation into the resident output block is safe
    out_ref[...] = out_ref[...].at[flat_idx].add(contrib.reshape(-1))


@functools.partial(jax.jit, static_argnames=("d", "tile_rows", "interpret"))
def ell_rmatvec_pallas(indices: jnp.ndarray, values: jnp.ndarray, q: jnp.ndarray,
                       d: int, *, tile_rows: int = DEF_TR,
                       interpret: bool = True) -> jnp.ndarray:
    n, k = indices.shape
    tr = min(tile_rows, n)
    if n % tr:
        pad = tr - n % tr
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        values = jnp.pad(values, ((0, pad), (0, 0)))
        q = jnp.pad(q, (0, pad))
    np_, _ = indices.shape
    grid = (np_ // tr,)
    return pl.pallas_call(
        _rmatvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, k), lambda i: (i, 0)),
            pl.BlockSpec((tr, k), lambda i: (i, 0)),
            pl.BlockSpec((tr,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), values.dtype),
        interpret=interpret,
    )(indices, values, q)
