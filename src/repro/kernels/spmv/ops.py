"""Public jit'd wrappers for the padded-ELL sparse mat-vec kernels.

``interpret`` defaults to True (this container is CPU-only; TPU is the
target).  On a real TPU pass ``interpret=False`` — block shapes and the
sequential-grid accumulation pattern are already TPU-legal.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sparse.formats import PaddedCSR
from repro.kernels.spmv.kernel import ell_matvec_pallas, ell_rmatvec_pallas


def ell_matvec(X: PaddedCSR, w: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """X · w for a PaddedCSR design matrix via the Pallas row-tile kernel."""
    return ell_matvec_pallas(X.indices, X.values, w, interpret=interpret)


def ell_rmatvec(X: PaddedCSR, q: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Xᵀ · q via the Pallas sequential scatter-accumulate kernel."""
    return ell_rmatvec_pallas(X.indices, X.values, q, X.shape[1], interpret=interpret)
