"""Pure-jnp oracle for the padded-ELL sparse mat-vec kernels.

Semantics (shared with kernel.py):
  * ``indices``/``values`` are (N, K) — each row padded to K lanes with
    ``index = 0, value = 0`` (inert in sums, safe to gather).
  * ``matvec``:  out[i]  = Σ_k values[i,k] · w[indices[i,k]]        → (N,)
  * ``rmatvec``: out[j] += Σ_{i,k: indices[i,k]=j} values[i,k]·q[i] → (D,)
"""
from __future__ import annotations

import jax.numpy as jnp


def ell_matvec_ref(indices: jnp.ndarray, values: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("nk,nk->n", values, w[indices])


def ell_rmatvec_ref(indices: jnp.ndarray, values: jnp.ndarray, q: jnp.ndarray,
                    d: int) -> jnp.ndarray:
    contrib = values * q[:, None]
    return jnp.zeros((d,), values.dtype).at[indices.reshape(-1)].add(contrib.reshape(-1))
