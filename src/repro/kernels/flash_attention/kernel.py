"""Pallas TPU flash-attention forward (online softmax, causal/local masking).

Grid: (B·H, nq, nk) with the KV axis innermost — each (batch·head, q-block)
pair sweeps its KV blocks sequentially, carrying the online-softmax state
(running max m, normalizer l, accumulator acc) in VMEM scratch.  The output
block is written once, on the final KV step.

BlockSpecs / VMEM budget per step (defaults bq=bk=512, hd=128, f32):
  q (1, bq, hd) 256 KB · k/v (1, bk, hd) 256 KB each · acc scratch 256 KB
  → ~1 MB, well inside the ~16 MB/core budget; bq/bk are multiples of the
  (8, 128) f32 tile so the MXU sees aligned (bq×hd)·(hd×bk) matmuls.

GQA without KV duplication: the wrapper folds H = KV·G into the grid's head
axis and the k/v index_map divides by G, so each kv head's blocks are DMA'd
once per G consecutive head programs (Pallas revisits the same block without
re-fetching when the index is unchanged between steps).

Causal/local block skipping: blocks wholly above the diagonal (or beyond the
window) are masked via ``pl.when`` — the MXU work is skipped, mirroring
models/flash.py's fori-loop bounds (there by trip count, here by predication;
identical FLOPs-avoided accounting, see benchmarks/bench_attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                      *, scale, causal, window, bq, bk, nk):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level visibility: skip blocks fully masked out
    q_lo = iq * bq
    k_lo = ik * bk
    visible = True
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_lo + bq - 1)
    if window:
        visible = jnp.logical_and(visible, q_lo - (k_lo + bk - 1) < window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0].astype(jnp.float32)        # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k",
                                    "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = True):
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, "seq lens must divide block sizes"
    nq, nk = sq // bq, sk // bk

    # (B, S, H, hd) → (B·H, S, hd) head-major so the grid's first axis is bh
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)

    kernel = functools.partial(_flash_fwd_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            # GQA: kv-head index = (bh mod h) // g within batch (bh // h)
            pl.BlockSpec((1, bk, hd),
                         lambda bh, iq, ik: ((bh // h) * kv + (bh % h) // g, ik, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, iq, ik: ((bh // h) * kv + (bh % h) // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # normalizer l
            pltpu.VMEM((bq, hd), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
