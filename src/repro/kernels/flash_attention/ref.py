"""Pure-jnp oracle for the flash-attention Pallas kernel.

Naive materialized attention (O(S²) memory) — only run at test sizes.
GQA layout matches models/flash.py: q (B,Sq,H,hd), k/v (B,Sk,KV,hd) with
H = KV·G query heads per kv head.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, sq, kv, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(b, sq, h, hd).astype(q.dtype)
