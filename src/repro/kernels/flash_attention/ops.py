"""jit'd wrapper for the Pallas flash-attention forward.

Training uses models/flash.py (pure-JAX custom-VJP flash) because the Pallas
kernel here is forward-only; serving/prefill paths can swap this in via
``ModelConfig``-level dispatch.  Validated against ref.py across
shape/dtype/mask sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention_tpu(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    """GQA flash attention forward: q (B,S,H,hd), k/v (B,S,KV,hd)."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
