"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage is kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling) + ops.py (jit'd public wrapper) + ref.py (pure-jnp oracle), validated
in interpret mode (CPU container; TPU is the compile target):

  spmv/            padded-ELL X·w and Xᵀ·q — the paper's Alg-1/first-iteration
                   products, row-tiled with sequential-grid scatter-accumulate.
  coord_update/    fused Alg-2 inner loop (lines 22-28): one coordinate's
                   v̄/q̄/α/g̃ propagation in a single VMEM-resident sweep.
  bsls_draw/       Alg-4's sub-linear EM draw as big step (XLA, √D scan) +
                   little step (scalar-prefetch Pallas kernel that DMAs only
                   the winning group's row — O(√D) bytes per draw).
  flash_attention/ online-softmax attention forward for the LM-side archs
                   (GQA, causal/local), grid (B·H, nq, nk) with VMEM scratch.
"""
