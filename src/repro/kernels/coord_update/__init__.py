from repro.kernels.coord_update.ops import coord_update  # noqa: F401
