"""jit'd wrapper for the fused Frank-Wolfe coordinate-update kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.coord_update.kernel import coord_update_pallas


def coord_update(vbar, qbar, alpha, w, rows, x_col, mask, row_idx, row_val,
                 *, eta, d_tilde, w_m, inv_n, loss: str = "logistic",
                 y_col=None, interpret: bool = True):
    """Fused Alg-2 lines 22-28 for one selected coordinate.

    Returns (v̄', q̄', α', g̃_increment); the caller folds the increment into
    its running gap estimate (fw_jax step, line 27 analogue).  ``y_col`` is
    the selected column's labels, required when ``loss`` is label-coupled.
    """
    scalars = jnp.stack([
        jnp.asarray(eta, jnp.float32), jnp.asarray(d_tilde, jnp.float32),
        jnp.asarray(w_m, jnp.float32), jnp.asarray(inv_n, jnp.float32),
    ])
    return coord_update_pallas(vbar, qbar, alpha, w, rows, x_col, mask,
                               row_idx, row_val, scalars, y_col,
                               loss=loss, interpret=interpret)
