"""Pure-jnp oracle for the fused Alg-2 inner loop (paper lines 22-28).

One Frank-Wolfe coordinate update touches:
  v̄[rows]  += η·d̃·x_col/w_m                  (line 23; v = w_m·v̄ implicitly)
  γ[i]      = h(w_m·v̄[i]) − q̄[i]             (line 24, logistic h = σ)
  q̄[rows]  += γ                               (line 25)
  α         += (γ/N)ᵀ · X[rows, :]            (line 26, scatter over row nnz)
  g̃        += w_m · Σᵢ (γᵢ/N)·⟨X[i,:], w⟩    (line 27)

Inputs use the padded layouts: ``rows/x_col/mask`` are column j's (Kc,) rows
from the PaddedCSC; ``row_idx/row_val`` are those rows' (Kc, Kr) entries from
the PaddedCSR.  Padding lanes carry mask=False and value 0.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp


def coord_update_ref(
    vbar: jnp.ndarray, qbar: jnp.ndarray, alpha: jnp.ndarray, w: jnp.ndarray,
    rows: jnp.ndarray, x_col: jnp.ndarray, mask: jnp.ndarray,
    row_idx: jnp.ndarray, row_val: jnp.ndarray,
    *, eta: jnp.ndarray, d_tilde: jnp.ndarray, w_m: jnp.ndarray,
    inv_n: float, h: Callable = None, y_col: jnp.ndarray = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    import jax
    h = h or jax.nn.sigmoid
    dv = jnp.where(mask, eta * d_tilde * x_col / w_m, 0.0)
    vbar = vbar.at[rows].add(dv)
    margins = w_m * vbar[rows]
    # label-coupled objectives pass the column's labels: γ = grad(m, y) − q̄
    hm = h(margins) if y_col is None else h(margins, y_col)
    gamma = jnp.where(mask, hm - qbar[rows], 0.0)
    qbar = qbar.at[rows].add(gamma)
    contrib = (gamma * inv_n)[:, None] * row_val                 # (Kc, Kr)
    alpha = alpha.at[row_idx.reshape(-1)].add(contrib.reshape(-1))
    g_delta = w_m * jnp.sum((gamma * inv_n) * jnp.einsum("ck,ck->c", row_val, w[row_idx]))
    return vbar, qbar, alpha, g_delta
