"""Pallas TPU kernel: fused Frank-Wolfe coordinate update (paper Alg 2, l.22-28).

This is the paper's per-iteration hot loop — the O(S_r·S_c) sparse propagation
of one coordinate step through v̄, q̄, α and g̃ — fused into a single kernel so
the four scatter/gather passes XLA would emit (one per state vector) become
one VMEM-resident sweep.

Layout (per-device shard scale, DESIGN.md §5: rows sharded over "data",
features over "model"):
  * v̄/q̄ shards: N_shard ≤ 33K rows → 132 KB each in VMEM.
  * α/w shards:  D_shard ≤ 79K feats → 316 KB each in VMEM.
  * column tile: (TC,) row ids + (TC, Kr) row data.
Everything lives in VMEM for the whole sweep; the TPU grid is sequential, so
read-modify-write accumulation across column tiles is race-free (the same
trick kernels/spmv uses).  The scalar step state (η, d̃, w_m, 1/N) rides in
SMEM; the g̃ increment is accumulated in SMEM and added by the wrapper.

The per-row gradient map is a specialization point: each registered
objective gets its own lowered kernel (memoized per loss name).  Separable
objectives (``dL/dm = h(m) − y``) trace ``h`` alone; label-coupled ones take
the column's labels as an extra (TC,) tile and trace ``grad(m, y)``.

Padding convention: lanes with mask=0 carry row=0/value=0 and contribute
nothing (their dv and γ are forced to 0 before any scatter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.losses import get_objective

DEF_TC = 128  # column-tile lanes per grid step


@functools.lru_cache(maxsize=None)
def _build_kernel(loss: str):
    """Kernel body specialized to one objective's row-gradient map.

    Returns ``(kernel_fn, labeled)`` where ``labeled`` says whether the body
    expects the extra (TC,) label tile (label-coupled objectives).
    """
    obj = get_objective(loss)

    if obj.separable:
        h = obj.split_grad

        def kernel(scal_ref, rows_ref, xcol_ref, mask_ref, ridx_ref, rval_ref,
                   vbar_in, qbar_in, alpha_in, w_ref,
                   vbar_o, qbar_o, alpha_o, gd_o):
            t = pl.program_id(0)

            @pl.when(t == 0)
            def _init():
                vbar_o[...] = vbar_in[...]
                qbar_o[...] = qbar_in[...]
                alpha_o[...] = alpha_in[...]
                gd_o[0] = jnp.float32(0.0)

            eta, d_tilde, w_m, inv_n = (scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3])
            r = rows_ref[...]
            m = mask_ref[...].astype(bool)
            # line 23: v̄[rows] += η·d̃·x/w_m  (true margin change rides on w_m scale)
            dv = jnp.where(m, eta * d_tilde * xcol_ref[...] / w_m, 0.0)
            vb = vbar_o[...].at[r].add(dv)
            vbar_o[...] = vb
            # line 24: γ = h(w_m·v̄) − q̄   (h = split_grad; stale rows untouched)
            margins = w_m * vb[r]
            gamma = jnp.where(m, h(margins) - qbar_o[...][r], 0.0)
            # line 25
            qbar_o[...] = qbar_o[...].at[r].add(gamma)
            # line 26: α += (γ/N)·X[rows,:]  — scatter over the rows' nnz
            gscaled = gamma * inv_n
            contrib = gscaled[:, None] * rval_ref[...]
            alpha_o[...] = alpha_o[...].at[ridx_ref[...].reshape(-1)].add(contrib.reshape(-1))
            # line 27: g̃ += w_m·Σᵢ (γᵢ/N)·⟨X[i,:], w⟩
            dots = jnp.sum(rval_ref[...] * w_ref[...][ridx_ref[...]], axis=1)
            gd_o[0] += w_m * jnp.sum(gscaled * dots)

        return kernel, False

    grad = obj.grad

    def kernel(scal_ref, rows_ref, xcol_ref, mask_ref, ycol_ref, ridx_ref, rval_ref,
               vbar_in, qbar_in, alpha_in, w_ref,
               vbar_o, qbar_o, alpha_o, gd_o):
        t = pl.program_id(0)

        @pl.when(t == 0)
        def _init():
            vbar_o[...] = vbar_in[...]
            qbar_o[...] = qbar_in[...]
            alpha_o[...] = alpha_in[...]
            gd_o[0] = jnp.float32(0.0)

        eta, d_tilde, w_m, inv_n = (scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3])
        r = rows_ref[...]
        m = mask_ref[...].astype(bool)
        dv = jnp.where(m, eta * d_tilde * xcol_ref[...] / w_m, 0.0)
        vb = vbar_o[...].at[r].add(dv)
        vbar_o[...] = vb
        # line 24 (label-coupled): γ = grad(w_m·v̄, y) − q̄
        margins = w_m * vb[r]
        gamma = jnp.where(m, grad(margins, ycol_ref[...]) - qbar_o[...][r], 0.0)
        qbar_o[...] = qbar_o[...].at[r].add(gamma)
        gscaled = gamma * inv_n
        contrib = gscaled[:, None] * rval_ref[...]
        alpha_o[...] = alpha_o[...].at[ridx_ref[...].reshape(-1)].add(contrib.reshape(-1))
        dots = jnp.sum(rval_ref[...] * w_ref[...][ridx_ref[...]], axis=1)
        gd_o[0] += w_m * jnp.sum(gscaled * dots)

    return kernel, True


@functools.partial(jax.jit, static_argnames=("loss", "tile", "interpret"))
def coord_update_pallas(vbar, qbar, alpha, w, rows, x_col, mask, row_idx, row_val,
                        scalars, y_col=None, *, loss: str = "logistic",
                        tile: int = DEF_TC, interpret: bool = True):
    """Apply one fused coordinate update; returns (v̄', q̄', α', g̃-increment).

    ``scalars`` = f32[4] = [η, d̃, w_m, 1/N] (SMEM).  ``y_col`` is the
    selected column's (Kc,) labels — required for label-coupled objectives,
    ignored for separable ones.
    """
    kernel, labeled = _build_kernel(loss)
    if labeled and y_col is None:
        raise ValueError(f"loss {loss!r} is label-coupled; pass y_col")
    kc, kr = row_idx.shape
    tc = min(tile, kc)
    if kc % tc:
        pad = tc - kc % tc
        rows = jnp.pad(rows, (0, pad))
        x_col = jnp.pad(x_col, (0, pad))
        mask = jnp.pad(mask, (0, pad))
        if labeled:
            y_col = jnp.pad(y_col, (0, pad))
        row_idx = jnp.pad(row_idx, ((0, pad), (0, 0)))
        row_val = jnp.pad(row_val, ((0, pad), (0, 0)))
    kp = rows.shape[0]
    n, d = vbar.shape[0], alpha.shape[0]
    grid = (kp // tc,)
    full = lambda sz: pl.BlockSpec((sz,), lambda i: (0,))
    tile_specs = [
        pl.BlockSpec((tc,), lambda i: (i,)),             # rows
        pl.BlockSpec((tc,), lambda i: (i,)),             # x_col
        pl.BlockSpec((tc,), lambda i: (i,)),             # mask
    ]
    operands = [rows, x_col, mask.astype(jnp.int32)]
    if labeled:
        tile_specs.append(pl.BlockSpec((tc,), lambda i: (i,)))   # y_col
        operands.append(y_col)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # scalars
            *tile_specs,
            pl.BlockSpec((tc, kr), lambda i: (i, 0)),        # row_idx
            pl.BlockSpec((tc, kr), lambda i: (i, 0)),        # row_val
            full(n), full(n), full(d), full(d),              # v̄, q̄, α, w
        ],
        out_specs=[
            full(n), full(n), full(d),
            pl.BlockSpec(memory_space=pltpu.SMEM),           # g̃ increment
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), vbar.dtype),
            jax.ShapeDtypeStruct((n,), qbar.dtype),
            jax.ShapeDtypeStruct((d,), alpha.dtype),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, *operands, row_idx, row_val, vbar, qbar, alpha, w)
    vb, qb, al, gd = out
    return vb, qb, al, gd[0]
