"""Pure-jnp oracle for the two-level exponential-mechanism draw.

Given group log-sum-exps ``c`` (G,), member log-weights ``v`` (G, M) and two
Gumbel noise vectors, returns the flat index ``g·M + m`` where
``g = argmax(c + γ_g)`` and ``m = argmax(v[g] + γ_m)``.  Because
P(g) = softmax(c)_g and P(m|g) = softmax(v[g])_m, the flat draw is exactly
``j ~ softmax(v.flatten())`` (law of total probability) — the same law the
paper's Alg 4 samples.
"""
from __future__ import annotations

import jax.numpy as jnp


def two_level_draw_ref(c: jnp.ndarray, v: jnp.ndarray,
                       gumbel_g: jnp.ndarray, gumbel_m: jnp.ndarray) -> jnp.ndarray:
    g = jnp.argmax(c + gumbel_g)
    m = jnp.argmax(v[g] + gumbel_m)
    return (g * v.shape[1] + m).astype(jnp.int32)
