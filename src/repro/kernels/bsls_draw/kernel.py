"""Pallas TPU kernel for the Big-Step-Little-Step draw (two-level EM sample).

The paper's Alg 4 walks groups sequentially with a reservoir threshold — a
cache trick, not part of the sampled law (DESIGN.md §2).  The TPU form draws
the same distribution in two Gumbel-max scans:

  big step    g = argmax(c + γ_G)       over G = ⌈√D⌉ group masses
  little step m = argmax(v[g] + γ_M)    over the M = ⌈D/G⌉ members of group g

This kernel implements the *little step* with the canonical Pallas
scalar-prefetch pattern: the winning group id (computed from the tiny c
vector) is prefetched, and the BlockSpec ``index_map`` uses it to DMA **only
group g's row** of the (G, M) member table from HBM into VMEM — O(√D) bytes
moved per draw, never the full table.  That is the kernel-level realization
of the paper's sub-linear-per-iteration claim: selection cost is O(√D), not
O(D).

The big step runs in plain XLA in ops.py (c is √D floats — a single VPU
vector op; a kernel would add nothing).

VMEM per draw: one (1, M) row + one (1, M) noise row ≈ 2·√D·4 B (for the
paper's largest D = 20.2M: 2·4500·4 ≈ 36 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _little_step_kernel(g_ref, v_row_ref, noise_ref, out_ref):
    row = v_row_ref[...][0]        # (M,) — only group g's row was DMA'd in
    noise = noise_ref[...][0]      # (M,)
    m = jnp.argmax(row + noise).astype(jnp.int32)
    out_ref[0] = g_ref[0] * row.shape[0] + m


@functools.partial(jax.jit, static_argnames=("interpret",))
def little_step_pallas(g: jnp.ndarray, v: jnp.ndarray, noise: jnp.ndarray,
                       *, interpret: bool = True) -> jnp.ndarray:
    """Flat index of the member draw inside prefetched group ``g``.

    Args:
      g: () int32 — winning group from the big step.
      v: (G, M) member log-weights (padded with -inf past D).
      noise: (1, M) Gumbel noise for the little step.
    """
    _, m_sz = v.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[
            # index_map sees (grid ids..., *prefetch refs); pick row g.
            pl.BlockSpec((1, m_sz), lambda i, g_ref: (g_ref[0], 0)),
            pl.BlockSpec((1, m_sz), lambda i, g_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
    )
    return pl.pallas_call(
        _little_step_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=interpret,
    )(g.reshape(1).astype(jnp.int32), v, noise)[0]
