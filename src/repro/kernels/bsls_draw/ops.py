"""jit'd wrapper: one DP exponential-mechanism draw via big step (XLA) +
little step (Pallas scalar-prefetch kernel)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bsls_draw.kernel import little_step_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def two_level_draw(c: jnp.ndarray, v: jnp.ndarray, key: jax.Array,
                   *, interpret: bool = True) -> jnp.ndarray:
    """Draw ``j ~ softmax(v.flatten())`` via group-then-member Gumbel-max.

    Args:
      c: (G,) group log-sum-exps (big-step table).
      v: (G, M) member log-weights, padding = -inf.
      key: PRNG key; split into the two noise draws (O(√D) variates total,
        mirroring the paper's O(log D) threshold draws in spirit — sub-linear).
    """
    kg, km = jax.random.split(key)
    g = jnp.argmax(c + jax.random.gumbel(kg, c.shape, jnp.float32)).astype(jnp.int32)
    noise = jax.random.gumbel(km, (1, v.shape[1]), jnp.float32)
    return little_step_pallas(g, v, noise, interpret=interpret)
