from repro.kernels.bsls_draw.ops import two_level_draw  # noqa: F401
