"""Batched serving engine: continuous batching over a fixed-slot KV cache.

vLLM-style request lifecycle adapted to JAX's static-shape world:

  * a fixed number of **slots** (the decode batch dimension) hold in-flight
    requests; shapes never change, so the jitted prefill/decode steps compile
    once per (slot count, cache length) and are reused forever;
  * **prefill** runs one request at a time through ``lm_forward(last_only)``
    (chunk-padded to a bucket length to bound recompilation), then its KV
    state is *inserted* into the batched cache at the assigned slot;
  * **decode** steps all live slots together — one token per live request per
    step (inactive slots are masked);
  * finished requests (EOS or max_tokens) free their slot immediately; the
    scheduler admits the longest-waiting request first (FCFS), which bounds
    head-of-line latency.

The per-slot insertion uses the same position-indexed cache layout the models
define (`lm_init_cache`), so every architecture family (GQA / MLA latent /
mamba state / RG-LRU ring buffer) serves through one engine.

Production notes (DESIGN.md §5): the decode batch axis is sharded over
("pod","data"); caches follow launch/sharding.py's cache rules; the engine's
host loop is the single-controller view and each step is one pjit call.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (P,) int32 token ids
    max_new_tokens: int = 32
    eos_id: int = -1                   # -1 → never matches (length-capped)
    # filled by the engine
    generated: Optional[List[int]] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def done(self) -> bool:
        if self.generated is None:
            return False
        return (len(self.generated) >= self.max_new_tokens
                or (self.eos_id >= 0 and self.eos_id in self.generated))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8                     # decode batch size (compiled once)
    max_len: int = 2048                # cache capacity per slot
    prefill_bucket: int = 256          # prompts padded up to a multiple
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


class ServingEngine:
    """Single-controller continuous-batching engine over a ModelAPI."""

    def __init__(self, api, params, config: ServeConfig):
        self.api = api
        self.params = params
        self.cfg = config
        self.cache = api.init_cache(config.slots, config.max_len)
        # Families lay caches out differently (stacked (L, B, ...) vs per-layer
        # lists with (B, ...) leaves).  Detect each leaf's slot axis once by
        # diffing abstract cache shapes at two batch sizes — fully
        # model-agnostic, no allocation (eval_shape).
        s2 = jax.eval_shape(lambda: api.init_cache(2, config.max_len))
        s3 = jax.eval_shape(lambda: api.init_cache(3, config.max_len))

        def slot_axis(a, b):
            diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
            if len(diffs) != 1:
                raise ValueError(f"ambiguous slot axis for cache leaf {a.shape}")
            return diffs[0]

        self.slot_axes = jax.tree.map(slot_axis, s2, s3)
        self.pos = np.zeros(config.slots, np.int32)        # next write index
        self.live: List[Optional[Request]] = [None] * config.slots
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(config.seed)
        self.steps = 0
        self.prefills = 0

        # jit once; shapes are static per bucket
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._prefill_fns: Dict[int, Callable] = {}

    # ------------------------------------------------------------------ public
    def submit(self, req: Request) -> None:
        req.submitted_at = time.time()
        req.generated = []
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue and slots drain; returns finished requests."""
        finished: List[Request] = []
        for _ in range(max_steps):
            self._admit()
            if not any(r is not None for r in self.live):
                if not self.queue:
                    break
                continue
            self._step(finished)
        return finished

    # ------------------------------------------------------------------ internals
    def _admit(self) -> None:
        for slot in range(self.cfg.slots):
            if self.live[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self._prefill_into_slot(req, slot)
            self.live[slot] = req

    def _bucket(self, n: int) -> int:
        b = self.cfg.prefill_bucket
        return min(((n + b - 1) // b) * b, self.cfg.max_len)

    def _prefill_into_slot(self, req: Request, slot: int) -> None:
        """Run the prompt through decode steps into this slot's cache rows.

        Uses a scanned multi-token pass (token-parallel prefill is the
        models' ``forward``; cache-writing prefill reuses ``decode_step`` so
        every family's cache layout is handled uniformly).  Bucketed to bound
        compile count.
        """
        p = len(req.prompt)
        bucket = self._bucket(p)
        toks = np.zeros(bucket, np.int32)
        toks[:p] = req.prompt
        if bucket not in self._prefill_fns:
            self._prefill_fns[bucket] = jax.jit(
                self._prefill_impl, static_argnums=(3,), donate_argnums=(1,))
        fn = self._prefill_fns[bucket]
        slot_cache = jax.tree.map(
            lambda c, ax: jax.lax.slice_in_dim(c, slot, slot + 1, axis=ax),
            self.cache, self.slot_axes)
        logits, slot_cache = fn(self.params, slot_cache, jnp.asarray(toks[None, :]),
                                bucket)
        # merge slot cache back
        self.cache = jax.tree.map(
            lambda full, part, ax: jax.lax.dynamic_update_slice_in_dim(
                full, part.astype(full.dtype), slot, ax),
            self.cache, slot_cache, self.slot_axes)
        self.pos[slot] = p
        # first generated token from the last prompt logit
        last = np.asarray(logits[0, p - 1 if p <= bucket else -1])
        req.generated.append(int(np.argmax(last)))
        self.prefills += 1

    def _prefill_impl(self, params, cache, tokens, bucket: int):
        """Sequential cache-filling prefill: scan decode_step over positions."""
        def body(cache, i):
            tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
            logits, cache = self.api.decode_step(params, cache, tok, i)
            return cache, logits[:, 0]
        cache, logits = jax.lax.scan(body, cache, jnp.arange(bucket))
        return jnp.moveaxis(logits, 0, 1), cache                # (1, bucket, V)

    def _decode_impl(self, params, cache, tokens, pos):
        """One batched decode step at per-slot positions.

        Per-slot positions require per-slot cache indexing; the models'
        ``decode_step`` takes a scalar pos, so the engine vmaps it over each
        leaf's detected slot axis (each slot is an independent 1-row batch —
        vmap re-inserts the batch dim the model expects).
        """
        def one(params, cache_row, tok, p):
            expanded = jax.tree.map(
                lambda c, ax: jnp.expand_dims(c, ax), cache_row, self.slot_axes)
            logits, new_cache = self.api.decode_step(params, expanded, tok[None, :], p)
            return logits[0], jax.tree.map(
                lambda c, ax: jnp.squeeze(c, ax), new_cache, self.slot_axes)

        logits, cache = jax.vmap(one, in_axes=(None, self.slot_axes, 0, 0),
                                 out_axes=(0, self.slot_axes))(
            params, cache, tokens, pos)
        return logits, cache

    def _step(self, finished: List[Request]) -> None:
        toks = np.zeros((self.cfg.slots, 1), np.int32)
        pos = np.zeros(self.cfg.slots, np.int32)
        for s, req in enumerate(self.live):
            if req is not None:
                toks[s, 0] = req.generated[-1]
                pos[s] = self.pos[s]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks), jnp.asarray(pos))
        logits = np.asarray(logits[:, 0], np.float32)           # (slots, V)
        self.steps += 1
        for s, req in enumerate(self.live):
            if req is None:
                continue
            self.pos[s] += 1
            if self.cfg.greedy:
                nxt = int(np.argmax(logits[s]))
            else:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[s]) / self.cfg.temperature))
            req.generated.append(nxt)
            if req.done or self.pos[s] >= self.cfg.max_len - 1:
                req.finished_at = time.time()
                finished.append(req)
                self.live[s] = None
