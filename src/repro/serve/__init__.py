from repro.serve.engine import ServeConfig, ServingEngine  # noqa: F401
from repro.serve.fit_service import (FitRequest, FitService,  # noqa: F401
                                     FitServiceConfig)
