"""DP-LASSO fit service: slot-based request/response engine over solve_many.

The LM side of the repo serves tokens through ``serve/engine.py``; this is
the same lifecycle — **submit → admit → batch → drain** — applied to the
paper's workload: multi-tenant DP-LASSO fit requests against one resident
design matrix (the hyperparameter-sweep traffic pattern of Khanna et al.).

  * **submit** queues a ``FitRequest`` (tenant + FWConfig);
  * **admit** resolves the request's queue, and for private queues charges
    the tenant's ``PrivacyAccountant`` *before* any compute — a request
    whose tenant budget (or tenant) is missing/exhausted is refused, never
    run, and never charged.  The charge is denominated in the accountant's
    own step currency: a request running T_req selections at its own
    (ε_req, δ) consumes ``ceil(T_req · (ε'_req/ε'_acct)²)`` tenant steps
    (= ``T_acct · (ε_req/ε_acct)²`` at matching δ), so under advanced
    composition the pool bounds the tenant's *actual* ε loss no matter what
    per-request (ε, T) mix arrives; requests with a weaker δ than the
    accountant's are refused outright;
  * **batch** packs admitted requests into sweep groups (``batched.group_key``)
    and chops each group to at most ``slots`` configs — the compiled-batch
    width, directly analogous to the serving engine's decode-slot count;
  * **drain** runs each slot-batch through ``solve_many`` (one shared setup
    + compiled scan per ``jax_sparse`` batch, scheduled by the §9 planner —
    cohort-chunked with retirement when requests carry ``gap_tol``;
    ``jax_shard`` batches share one setup + compiled scan on their mesh).
    Each backend's data layout is coerced once per service lifetime — the
    service owns the ``prepared`` cache ``solve_many`` fills — so
    per-request ``backend=`` selection (e.g. a ``jax_shard`` scale-out fit
    next to ``jax_sparse`` traffic) costs no repeated conversions and
    changes nothing about ε-accounting: admission charges by the *resolved*
    queue name, whatever engine realizes it.

Per-request planning (DESIGN.md §9): a request may submit
``backend="auto"`` — admission resolves it through the cost-model planner
against the resident dataset's shape statistics *before* queue resolution,
so grouping, slot packing and ε-charging all see a concrete backend.
Early-stopping requests (``gap_tol``/``max_seconds``) are admitted and
charged exactly like fixed-T ones: DP budget is charged up-front for the
requested T (stopping early never refunds — the noise draws past the stop
are simply never consumed, which only *under*-uses the charged budget).

Everything is synchronous single-controller, like ``ServingEngine``: the
host loop is the scheduler, each drained batch is one XLA program.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Mapping, Optional

from repro import obs
from repro.core.dp.accountant import PrivacyAccountant, per_step_epsilon
from repro.core.solvers.batched import group_key, solve_many
from repro.core.solvers.config import (FWConfig, FWResult,
                                       check_gap_certificate)
from repro.core.solvers.registry import (check_path_support,
                                         check_screening_support, get_backend,
                                         resolve_queue)
from repro.obs.ledger import AuditLedger
from repro.obs.metrics import quantile

# Native queue/selection names that consume privacy budget (the DP
# exponential mechanism and report-noisy-max realizations, per backend).
PRIVATE_QUEUES = frozenset({"bsls", "two_level", "gumbel", "noisy_max"})


@dataclasses.dataclass
class FitRequest:
    uid: int
    tenant: str
    config: FWConfig
    # filled by the service
    status: str = "queued"            # queued | done | rejected | failed
    reason: str = ""                  # set when rejected/failed
    result: Optional[FWResult] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return max(self.finished_at - self.submitted_at, 0.0)


@dataclasses.dataclass(frozen=True)
class FitServiceConfig:
    slots: int = 8                    # max configs per compiled batch
    # ε-spend audit trail (DESIGN.md §12): None keeps the ledger in-memory
    # only; a path appends every charge/refusal as JSONL (and a restarted
    # service continues the same file)
    ledger_path: Optional[str] = None


class FitService:
    """Multi-tenant DP-LASSO fitting over one resident (X, y) dataset."""

    def __init__(self, X, y=None,
                 accountants: Optional[Mapping[str, PrivacyAccountant]] = None,
                 config: FitServiceConfig = FitServiceConfig()):
        if config.slots < 1:
            raise ValueError("slots must be >= 1")
        # Resolve the data source once and coerce each backend layout once
        # per service lifetime: ``self._coerced`` is the caller-owned cache
        # ``solve_many`` fills lazily (padded is pre-warmed here — the common
        # case), so no request ever re-pays a conversion.  Keeping the
        # *resolved source* (not just one coerced layout) is what lets a
        # per-request ``backend=`` choose its own layout — a jax_shard
        # request against a DatasetStore maps shards onto BlockSparse blocks
        # through the store's content-hash-guarded block cache, while
        # jax_sparse requests keep the PreparedDataset padded/setup caches
        # (both persist across service restarts via the store's cache/ dir).
        from repro.core.solvers.registry import as_padded, resolve_data
        X, y = resolve_data(X, y)
        self._source = X
        self._coerced: Dict[str, object] = {"padded": as_padded(X)}
        self.X = self._coerced["padded"]   # kept for introspection/back-compat
        self.y = y
        self._stats = None                 # planner ProblemStats, lazy (§9)
        self.accountants: Dict[str, PrivacyAccountant] = dict(accountants or {})
        self.cfg = config
        self.queue: List[FitRequest] = []
        self.finished: List[FitRequest] = []
        self.batches_run = 0
        self.batch_sizes: List[int] = []
        self.serving_s = 0.0              # wall-clock actually spent draining
        # the ε-spend audit trail: every accountant's attach state is the
        # base of its replay chain (pre-spent budgets audit cleanly)
        self.ledger = AuditLedger(config.ledger_path)
        for tenant, acct in sorted(self.accountants.items()):
            self.ledger.open_tenant(tenant, acct)

    # ------------------------------------------------------------------ public
    def submit(self, req: FitRequest) -> None:
        req.submitted_at = time.time()
        req.status = "queued"
        self.queue.append(req)
        obs.count("service.submitted", tenant=req.tenant)
        obs.gauge("service.queue_depth", len(self.queue))

    def run(self) -> List[FitRequest]:
        """Drain the queue; returns every request (done/rejected/failed)."""
        with obs.span("service.run", queued=len(self.queue)):
            admitted = [r for r in self.queue if self._admit(r)]
            rejected = [r for r in self.queue if r.status == "rejected"]
            self.queue = []
            obs.gauge("service.queue_depth", 0)
            for batch in self._pack(admitted):
                self._drain(batch)
        done = sorted(admitted + rejected, key=lambda r: r.uid)
        for r in done:
            obs.count("service.finished", status=r.status)
            if r.status == "done":
                obs.observe("service.latency_s", r.latency_s)
        self.finished.extend(done)
        return done

    def stats(self) -> dict:
        """Per-request latency + throughput + per-tenant accountant state."""
        done = [r for r in self.finished if r.status == "done"]
        lat = [r.latency_s for r in done]
        return {
            "requests": len(self.finished),
            "done": len(done),
            "rejected": sum(r.status == "rejected" for r in self.finished),
            "failed": sum(r.status == "failed" for r in self.finished),
            "batches": self.batches_run,
            "batch_sizes": list(self.batch_sizes),
            "queue_depth": len(self.queue),
            # interpolated order statistics (shared obs helper) — the old
            # lat[len(lat)//2] midpoint was not a p50 on even-length samples
            "latency_s": {
                "p50": quantile(lat, 0.50),
                "p90": quantile(lat, 0.90),
                "p99": quantile(lat, 0.99),
                "max": max(lat) if lat else 0.0,
            },
            # over drain time only — idle wall-clock between run() calls is
            # not serving time
            "throughput_fits_per_s": (
                len(done) / self.serving_s if self.serving_s > 0 else 0.0),
            "tenants": {
                t: {"spent_steps": a.spent_steps,
                    "remaining_steps": a.remaining_steps,
                    "spent_epsilon": a.spent_epsilon()}
                for t, a in self.accountants.items()},
        }

    def verify_ledger(self) -> Dict[str, dict]:
        """Audit the ε-spend ledger against the live accountants (exact —
        raises on any drift; see ``AuditLedger.verify``)."""
        return self.ledger.verify(self.accountants)

    def checkpoint_accountants(self, directory: str) -> str:
        """Snapshot accountant state via ``repro.checkpoint`` so a restart
        resumes from audited spend (pair with ``config.ledger_path``)."""
        return self.ledger.checkpoint(directory, self.accountants)

    # --------------------------------------------------------------- internals
    def _planned_backend(self, cfg: FWConfig) -> str:
        """Cost-model backend choice against the resident dataset.

        Stats come from the resolved *source* — for a ``DatasetStore`` that
        is O(1) manifest metadata (cached per content hash by the planner),
        so admissions never re-derive shape facts from the coerced padded
        pair, let alone materialize anything."""
        from repro.core.solvers.planner import choose_backend, data_stats
        if self._stats is None:
            self._stats = data_stats(self._source)
        return choose_backend(self._stats, cfg)

    def _admit(self, req: FitRequest) -> bool:
        """Validate the config, resolve the queue, and charge the tenant for
        private fits.  Refusals leave the accountant untouched (spend is
        atomic — it raises before mutating), and a request is only charged
        once it can no longer fail validation."""
        try:
            cfg = req.config
            if cfg.backend == "auto":                # §9 per-request planning
                cfg = dataclasses.replace(
                    cfg, backend=self._planned_backend(cfg))
            backend = get_backend(cfg.backend)
            if (cfg.max_seconds is not None
                    and not backend.supports_max_seconds):
                # the backend adapter would raise this at drain time — after
                # the charge, and failing its whole batch; refuse here,
                # charge-free, instead
                raise ValueError(
                    f"backend {backend.name!r} runs as one compiled scan "
                    "and cannot enforce max_seconds; use gap_tol or a "
                    "chunked backend")
            # §13: bad screening knobs and engines without a mutable-geometry
            # chunk loop are refused here, charge-free, not at drain time
            if cfg.screen_every:
                from repro.core.solvers.screening import check_screen_config
                check_screen_config(cfg)
            check_screening_support(backend, cfg)
            # §14: malformed λ-paths and engines without a re-enterable
            # chunked driver — same contract: refuse before any charge
            if cfg.lambdas is not None:
                from repro.core.solvers.path import check_path_config
                check_path_config(cfg)
            check_path_support(backend, cfg)
            resolved = resolve_queue(backend, cfg)
            # unknown loss -> KeyError; gap_tol on a non-smooth objective ->
            # ValueError — both refused here, before any budget is charged
            check_gap_certificate(resolved)
        except (ValueError, KeyError) as e:
            return self._reject(req, str(e))
        req.config = resolved
        # effective selection rule: the dense adapter runs `queue` when one
        # was given, falling back to `selection` only for queue=None
        if resolved.queue is not None:
            effective = resolved.queue
        elif backend.name == "dense":
            effective = resolved.selection
        else:
            effective = None
        if effective in PRIVATE_QUEUES:
            acct = self.accountants.get(req.tenant)
            if acct is None:
                return self._reject(
                    req, f"tenant {req.tenant!r} has no privacy budget")
            try:
                # bad (ε, δ, T) raise here, BEFORE the budget is touched —
                # a config the solver would choke on must never be charged
                steps = self._charged_steps(acct, resolved)
                before = AuditLedger.state_of(acct)
                acct.spend(steps)
            except (RuntimeError, ValueError) as e:
                return self._reject(req, str(e))
            self.ledger.charge(
                tenant=req.tenant, uid=req.uid, steps=steps, before=before,
                acct=acct, request=self._request_facts(resolved))
        obs.count("service.admitted", tenant=req.tenant)
        return True

    @staticmethod
    def _request_facts(cfg: FWConfig) -> dict:
        """The request facts a later audit needs to interpret a charge.

        Must never raise, even on invalid configs — the refusal path records
        these same facts — so screening contributes only its raw knobs, never
        derived ``screen_plan`` quantities (whose math refuses bad fracs).
        """
        facts = {"epsilon": cfg.epsilon, "delta": cfg.delta,
                 "steps": cfg.steps, "queue": cfg.queue,
                 "backend": cfg.backend, "loss": cfg.loss}
        if cfg.screen_every:
            facts["screen_every"] = cfg.screen_every
            facts["screen_eps_frac"] = cfg.screen_eps_frac
        if cfg.lambdas is not None:
            # raw λ-sequence only — the derived PathPlan refuses malformed
            # paths, and refusals must record facts without raising
            facts["lambdas"] = [float(l) for l in cfg.lambdas]
        return facts

    @staticmethod
    def _charged_steps(acct: PrivacyAccountant, cfg: FWConfig) -> int:
        """Tenant steps consumed by a fit running T_req selections at its own
        per-step rate ε'_req = ε_req/√(8·T_req·log(1/δ)).

        The accountant's pool is T_acct steps at rate ε'_acct; under advanced
        composition ε grows as ε'·√k, so equal-ε-budget accounting charges
        ``T_req · (ε'_req/ε'_acct)²`` pool steps (the 1e-9 absorbs float slop
        before ceil).  A request with δ weaker than the accountant's is not
        expressible in its currency and is refused.

        §13 screening splits the request ε: the T EM selections run at the
        solve share ε·(1 − screen_eps_frac), and each of the R planned
        screening rounds is one extra advanced-composition query at
        ε_round = ε·frac/√(8R·log(1/δ)) — both priced in the same pool-step
        currency and charged up-front at admission (a screen that never
        fires, like a stop before T, under-uses the charge; never refunds).
        """
        if cfg.delta > acct.delta * (1.0 + 1e-12):
            raise ValueError(
                f"request δ={cfg.delta:g} is weaker than the tenant "
                f"accountant's δ={acct.delta:g}")
        if cfg.lambdas is not None:
            # §14: a path runs T_total = Σ budgets selections at the single
            # uniform rate ε' = ε/√(8·T_total·log(1/δ)).  T·ε'² is T-free,
            # so this prices identically to a plain solve at the same ε —
            # kept explicit so the charge derives from the plan the drivers
            # execute, not from a coincidence of algebra.  Screening is
            # refused with paths at admission, so there is no rounds term.
            from repro.core.solvers.path import path_plan
            pplan = path_plan(cfg, private=True)
            ratio = pplan.eps_per_step / acct.per_step
            return max(1, math.ceil(pplan.total_steps * ratio * ratio - 1e-9))
        from repro.core.solvers.screening import screen_plan
        plan = screen_plan(cfg, private=True)
        eps_req_step = per_step_epsilon(plan.eps_solve, cfg.delta, cfg.steps)
        ratio = eps_req_step / acct.per_step
        charged = max(1, math.ceil(cfg.steps * ratio * ratio - 1e-9))
        if plan.rounds:
            sratio = plan.eps_round / acct.per_step
            charged += max(1, math.ceil(plan.rounds * sratio * sratio - 1e-9))
        return charged

    def _reject(self, req: FitRequest, reason: str) -> bool:
        req.status, req.reason = "rejected", reason
        req.finished_at = time.time()
        # every refusal is a ledger fact: charge-free, with the tenant's
        # (unchanged) accountant state attested when one exists
        self.ledger.refusal(tenant=req.tenant, uid=req.uid, reason=reason,
                            acct=self.accountants.get(req.tenant),
                            request=self._request_facts(req.config))
        obs.count("service.rejected", tenant=req.tenant)
        return False

    def _pack(self, admitted: List[FitRequest]) -> List[List[FitRequest]]:
        """Group compatible configs, then chop each group to ``slots``."""
        groups: Dict[tuple, List[FitRequest]] = {}
        for r in admitted:
            groups.setdefault(group_key(r.config), []).append(r)
        batches = []
        for members in groups.values():
            for i in range(0, len(members), self.cfg.slots):
                batches.append(members[i:i + self.cfg.slots])
        return batches

    def _drain(self, batch: List[FitRequest]) -> None:
        t0 = time.time()
        try:
            with obs.span("service.batch", size=len(batch),
                          backend=batch[0].config.backend):
                results = solve_many(self._source, self.y,
                                     [r.config for r in batch],
                                     prepared=self._coerced)
        except Exception as e:  # noqa: BLE001 — one bad batch must not
            # strand the rest of the queue.  The charged budget is NOT
            # refunded: admission cannot prove how far the mechanism got
            # before failing, and DP accounting must stay conservative.
            now = time.time()
            obs.count("service.batch_failures")
            for req in batch:
                req.status = "failed"
                req.reason = f"solver error: {e}"
                req.finished_at = now
            self.serving_s += now - t0
            return
        now = time.time()
        for req, res in zip(batch, results):
            req.result = res
            req.status = "done"
            req.finished_at = now
        self.serving_s += now - t0
        self.batches_run += 1
        self.batch_sizes.append(len(batch))
