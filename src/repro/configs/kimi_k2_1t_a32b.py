"""kimi-k2-1t-a32b [arXiv:2501.kimi2; unverified] — trillion-param MoE.

Per the assignment's paper-table spec: 61L, d_model 7168, 64 heads with GQA
kv=8, 384 routed experts top-8 with expert d_ff 2048 (+1 shared expert and a
dense first layer with d_ff 18432, following the K2 lineage).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=18_432,          # leading dense layer
    vocab=163_840,
    head_dim=112,         # d_model / n_heads
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=1,
    act="swiglu",
    rope_theta=50_000.0,
    optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, n_experts=4, n_shared_experts=1, top_k=2,
    moe_d_ff=32, first_dense_layers=1, dtype="float32",
)
