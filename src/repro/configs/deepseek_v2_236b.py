"""deepseek-v2-236b [arXiv:2405.04434; hf] — MLA (kv_lora=512, q_lora=1536)
+ MoE: 160 routed experts top-6 with 2 shared experts, expert d_ff=1536.
First layer uses a dense FFN (d_ff=12288), per the HF config.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,       # MLA: per-head k/v up-projected from the latent
    d_ff=12_288,          # the single leading dense layer
    vocab=102_400,
    head_dim=128,         # qk nope dims
    use_mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    act="swiglu",
    rope_theta=10_000.0,
    optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=16, kv_lora=32, q_lora=48, rope_head_dim=8,
    v_head_dim=16, n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=32,
    first_dense_layers=1, dtype="float32",
)
