"""seamless-m4t-medium [arXiv:2308.11596; hf] — enc-dec multimodal backbone.

12 encoder + 12 decoder layers, d_model 1024, 16 heads (MHA), d_ff 4096,
vocab 256206.  The audio frontend is a stub per the brief: ``input_specs``
supplies precomputed frame embeddings (B, S_enc, d_model).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,          # 12 enc + 12 dec
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256_206,
    head_dim=64,
    act="gelu",
    rope_theta=10_000.0,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, enc_layers=2, dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, dtype="float32",
)
