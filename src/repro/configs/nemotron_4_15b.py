"""nemotron-4-15b [arXiv:2402.16819; unverified] — GQA kv=8, squared-ReLU MLP."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab=256_000,
    head_dim=128,
    act="relu2",
    rope_theta=10_000.0,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=256, head_dim=24, dtype="float32",
)
