"""minicpm-2b [arXiv:2404.06395; hf] — llama-like arch trained with the WSD
(warmup-stable-decay) schedule; the trainer's ``wsd`` schedule reproduces it."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,      # MHA (kv = heads)
    d_ff=5760,
    vocab=122_753,
    head_dim=64,
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=72, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=18, dtype="float32",
)
