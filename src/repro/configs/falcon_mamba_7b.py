"""falcon-mamba-7b [arXiv:2410.05355; unverified] — attention-free Mamba-1.

d_inner = 2·d_model, ssm_state = 16, dt_rank = d_model/16 = 256, conv 4.
Sub-quadratic → runs the long_500k cell.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65_024,
    ssm_state=16,
    d_inner=8192,
    conv_kernel=4,
    dt_rank=256,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, d_inner=128, dt_rank=8, vocab=256,
    dtype="float32",
)
