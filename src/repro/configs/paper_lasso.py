"""The paper's own workloads — DP LASSO logistic regression datasets (Table 2)
and hyperparameters (§4: T=4000, λ=50 speed runs; T=400000, λ=5000 accuracy
runs; ε ∈ {1, 0.1}, δ = 1/N²).

Real files are not available offline; ``repro.data.synthetic`` generates
sparse design matrices matched to each dataset's (N, D, nnz/row) so the
benchmark harness reproduces the paper's tables at selectable scale.
"""
import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class LassoDataset:
    name: str
    n: int
    d: int
    nnz_per_row: float     # average S_c (public dataset statistics)
    informative: int       # features carrying signal in the synthetic twin
    dense_features: int = 0  # URL-style dense informative block


DATASETS: Dict[str, LassoDataset] = {
    "rcv1": LassoDataset("rcv1", 20_242, 47_236, 73.2, 512),
    "news20": LassoDataset("news20", 19_996, 1_355_191, 454.9, 1024),
    "url": LassoDataset("url", 2_396_130, 3_231_961, 115.6, 512, dense_features=200),
    "web": LassoDataset("web", 350_000, 16_609_143, 3727.7, 1024),
    "kdda": LassoDataset("kdda", 8_407_752, 20_216_830, 36.3, 512),
}


@dataclasses.dataclass(frozen=True)
class PaperRun:
    lam: float
    steps: int
    epsilon: float
    delta_rule: str = "1/n^2"


SPEED_RUN = PaperRun(lam=50.0, steps=4000, epsilon=1.0)
SPEED_RUN_HIGH_PRIVACY = PaperRun(lam=50.0, steps=4000, epsilon=0.1)
ACCURACY_RUN = PaperRun(lam=5000.0, steps=400_000, epsilon=0.1)

CONFIG = {
    "datasets": DATASETS,
    "speed": SPEED_RUN,
    "speed_high_privacy": SPEED_RUN_HIGH_PRIVACY,
    "accuracy": ACCURACY_RUN,
}

# CPU-runnable reduced twin (same generator, smaller N/D) for tests/benches.
SMOKE = {
    "rcv1": LassoDataset("rcv1-smoke", 2000, 4000, 40.0, 64),
    "news20": LassoDataset("news20-smoke", 1000, 20_000, 100.0, 128),
    "url": LassoDataset("url-smoke", 4000, 8000, 30.0, 64, dense_features=20),
}
