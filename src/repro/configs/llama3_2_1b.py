"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B; unverified] — small llama3."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128_256,
    head_dim=64,
    act="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, dtype="float32",
)
