"""recurrentgemma-2b [arXiv:2402.19427; hf] — Griffin: RG-LRU + local attention.

Pattern (recurrent, recurrent, local-attn) tiled over 26 layers; MQA (kv=1),
head_dim 256, window 2048, GeGLU d_ff 7680, lru width = d_model.
Sub-quadratic (window-bounded attention) → runs the long_500k cell.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256_000,
    head_dim=256,
    act="geglu",
    window=2048,
    layer_pattern="rra",
    d_rnn=2560,
    conv_kernel=4,
    emb_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab=256, head_dim=32, d_rnn=64, window=32, dtype="float32",
)
