"""chameleon-34b [arXiv:2405.09818; unverified] — early-fusion VLM backbone.

Images enter as discrete VQ tokens inside the 65536-entry vocabulary, so the
backbone is a dense llama-style LM with qk-norm (Chameleon's stability fix);
the VQ tokenizer frontend is a stub per the assignment brief.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab=65_536,
    head_dim=128,
    qk_norm=True,
    act="swiglu",
    rope_theta=10_000.0,
    optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, dtype="float32",
)
