"""tinyllama-1.1b [arXiv:2401.02385; hf] — llama2-arch small, GQA kv=4."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32_000,
    head_dim=64,
    act="swiglu",
    rope_theta=10_000.0,
    optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=256, head_dim=16, dtype="float32",
)
