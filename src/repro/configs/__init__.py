"""Exact published configs for the assigned architectures (+ the paper's own
LASSO workloads).  One module per arch; ``get_config(name)`` resolves ids."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "seamless-m4t-medium",
    "falcon-mamba-7b",
    "llama3.2-1b",
    "minicpm-2b",
    "tinyllama-1.1b",
    "nemotron-4-15b",
    "chameleon-34b",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
    "recurrentgemma-2b",
]


def _modname(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str):
    if arch_id not in ARCH_IDS and arch_id != "paper_lasso":
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.CONFIG


def smoke_config(arch_id: str):
    """Reduced config of the same family for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.SMOKE
