"""Pure-JAX flash attention with a custom VJP (Dao et al., TPU-adapted).

Forward: online-softmax over KV blocks (never materializes S×S), saving only
(q, k, v, out, lse).  Backward: two blockwise passes (dq by q-block; dk/dv by
kv-block) that *recompute* the probability tiles — O(block²) live memory in
both directions, which is what makes train_4k at the assigned batch sizes and
prefill_32k fit HBM.

Causal block-skipping uses ``fori_loop`` with data-dependent trip counts —
legal here because custom_vjp hides the loops from autodiff; each pass saves
~2× FLOPs versus a full masked sweep.

GQA layout: q (B,Sq,H,hd), k/v (B,Sk,KV,hd[v]) with H = KV·G.  This module is
also the reference implementation the Pallas kernel
(``repro/kernels/flash_attention``) is validated against.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG = -1e30


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 1024):
    return _flash(q, k, v, causal, window, block_q, block_k)


def _bounds(iq, bq, bk, nk, causal, window):
    """KV-block range [lo, hi) visible to q-block iq."""
    hi = jnp.minimum(((iq + 1) * bq + bk - 1) // bk, nk) if causal else nk
    lo = jnp.maximum((iq * bq - window + 1) // bk, 0) if window else 0
    return lo, hi


def _mask(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, block_q, block_k):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, block_q, block_k)
    return out


def _flash_fwd_impl(q, k, v, causal, window, block_q, block_k):
    b, sq, h, hd = q.shape
    _, sk, kv, hdk = k.shape
    hdv = v.shape[-1]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    assert sq % bq == 0 and sk % bk == 0

    qr = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 3, 4, 2, 5)   # (nq,B,KV,G,bq,hd)
    kr = k.reshape(b, nk, bk, kv, hdk).transpose(1, 0, 3, 2, 4)        # (nk,B,KV,bk,hdk)
    vr = v.reshape(b, nk, bk, kv, hdv).transpose(1, 0, 3, 2, 4)

    def q_block(iq, qb):
        qpos = iq * bq + jnp.arange(bq)

        def body(ik, state):
            m, l, acc = state
            kb = jax.lax.dynamic_index_in_dim(kr, ik, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ik, 0, keepdims=False)
            kpos = ik * bk + jnp.arange(bk)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            s = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bksd->bkgqd", p, vb.astype(jnp.float32))
            return m_new, l, acc

        m0 = jnp.full((b, kv, g, bq), NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, bq, hdv), jnp.float32)
        lo, hi = _bounds(iq, bq, bk, nk, causal, window)
        m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    outs, lses = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qr))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hdv).astype(q.dtype)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kv, g, sq)
    return out, lse


def _flash_fwd(q, k, v, causal, window, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    _, sk, kv, hdk = k.shape
    hdv = v.shape[-1]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = sq // bq, sk // bk

    qr = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, nk, bk, kv, hdk).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nk, bk, kv, hdv).transpose(1, 0, 3, 2, 4)
    do = dout.reshape(b, nq, bq, kv, g, hdv).transpose(1, 0, 3, 4, 2, 5)
    o = out.reshape(b, nq, bq, kv, g, hdv).transpose(1, 0, 3, 4, 2, 5)
    lse_r = lse.reshape(b, kv, g, nq, bq).transpose(3, 0, 1, 2, 4)     # (nq,B,KV,G,bq)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)  # (nq,B,KV,G,bq)

    def _p(qb, kb, qpos, kpos, lse_b):
        s = jnp.einsum("bkgqd,bksd->bkgqs", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        s = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None], s, NEG)
        return jnp.exp(s - lse_b[..., None])

    # ---- pass 1: dq (loop q blocks; inner kv) -------------------------------
    def dq_block(args):
        iq, qb, dob, deltab, lseb = args
        qpos = iq * bq + jnp.arange(bq)

        def body(ik, dq_acc):
            kb = jax.lax.dynamic_index_in_dim(kr, ik, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ik, 0, keepdims=False)
            kpos = ik * bk + jnp.arange(bk)
            p = _p(qb, kb, qpos, kpos, lseb)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", dob.astype(jnp.float32), vb.astype(jnp.float32))
            ds = p * (dp - deltab[..., None])
            return dq_acc + jnp.einsum("bkgqs,bksd->bkgqd", ds, kb.astype(jnp.float32)) * scale

        lo, hi = _bounds(iq, bq, bk, nk, causal, window)
        dq0 = jnp.zeros((b, kv, g, bq, hd), jnp.float32)
        return jax.lax.fori_loop(lo, hi, body, dq0)

    dqs = jax.lax.map(dq_block, (jnp.arange(nq), qr, do, delta, lse_r))
    dq = dqs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd).astype(q.dtype)

    # ---- pass 2: dk, dv (loop kv blocks; inner q) ----------------------------
    def dkv_block(args):
        ik, kb, vb = args
        kpos = ik * bk + jnp.arange(bk)

        def body(iq, acc):
            dk_acc, dv_acc = acc
            qb = jax.lax.dynamic_index_in_dim(qr, iq, 0, keepdims=False)
            dob = jax.lax.dynamic_index_in_dim(do, iq, 0, keepdims=False)
            deltab = jax.lax.dynamic_index_in_dim(delta, iq, 0, keepdims=False)
            lseb = jax.lax.dynamic_index_in_dim(lse_r, iq, 0, keepdims=False)
            qpos = iq * bq + jnp.arange(bq)
            p = _p(qb, kb, qpos, kpos, lseb)
            dv_acc = dv_acc + jnp.einsum("bkgqs,bkgqd->bksd", p, dob.astype(jnp.float32))
            dp = jnp.einsum("bkgqd,bksd->bkgqs", dob.astype(jnp.float32), vb.astype(jnp.float32))
            ds = p * (dp - deltab[..., None])
            dk_acc = dk_acc + jnp.einsum("bkgqs,bkgqd->bksd", ds, qb.astype(jnp.float32)) * scale
            return dk_acc, dv_acc

        # q blocks that can see kv block ik
        lo_q = (ik * bk) // bq if causal else 0
        hi_q = jnp.minimum((ik * bk + bk + window + bq - 1) // bq, nq) if window else nq
        dk0 = jnp.zeros((b, kv, bk, hdk), jnp.float32)
        dv0 = jnp.zeros((b, kv, bk, hdv), jnp.float32)
        return jax.lax.fori_loop(lo_q, hi_q, body, (dk0, dv0))

    dks, dvs = jax.lax.map(dkv_block, (jnp.arange(nk), kr, vr))
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(b, sk, kv, hdk).astype(k.dtype)
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(b, sk, kv, hdv).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)
