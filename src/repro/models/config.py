"""Model configuration shared by every assigned architecture.

Exact published hyperparameters live in ``repro/configs/<arch>.py``; this
dataclass is the superset of knobs those configs set.  Derived/padded values
(vocab padding for TP divisibility, head-sharding fallbacks) are computed
here so dry-run reports can show both the true and padded shapes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | ssm | hybrid | moe | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    head_dim: int = 0           # 0 → d_model // n_heads
    qk_norm: bool = False       # chameleon
    rope_theta: float = 10_000.0
    window: int = 0             # >0 → sliding-window (local) attention
    attn_logit_softcap: float = 0.0

    # FFN
    act: str = "swiglu"         # swiglu | relu2 | geglu

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading layers with dense FFN (DeepSeek style)
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64      # decoupled rope dims per head for MLA
    v_head_dim: int = 0

    # SSM (mamba1)
    ssm_state: int = 0
    d_inner: int = 0
    conv_kernel: int = 4
    dt_rank: int = 0             # 0 → ceil(d_model/16)

    # hybrid (recurrentgemma)
    layer_pattern: str = ""      # e.g. "rra" tiled over n_layers
    d_rnn: int = 0               # RG-LRU width

    # encoder-decoder (seamless)
    enc_layers: int = 0
    dec_layers: int = 0

    # embeddings / head
    tie_embeddings: bool = False
    emb_scale: bool = False      # multiply embeddings by sqrt(d_model)
    logit_softcap: float = 0.0

    # numerics / training
    dtype: str = "bfloat16"      # activations/params dtype for large-scale runs
    norm_eps: float = 1e-5

    # lower with a Python loop over layers instead of lax.scan — used by the
    # roofline two-point method (cost_analysis counts a scan body once, so
    # per-layer costs are invisible under scan; unrolled lowering exposes
    # them exactly).  Never used for real training (compile time).
    unroll_layers: bool = False

    # MoE dispatch locality: 0 = single global sort (fine on one device;
    # SPMD-hostile at pod scale — a global argsort forces token replication,
    # measured 43–86 TB/step of all-reduce on deepseek/kimi train, §Perf).
    # >1 = route each of `moe_local_groups` token groups locally (group dim
    # rides the data axis), so only the (groups, E, C_loc, D) expert buffer
    # crosses the mesh — the intrinsic all-to-all volume.
    moe_local_groups: int = 0
    # combine form: "gather" pulls each token's expert rows (partitioner
    # broadcasts the (E,C,D) buffer across shards); "scatter" pushes each
    # expert row into a token partial-sum (activation-sized reduce + D-free
    # index maps).  Identical math (test-pinned); §Perf thread-2 i3.
    moe_combine: str = "gather"

    # which optimizer the trainer uses at scale (DESIGN.md §5 memory notes)
    optimizer: str = "adamw"     # adamw | adafactor

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vhd(self) -> int:
        return self.v_head_dim or self.hd

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab, 2048)

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or (self.d_model + 15) // 16

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can run long_500k (no full-attention layer)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return self.window > 0  # local attention is O(S·window)
        return False

    def pattern(self) -> str:
        """Per-layer kind string of length n_layers ('f'=full attn, 'l'=local,
        'r'=recurrent, 'm'=mamba)."""
        if self.family == "ssm":
            return "m" * self.n_layers
        if self.layer_pattern:
            reps = (self.n_layers + len(self.layer_pattern) - 1) // len(self.layer_pattern)
            return (self.layer_pattern * reps)[: self.n_layers]
        return ("l" if self.window else "f") * self.n_layers

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for kind in self.pattern():
            total += self._block_params(kind)
        if self.family == "encdec":
            # encoder blocks (full attn + ffn) — pattern above covered decoder
            total += self.enc_layers * self._block_params("f", cross=False)
            total += self.dec_layers * (self.d_model * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                                        + self.n_heads * self.hd * self.d_model)  # cross-attn
        return total

    def _block_params(self, kind: str, cross: bool = False) -> int:
        d = self.d_model
        if kind == "m":
            di, r, s = self.d_inner, self.dt_rank_, self.ssm_state
            return (d * 2 * di + di * self.conv_kernel + di * (r + 2 * s)
                    + r * di + di * s + di + di * d)
        total = 0
        if kind in ("f", "l"):
            if self.use_mla:
                qd = self.q_lora or d
                total += d * self.q_lora if self.q_lora else 0
                total += qd * self.n_heads * (self.hd + self.rope_head_dim)
                total += d * (self.kv_lora + self.rope_head_dim)
                total += self.kv_lora * self.n_heads * (self.hd + self.vhd)
                total += self.n_heads * self.vhd * d
            else:
                total += d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * self.vhd * d
        if kind == "r":
            dr = self.d_rnn
            total += d * dr * 2 + dr * 4 + dr * self.conv_kernel + dr * d  # in-projs, gates, conv, out
        # ffn
        total += self._ffn_params()
        return total

    def _ffn_params(self) -> int:
        d = self.d_model
        def dense_ffn(f):
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            return mult * d * f
        if self.n_experts:
            per = dense_ffn(self.moe_d_ff)
            return (self.n_experts + self.n_shared_experts) * per + d * self.n_experts
        return dense_ffn(self.d_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
