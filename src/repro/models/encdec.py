"""Encoder-decoder transformer — seamless-m4t-medium backbone.

Per the assignment brief the audio frontend is a stub: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, d_model) straight into the encoder.
The text decoder is a standard causal transformer with cross-attention.
The assigned shapes budget ``seq_len`` across the pair: S_enc = S_dec = S/2.

Serving: encoder prefill computes cross-attention K/V once; decode carries a
self-attention KV cache plus the fixed cross K/V.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig


def _xattn_init(key, cfg: ModelConfig, dtype):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.dense_init(ks[0], d, h * hd, dtype),
        "wk": cm.dense_init(ks[1], d, kvh * hd, dtype),
        "wv": cm.dense_init(ks[2], d, kvh * cfg.vhd, dtype),
        "wo": cm.dense_init(ks[3], h * cfg.vhd, d, dtype),
    }


def enc_block_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    ka, kf = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": cm.attn_init(ka, cfg, dtype),
        "ffn": cm.ffn_init(kf, cfg, dtype=dtype),
    }


def dec_block_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "lnx": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": cm.attn_init(ka, cfg, dtype),
        "xattn": _xattn_init(kx, cfg, dtype),
        "ffn": cm.ffn_init(kf, cfg, dtype=dtype),
    }


def enc_block_apply(p, x, cfg: ModelConfig):
    from repro.models.flash import flash_attention
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = cm.attn_qkv(p["attn"], h, cfg, positions)
    out = flash_attention(q, k, v, causal=False)           # bidirectional
    x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + cm.ffn_apply(p["ffn"], h, cfg)


def _cross_kv(p, enc_out, cfg: ModelConfig):
    b, se, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.vhd)
    return k, v


def dec_block_apply(p, x, enc_out, cfg: ModelConfig):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    # causal self-attention
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + cm.attn_apply(p["attn"], h, cfg, positions=positions)
    # cross-attention (no rope on encoder memory)
    from repro.models.flash import flash_attention
    h = cm.rmsnorm(x, p["lnx"], cfg.norm_eps)
    q = (h @ p["xattn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k, v = _cross_kv(p["xattn"], enc_out, cfg)
    out = flash_attention(q, k, v, causal=False)
    x = x + out.reshape(b, s, -1) @ p["xattn"]["wo"]
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + cm.ffn_apply(p["ffn"], h, cfg)


# ---------------------------------------------------------------------------
# model shell
# ---------------------------------------------------------------------------


def lm_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.dec_layers)
    return {
        "embed": cm.embed_init(ks[2], cfg.padded_vocab, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "head": cm.dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dtype),
    }


def encode(p, frames, cfg: ModelConfig, *, remat: bool = True):
    def body(h, layer_p):
        return enc_block_apply(layer_p, h, cfg), None
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = cm.scan_or_unroll(body, frames, p["enc_blocks"], cfg.unroll_layers)
    return cm.rmsnorm(x, p["enc_norm"], cfg.norm_eps)


def lm_loss(p, batch, cfg: ModelConfig, *, remat: bool = True):
    """batch = {"frames": (B, S_enc, d) dtype, "tokens": (B, S_dec) int32}."""
    frames, tokens = batch["frames"], batch["tokens"]
    b, s = tokens.shape
    enc_out = encode(p, frames.astype(cfg.jdtype), cfg, remat=remat)
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(h, layer_p):
        return dec_block_apply(layer_p, h, enc_out, cfg), None
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = cm.scan_or_unroll(body, x, p["dec_blocks"], cfg.unroll_layers)
    x = cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = (jnp.arange(s) < s - 1)[None, :]
    return cm.ce_loss(x, p["head"], targets, mask, cfg.vocab, cfg.padded_vocab)


def lm_forward(p, batch, cfg: ModelConfig, *, remat: bool = False,
               last_only: bool = False):
    """Serving prefill: encoder pass + teacher-forced decoder logits.

    ``batch`` may be {"frames", "tokens"} or a bare (B, S) token array (the
    frames are then zero — text-only probing path)."""
    if isinstance(batch, dict):
        frames, tokens = batch["frames"], batch["tokens"]
    else:
        tokens = batch
        frames = jnp.zeros((tokens.shape[0], tokens.shape[1], cfg.d_model), cfg.jdtype)
    enc_out = encode(p, frames.astype(cfg.jdtype), cfg, remat=remat)
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(h, layer_p):
        return dec_block_apply(layer_p, h, enc_out, cfg), None
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = cm.scan_or_unroll(body, x, p["dec_blocks"], cfg.unroll_layers)
    x = cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    return x @ p["head"]


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Self-attention KV cache + cross K/V (filled at prefill)."""
    dtype = cfg.jdtype
    return {
        "self_k": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "self_v": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv_heads, cfg.vhd), dtype),
        "cross_k": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "cross_v": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv_heads, cfg.vhd), dtype),
        "cross_len": jnp.zeros((), jnp.int32),
    }


def prefill_cross(p, cache, frames, cfg: ModelConfig):
    """Run the encoder and populate per-layer cross K/V."""
    enc_out = encode(p, frames.astype(cfg.jdtype), cfg, remat=False)

    def body(_, layer_p):
        k, v = _cross_kv(layer_p["xattn"], enc_out, cfg)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, p["dec_blocks"])
    se = enc_out.shape[1]
    cache = dict(cache)
    cache["cross_k"] = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros_like(cache["cross_k"]), ks.astype(cfg.jdtype), 0, 2)
    cache["cross_v"] = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros_like(cache["cross_v"]), vs.astype(cfg.jdtype), 0, 2)
    cache["cross_len"] = jnp.asarray(se, jnp.int32)
    return cache


def lm_decode_step(p, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(h, inp):
        layer_p, sk, sv, ck, cv = inp
        positions = jnp.broadcast_to(pos, (b, 1))
        hh = cm.rmsnorm(h, layer_p["ln1"], cfg.norm_eps)
        q, k, v = cm.attn_qkv(layer_p["attn"], hh, cfg, positions)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), pos, 1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), pos, 1)
        out = cm.decode_attention(q, sk, sv, pos + 1)
        h = h + out.reshape(b, 1, -1) @ layer_p["attn"]["wo"]
        hh = cm.rmsnorm(h, layer_p["lnx"], cfg.norm_eps)
        q = (hh @ layer_p["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        out = cm.decode_attention(q, ck, cv, cache["cross_len"])
        h = h + out.reshape(b, 1, -1) @ layer_p["xattn"]["wo"]
        hh = cm.rmsnorm(h, layer_p["ln2"], cfg.norm_eps)
        h = h + cm.ffn_apply(layer_p["ffn"], hh, cfg)
        return h, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        body, x, (p["dec_blocks"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    cache = dict(cache)
    cache["self_k"], cache["self_v"] = new_sk, new_sv
    x = cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    logits = x @ p["head"]
    return logits, cache
