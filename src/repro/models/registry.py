"""Architecture registry: resolve ``--arch <id>`` to model functions and
ShapeDtypeStruct input specs for every assigned (arch × shape) cell."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.config import SHAPES, ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    """Uniform surface over the four model families."""

    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    forward: Optional[Callable[..., Any]]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., Any]


def _family_module(cfg: ModelConfig):
    if cfg.family == "ssm":
        from repro.models import mamba as m
    elif cfg.family == "hybrid":
        from repro.models import rglru as m
    elif cfg.family == "encdec":
        from repro.models import encdec as m
    else:  # dense / moe
        from repro.models import transformer as m
    return m


def get_model(arch_id: str, *, smoke: bool = False,
              overrides: Optional[dict] = None) -> ModelAPI:
    """``overrides``: dataclasses.replace fields applied to the config —
    used by the roofline two-point method (lower at n_layers ∈ {1, 2} and
    extrapolate; see roofline/analysis.py)."""
    cfg = smoke_config(arch_id) if smoke else get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    m = _family_module(cfg)
    return ModelAPI(
        cfg=cfg,
        init=lambda key: m.lm_init(key, cfg),
        loss=lambda p, batch, remat=True: m.lm_loss(p, batch, cfg, remat=remat),
        forward=(lambda p, tokens, remat=False, last_only=False:
                 m.lm_forward(p, tokens, cfg, remat=remat, last_only=last_only))
        if hasattr(m, "lm_forward") else None,
        init_cache=lambda batch, max_len: m.lm_init_cache(cfg, batch, max_len),
        decode_step=lambda p, cache, tokens, pos: m.lm_decode_step(p, cache, tokens, pos, cfg),
    )


def input_specs(arch_id: str, shape_name: str, *, smoke: bool = False,
                overrides: Optional[dict] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the cell's step inputs (no allocation).

    train/prefill → {"tokens": (B,S)} (+ "frames" for enc-dec);
    decode        → {"tokens": (B,1), "pos": scalar} (cache specs come from
                    ``cache_specs``).
    """
    cfg = smoke_config(arch_id) if smoke else get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            half = s // 2
            return {
                "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model), cfg.jdtype),
                "tokens": jax.ShapeDtypeStruct((b, half), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len cache
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(arch_id: str, shape_name: str, *, smoke: bool = False,
                overrides: Optional[dict] = None):
    """Abstract cache pytree for decode cells (eval_shape — no allocation)."""
    cfg = smoke_config(arch_id) if smoke else get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    m = _family_module(cfg)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        s = s // 2
    return jax.eval_shape(lambda: m.lm_init_cache(cfg, b, s))


def supported_cells(arch_id: str):
    """The assigned shape list for this arch, with skip rationale applied."""
    cfg = get_config(arch_id)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        cells.append("long_500k")
    return cells


ALL_CELLS = [(a, s) for a in ARCH_IDS for s in SHAPES]
