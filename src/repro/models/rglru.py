"""RecurrentGemma / Griffin hybrid — RG-LRU recurrent blocks + local attention.

Layer pattern "rra" (two recurrent blocks, one local-MQA attention block)
tiled over ``n_layers``.  The RG-LRU recurrence

    a_t = exp(-c · softplus(Λ) · r_t),   r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

is diagonal, so it runs as a chunked associative scan (same machinery as
mamba, state (B, d_rnn)).  Local attention uses a ring-buffer KV cache of
``window`` positions → the arch is sub-quadratic and runs ``long_500k``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig

LRU_C = 8.0
CHUNK = 256


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------


def rec_block_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    d, dr, k = cfg.d_model, cfg.d_rnn, cfg.conv_kernel
    ks = jax.random.split(key, 7)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (Griffin §2.4)
    u = jax.random.uniform(ks[5], (dr,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.exp(-jnp.log(u) / LRU_C) - 1.0)  # softplus⁻¹(-log a / c)
    return {
        "ln": jnp.zeros((d,), dtype),
        "in_x": cm.dense_init(ks[0], d, dr, dtype),
        "in_gate": cm.dense_init(ks[1], d, dr, dtype),
        "conv_w": (jax.random.truncated_normal(ks[2], -2, 2, (k, dr), jnp.float32) / math.sqrt(k)).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_r": cm.dense_init(ks[3], dr, dr, dtype),
        "w_i": cm.dense_init(ks[4], dr, dr, dtype),
        "lam": lam.astype(jnp.float32),
        "out": cm.dense_init(ks[6], dr, d, dtype),
    }


def _lru_scan(a, bx, h0):
    """Diagonal linear recurrence via chunked associative scan.
    a, bx: (B,S,dr) f32; h0: (B,dr)."""
    b, s, dr = a.shape
    nc = max(1, s // CHUNK)
    ck = s // nc
    a_c = a.reshape(b, nc, ck, dr).transpose(1, 0, 2, 3)
    bx_c = bx.reshape(b, nc, ck, dr).transpose(1, 0, 2, 3)

    def chunk(h, inp):
        aa, bb = inp
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        a_cum, b_cum = jax.lax.associative_scan(combine, (aa, bb), axis=1)
        h_t = a_cum * h[:, None] + b_cum
        return h_t[:, -1], h_t

    h_final, hs = jax.lax.scan(chunk, h0, (a_c, bx_c))
    return hs.transpose(1, 0, 2, 3).reshape(b, s, dr), h_final


def _lru_gates(p, xc):
    r = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * xc.astype(jnp.float32)
    return a, gated


def rec_block_apply(p, x, cfg: ModelConfig, h0=None, conv_state=None):
    from repro.models.mamba import _causal_conv
    b, s, d = x.shape
    res = x
    x = cm.rmsnorm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(x @ p["in_gate"])
    xi = x @ p["in_x"]
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    a, gated = _lru_gates(p, xc)
    if h0 is None:
        h0 = jnp.zeros((b, cfg.d_rnn), jnp.float32)
    h, h_final = _lru_scan(a, gated, h0)
    y = (h.astype(x.dtype) * gate) @ p["out"]
    return res + y, (h_final, conv_state)


def rec_block_decode(p, x, cache, cfg: ModelConfig):
    from repro.models.mamba import _causal_conv
    res = x
    x = cm.rmsnorm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(x @ p["in_gate"])
    xi = x @ p["in_x"]
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], cache["conv"])
    a, gated = _lru_gates(p, xc)
    h = a[:, 0] * cache["h"] + gated[:, 0]
    y = (h[:, None].astype(x.dtype) * gate) @ p["out"]
    return res + y, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# Hybrid LM: pattern-tiled blocks
# ---------------------------------------------------------------------------


def _layer_kinds(cfg: ModelConfig):
    pat = cfg.pattern()
    return [k for k in pat]


def _mlp_init(key, cfg):
    return {"ln": jnp.zeros((cfg.d_model,), cfg.jdtype), "ffn": cm.ffn_init(key, cfg, dtype=cfg.jdtype)}


def _mlp_apply(p, x, cfg):
    return x + cm.ffn_apply(p["ffn"], cm.rmsnorm(x, p["ln"], cfg.norm_eps), cfg)


def _attn_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln": jnp.zeros((cfg.d_model,), cfg.jdtype), "attn": cm.attn_init(k1, cfg, cfg.jdtype)}


def lm_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    kinds = _layer_kinds(cfg)
    keys = jax.random.split(key, len(kinds) * 2 + 2)
    blocks = []
    for li, kind in enumerate(kinds):
        kb, km = keys[2 * li], keys[2 * li + 1]
        if kind == "r":
            blk = {"kind_r": rec_block_init(kb, cfg), "mlp": _mlp_init(km, cfg)}
        else:
            blk = {"kind_a": _attn_block_init(kb, cfg), "mlp": _mlp_init(km, cfg)}
        blocks.append(blk)
    p = {
        "embed": cm.embed_init(keys[-2], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": blocks,  # heterogeneous list (pattern-ordered)
    }
    if not cfg.tie_embeddings:
        p["head"] = cm.dense_init(keys[-1], cfg.d_model, cfg.padded_vocab, dtype)
    return p


def _apply_block(blk, x, cfg: ModelConfig, remat: bool):
    def run(x):
        if "kind_r" in blk:
            x, _ = rec_block_apply(blk["kind_r"], x, cfg)
        else:
            a = blk["kind_a"]
            h = cm.rmsnorm(x, a["ln"], cfg.norm_eps)
            x = x + cm.attn_apply(a["attn"], h, cfg, window=cfg.window)
        return _mlp_apply(blk["mlp"], x, cfg)
    if remat:
        run = jax.checkpoint(run, policy=jax.checkpoint_policies.nothing_saveable)
    return run(x)


def _backbone(p, x, cfg: ModelConfig, *, remat: bool = True):
    for blk in p["blocks"]:
        x = _apply_block(blk, x, cfg, remat)
    return cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)


def lm_loss(p, batch, cfg: ModelConfig, *, remat: bool = True):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = _backbone(p, x, cfg, remat=remat)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = (jnp.arange(s) < s - 1)[None, :]
    head = p["embed"] if cfg.tie_embeddings else p["head"]
    return cm.ce_loss(x, head, targets, mask, cfg.vocab, cfg.padded_vocab,
                      tied=cfg.tie_embeddings)


def lm_forward(p, tokens, cfg: ModelConfig, *, remat: bool = False,
               last_only: bool = False):
    from repro.models.transformer import _logits
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = _backbone(p, x, cfg, remat=remat)
    if last_only:
        x = x[:, -1:, :]
    return _logits(p, x, cfg)


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer caches: LRU state + conv for 'r', ring KV for 'a'."""
    kinds = _layer_kinds(cfg)
    win = min(cfg.window or max_len, max_len)
    caches = []
    for kind in kinds:
        if kind == "r":
            caches.append({
                "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_rnn), cfg.jdtype),
            })
        else:
            caches.append({
                "k": jnp.zeros((batch, win, cfg.n_kv_heads, cfg.hd), cfg.jdtype),
                "v": jnp.zeros((batch, win, cfg.n_kv_heads, cfg.vhd), cfg.jdtype),
            })
    return caches


def lm_decode_step(p, cache, tokens, pos, cfg: ModelConfig):
    from repro.models.transformer import _logits
    b = tokens.shape[0]
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    new_cache = []
    for blk, lc in zip(p["blocks"], cache):
        if "kind_r" in blk:
            x, lc = rec_block_decode(blk["kind_r"], x, lc, cfg)
        else:
            a = blk["kind_a"]
            h = cm.rmsnorm(x, a["ln"], cfg.norm_eps)
            positions = jnp.broadcast_to(pos, (b, 1))
            q, k, v = cm.attn_qkv(a["attn"], h, cfg, positions)
            win = lc["k"].shape[1]
            slot = pos % win                      # ring buffer
            lc = dict(lc)
            lc["k"] = jax.lax.dynamic_update_slice_in_dim(lc["k"], k.astype(lc["k"].dtype), slot, 1)
            lc["v"] = jax.lax.dynamic_update_slice_in_dim(lc["v"], v.astype(lc["v"].dtype), slot, 1)
            fill = jnp.minimum(pos + 1, win)
            out = cm.decode_attention(q, lc["k"], lc["v"], fill)
            x = x + out.reshape(b, 1, -1) @ a["attn"]["wo"]
        x = _mlp_apply(blk["mlp"], x, cfg)
        new_cache.append(lc)
    x = cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return _logits(p, x, cfg), new_cache
