"""Decoder-only transformer LM — dense (llama family, nemotron, chameleon)
and MoE (deepseek-v2 MLA, kimi-k2) variants.

Layers are scanned with stacked parameters (two groups when the config has
``first_dense_layers`` à la DeepSeek); blocks are optionally rematerialized.
Serving uses a position-indexed KV cache — full k/v for GQA, the compressed
latent for MLA (with matrix-absorbed decode, the production trick that makes
the MLA cache pay off).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    hd, rhd, vhd = cfg.hd, cfg.rope_head_dim, cfg.vhd
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora:
        p["wdq"] = cm.dense_init(ks[0], d, cfg.q_lora, dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora,), dtype)
        p["wuq"] = cm.dense_init(ks[1], cfg.q_lora, h * (hd + rhd), dtype)
    else:
        p["wq"] = cm.dense_init(ks[1], d, h * (hd + rhd), dtype)
    p["wdkv"] = cm.dense_init(ks[2], d, cfg.kv_lora + rhd, dtype)
    p["kv_norm"] = jnp.zeros((cfg.kv_lora,), dtype)
    p["wuk"] = cm.dense_init(ks[3], cfg.kv_lora, h * hd, dtype)
    p["wuv"] = cm.dense_init(ks[4], cfg.kv_lora, h * vhd, dtype)
    p["wo"] = cm.dense_init(ks[5], h * vhd, d, dtype)
    return p


def _mla_q(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, hd, rhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    if cfg.q_lora:
        q = cm.rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps) @ p["wuq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, hd + rhd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = cm.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    ckv = x @ p["wdkv"]                                   # (B,S,kv_lora+rhd)
    c, k_rope = ckv[..., : cfg.kv_lora], ckv[..., cfg.kv_lora :]
    c = cm.rmsnorm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = cm.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rhd)
    return c, k_rope


def mla_apply(p, x, cfg: ModelConfig, positions=None):
    """Training/prefill: materialize per-head k/v from the latent."""
    b, s, _ = x.shape
    h, hd, rhd, vhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.vhd
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c, k_rope = _mla_latent(p, x, cfg, positions)
    k_nope = (c @ p["wuk"]).reshape(b, s, h, hd)
    v = (c @ p["wuv"]).reshape(b, s, h, vhd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rhd))], axis=-1)
    from repro.models.flash import flash_attention
    out = flash_attention(q, k, v, causal=True)
    return out.reshape(b, s, h * vhd) @ p["wo"]


def mla_decode(p, x, cache_c, cache_kr, pos, cfg: ModelConfig):
    """Matrix-absorbed decode: score and readout in latent space.

    cache_c: (B, S_max, kv_lora); cache_kr: (B, S_max, rhd); pos: scalar index
    of the current token.  Returns (out, cache_c, cache_kr).
    """
    b = x.shape[0]
    h, hd, rhd, vhd, kl = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.vhd, cfg.kv_lora
    positions = jnp.broadcast_to(pos, (b, 1))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)          # (B,1,H,·)
    c, k_rope = _mla_latent(p, x, cfg, positions)          # (B,1,kl), (B,1,1,rhd)
    cache_c = jax.lax.dynamic_update_slice_in_dim(cache_c, c.astype(cache_c.dtype), pos, 1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, k_rope[:, :, 0, :].astype(cache_kr.dtype), pos, 1)
    # absorb W_uk into q: q_lat (B,H,kl)
    wuk = p["wuk"].reshape(kl, h, hd)
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0], wuk)
    s_nope = jnp.einsum("bhc,bsc->bhs", q_lat.astype(jnp.float32), cache_c.astype(jnp.float32))
    s_rope = jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32), cache_kr.astype(jnp.float32))
    scores = (s_nope + s_rope) / math.sqrt(hd + rhd)
    live = jnp.arange(cache_c.shape[1]) <= pos
    scores = jnp.where(live[None, None, :], scores, cm.NEG)
    pr = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsc->bhc", pr, cache_c.astype(jnp.float32))   # (B,H,kl)
    wuv = p["wuv"].reshape(kl, h, vhd)
    out = jnp.einsum("bhc,chd->bhd", o_lat, wuv.astype(jnp.float32))      # (B,H,vhd)
    out = out.reshape(b, 1, h * vhd).astype(x.dtype) @ p["wo"]
    return out, cache_c, cache_kr


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, use_moe: bool):
    dtype = cfg.jdtype
    ka, kf, kn = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": mla_init(ka, cfg, dtype) if cfg.use_mla else cm.attn_init(ka, cfg, dtype),
    }
    if use_moe:
        p["moe"] = cm.moe_init(kf, cfg, dtype)
    else:
        p["ffn"] = cm.ffn_init(kf, cfg, dtype=dtype)
    return p


def block_apply(p, x, cfg: ModelConfig, use_moe: bool, positions=None,
                full_capacity: bool = False):
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out = mla_apply(p["attn"], h, cfg, positions)
    else:
        attn_out = cm.attn_apply(p["attn"], h, cfg, window=cfg.window, positions=positions)
    x = x + attn_out
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        b, s, d = h.shape
        # training: capacity-bounded dispatch (drops bound memory/compute);
        # inference: capacity = T so no token is ever dropped and decode
        # matches the parallel forward bit-for-bit.
        cap = b * s if full_capacity else None
        y, moe_aux = cm.moe_apply(p["moe"], h.reshape(b * s, d), cfg, capacity=cap)
        x = x + y.reshape(b, s, d)
        aux = moe_aux["moe_aux"].astype(jnp.float32)
    else:
        x = x + cm.ffn_apply(p["ffn"], h, cfg)
    return x, aux


def block_decode(p, x, cache, pos, cfg: ModelConfig, use_moe: bool):
    """One-token decode through a block.  cache is a dict of this block's
    per-layer buffers; returns (x, cache)."""
    b = x.shape[0]
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out, cache["c"], cache["kr"] = mla_decode(
            p["attn"], h, cache["c"], cache["kr"], pos, cfg)
    else:
        positions = jnp.broadcast_to(pos, (b, 1))
        q, k, v = cm.attn_qkv(p["attn"], h, cfg, positions)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, 1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, 1)
        out = cm.decode_attention(q, cache["k"], cache["v"], pos + 1, window=cfg.window)
        attn_out = out.reshape(b, 1, -1) @ p["attn"]["wo"]
    x = x + attn_out
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        s = h.shape[1]
        # decode: capacity = token count → no token is ever dropped (an
        # expert can receive at most one assignment per token), so decode
        # logits match the parallel forward exactly.
        y, _ = cm.moe_apply(p["moe"], h.reshape(b * s, -1), cfg, capacity=b * s)
        x = x + y.reshape(b, s, -1)
    else:
        x = x + cm.ffn_apply(p["ffn"], h, cfg)
    return x, cache


def init_block_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int):
    dtype = cfg.jdtype
    if cfg.use_mla:
        return {
            "c": jnp.zeros((n_layers, batch, max_len, cfg.kv_lora), dtype),
            "kr": jnp.zeros((n_layers, batch, max_len, cfg.rope_head_dim), dtype),
        }
    window = cfg.window or 0
    s = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((n_layers, batch, s, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((n_layers, batch, s, cfg.n_kv_heads, cfg.vhd), dtype),
    }


# ---------------------------------------------------------------------------
# LM: init / loss / prefill / decode
# ---------------------------------------------------------------------------


def _split_groups(cfg: ModelConfig) -> Tuple[int, int]:
    """(#leading dense-FFN layers, #scanned main layers)."""
    lead = cfg.first_dense_layers if cfg.n_experts else 0
    return lead, cfg.n_layers - lead


def lm_init(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jdtype
    lead, main = _split_groups(cfg)
    keys = jax.random.split(key, 4)
    p: Params = {
        "embed": cm.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = cm.dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dtype)
    if lead:
        lead_keys = jax.random.split(keys[2], lead)
        p["lead_blocks"] = jax.vmap(lambda k: block_init(k, cfg, use_moe=False))(lead_keys)
    main_keys = jax.random.split(keys[3], main)
    p["blocks"] = jax.vmap(lambda k: block_init(k, cfg, use_moe=bool(cfg.n_experts)))(main_keys)
    return p


def _embed(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _logits(p, x, cfg: ModelConfig):
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = x @ head
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def _backbone(p, x, cfg: ModelConfig, *, remat: bool = True, positions=None,
              full_capacity: bool = False):
    lead, _ = _split_groups(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def body(carry, layer_p, use_moe: bool):
        h, aux = carry
        h, a = block_apply(layer_p, h, cfg, use_moe, positions,
                           full_capacity=full_capacity)
        return (h, aux + a), None

    def run_group(carry, stacked, use_moe: bool):
        group_body = partial(body, use_moe=use_moe)
        if remat:
            group_body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.unroll_layers:  # roofline costing path — see ModelConfig
            n = jax.tree.leaves(stacked)[0].shape[0]
            for i in range(n):
                layer_p = jax.tree.map(lambda a: a[i], stacked)
                carry, _ = group_body(carry, layer_p)
            return carry
        carry, _ = jax.lax.scan(group_body, carry, stacked)
        return carry

    if lead:
        (x, aux_total) = run_group((x, aux_total), p["lead_blocks"], False)
    (x, aux_total) = run_group((x, aux_total), p["blocks"], bool(cfg.n_experts))
    return cm.rmsnorm(x, p["final_norm"], cfg.norm_eps), aux_total


def lm_loss(p, batch, cfg: ModelConfig, *, remat: bool = True):
    """Causal LM loss.  batch = {"tokens": (B,S) int32}; position i predicts
    token i+1 (last position masked).  Chunked CE keeps the logits tensor
    memory-bounded (cm.ce_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(p, tokens, cfg)
    x, aux = _backbone(p, x, cfg, remat=remat)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = (jnp.arange(s) < s - 1)[None, :]
    head = p["embed"] if cfg.tie_embeddings else p["head"]
    loss = cm.ce_loss(x, head, targets, mask, cfg.vocab, cfg.padded_vocab,
                      tied=cfg.tie_embeddings, logit_softcap=cfg.logit_softcap)
    if cfg.n_experts:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss


def lm_forward(p, tokens, cfg: ModelConfig, *, remat: bool = False,
               last_only: bool = False):
    """Sequence logits.  ``last_only`` returns just the final position — the
    production prefill contract (avoids a (B,S,V) output buffer)."""
    x = _embed(p, tokens, cfg)
    x, _ = _backbone(p, x, cfg, remat=remat, full_capacity=True)
    if last_only:
        x = x[:, -1:, :]
    return _logits(p, x, cfg)


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    _, main = _split_groups(cfg)
    lead, _ = _split_groups(cfg)
    caches = {"main": init_block_cache(cfg, batch, max_len, main)}
    if lead:
        caches["lead"] = init_block_cache(cfg, batch, max_len, lead)
    return caches


def lm_decode_step(p, cache, tokens, pos, cfg: ModelConfig):
    """One decode step.  tokens: (B,1) int32; pos: scalar int32 — the index
    the new token occupies (attends to cache[:pos+1]).  Returns (logits,
    cache)."""
    x = _embed(p, tokens, cfg)
    lead, _ = _split_groups(cfg)

    def scan_blocks(x, stacked_p, stacked_cache, use_moe):
        def body(h, inp):
            layer_p, layer_cache = inp
            h, layer_cache = block_decode(layer_p, h, layer_cache, pos, cfg, use_moe)
            return h, layer_cache
        x, new_cache = jax.lax.scan(body, x, (stacked_p, stacked_cache))
        return x, new_cache

    if lead:
        x, cache["lead"] = scan_blocks(x, p["lead_blocks"], cache["lead"], False)
    x, cache["main"] = scan_blocks(x, p["blocks"], cache["main"], bool(cfg.n_experts))
    x = cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return _logits(p, x, cfg), cache
