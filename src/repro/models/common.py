"""Shared neural building blocks (pure JAX, framework-free).

Every module is a pair of functions:
  ``<name>_init(key, cfg, ...) -> params`` (a dict pytree) and
  ``<name>_apply(params, x, ...) -> out``.

Attention is *blocked* (flash-style online softmax over KV chunks inside a
``lax.scan`` / ``fori_loop``) so no S×S score tensor is ever materialized —
required even for train_4k at the assigned batch sizes, and the pure-JAX
reference for the Pallas flash kernel in ``repro/kernels/flash_attention``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    # 1/sqrt(d) scale keeps tied-head logits O(1) at init
    scale = 1.0 / math.sqrt(d)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope / activations
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Llama-style rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def activation(name: str):
    if name == "swiglu" or name == "geglu":
        raise ValueError("gated activations are applied inside ffn_apply")
    return {"relu2": lambda u: jnp.square(jax.nn.relu(u)), "gelu": jax.nn.gelu,
            "silu": jax.nn.silu}[name]


# ---------------------------------------------------------------------------
# blocked attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

NEG = -1e30


def _attend_block(q, k, v, qpos, kpos, *, causal: bool, window: int, softcap: float,
                  scale: float, state):
    """Online-softmax update for one KV block.

    q: (B, bq, KV, G, hd); k/v: (B, bk, KV, hd); state = (m, l, acc).
    """
    m, l, acc = state
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.ones((qpos.shape[-1], kpos.shape[-1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p, v.astype(jnp.float32)
    )
    return m_new, l_new, acc_new


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_offset=0, softcap: float = 0.0,
                      block_q: int = 512, block_k: int = 1024,
                      kv_len=None):
    """Memory-bounded attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) with H = KV·G (GQA).
    ``q_offset``: global position of q[0] (decode/prefill continuation).
    ``kv_len``: live prefix length of the KV buffers (masks cache padding).
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    hdv = v.shape[-1]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq, nk = sq // block_q, sk // block_k
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)

    qr = q.reshape(b, nq, block_q, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, block_k, kv, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, block_k, kv, hdv).transpose(1, 0, 2, 3, 4)

    kpos_all = jnp.arange(sk)
    live = kpos_all < (kv_len if kv_len is not None else sk)

    def q_block(iq, qb):
        qpos = q_offset + iq * block_q + jnp.arange(block_q)

        def kv_step(ik, state):
            kb = jax.lax.dynamic_index_in_dim(kr, ik, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ik, 0, keepdims=False)
            kpos = ik * block_k + jnp.arange(block_k)
            kpos = jnp.where(
                jax.lax.dynamic_slice_in_dim(live, ik * block_k, block_k),
                kpos, jnp.full((block_k,), 2**30),
            )
            return _attend_block(qb, kb, vb, qpos, kpos, causal=causal,
                                 window=window, softcap=softcap, scale=scale,
                                 state=state)

        m0 = jnp.full((b, kv, g, block_q), NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, kv, g, block_q, hdv), jnp.float32)
        if causal and window == 0:
            # skip blocks strictly after the diagonal (trip count is dynamic
            # in iq → lowers to a while loop; saves ~2× FLOPs vs full sweep)
            hi = (q_offset + (iq + 1) * block_q + block_k - 1) // block_k
            hi = jnp.minimum(hi, nk)
            m, l, acc = jax.lax.fori_loop(0, hi, kv_step, (m0, l0, a0))
        elif window:
            lo = jnp.maximum((q_offset + iq * block_q - window) // block_k, 0)
            hi = jnp.minimum((q_offset + (iq + 1) * block_q + block_k - 1) // block_k, nk)
            m, l, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        else:
            m, l, acc = jax.lax.fori_loop(0, nk, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, block_q, kv * g, hdv)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qr))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hdv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_len, *, window: int = 0):
    """Single-position attention over a (possibly padded) KV cache.

    q: (B, 1, H, hd); caches: (B, S_max, KV, hd); kv_len: live length (incl.
    current token).  Window > 0 restricts to a trailing window (ring caches
    pass the window-sized buffer directly with kv_len = window fill).
    """
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    hdv = v_cache.shape[-1]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, :] < kv_len if jnp.ndim(kv_len) else pos < kv_len
    if window:
        lo = kv_len - window
        mask = mask & (pos[None, :] >= lo if jnp.ndim(kv_len) else pos >= lo)
    s = jnp.where(mask[:, None, None, :] if jnp.ndim(kv_len) else mask[None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, dtype):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kvh * hd, dtype),
        "wv": dense_init(ks[2], d, kvh * cfg.vhd, dtype),
        "wo": dense_init(ks[3], h * cfg.vhd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attn_qkv(p, x, cfg: ModelConfig, positions):
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kvh, hd)
    v = (x @ p["wv"]).reshape(b, s, kvh, cfg.vhd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, *, window: int = 0, positions=None):
    from repro.models.flash import flash_attention
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = attn_qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, causal=True, window=window)
    return out.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# FFN (dense)
# ---------------------------------------------------------------------------


def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dtype = dtype or cfg.jdtype
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w1": dense_init(ks[0], d, f, dtype),
            "w3": dense_init(ks[1], d, f, dtype),
            "w2": dense_init(ks[2], f, d, dtype),
        }
    return {"w1": dense_init(ks[0], d, f, dtype), "w2": dense_init(ks[2], f, d, dtype)}


def ffn_apply(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    if cfg.act == "geglu":
        return (jax.nn.gelu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    act = activation(cfg.act)
    return act(x @ p["w1"]) @ p["w2"]


# ---------------------------------------------------------------------------
# sharding-constraint helper (no-op outside a mesh context)
# ---------------------------------------------------------------------------


# which mesh axes the "batch" sentinel expands to.  The §Perf full-DP layout
# (launch/dryrun.py PERF_OVERRIDES dp="full") widens it to include "model" so
# activations stay batch-sharded across the whole pod instead of being
# tensor-parallel (weight-gather traffic then replaces activation all-reduce
# traffic — the right trade for batch-heavy dense train cells).
BATCH_AXES = ("pod", "data")


def constrain(x, *spec):
    """``with_sharding_constraint`` against the ambient mesh; axis names not
    present on the mesh are dropped; outside any mesh this is the identity —
    so model code stays runnable on a bare CPU while anchoring the SPMD
    partitioner's propagation on the production mesh.

    Spec entries: None, axis name, tuple of names, or the sentinel "batch"
    (expands to BATCH_AXES ∩ mesh axes).
    """
    from jax._src import mesh as mesh_lib
    env = mesh_lib.thread_resources.env
    mesh = env.physical_mesh
    if mesh.empty:
        return x
    names = set(mesh.axis_names)

    def fix(a):
        if a == "batch":
            a = tuple(n for n in BATCH_AXES if n in names)
            return a if a else None
        if isinstance(a, tuple):
            a = tuple(n for n in a if n in names)
            return a if a else None
        return a if (a is None or a in names) else None

    clean = [fix(a) for a in spec]
    # drop axes whose dim size doesn't divide
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, a in zip(x.shape, clean + [None] * (x.ndim - len(clean))):
        if a is None:
            out.append(None)
            continue
        total = 1
        for n in (a if isinstance(a, tuple) else (a,)):
            total *= sizes[n]
        out.append(a if (dim % total == 0 and dim > 0) else None)
    return jax.lax.with_sharding_constraint(x, jax.sharding.PartitionSpec(*out))


# ---------------------------------------------------------------------------
# chunked cross-entropy (memory-bounded loss head)
# ---------------------------------------------------------------------------


def ce_loss(x, head, targets, loss_mask, vocab: int, padded_vocab: int,
            *, tied: bool = False, logit_softcap: float = 0.0,
            chunk_seq: int = 256):
    """Causal-LM CE with the (B,S,padded_vocab) logits tensor never fully
    materialized: a checkpointed ``lax.scan`` over **sequence** chunks
    computes each chunk's f32 logits, logsumexp and target logit, then
    discards them — backward recomputes per chunk.

    Chunking over the sequence dim (not flattened tokens) keeps every chunk
    spread across all batch (data-axis) shards, so the SPMD partitioner never
    needs to gather the activations — chunk logits stay sharded
    (batch → data, vocab → model).

    x: (B,S,D) final hiddens; head: (D,Vp) or (Vp,D) when ``tied``;
    targets/loss_mask: (B,S).  Returns mean NLL over masked positions.
    """
    b, s, d = x.shape
    ck = min(chunk_seq, s)
    n_chunks = -(-s // ck)
    pad = n_chunks * ck - s
    mf = jnp.broadcast_to(loss_mask, (b, s)).astype(jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mf = jnp.pad(mf, ((0, 0), (0, pad)))
    xs = jnp.moveaxis(x.reshape(b, n_chunks, ck, d), 1, 0)        # (C,B,ck,D)
    ts = jnp.moveaxis(targets.reshape(b, n_chunks, ck), 1, 0)
    ms = jnp.moveaxis(mf.reshape(b, n_chunks, ck), 1, 0)
    # anchor: chunk dim replicated, batch stays on the data axes
    xs = constrain(xs, None, "batch", None, None)
    ts = constrain(ts, None, "batch", None)
    ms = constrain(ms, None, "batch", None)
    vmask_neg = jnp.where(jnp.arange(padded_vocab) < vocab, 0.0, -1e30).astype(jnp.float32)

    def chunk_nll(xc, tc, mc):
        logits = (jnp.einsum("bsd,vd->bsv", xc, head) if tied else xc @ head).astype(jnp.float32)
        if logit_softcap:
            logits = jnp.tanh(logits / logit_softcap) * logit_softcap
        logits = logits + vmask_neg[None, None, :]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mc)

    chunk_nll = jax.checkpoint(chunk_nll, policy=jax.checkpoint_policies.nothing_saveable)

    def body(acc, inp):
        xc, tc, mc = inp
        return acc + chunk_nll(xc, tc, mc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts, ms))
    return total / jnp.maximum(jnp.sum(mf), 1.0)


# ---------------------------------------------------------------------------
# Mixture of Experts — sort-based dispatch (production path, pjit-shardable)
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "w1": _stack_init(ks[1], e, d, f, dtype),
        "w2": _stack_init(ks[2], e, f, d, dtype),
    }
    if gated:
        p["w3"] = _stack_init(ks[3], e, d, f, dtype)
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts, dtype=dtype)
    return p


def _stack_init(key, e, d_in, d_out, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (e, d_in, d_out), jnp.float32) * scale).astype(dtype)


def moe_apply(p, x, cfg: ModelConfig, capacity: Optional[int] = None):
    """Top-k MoE with sort-based capacity dispatch.

    x: (T, d) flattened tokens.  Returns (T, d) plus aux losses dict.
    Dispatch: argsort token→expert assignments, positions via cumsum, drop
    beyond capacity, scatter into an (E, C, d) buffer, grouped matmul, scatter
    back weighted by gates.

    ``cfg.moe_local_groups > 1`` (the pod-scale path): tokens split into
    groups riding the data axis; each group routes **its own tokens only**,
    so the sort/cumsum/scatter bookkeeping never crosses a shard and the one
    cross-mesh transfer is the expert buffer itself (the intrinsic
    all-to-all).  A global argsort instead replicates every token on every
    device — measured 43–86 TB/step of all-reduce on the MoE train cells
    (EXPERIMENTS.md §Perf).
    """
    g = cfg.moe_local_groups
    t_all = x.shape[0]
    if g > 1 and t_all % g == 0 and t_all // g >= 1:
        tl = t_all // g
        cap = capacity or max(1, int(tl * cfg.top_k / cfg.n_experts
                                     * cfg.capacity_factor))
        xg = constrain(x.reshape(g, tl, x.shape[1]), "batch", None, None)
        y, aux = jax.vmap(lambda xx: _moe_dispatch(p, xx, cfg, cap))(xg)
        return y.reshape(t_all, -1), jax.tree_util.tree_map(jnp.mean, aux)
    cap = capacity or max(1, int(t_all * cfg.top_k / cfg.n_experts
                                 * cfg.capacity_factor))
    return _moe_dispatch(p, x, cfg, cap)


def _moe_dispatch(p, x, cfg: ModelConfig, cap: int):
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                    # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                                # (T·k,)
    sort_idx = jnp.argsort(flat_e)                          # stable
    sorted_e = flat_e[sort_idx]
    token_of = sort_idx // k
    counts = jnp.bincount(sorted_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # overflow slot

    xb = x[token_of]                                        # (T·k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].add(jnp.where(keep[:, None], xb, 0))
    buf = constrain(buf[:-1].reshape(e, cap, d), "model", "batch", None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    else:
        h = activation(cfg.act)(h)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(e * cap, d)

    gates_sorted = gates.reshape(-1)[sort_idx]
    if cfg.moe_combine == "scatter":
        # expert-side combine: build slot→token index/gate maps (D-free — the
        # only cross-shard traffic), scatter each expert row into its token's
        # partial sum; the partitioner reduces y over the expert shards as an
        # activation-sized all-reduce instead of broadcasting the whole
        # (E,C,D) buffer (§Perf thread-2 i3).
        tok_slot = jnp.full((e * cap + 1,), t, jnp.int32).at[dest].set(
            jnp.where(keep, token_of, t).astype(jnp.int32))
        gate_slot = jnp.zeros((e * cap + 1,), x.dtype).at[dest].set(
            jnp.where(keep, gates_sorted, 0.0).astype(x.dtype))
        y = jnp.zeros((t + 1, d), x.dtype).at[tok_slot[:-1]].add(
            out_e * gate_slot[:-1, None])[:t]
    else:  # "gather" — token-side (single-device-friendly) form
        gath = jnp.where(keep[:, None], out_e[jnp.minimum(dest, e * cap - 1)], 0)
        contrib = gath * gates_sorted[:, None].astype(x.dtype)
        y = jnp.zeros((t, d), x.dtype).at[token_of].add(contrib)

    if cfg.n_shared_experts:
        y = y + ffn_apply(p["shared"], x, cfg)

    # load-balance aux loss (Switch): E · Σ_e f_e · p_e
    frac = jnp.bincount(flat_e, length=e) / (t * k)
    mean_p = probs.mean(0)
    aux = e * jnp.sum(frac * mean_p)
    return y, {"moe_aux": aux, "dropped": 1.0 - keep.mean()}


def scan_or_unroll(body, carry, stacked, unroll: bool):
    """lax.scan over stacked layer params, or a Python loop when ``unroll``
    (the roofline two-point costing path — scan bodies are invisible to
    cost_analysis; see ModelConfig.unroll_layers)."""
    if unroll:
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        ys = []
        for i in range(n):
            layer_p = jax.tree_util.tree_map(lambda a: a[i], stacked)
            carry, y = body(carry, layer_p)
            ys.append(y)
        stacked_y = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ys)
                     if ys and ys[0] is not None else None)
        return carry, stacked_y
    return jax.lax.scan(body, carry, stacked)
