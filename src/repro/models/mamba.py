"""Mamba-1 (selective SSM) LM — falcon-mamba-7b family.

The selective scan is computed chunk-parallel: the sequence is split into
chunks, an ``associative_scan`` (parallel prefix over (a, b) pairs with
(a₁,b₁)∘(a₂,b₂) = (a₁a₂, a₂b₁+b₂)) runs inside each chunk, and a sequential
``lax.scan`` carries the (B, d_inner, N) state across chunks — bounded memory
at any sequence length, which is what lets this arch run the ``long_500k``
cell.  Decode carries (conv window, ssm state) — O(1) per token, no KV cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig

CHUNK = 256


def block_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    d, di, r, n, k = cfg.d_model, cfg.d_inner, cfg.dt_rank_, cfg.ssm_state, cfg.conv_kernel
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    dt_bias = jnp.log(jnp.exp(jnp.exp(
        jax.random.uniform(ks[4], (di,), minval=math.log(1e-3), maxval=math.log(1e-1)))) - 1.0 + 1e-9)
    return {
        "ln": jnp.zeros((d,), dtype),
        "in_proj": cm.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.truncated_normal(ks[1], -2, 2, (k, di), jnp.float32) / math.sqrt(k)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": cm.dense_init(ks[2], di, r + 2 * n, dtype),
        "dt_proj": cm.dense_init(ks[3], r, di, dtype, scale=r**-0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": cm.dense_init(ks[5], di, d, dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B,S,di); w: (K,di).  state: (B,K-1,di)
    carried for decode.  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, di)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return y + b[None, None, :], new_state


def _ssm_chunked(dA, dBx, c, h0):
    """Chunk-parallel selective scan.

    dA, dBx: (B, S, di, N); c: (B, S, N); h0: (B, di, N) initial state.
    Returns y: (B, S, di), h_final.
    """
    b, s, di, n = dA.shape
    nc = max(1, s // CHUNK)
    ck = s // nc
    assert s % ck == 0

    dA_c = dA.reshape(b, nc, ck, di, n).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(b, nc, ck, di, n).transpose(1, 0, 2, 3, 4)
    c_c = c.reshape(b, nc, ck, n).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        a, bx, cc = inp  # (B, ck, di, N), (B, ck, N)
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_t = a_cum * h[:, None] + b_cum                 # (B, ck, di, N)
        y = jnp.einsum("bsdn,bsn->bsd", h_t, cc)
        return h_t[:, -1], y

    h_final, ys = jax.lax.scan(chunk_step, h0, (dA_c, dBx_c, c_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    return y, h_final


def _ssm_inputs(p, xc, cfg: ModelConfig):
    """Shared projections: returns (dA, dBx, C) from conv output xc (B,S,di)."""
    r, n = cfg.dt_rank_, cfg.ssm_state
    proj = xc @ p["x_proj"]                               # (B,S,r+2N)
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,di)
    a = -jnp.exp(p["a_log"])                              # (di,N)
    dA = jnp.exp(dt[..., None] * a[None, None])           # (B,S,di,N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * b_mat.astype(jnp.float32)[:, :, None, :]
    return dA, dBx, c_mat.astype(jnp.float32)


def block_apply(p, x, cfg: ModelConfig, h0=None, conv_state=None):
    """Full-sequence mamba block.  Returns (x_out, (h_final, conv_state))."""
    b, s, d = x.shape
    di = cfg.d_inner
    res = x
    x = cm.rmsnorm(x, p["ln"], cfg.norm_eps)
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dA, dBx, c_mat = _ssm_inputs(p, xc, cfg)
    if h0 is None:
        h0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    y, h_final = _ssm_chunked(dA, dBx, c_mat, h0)
    y = y + p["d_skip"][None, None] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return res + y @ p["out_proj"], (h_final, conv_state)


def block_decode(p, x, cache, cfg: ModelConfig):
    """One-token step.  cache = {"h": (B,di,N) f32, "conv": (B,K-1,di)}."""
    b = x.shape[0]
    di = cfg.d_inner
    res = x
    x = cm.rmsnorm(x, p["ln"], cfg.norm_eps)
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], cache["conv"])
    xc = jax.nn.silu(xc)
    dA, dBx, c_mat = _ssm_inputs(p, xc, cfg)              # S=1
    h = dA[:, 0] * cache["h"] + dBx[:, 0]                 # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None]
    y = y + p["d_skip"][None, None] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return res + y @ p["out_proj"], {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# LM shell
# ---------------------------------------------------------------------------


def lm_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    k1, k2, k3 = jax.random.split(key, 3)
    layer_keys = jax.random.split(k3, cfg.n_layers)
    p = {
        "embed": cm.embed_init(k1, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": jax.vmap(lambda k: block_init(k, cfg))(layer_keys),
    }
    if not cfg.tie_embeddings:
        p["head"] = cm.dense_init(k2, cfg.d_model, cfg.padded_vocab, dtype)
    return p


def _backbone(p, x, cfg: ModelConfig, *, remat: bool = True):
    def body(h, layer_p):
        h, _ = block_apply(layer_p, h, cfg)
        return h, None
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = cm.scan_or_unroll(body, x, p["blocks"], cfg.unroll_layers)
    return cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)


def lm_loss(p, batch, cfg: ModelConfig, *, remat: bool = True):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0)
    x = _backbone(p, x, cfg, remat=remat)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = (jnp.arange(s) < s - 1)[None, :]
    head = p["embed"] if cfg.tie_embeddings else p["head"]
    return cm.ce_loss(x, head, targets, mask, cfg.vocab, cfg.padded_vocab,
                      tied=cfg.tie_embeddings)


def lm_forward(p, tokens, cfg: ModelConfig, *, remat: bool = False,
               last_only: bool = False):
    from repro.models.transformer import _logits
    x = jnp.take(p["embed"], tokens, axis=0)
    x = _backbone(p, x, cfg, remat=remat)
    if last_only:
        x = x[:, -1:, :]
    return _logits(p, x, cfg)


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    del max_len  # state size is sequence-independent (the SSM win)
    return {
        "h": jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, cfg.d_inner), cfg.jdtype),
    }


def lm_decode_step(p, cache, tokens, pos, cfg: ModelConfig):
    from repro.models.transformer import _logits
    del pos  # stateful recurrence — position-free
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(h, inp):
        layer_p, layer_cache = inp
        h, new_cache = block_decode(layer_p, h, layer_cache, cfg)
        return h, new_cache

    x, new_cache = jax.lax.scan(body, x, (p["blocks"], cache))
    x = cm.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return _logits(p, x, cfg), new_cache
