from repro.models.registry import get_model, input_specs  # noqa: F401
