"""Sharding-aware batch loader with background prefetch.

Wraps a host iterator; each batch is placed onto the mesh with the step's
input sharding (batch → ("pod","data")) so device transfers overlap host
generation — the data-pipeline half of compute/comm overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterator, Optional

import jax

class ShardedLoader:
    def __init__(self, it: Iterator, sharding: Optional[Any] = None,
                 prefetch: int = 2):
        self.it = it
        self.sharding = sharding
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _place(self, batch):
        if self.sharding is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(lambda x: jax.device_put(x, self.sharding), batch)

    def _worker(self):
        try:
            for batch in self.it:
                if self._stop.is_set():
                    return
                self.q.put(self._place(batch))
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
