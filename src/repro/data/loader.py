"""Sharding-aware batch loader with background prefetch.

Wraps a host iterator; each batch is placed onto the mesh with the step's
input sharding (batch → ("pod","data")) so device transfers overlap host
generation — the data-pipeline half of compute/comm overlap.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Iterator, Optional

import jax

class ShardedLoader:
    def __init__(self, it: Iterator, sharding: Optional[Any] = None,
                 prefetch: int = 2):
        self.it = it
        self.sharding = sharding
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _place(self, batch):
        if self.sharding is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(lambda x: jax.device_put(x, self.sharding), batch)

    def _worker(self):
        try:
            for batch in self.it:
                if self._stop.is_set():
                    return
                placed = self._place(batch)
                # A bare q.put would deadlock on close(): with the consumer
                # gone and the queue full it blocks forever, so the stop
                # event is re-checked between bounded put attempts.
                while not self._stop.is_set():
                    try:
                        self.q.put(placed, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        finally:
            try:
                self.q.put_nowait(None)
            except queue.Full:
                pass

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():          # closed loaders yield nothing
            raise StopIteration
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self, timeout: float = 5.0) -> None:
        """Stop the prefetch thread and join it (safe with a full queue)."""
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self.thread.is_alive() and time.monotonic() < deadline:
            # drain so a put blocked on a full queue wakes and sees the stop
            try:
                self.q.get_nowait()
            except queue.Empty:
                pass
            self.thread.join(timeout=0.05)
        # wake any consumer still blocked in __next__'s q.get()
        try:
            self.q.put_nowait(None)
        except queue.Full:
            pass
