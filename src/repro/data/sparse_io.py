"""Streaming libsvm/svmlight text I/O (bounded memory).

The paper's datasets (RCV1, news20, URL, KDD2012 — Table 2) ship as
libsvm/svmlight text: one row per line, ``label idx:val idx:val ...``.  At
those scales (up to 8.4M rows × 20.2M features) the full COO triple never
fits comfortably in RAM, so the parser here is a *chunk iterator*: it reads
``chunk_rows`` lines at a time and yields self-contained :class:`LibsvmChunk`
objects (local CSR layout), which ``repro.data.store.DatasetStore.write``
consumes to build the sharded on-disk store without ever materializing the
whole matrix.

Conventions (matching the LIBSVM distribution of the paper's datasets):

* indices are 1-based in the text unless ``zero_based=True``;
* labels parse to y ∈ {0, 1}: any label > 0 → 1.0, else 0.0 (covers the
  ``+1/-1`` and ``0/1`` conventions);
* ``# comment`` suffixes and ``qid:`` tokens are ignored;
* the writer emits values with ``%.17g`` so a float64 round-trips
  bit-for-bit through text — the store round-trip tests rely on this.
"""
from __future__ import annotations

import dataclasses
import io
from typing import IO, Iterable, Iterator, Union

import numpy as np

from repro.core.sparse.formats import HostCSR

PathOrFile = Union[str, "io.TextIOBase", IO[str]]


@dataclasses.dataclass
class LibsvmChunk:
    """A contiguous block of rows in local CSR layout.

    ``indptr`` is chunk-local (``indptr[0] == 0``); ``cols`` are global
    0-based column ids; ``y`` is float64 in {0, 1}.
    """

    y: np.ndarray        # (rows,)  float64
    indptr: np.ndarray   # (rows+1,) int64, local
    cols: np.ndarray     # (nnz,)   int64
    vals: np.ndarray     # (nnz,)   float64

    @property
    def n_rows(self) -> int:
        return int(self.y.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.cols.shape[0])

    @property
    def max_col(self) -> int:
        return int(self.cols.max()) if self.nnz else -1


def _parse_line(line: str, zero_based: bool):
    """One libsvm line -> (label, [cols], [vals]); None for blank/comment."""
    hash_pos = line.find("#")
    if hash_pos >= 0:
        line = line[:hash_pos]
    parts = line.split()
    if not parts:
        return None
    label = float(parts[0])
    cols, vals = [], []
    off = 0 if zero_based else 1
    for tok in parts[1:]:
        if tok.startswith("qid:"):
            continue
        idx_s, _, val_s = tok.partition(":")
        j = int(idx_s) - off
        if j < 0:
            raise ValueError(f"column index {idx_s} underflows "
                             f"(zero_based={zero_based})")
        cols.append(j)
        vals.append(float(val_s))
    return (1.0 if label > 0 else 0.0), cols, vals


def iter_libsvm(source: PathOrFile, chunk_rows: int = 8192,
                zero_based: bool = False) -> Iterator[LibsvmChunk]:
    """Stream a libsvm text file as :class:`LibsvmChunk` blocks.

    Memory is bounded by ``chunk_rows`` rows (plus their nonzeros) — the full
    COO is never materialized, which is what lets ingestion scale to files
    larger than RAM.
    """
    if chunk_rows < 1:
        raise ValueError("chunk_rows must be >= 1")
    own = isinstance(source, str)
    fh = open(source, "r") if own else source
    try:
        ys, lens, cols, vals = [], [], [], []
        for line in fh:
            parsed = _parse_line(line, zero_based)
            if parsed is None:
                continue
            label, c, v = parsed
            ys.append(label)
            lens.append(len(c))
            cols.extend(c)
            vals.extend(v)
            if len(ys) >= chunk_rows:
                yield _make_chunk(ys, lens, cols, vals)
                ys, lens, cols, vals = [], [], [], []
        if ys:
            yield _make_chunk(ys, lens, cols, vals)
    finally:
        if own:
            fh.close()


def _make_chunk(ys, lens, cols, vals) -> LibsvmChunk:
    indptr = np.zeros(len(ys) + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    return LibsvmChunk(
        y=np.asarray(ys, dtype=np.float64),
        indptr=indptr,
        cols=np.asarray(cols, dtype=np.int64),
        vals=np.asarray(vals, dtype=np.float64))


def chunks_from_arrays(X: HostCSR, y: np.ndarray,
                       chunk_rows: int = 8192) -> Iterator[LibsvmChunk]:
    """Adapt an in-memory (HostCSR, y) pair to the streaming chunk protocol."""
    y = np.asarray(y, dtype=np.float64)
    if y.shape[0] != X.shape[0]:
        raise ValueError("X/y row mismatch")
    for lo in range(0, X.shape[0], chunk_rows):
        hi = min(lo + chunk_rows, X.shape[0])
        p0, p1 = int(X.indptr[lo]), int(X.indptr[hi])
        yield LibsvmChunk(
            y=y[lo:hi].copy(),
            indptr=(X.indptr[lo:hi + 1] - X.indptr[lo]).astype(np.int64),
            cols=X.indices[p0:p1].astype(np.int64),
            vals=X.data[p0:p1].astype(np.float64))


def write_libsvm(dest: PathOrFile, X: HostCSR, y: np.ndarray,
                 zero_based: bool = False) -> None:
    """Write (X, y) as libsvm text; values use %.17g (float64-exact)."""
    y = np.asarray(y)
    own = isinstance(dest, str)
    fh = open(dest, "w") if own else dest
    off = 0 if zero_based else 1
    try:
        for i in range(X.shape[0]):
            idx, val = X.row(i)
            feats = " ".join(f"{int(j) + off}:{v:.17g}"
                             for j, v in zip(idx, val))
            fh.write(f"{y[i]:g} {feats}\n" if feats else f"{y[i]:g}\n")
    finally:
        if own:
            fh.close()


def iter_any(chunks_or_csr, y=None, chunk_rows: int = 8192
             ) -> Iterable[LibsvmChunk]:
    """Normalize store ingestion input: chunk iterable | (HostCSR, y)."""
    if isinstance(chunks_or_csr, HostCSR):
        if y is None:
            raise ValueError("labels required when ingesting a HostCSR")
        return chunks_from_arrays(chunks_or_csr, y, chunk_rows)
    return chunks_or_csr
