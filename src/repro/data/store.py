"""Sharded on-disk sparse dataset store with mmap views and cached setup.

The paper's pipeline starts from huge static sparse datasets (Table 2: up to
8.4M rows × 20.2M features) that every (λ, ε) grid point and every tenant
re-reads.  ``DatasetStore`` materializes a dataset **once**:

    <root>/
      manifest.json                   shapes, dtypes, per-shard nnz, hash
      shard-00000.indptr.npy          int64 (rows+1,), shard-local
      shard-00000.indices.npy         int64 (nnz,), global column ids
      shard-00000.data.npy            float64 (nnz,)
      shard-00000.y.npy               float64 (rows,)
      colstats.npz                    df / norm_sq / col_sum / col_y_sum
      cache/padded-{csr,csc}.*.npy    ELL padded device layout (mmap-read)
      cache/setup-<loss>-<mode>.npz   fw_setup state (v̄₀, q̄₀, α₀), float32

* **Ingestion is streaming**: ``DatasetStore.write`` consumes the chunk
  protocol of ``repro.data.sparse_io`` (libsvm parser or in-memory adapter),
  holding at most one shard in RAM, and accumulates the O(NS) per-column
  statistics (df counts, L2 norms, plain and label-weighted column sums) in
  the same single pass — the setup sweep becomes a one-time ingest cost.
* **Reads are zero-copy**: ``shard(i)`` returns a ``HostCSR`` over
  ``np.load(..., mmap_mode="r")`` views; arrays are stored in the exact
  dtypes ``HostCSR`` wants (int64/float64) so no conversion copy happens.
* **Splits are deterministic**: ``split`` hashes global row ids (splitmix64)
  so train/test membership is a pure function of (row, salt) — stable across
  processes, machines and shard layout.
* **Setup is cached**: ``prepared()`` returns a
  ``repro.core.solvers.prepared.PreparedDataset`` whose fw_setup state is
  persisted under ``cache/`` on first computation and replayed bit-for-bit
  afterwards, so warm solves skip the O(nnz) setup spmv entirely.
  ``setup_streamed`` provides the out-of-core equivalent: α₀ rebuilt in
  O(D) from the ingest-time column stats, one shard in memory at a time.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.sparse.formats import HostCSR
from repro.data.sparse_io import LibsvmChunk, iter_any


def _cache_count(cache: str, hit: bool) -> None:
    obs.count("store.cache", cache=cache, outcome="hit" if hit else "miss")

FORMAT_VERSION = 1
MANIFEST = "manifest.json"
COLSTATS = "colstats.npz"
CACHE_DIR = "cache"


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Per-column O(NS) ingest-pass products (the solvers' setup currency).

    ``col_y_sum`` is ``Xᵀy`` unnormalized; with ``col_sum`` it rebuilds the
    Frank-Wolfe setup state in O(D): ȳ = col_y_sum/N and, since v̄₀ = 0 makes
    q̄₀ = h(0)·1 constant for every supported loss,
    α₀ = h(0)·col_sum/N − ȳ.  No data pass required.
    """

    df: np.ndarray         # (D,) int64   rows containing the column
    norm_sq: np.ndarray    # (D,) float64 Σ x_ij²
    col_sum: np.ndarray    # (D,) float64 Σ x_ij
    col_y_sum: np.ndarray  # (D,) float64 Σ x_ij·y_i

    @property
    def norm(self) -> np.ndarray:
        return np.sqrt(self.norm_sq)


def _hash01(idx: np.ndarray, salt: int) -> np.ndarray:
    """splitmix64 finalizer → uniform [0, 1) per global row id (+ salt)."""
    x = idx.astype(np.uint64)
    x = x + np.uint64((0x9E3779B97F4A7C15 * (salt + 1)) & 0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return (x >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def _grow_to(arr: np.ndarray, size: int) -> np.ndarray:
    if arr.shape[0] >= size:
        return arr
    out = np.zeros(max(size, 2 * arr.shape[0]), dtype=arr.dtype)
    out[:arr.shape[0]] = arr
    return out


class _ShardWriter:
    """Buffers chunks; flushes ≥ rows_per_shard rows as one on-disk shard."""

    def __init__(self, root: str, rows_per_shard: int):
        self.root = root
        self.rows_per_shard = rows_per_shard
        self.buf: List[LibsvmChunk] = []
        self.buf_rows = 0
        self.shards: List[dict] = []

    def add(self, chunk: LibsvmChunk) -> None:
        self.buf.append(chunk)
        self.buf_rows += chunk.n_rows
        while self.buf_rows >= self.rows_per_shard:
            self._flush(self.rows_per_shard)

    def finish(self) -> List[dict]:
        if self.buf_rows:
            self._flush(self.buf_rows)
        return self.shards

    def _flush(self, rows: int) -> None:
        take, rest, got = [], [], 0
        for c in self.buf:
            if got >= rows:
                rest.append(c)
            elif got + c.n_rows <= rows:
                take.append(c)
                got += c.n_rows
            else:  # split a chunk at the shard boundary
                cut = rows - got
                p = int(c.indptr[cut])
                take.append(LibsvmChunk(c.y[:cut], c.indptr[:cut + 1].copy(),
                                        c.cols[:p], c.vals[:p]))
                rest.append(LibsvmChunk(c.y[cut:], c.indptr[cut:] - p,
                                        c.cols[p:], c.vals[p:]))
                got = rows
        self.buf, self.buf_rows = rest, sum(c.n_rows for c in rest)

        indptr = np.zeros(rows + 1, dtype=np.int64)
        pos = 0
        for c in take:
            indptr[pos + 1: pos + c.n_rows + 1] = indptr[pos] + c.indptr[1:]
            pos += c.n_rows
        cols = np.concatenate([c.cols for c in take]) if take else \
            np.zeros(0, np.int64)
        vals = np.concatenate([c.vals for c in take]) if take else \
            np.zeros(0, np.float64)
        y = np.concatenate([c.y for c in take]) if take else \
            np.zeros(0, np.float64)

        i = len(self.shards)
        base = os.path.join(self.root, f"shard-{i:05d}")
        np.save(base + ".indptr.npy", indptr)
        np.save(base + ".indices.npy", cols.astype(np.int64))
        np.save(base + ".data.npy", vals.astype(np.float64))
        np.save(base + ".y.npy", y.astype(np.float64))
        self.shards.append({"rows": rows, "nnz": int(cols.shape[0])})


class DatasetStore:
    """Open/written handle over one sharded on-disk sparse dataset."""

    def __init__(self, root: str, manifest: dict):
        self.root = root
        self.manifest = manifest
        self._labels: Optional[np.ndarray] = None
        self._csr: Optional[HostCSR] = None
        self._stats: Optional[ColumnStats] = None
        self._prepared = None
        self._row_starts = np.concatenate(
            [[0], np.cumsum([s["rows"] for s in manifest["shards"]])]
        ).astype(np.int64)

    # ------------------------------------------------------------ properties
    @property
    def n(self) -> int:
        return int(self.manifest["n"])

    @property
    def d(self) -> int:
        return int(self.manifest["d"])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.d)

    @property
    def nnz(self) -> int:
        return int(self.manifest["nnz"])

    @property
    def n_shards(self) -> int:
        return len(self.manifest["shards"])

    @property
    def content_hash(self) -> str:
        return self.manifest["content_hash"]

    # ------------------------------------------------------------- write/open
    @classmethod
    def write(cls, root: str, chunks: Iterable[LibsvmChunk], *,
              n_cols: Optional[int] = None, rows_per_shard: int = 65536,
              source: Optional[dict] = None) -> "DatasetStore":
        """Stream ``chunks`` (see ``sparse_io``) into a new store at ``root``.

        One pass, bounded memory: shards are flushed every ``rows_per_shard``
        rows; column stats and the content hash accumulate alongside.  ``d``
        is ``n_cols`` when given, else ``max column id + 1``.

        The write is atomic at the directory level: everything lands in a
        sibling temp dir that replaces ``root`` only once the manifest is
        complete, so an interrupted (re)build leaves either the previous
        store intact or no store at all — never a mixed one that
        ``open()`` would happily serve.
        """
        if rows_per_shard < 1:
            raise ValueError("rows_per_shard must be >= 1")
        root = os.path.normpath(root)
        tmp_root = f"{root}.tmp-{os.getpid()}"
        if os.path.exists(tmp_root):
            shutil.rmtree(tmp_root)
        os.makedirs(tmp_root)
        os.makedirs(os.path.join(tmp_root, CACHE_DIR))
        writer = _ShardWriter(tmp_root, rows_per_shard)
        row_nnz_max = 0
        # one hasher per logical stream so the digest is invariant to chunk
        # geometry: the same rows hash identically however they arrive
        h_lens, h_cols, h_vals, h_y = (hashlib.sha256() for _ in range(4))
        size0 = n_cols or 1024
        df = np.zeros(size0, np.int64)
        norm_sq = np.zeros(size0, np.float64)
        col_sum = np.zeros(size0, np.float64)
        col_y_sum = np.zeros(size0, np.float64)
        n = nnz = 0
        max_col = -1
        for chunk in chunks:
            if chunk.n_rows == 0:
                continue
            row_lens = np.diff(chunk.indptr).astype(np.int64)
            if row_lens.size:
                row_nnz_max = max(row_nnz_max, int(row_lens.max()))
            h_lens.update(row_lens.tobytes())
            h_cols.update(chunk.cols.astype(np.int64).tobytes())
            h_vals.update(chunk.vals.astype(np.float64).tobytes())
            h_y.update(chunk.y.astype(np.float64).tobytes())
            if chunk.nnz:
                max_col = max(max_col, chunk.max_col)
                df = _grow_to(df, max_col + 1)
                norm_sq = _grow_to(norm_sq, max_col + 1)
                col_sum = _grow_to(col_sum, max_col + 1)
                col_y_sum = _grow_to(col_y_sum, max_col + 1)
                # bincount, not np.add.at: this is the ingest hot loop and
                # the unbuffered ufunc scatter is ~10-50x slower per nnz
                df += np.bincount(chunk.cols, minlength=df.size)
                norm_sq += np.bincount(chunk.cols, weights=chunk.vals ** 2,
                                       minlength=norm_sq.size)
                col_sum += np.bincount(chunk.cols, weights=chunk.vals,
                                       minlength=col_sum.size)
                y_rep = np.repeat(chunk.y, np.diff(chunk.indptr))
                col_y_sum += np.bincount(chunk.cols,
                                         weights=chunk.vals * y_rep,
                                         minlength=col_y_sum.size)
            n += chunk.n_rows
            nnz += chunk.nnz
            writer.add(chunk)
        shards = writer.finish()
        d = n_cols if n_cols is not None else max_col + 1
        if max_col >= d:
            raise ValueError(f"column id {max_col} >= n_cols={d}")
        manifest = {
            "format_version": FORMAT_VERSION,
            "n": n, "d": d, "nnz": nnz,
            "index_dtype": "int64", "value_dtype": "float64",
            "rows_per_shard": rows_per_shard,
            "shards": shards,
            # max row/col nnz: the planner's O(1) ProblemStats source —
            # col max is exact off the df counts (one per stored entry)
            "row_nnz_max": row_nnz_max,
            "col_nnz_max": int(df[:d].max()) if d else 0,
            "content_hash": hashlib.sha256(
                b"".join(h.digest()
                         for h in (h_lens, h_cols, h_vals, h_y))).hexdigest(),
            "source": source or {},
            "created_unix": time.time(),
        }
        np.savez(os.path.join(tmp_root, COLSTATS),
                 df=df[:d].copy(), norm_sq=norm_sq[:d].copy(),
                 col_sum=col_sum[:d].copy(), col_y_sum=col_y_sum[:d].copy())
        with open(os.path.join(tmp_root, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
        # commit: swap the finished temp dir into place
        if os.path.exists(root):
            shutil.rmtree(root)
        os.makedirs(os.path.dirname(root) or ".", exist_ok=True)
        os.rename(tmp_root, root)
        return cls(root, manifest)

    @classmethod
    def from_arrays(cls, root: str, X: HostCSR, y, *,
                    rows_per_shard: int = 65536, chunk_rows: int = 8192,
                    source: Optional[dict] = None) -> "DatasetStore":
        """Materialize an in-memory (HostCSR, y) pair through the store."""
        return cls.write(root, iter_any(X, y, chunk_rows), n_cols=X.shape[1],
                         rows_per_shard=rows_per_shard, source=source)

    @classmethod
    def open(cls, root: str) -> "DatasetStore":
        path = os.path.join(root, MANIFEST)
        if not os.path.exists(path):
            raise FileNotFoundError(f"no dataset store at {root!r} "
                                    f"(missing {MANIFEST})")
        with open(path) as f:
            manifest = json.load(f)
        if manifest.get("format_version") != FORMAT_VERSION:
            raise ValueError(
                f"store {root!r} has format_version "
                f"{manifest.get('format_version')}, expected {FORMAT_VERSION}")
        return cls(root, manifest)

    @staticmethod
    def exists(root: str) -> bool:
        return os.path.exists(os.path.join(root, MANIFEST))

    # ----------------------------------------------------------------- reads
    def _shard_base(self, i: int) -> str:
        if not 0 <= i < self.n_shards:
            raise IndexError(f"shard {i} out of range [0, {self.n_shards})")
        return os.path.join(self.root, f"shard-{i:05d}")

    def shard(self, i: int) -> HostCSR:
        """Zero-copy mmap ``HostCSR`` view of shard ``i`` (global col ids)."""
        base = self._shard_base(i)
        indptr = np.load(base + ".indptr.npy", mmap_mode="r")
        indices = np.load(base + ".indices.npy", mmap_mode="r")
        data = np.load(base + ".data.npy", mmap_mode="r")
        return HostCSR(indptr, indices, data,
                       (self.manifest["shards"][i]["rows"], self.d))

    def shard_labels(self, i: int) -> np.ndarray:
        return np.load(self._shard_base(i) + ".y.npy", mmap_mode="r")

    def shard_row_range(self, i: int) -> Tuple[int, int]:
        return int(self._row_starts[i]), int(self._row_starts[i + 1])

    def iter_shards(self):
        """(row_start, HostCSR view, labels view) per shard — the out-of-core
        access pattern: one shard resident at a time."""
        for i in range(self.n_shards):
            yield int(self._row_starts[i]), self.shard(i), self.shard_labels(i)

    def labels(self) -> np.ndarray:
        if self._labels is None:
            self._labels = (
                np.concatenate([self.shard_labels(i)
                                for i in range(self.n_shards)])
                if self.n_shards else np.zeros(0, np.float64))
        return self._labels

    def to_host_csr(self) -> HostCSR:
        """The whole dataset as one ``HostCSR``.

        Single-shard stores stay zero-copy (the mmap views pass straight
        through); multi-shard stores concatenate — use ``iter_shards`` when
        N×S does not fit in RAM.
        """
        if self._csr is None:
            if self.n_shards == 1:
                self._csr = self.shard(0)
            else:
                parts = [self.shard(i) for i in range(self.n_shards)]
                indptr = np.zeros(self.n + 1, np.int64)
                pos = 0
                for p in parts:
                    rows = p.shape[0]
                    indptr[pos + 1: pos + rows + 1] = \
                        indptr[pos] + p.indptr[1:]
                    pos += rows
                self._csr = HostCSR(
                    indptr,
                    np.concatenate([p.indices for p in parts])
                    if parts else np.zeros(0, np.int64),
                    np.concatenate([p.data for p in parts])
                    if parts else np.zeros(0, np.float64),
                    self.shape)
        return self._csr

    def col_stats(self) -> ColumnStats:
        if self._stats is None:
            with np.load(os.path.join(self.root, COLSTATS)) as z:
                self._stats = ColumnStats(df=z["df"], norm_sq=z["norm_sq"],
                                          col_sum=z["col_sum"],
                                          col_y_sum=z["col_y_sum"])
        return self._stats

    # ---------------------------------------------------------------- splits
    def split(self, test_frac: float = 0.2, salt: int = 0
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic hash-based (train_rows, test_rows) global row ids."""
        if not 0.0 <= test_frac <= 1.0:
            raise ValueError("test_frac must be in [0, 1]")
        u = _hash01(np.arange(self.n, dtype=np.int64), salt)
        test = u < test_frac
        idx = np.arange(self.n, dtype=np.int64)
        return idx[~test], idx[test]

    def take(self, rows: Sequence[int]) -> Tuple[HostCSR, np.ndarray]:
        """Materialize a row subset as an exact in-memory (HostCSR, y).

        Output rows follow the order of ``rows`` (duplicates allowed), so a
        shuffled permutation yields a shuffled matrix.
        """
        rows = np.asarray(rows, dtype=np.int64)
        order = np.argsort(rows, kind="stable")
        sorted_rows = rows[order]
        if rows.size and (sorted_rows[0] < 0 or sorted_rows[-1] >= self.n):
            raise IndexError("row id out of range")
        lens_parts, idx_parts, val_parts, y_parts = [], [], [], []
        for si in range(self.n_shards):
            lo, hi = self.shard_row_range(si)
            local = sorted_rows[(sorted_rows >= lo) & (sorted_rows < hi)] - lo
            if local.size == 0:
                continue
            csr = self.shard(si)
            starts = csr.indptr[local]
            lens = csr.indptr[local + 1] - starts
            total = int(lens.sum())
            take_idx = (np.repeat(starts - np.concatenate(
                [[0], np.cumsum(lens)[:-1]]), lens)
                + np.arange(total)) if total else np.zeros(0, np.int64)
            lens_parts.append(lens)
            idx_parts.append(np.asarray(csr.indices[take_idx]))
            val_parts.append(np.asarray(csr.data[take_idx]))
            y_parts.append(np.asarray(self.shard_labels(si))[local])
        lens_sorted = np.concatenate(lens_parts) if lens_parts else \
            np.zeros(0, np.int64)
        idx_sorted = np.concatenate(idx_parts) if idx_parts \
            else np.zeros(0, np.int64)
        val_sorted = np.concatenate(val_parts) if val_parts \
            else np.zeros(0, np.float64)
        y_sorted = np.concatenate(y_parts) if y_parts \
            else np.zeros(0, np.float64)
        # un-sort: output position i holds the row rows[i]
        inv = np.empty(rows.size, np.int64)
        inv[order] = np.arange(rows.size)
        indptr_sorted = np.zeros(rows.size + 1, np.int64)
        np.cumsum(lens_sorted, out=indptr_sorted[1:])
        starts = indptr_sorted[inv]
        lens = lens_sorted[inv]
        total = int(lens.sum())
        gather = (np.repeat(starts - np.concatenate(
            [[0], np.cumsum(lens)[:-1]]), lens)
            + np.arange(total)) if total else np.zeros(0, np.int64)
        indptr = np.zeros(rows.size + 1, np.int64)
        np.cumsum(lens, out=indptr[1:])
        return (HostCSR(indptr, idx_sorted[gather], val_sorted[gather],
                        (rows.size, self.d)),
                y_sorted[inv])

    # ------------------------------------------------------- solver adapters
    def _padded_meta_path(self) -> str:
        return os.path.join(self.root, CACHE_DIR, "padded-meta.json")

    def _padded_load(self):
        """The padded ELL pair straight off mmap, or None on cache miss."""
        meta_path = self._padded_meta_path()
        if not os.path.exists(meta_path):
            _cache_count("padded", hit=False)
            return None
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("content_hash") != self.content_hash:
            _cache_count("padded", hit=False)
            return None
        _cache_count("padded", hit=True)
        import jax.numpy as jnp

        from repro.core.sparse.formats import PaddedCSC, PaddedCSR

        def arrays(kind):
            base = os.path.join(self.root, CACHE_DIR, f"padded-{kind}")
            return tuple(jnp.asarray(
                np.load(f"{base}.{part}.npy", mmap_mode="r"))
                for part in ("indices", "values", "nnz"))

        return (PaddedCSR(*arrays("csr"), shape=self.shape),
                PaddedCSC(*arrays("csc"), shape=self.shape))

    def _padded_save(self, pcsr, pcsc) -> None:
        os.makedirs(os.path.join(self.root, CACHE_DIR), exist_ok=True)
        for kind, p in (("csr", pcsr), ("csc", pcsc)):
            base = os.path.join(self.root, CACHE_DIR, f"padded-{kind}")
            np.save(f"{base}.indices.npy", np.asarray(p.indices))
            np.save(f"{base}.values.npy", np.asarray(p.values))
            np.save(f"{base}.nnz.npy", np.asarray(p.nnz))
        with open(self._padded_meta_path(), "w") as f:
            json.dump({"content_hash": self.content_hash}, f)

    def _blocks_meta_path(self, a: int, b: int) -> str:
        return os.path.join(self.root, CACHE_DIR, f"blocks-{a}x{b}-meta.json")

    def blocks_load(self, a: int, b: int):
        """The cached (a × b) ``BlockSparse`` layout off mmap, or None.

        Third cache layer alongside padded/setup (DESIGN.md §8): the
        ``jax_shard`` backend's block bucketing is an O(nnz) host pass, so
        warm opens replay the padded block arrays straight from ``cache/``
        — guarded, like the others, by the store's content hash.
        """
        meta_path = self._blocks_meta_path(a, b)
        if not os.path.exists(meta_path):
            _cache_count("blocks", hit=False)
            return None
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("content_hash") != self.content_hash:
            _cache_count("blocks", hit=False)
            return None
        _cache_count("blocks", hit=True)
        import jax.numpy as jnp

        from repro.distributed.block_sparse import BlockSparse
        base = os.path.join(self.root, CACHE_DIR, f"blocks-{a}x{b}")
        arrays = {
            part: jnp.asarray(np.load(f"{base}.{part}.npy", mmap_mode="r"))
            for part in ("csc_rows", "csc_vals", "csr_cols", "csr_vals")}
        return BlockSparse(shape=tuple(meta["shape"]),
                           padded=tuple(meta["padded"]), **arrays)

    def blocks_save(self, a: int, b: int, blocks) -> None:
        os.makedirs(os.path.join(self.root, CACHE_DIR), exist_ok=True)
        base = os.path.join(self.root, CACHE_DIR, f"blocks-{a}x{b}")
        for part in ("csc_rows", "csc_vals", "csr_cols", "csr_vals"):
            np.save(f"{base}.{part}.npy", np.asarray(getattr(blocks, part)))
        with open(self._blocks_meta_path(a, b), "w") as f:
            json.dump({"content_hash": self.content_hash,
                       "shape": list(blocks.shape),
                       "padded": list(blocks.padded)}, f)

    def _autotune_path(self, backend: str, loss: str, platform: str) -> str:
        return os.path.join(self.root, CACHE_DIR,
                            f"autotune-{backend}-{loss}-{platform}.json")

    def autotune_load(self, backend: str, loss: str, platform: str):
        """The persisted §11 ``TuningRecord`` for (backend, loss, platform),
        or None — fourth cache layer alongside padded/setup/blocks, guarded
        like the others by the store's content hash (and the tuner's record
        version, so stale search formats never replay)."""
        path = self._autotune_path(backend, loss, platform)
        if not os.path.exists(path):
            _cache_count("autotune", hit=False)
            return None
        from repro.core.solvers.autotune import TuningRecord
        with open(path) as f:
            rec = TuningRecord.from_json(json.load(f))
        if rec is None or rec.content_hash != self.content_hash:
            _cache_count("autotune", hit=False)
            return None
        _cache_count("autotune", hit=True)
        return rec

    def autotune_save(self, record) -> None:
        os.makedirs(os.path.join(self.root, CACHE_DIR), exist_ok=True)
        path = self._autotune_path(record.backend, record.loss,
                                   record.platform)
        with open(path, "w") as f:
            json.dump(record.to_json(), f, indent=1)

    def _setup_cache_path(self, loss: str, interpret: bool) -> str:
        mode = "interp" if interpret else "compiled"
        return os.path.join(self.root, CACHE_DIR, f"setup-{loss}-{mode}.npz")

    def _setup_load(self, loss: str, interpret: bool):
        path = self._setup_cache_path(loss, interpret)
        if not os.path.exists(path):
            _cache_count("setup", hit=False)
            return None
        import jax.numpy as jnp
        with np.load(path) as z:
            if str(z["content_hash"]) != self.content_hash:
                _cache_count("setup", hit=False)
                return None
            _cache_count("setup", hit=True)
            return (jnp.asarray(z["vbar0"]), jnp.asarray(z["qbar0"]),
                    jnp.asarray(z["alpha0"]))

    def _setup_save(self, loss: str, interpret: bool, state) -> None:
        vbar0, qbar0, alpha0 = (np.asarray(s) for s in state)
        os.makedirs(os.path.join(self.root, CACHE_DIR), exist_ok=True)
        np.savez(self._setup_cache_path(loss, interpret),
                 vbar0=vbar0, qbar0=qbar0, alpha0=alpha0,
                 content_hash=np.array(self.content_hash))

    def prepared(self):
        """Device-ready ``PreparedDataset`` (padded pair + setup cache).

        Built once per open store and memoized, so a fit service or a sweep
        re-draining the same store never re-pays padding or setup.  Both
        layers persist under ``cache/`` across processes: the padded ELL
        lanes are mmap-read on warm opens (skipping the per-row padding
        pass) and the fw_setup state is replayed bit-for-bit (skipping the
        O(nnz) setup spmv) — every cache file is guarded by the store's
        content hash.
        """
        if self._prepared is None:
            from repro.core.sparse.formats import host_to_padded
            from repro.core.solvers.prepared import PreparedDataset
            pair = self._padded_load()     # padded lanes straight off mmap
            if pair is None:
                pair = host_to_padded(self.to_host_csr())
                self._padded_save(*pair)
            pcsr, pcsc = pair
            self._prepared = PreparedDataset(
                pcsr=pcsr, pcsc=pcsc,
                y=np.asarray(self.labels(), np.float64),
                loader=self._setup_load, saver=self._setup_save,
                tuning_loader=self.autotune_load)
        return self._prepared

    def setup_streamed(self, loss: str = "logistic"):
        """Out-of-core fw_setup: (v̄₀, q̄₀, α₀) in O(D) from column stats.

        Because v̄₀ = 0 and labels are binary, the initial row gradient is an
        affine function of y: q̄₀_i = grad(0, y_i) = a + b·y_i with
        a = grad(0, 0) and b = grad(0, 1) − a (exact on y ∈ {0, 1}, the
        store's label contract — for separable losses this is the familiar
        constant h(0) minus the ȳ residual).  α₀ = Xᵀq̄₀/N then needs **no
        pass over the data**: (a·col_sum + b·col_y_sum)/N from the
        ingest-time column stats.  Float64 accumulation on host, cast to the
        device dtype; agrees with the kernel ``fw_setup`` to float32
        tolerance (not bit-for-bit — use ``prepared()`` when exact replay
        matters and the padded pair fits in memory).
        """
        import jax.numpy as jnp

        from repro.core.losses import get_loss
        obj = get_loss(loss)
        stats = self.col_stats()
        inv_n = 1.0 / max(self.n, 1)
        if obj.separable:
            # q̄₀ = h(0)·1; the engine keeps the ȳ residual out of q̄
            h0 = float(obj.split_grad(jnp.zeros(())))
            alpha0 = h0 * stats.col_sum * inv_n - stats.col_y_sum * inv_n
            qbar0 = jnp.full(self.n, h0, jnp.float32)
        else:
            # label-coupled: q̄₀ carries the full row gradient, no ȳ term
            zero = jnp.zeros(())
            a = float(obj.grad(zero, jnp.float32(0.0)))
            b = float(obj.grad(zero, jnp.float32(1.0))) - a
            alpha0 = (a * stats.col_sum + b * stats.col_y_sum) * inv_n
            y_host = np.asarray(self.labels(), np.float64)
            qbar0 = jnp.asarray(a + b * y_host, jnp.float32)
        return (jnp.zeros(self.n, jnp.float32), qbar0,
                jnp.asarray(alpha0, jnp.float32))


# ---------------------------------------------------------------------------
# DatasetRef — the name/path handle solvers accept in place of a matrix
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DatasetRef:
    """A by-name or by-path reference to a stored dataset (+ optional split).

    ``solve(DatasetRef("rcv1_like"), config=...)`` — labels come from the
    store; ``split="train"/"test"`` selects the deterministic hash split.
    Named refs resolve through ``repro.data.registry`` (generating and
    caching the dataset on first use); path refs open the store directly.
    """

    name: Optional[str] = None
    path: Optional[str] = None
    split: str = "all"            # all | train | test
    test_frac: float = 0.2
    salt: int = 0

    def __post_init__(self):
        if (self.name is None) == (self.path is None):
            raise ValueError("DatasetRef needs exactly one of name= or path=")
        if self.split not in ("all", "train", "test"):
            raise ValueError(f"unknown split {self.split!r}")

    def open(self) -> DatasetStore:
        if self.path is not None:
            return DatasetStore.open(self.path)
        from repro.data.registry import load
        return load(self.name)

    def resolve(self):
        """→ (data source, labels): the whole store for ``split="all"`` (so
        padded/setup caches apply), or a materialized row subset."""
        store = self.open()
        if self.split == "all":
            return store, store.labels()
        train, test = store.split(self.test_frac, self.salt)
        return store.take(train if self.split == "train" else test)
