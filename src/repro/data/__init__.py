from repro.data.synthetic import lm_batches, make_sparse_classification  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
from repro.data.sparse_io import (LibsvmChunk, iter_libsvm,  # noqa: F401
                                  write_libsvm)
from repro.data.store import ColumnStats, DatasetRef, DatasetStore  # noqa: F401
from repro.data.registry import (available_datasets, load,  # noqa: F401
                                 register_dataset)
