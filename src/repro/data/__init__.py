from repro.data.synthetic import lm_batches, make_sparse_classification  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
