"""Named-dataset registry: the paper's Table-2 regimes as cached stores.

``load("rcv1_like")`` returns a ``DatasetStore`` for a synthetic twin of the
named paper dataset — generated through ``make_sparse_classification`` on
first use, materialized through the store (shards + column stats + manifest),
and opened from disk ever after.  The generate-once/serve-many shape is the
point: every (λ, ε) grid, benchmark and tenant solves against the same
on-disk artifact instead of re-generating and re-coercing a matrix
per process.

Sizes mirror ``benchmarks/common.BENCH_SCALE`` (CPU-scale twins of the
paper's Table 2; N shrinks hard, D less, keeping the D ≫ N regime the
speedups live in).  The cache root is ``$REPRO_DATA_DIR`` when set, else
``~/.cache/repro/datasets``; a spec change (or a registry re-registration
with different parameters) invalidates the cached store via the spec
fingerprint recorded in its manifest.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

from repro.data.store import DatasetStore


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Generator parameters for one named synthetic dataset."""

    name: str
    n: int
    d: int
    nnz_per_row: float
    informative: int
    dense_features: int = 0
    seed: int = 0
    rows_per_shard: int = 4096

    def fingerprint(self) -> dict:
        return dataclasses.asdict(self)

    def generate(self):
        """(HostCSR, y) via the paper-matched synthetic generator."""
        from repro.data.synthetic import make_sparse_classification
        X, y, _ = make_sparse_classification(
            n=self.n, d=self.d, nnz_per_row=self.nnz_per_row,
            informative=self.informative, dense_features=self.dense_features,
            seed=self.seed)
        return X, y


# Table-2 twins at bench scale (see benchmarks/common.BENCH_SCALE and
# repro.configs.paper_lasso.DATASETS for the full-size statistics).
_REGISTRY: Dict[str, DatasetSpec] = {}


def register_dataset(spec: DatasetSpec) -> DatasetSpec:
    _REGISTRY[spec.name] = spec
    return spec


for _spec in (
    DatasetSpec("rcv1_like", n=2_000, d=4_800, nnz_per_row=40.0,
                informative=64),
    DatasetSpec("news20_like", n=1_000, d=135_000, nnz_per_row=110.0,
                informative=128),
    DatasetSpec("url_like", n=4_000, d=32_000, nnz_per_row=30.0,
                informative=64, dense_features=24),
    # CPU-friendly URL twin: same dense-informative-block structure, sized so
    # the padded CSC (D × max col nnz — the dense block pins that at N) stays
    # well under 100 MB for the ingest bench and the workflow example.
    DatasetSpec("url_small_like", n=1_500, d=8_000, nnz_per_row=25.0,
                informative=32, dense_features=16),
    DatasetSpec("web_like", n=1_200, d=166_000, nnz_per_row=260.0,
                informative=128),
    DatasetSpec("kdda_like", n=2_000, d=202_000, nnz_per_row=12.0,
                informative=64),
):
    register_dataset(_spec)


def available_datasets() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_spec(name: str) -> DatasetSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown dataset {name!r}; available: "
                         f"{', '.join(available_datasets())}") from None


def data_root(root: Optional[str] = None) -> str:
    if root is not None:
        return root
    env = os.environ.get("REPRO_DATA_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "datasets")


def load(name: str, root: Optional[str] = None) -> DatasetStore:
    """Open the named dataset's store, generating + ingesting on first use."""
    spec = get_spec(name)
    path = os.path.join(data_root(root), name)
    if DatasetStore.exists(path):
        store = DatasetStore.open(path)
        if store.manifest.get("source") == spec.fingerprint():
            return store
        # spec changed since this store was materialized: rebuild
    X, y = spec.generate()
    return DatasetStore.from_arrays(
        path, X, y, rows_per_shard=spec.rows_per_shard,
        source=spec.fingerprint())
