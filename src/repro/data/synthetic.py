"""Synthetic data generators.

* ``make_sparse_classification`` — sparse design matrices statistically
  matched to the paper's Table-2 datasets (N, D, nnz/row, an informative
  subset, and optionally a URL-style dense informative block).  Labels come
  from a planted sparse logistic model, so LASSO recovery is measurable.
* ``lm_batches`` — an infinite token stream with latent bigram structure
  (per-seed random Markov chain over a vocab subset) so LM training shows a
  real, decreasing loss rather than memorizing noise.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.sparse.formats import HostCSR, coo_to_host


def make_sparse_classification(
    n: int, d: int, nnz_per_row: float, informative: int,
    dense_features: int = 0, seed: int = 0, label_noise: float = 0.05,
) -> Tuple[HostCSR, np.ndarray, np.ndarray]:
    """Returns (X as HostCSR with values in [-1, 1], y ∈ {0,1}, true_w)."""
    rng = np.random.default_rng(seed)
    rows_list, cols_list, vals_list = [], [], []
    # heavy-tailed column popularity (text-like power law)
    col_p = 1.0 / np.arange(1, d + 1) ** 1.1
    col_p /= col_p.sum()

    nnz_row = np.maximum(1, rng.poisson(max(nnz_per_row - dense_features, 1), size=n))
    for i in range(n):
        k = min(int(nnz_row[i]), d)
        cols = rng.choice(d, size=k, replace=False, p=col_p) if d <= 200_000 else \
            np.unique(rng.zipf(1.3, size=k) % d)
        vals = rng.uniform(0.1, 1.0, size=cols.shape[0]) * rng.choice([-1.0, 1.0], size=cols.shape[0])
        rows_list.append(np.full(cols.shape[0], i))
        cols_list.append(cols)
        vals_list.append(vals)
    if dense_features:
        # URL-style: a dense informative block occupying the first columns
        dense_vals = np.clip(rng.normal(0, 0.5, size=(n, dense_features)), -1, 1)
        for j in range(dense_features):
            rows_list.append(np.arange(n))
            cols_list.append(np.full(n, j))
            vals_list.append(dense_vals[:, j])
    rows = np.concatenate(rows_list)
    cols = np.concatenate(cols_list)
    vals = np.concatenate(vals_list)
    # tf-idf-style column scaling + row normalization, matching the LIBSVM
    # preprocessing of the paper's datasets.  Without idf, the power-law-head
    # (dense) columns carry large CLT-noise gradients and Frank-Wolfe zig-zags
    # on them forever — real text data downweights frequent terms, which is
    # exactly what makes the paper's sparse updates pay off.
    df = np.bincount(cols, minlength=d).astype(np.float64)
    idf = np.log1p(n / np.maximum(df, 1.0))
    idf /= idf.max()
    is_text = cols >= dense_features  # the URL-style dense block skips idf
    vals = np.where(is_text, vals * idf[cols], vals)
    # unit-L2 rows (liblinear convention); keeps |x_ij| ≤ 1 for the DP
    # sensitivity bound
    sq = np.bincount(rows, weights=vals ** 2, minlength=n)
    norm = np.sqrt(np.maximum(sq, 1e-12))
    vals = vals / norm[rows]
    X = coo_to_host(rows, cols, vals, (n, d))

    # planted sparse weight vector.  Informative columns are drawn from the
    # *middle* of the popularity distribution (log-spread between rank ~10 and
    # D/4): real text corpora carry signal in moderately-frequent terms, not
    # only the few densest columns.  Planting on arange(informative) (= the
    # power-law head) makes every FW pick a near-dense column and erases the
    # sparse-update advantage — the paper's URL phenomenon, which we model
    # explicitly via ``dense_features`` instead.
    true_w = np.zeros(d)
    if dense_features:
        # URL-style: signal rides on the dense block
        info_idx = np.arange(min(informative, d))
    else:
        lo, hi = min(10, d - 1), max(d // 4, min(10, d - 1) + 1)
        cand = np.unique(np.geomspace(lo, hi, num=4 * informative).astype(int))
        info_idx = rng.choice(cand, size=min(informative, cand.shape[0]),
                              replace=False)
    true_w[info_idx] = rng.normal(0, 2.0, size=info_idx.shape[0])
    margins = X.matvec(true_w)
    p = 1.0 / (1.0 + np.exp(-margins))
    y = (rng.random(n) < p).astype(np.float64)
    flip = rng.random(n) < label_noise
    y[flip] = 1.0 - y[flip]
    return X, y, true_w


def make_markov_chain(vocab: int, seed: int, branching: int = 8):
    """Sparse random bigram transition table: token -> `branching` successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branching))
    logits = rng.normal(0, 1, size=(vocab, branching))
    probs = np.exp(logits)
    probs /= probs.sum(axis=1, keepdims=True)
    return succ, probs


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
               frames_dim: Optional[int] = None,
               enc_frac: float = 0.5) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {"tokens": (B,S) int32} (+ "frames" for enc-dec)."""
    succ, probs = make_markov_chain(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = np.empty((batch, seq), dtype=np.int32)
        cur = rng.integers(0, vocab, size=batch)
        for t in range(seq):
            toks[:, t] = cur
            choice = np.array([rng.choice(succ.shape[1], p=probs[c]) for c in cur])
            cur = succ[cur, choice]
        out = {"tokens": toks}
        if frames_dim is not None:
            s_enc = int(seq * enc_frac)
            out["frames"] = rng.normal(0, 1, size=(batch, s_enc, frames_dim)).astype(np.float32)
        yield out
