"""Sharding rules: pytree path + leaf shape → PartitionSpec.

MaxText-style named rules with a universal divisibility fallback: any dim
whose size does not divide the mesh axis is replicated instead (e.g. minicpm's
36 heads or GQA kv=8 against model=16) — recorded by ``explain`` so dry-run
reports show every fallback.

Rules are right-aligned: a rule written for the logical shape (D, F) applies
to a stacked (L, D, F) leaf with the leading dims replicated.
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, right-aligned spec) — first match wins.
_RULES: List[Tuple[str, Tuple]] = [
    # MoE expert-parallel weights (E, D, F) / (E, F, D): experts → model
    (r"moe/(w1|w2|w3)$", ("model", None, None)),
    (r"moe/router$", (None, None)),
    (r"moe/shared/(w1|w3)$", (None, "model")),
    (r"moe/shared/w2$", ("model", None)),
    # embeddings / head: vocab → model
    (r"embed$", ("model", None)),
    (r"head$", (None, "model")),
    # attention projections (megatron column/row parallel)
    (r"(wq|wuq|wk|wv|wuk|wuv)$", (None, "model")),
    (r"(wdq|wdkv)$", (None, None)),             # small latent down-projections
    (r"wo$", ("model", None)),
    # dense FFN
    (r"ffn/(w1|w3)$", (None, "model")),
    (r"ffn/w2$", ("model", None)),
    # mamba
    (r"in_proj$", (None, "model")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"x_proj$", ("model", None)),
    (r"dt_proj$", (None, "model")),
    (r"dt_bias$", ("model",)),
    (r"a_log$", ("model", None)),
    (r"d_skip$", ("model",)),
    (r"out_proj$", ("model", None)),
    # rg-lru
    (r"(in_x|in_gate)$", (None, "model")),
    (r"(w_r|w_i)$", (None, "model")),
    (r"lam$", ("model",)),
    (r"kind_r/out$", ("model", None)),
    # norms and everything else: replicated
    (r".*", ()),
]

# FSDP (ZeRO-3-style) rules: weights sharded over BOTH mesh axes so params +
# optimizer state scale as 1/(data·model).  Used for archs whose replicated-
# over-data state exceeds HBM (kimi-k2 1T, deepseek-v2 236B, chameleon-34B,
# nemotron-15B — see dryrun PERF table).  XLA inserts the per-layer weight
# all-gathers; the roofline's collective term prices them (§Perf records the
# memory-vs-ICI trade explicitly).
_RULES_FSDP: List[Tuple[str, Tuple]] = [
    (r"moe/(w1|w3)$", ("model", None, "data")),
    (r"moe/w2$", ("model", "data", None)),
    (r"moe/router$", (None, None)),
    (r"moe/shared/(w1|w3)$", ("data", "model")),
    (r"moe/shared/w2$", ("model", "data")),
    # embed/head stay vocab-(model-)sharded even under FSDP: the chunked CE
    # loss touches the head once per chunk — a doubly-sharded head would be
    # re-gathered 16×3 times per step (measured 150 GiB on nemotron §Perf i3)
    (r"embed$", ("model", None)),
    (r"head$", (None, "model")),
    (r"(wq|wuq|wk|wv|wuk|wuv)$", ("data", "model")),
    (r"(wdq|wdkv)$", ("data", None)),
    (r"wo$", ("model", "data")),
    (r"ffn/(w1|w3)$", ("data", "model")),
    (r"ffn/w2$", ("model", "data")),
    (r"in_proj$", ("data", "model")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"x_proj$", ("model", "data")),
    (r"dt_proj$", ("data", "model")),
    (r"dt_bias$", ("model",)),
    (r"a_log$", ("model", None)),
    (r"d_skip$", ("model",)),
    (r"out_proj$", ("model", "data")),
    (r"(in_x|in_gate)$", ("data", "model")),
    (r"(w_r|w_i)$", ("data", "model")),
    (r"lam$", ("model",)),
    (r"kind_r/out$", ("model", "data")),
    (r".*", ()),
]

# decode caches (right-aligned over the trailing dims); the "|"-separated
# alternatives are tried in order — first one whose dims all divide wins
# (e.g. KV=8 < model=16 → falls back to sharding head_dim instead).
_CACHE_RULES: List[Tuple[str, Any]] = [
    (r"(self_|cross_)?k$", [("data", None, "model", None),   # (B,S,KV,hd)
                            ("data", None, None, "model")]),
    (r"(self_|cross_)?v$", [("data", None, "model", None),
                            ("data", None, None, "model")]),
    (r"c$", [("data", None, "model")]),                      # MLA latent (B,S,kl)
    (r"kr$", [("data", None, None)]),
    (r"h$", [("data", "model", None)]),                      # mamba (B,di,N)
    (r"conv$", [("data", None, "model")]),                   # (B,K-1,di)
    (r"cross_len$", [()]),
    (r".*", [()]),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        v = getattr(p, "key", None)          # DictKey
        if v is None:
            v = getattr(p, "idx", None)      # SequenceKey
        if v is None:
            v = getattr(p, "name", None)     # GetAttrKey (TrainState fields)
        parts.append(str(p if v is None else v))
    return "/".join(parts)


def _sanitize(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh,
              log: Optional[list] = None, path: str = "") -> P:
    """Right-align, then drop any axis that doesn't divide its dim."""
    full = (None,) * (len(shape) - len(spec)) + tuple(spec)
    full = full[: len(shape)]
    out = []
    for dim, ax in zip(shape, full):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        if dim % total == 0 and dim > 0:
            out.append(ax)
        else:
            out.append(None)
            if log is not None:
                log.append(f"{path}: dim {dim} % {axes}({total}) != 0 → replicated")
    return P(*out)


def _spec_for(path: str, shape, mesh, rules, log=None) -> P:
    # strip train-state / optimizer-state prefixes so m/v/stats reuse the
    # param's rule ("opt_state/m/blocks/attn/wq" → "blocks/attn/wq")
    stripped = re.sub(r"^(params/|opt_state/)+", "", path)
    stripped = re.sub(r"^(m|v|stats)/", "", stripped)
    is_vr = stripped.endswith("/vr")
    is_vc = stripped.endswith("/vc")
    stripped = re.sub(r"/(vr|vc|v)$", "", stripped) if (is_vr or is_vc) else stripped
    for pat, spec in rules:
        if re.search(pat, stripped):
            if is_vr:
                # row stats: param shape minus last dim → drop last spec entry
                spec = tuple(spec[:-1]) if spec else ()
            elif is_vc:
                # col stats: param shape minus 2nd-to-last dim
                spec = tuple(s for i, s in enumerate(spec) if i != len(spec) - 2) if len(spec) >= 2 else spec
            return _sanitize(spec, shape, mesh, log, path)
    return P()


def params_shardings(abstract_tree, mesh: Mesh, log: Optional[list] = None,
                     *, fsdp=False):
    """NamedShardings for a params / opt-state / train-state pytree.

    ``fsdp`` grades how aggressively state is sharded over the data axis
    (§Perf iterations — each tier trades ICI traffic for HBM):

      False        params & opt state follow _RULES (model-axis only).
      "zero2"      opt state doubly sharded; params model-axis only — one
                   param-delta all-gather per step, no per-layer gathers.
      "zero3_moe"  zero2 + expert weights doubly sharded (MoE params are
                   the bulk; their contraction keeps the sharded dim local,
                   so no full-weight gather is forced).
      True/"zero3" everything doubly sharded (max memory savings; weight
                   all-gather per layer per microbatch — measured 462 GiB
                   collective on nemotron train, kept only as a knob).
    """
    def pick_rules(path: str):
        is_opt = path.startswith("opt_state")
        if fsdp is False or fsdp is None:
            return _RULES
        if fsdp == "zero2":
            return _RULES_FSDP if is_opt else _RULES
        if fsdp == "zero3_moe":
            is_expert = re.search(r"moe/(w1|w2|w3)$", path) is not None
            return _RULES_FSDP if (is_opt or is_expert) else _RULES
        return _RULES_FSDP  # True / "zero3"

    def leaf_spec(path, leaf):
        p = _path_str(path)
        spec = _spec_for(p, leaf.shape, mesh, pick_rules(p), log)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_tree)


def cache_shardings(abstract_tree, mesh: Mesh, log: Optional[list] = None):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fits(spec, shape) -> bool:
        full = (None,) * (len(shape) - len(spec)) + tuple(spec)
        for dim, ax in zip(shape, full[: len(shape)]):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= sizes[a]
            if dim % total != 0 or dim == 0:
                return False
        return True

    def leaf_spec(path, leaf):
        p = _path_str(path)
        for pat, alternatives in _CACHE_RULES:
            if re.search(pat, p):
                for spec in alternatives:
                    if _fits(spec, leaf.shape):
                        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh, None, p))
                # none fits fully — sanitize the first (per-dim fallback)
                return NamedSharding(mesh, _sanitize(alternatives[0], leaf.shape, mesh, log, p))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_tree)


def batch_shardings(abstract_tree, mesh: Mesh, log: Optional[list] = None,
                    *, axes: Optional[Tuple[str, ...]] = None):
    """Batch inputs: leading dim over (pod, data) — or ``axes`` when the
    full-DP layout also spreads the batch over "model" (§Perf dp="full")."""
    baxes = axes or (("pod", "data") if "pod" in mesh.axis_names else ("data",))
    baxes = tuple(a for a in baxes if a in mesh.axis_names)

    def leaf_spec(path, leaf):
        spec = _sanitize((baxes,), leaf.shape, mesh, log, _path_str(path))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
