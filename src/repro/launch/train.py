"""Training launcher — LM architectures and the paper's DP-LASSO runs.

Examples (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \\
      --steps 50 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch paper-lasso \\
      --dataset rcv1 --smoke --steps 500 --epsilon 1.0

Production path (TPU pod): the same entry point with --mesh data,model picks
up the production mesh and pjit shardings from launch/sharding.py; elastic
resume re-places a checkpoint onto whatever mesh is live (--resume).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_lm(args) -> dict:
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.loader import ShardedLoader
    from repro.data.synthetic import lm_batches
    from repro.models.registry import get_model
    from repro.train.optimizer import get_optimizer
    from repro.train.trainer import TrainConfig, TrainState, fit, make_train_step

    api = get_model(args.arch, smoke=args.smoke)
    cfg = api.cfg
    tc = TrainConfig(optimizer=cfg.optimizer, peak_lr=args.lr,
                     total_steps=args.steps, warmup=max(args.steps // 20, 5),
                     microbatches=args.microbatches,
                     schedule="wsd" if args.arch == "minicpm-2b" else "cosine")
    opt = get_optimizer(tc.optimizer)
    params = api.init(jax.random.PRNGKey(args.seed))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {args.arch}{' (smoke)' if args.smoke else ''}: "
          f"{n_params/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch}×{args.seq}")

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        print(f"[train] resumed from step {meta.get('step')}")

    frames = cfg.d_model if cfg.family == "encdec" else None
    stream = lm_batches(cfg.vocab, args.batch, args.seq, seed=args.seed,
                        frames_dim=frames)
    loader = ShardedLoader(stream)
    step_fn = make_train_step(api.loss, tc)
    t0 = time.time()
    state, history = fit(state, step_fn, loader, steps=args.steps,
                         checkpointer=ckpt, ckpt_every=args.ckpt_every,
                         log_every=max(args.steps // 20, 1))
    loader.close()
    wall = time.time() - t0
    first, last = history[0]["loss"], history[-1]["loss"]
    tok_s = args.steps * args.batch * args.seq / wall
    print(f"[train] done: loss {first:.3f} → {last:.3f} "
          f"({wall:.1f}s, {tok_s:.0f} tok/s)")
    return {"arch": args.arch, "loss_first": first, "loss_last": last,
            "wall_s": wall, "tokens_per_s": tok_s, "history": history}


def train_lasso(args) -> dict:
    from repro.configs.paper_lasso import DATASETS, SMOKE
    from repro.core.dp.accountant import PrivacyAccountant
    from repro.core.fw_jax import SparseJaxConfig, sparse_fw_jax
    from repro.core.sparse.formats import host_to_padded
    from repro.data.synthetic import make_sparse_classification

    ds = (SMOKE if args.smoke else DATASETS)[args.dataset]
    X, y, _ = make_sparse_classification(
        ds.n, ds.d, ds.nnz_per_row, ds.informative,
        dense_features=ds.dense_features, seed=args.seed)
    pcsr, pcsc = host_to_padded(X)
    cfg = SparseJaxConfig(lam=args.lam, steps=args.steps, epsilon=args.epsilon,
                          delta=1.0 / ds.n ** 2, seed=args.seed,
                          queue="two_level" if args.epsilon > 0 else "group_argmax")
    print(f"[lasso] {ds.name}: N={ds.n} D={ds.d} nnz/row≈{ds.nnz_per_row} "
          f"T={args.steps} λ={args.lam} ε={args.epsilon}")
    t0 = time.time()
    res = sparse_fw_jax(pcsr, pcsc, jnp.asarray(y, jnp.float32), cfg)
    jax.block_until_ready(res.w)
    wall = time.time() - t0
    margins = np.asarray(pcsr.matvec(res.w))
    acc = float(((margins > 0) == (y > 0.5)).mean())
    nnz = int(np.sum(np.abs(np.asarray(res.w)) > 0))
    acct = PrivacyAccountant(epsilon=args.epsilon, delta=1.0 / ds.n ** 2,
                             total_steps=args.steps)
    acct.spend(args.steps)
    print(f"[lasso] acc={acc:.4f} nnz={nnz} gap={float(res.gaps[-1]):.4f} "
          f"({wall:.1f}s); privacy spent: ε={acct.spent_epsilon():.3f} "
          f"of {args.epsilon} (δ={acct.delta:.2e})")
    return {"dataset": ds.name, "accuracy": acc, "nnz": nnz,
            "gap": float(res.gaps[-1]), "wall_s": wall}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    # lasso
    ap.add_argument("--dataset", default="rcv1")
    ap.add_argument("--lam", type=float, default=50.0)
    ap.add_argument("--epsilon", type=float, default=1.0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    result = train_lasso(args) if args.arch == "paper-lasso" else train_lm(args)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
