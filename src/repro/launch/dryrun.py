import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get(
    "REPRO_DRYRUN_DEVICES", "512")

# --- everything below must come after the XLA flag (jax locks device count
# --- on first init) -------------------------------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config                     # noqa: E402
from repro.launch.mesh import make_production_mesh                 # noqa: E402
from repro.launch import sharding as shd                           # noqa: E402
from repro.models.registry import (                                # noqa: E402
    cache_specs, get_model, input_specs, supported_cells)
from repro.models.config import SHAPES                             # noqa: E402
from repro.roofline.hlo import cost_analysis_dict                  # noqa: E402
from repro.train.optimizer import get_optimizer                    # noqa: E402
from repro.train.trainer import TrainConfig, TrainState, make_train_step  # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, build the production mesh
(16×16 single-pod / 2×16×16 multi-pod), attach shardings from
``launch/sharding.py``, and prove the distribution config is coherent:
``jit(step).lower(**specs).compile()`` must succeed with per-device memory
that fits a v5e (16 GB).  Records memory_analysis + cost_analysis +
collective-bytes (parsed from the optimized HLO) per cell into a JSON that
EXPERIMENTS.md §Dry-run / §Roofline and the roofline tooling consume.
"""

# per-arch microbatch (gradient accumulation) for the train_4k cell — memory
# knob iterated per §Perf; 1 = no accumulation.
TRAIN_MICROBATCH = {
    "kimi-k2-1t-a32b": 8,
    "deepseek-v2-236b": 4,
    "chameleon-34b": 2,
    "nemotron-4-15b": 2,
}

# §Perf optimized configuration (--optimized): outcome of the hillclimb
# iterations recorded in EXPERIMENTS.md §Perf.  fsdp doubly-shards weights
# (launch/sharding.py _RULES_FSDP) for models whose replicated-over-data
# state exceeds the 16 GB HBM; microbatches bound the activation high-water
# mark of the train_4k cells.
PERF_OVERRIDES = {
    "kimi-k2-1t-a32b": dict(fsdp="zero3_moe", microbatches=64, moe_groups=16,
                            moe_combine="scatter"),
    "deepseek-v2-236b": dict(fsdp="zero3_moe", microbatches=32, moe_groups=16,
                             moe_combine="scatter"),
    "chameleon-34b": dict(fsdp="zero2", microbatches=16),
    "nemotron-4-15b": dict(dp="full", microbatches=1, grad_dtype="bfloat16"),
    "falcon-mamba-7b": dict(microbatches=16),
    "recurrentgemma-2b": dict(microbatches=16),
    "minicpm-2b": dict(fsdp="zero2", microbatches=4),
    "tinyllama-1.1b": dict(microbatches=2),
    "llama3.2-1b": dict(microbatches=2),
    "seamless-m4t-medium": dict(),
}
OPTIMIZED = False  # set by main(); run_cell/builders read it


def _metrics_shardings(abstract, mesh):
    return jax.tree.map(lambda _: shd.replicated(mesh), abstract)


def _perf(arch: str) -> dict:
    return PERF_OVERRIDES.get(arch, {}) if OPTIMIZED else {}


def _perf_overrides(arch: str, overrides=None) -> dict:
    """Merge PERF config-level knobs (moe_local_groups) into model overrides.
    (unroll_layers is train-only — decode/prefill scans carry caches.)"""
    perf = _perf(arch)
    out = dict(overrides or {})
    if perf.get("moe_groups"):
        out["moe_local_groups"] = perf["moe_groups"]
    if perf.get("moe_combine"):
        out["moe_combine"] = perf["moe_combine"]
    return out


def build_train(arch: str, mesh, log, overrides=None):
    api = get_model(arch, overrides=overrides)
    cfg = api.cfg
    perf = _perf(arch)
    mb = perf.get("microbatches", TRAIN_MICROBATCH.get(arch, 1))
    tc = TrainConfig(optimizer=cfg.optimizer, remat=True, microbatches=mb,
                     grad_reduce_dtype=perf.get("grad_dtype", ""))
    opt = get_optimizer(cfg.optimizer)

    from repro.models import common as cm
    full_dp = perf.get("dp") == "full"
    # full-DP: batch spread over every mesh axis; weights live doubly sharded
    # (ZeRO-3 storage) and are gathered per layer — trades batch-proportional
    # TP all-reduce traffic for batch-independent weight gathers (§Perf).
    cm.BATCH_AXES = ("pod", "data", "model") if full_dp else ("pod", "data")
    baxes = cm.BATCH_AXES if full_dp else None
    cfg_over = dict(overrides or {})
    if perf.get("unroll"):
        # per-layer weight gathers must not be hoisted as one stacked gather
        # (lax.scan over stacked FSDP params materializes ALL layers' weights
        # — measured +30 GiB temp on nemotron §Perf i3); a Python-unrolled
        # loop lets XLA schedule gather→use→free per layer.
        cfg_over["unroll_layers"] = True
    if perf.get("moe_groups"):
        # locality-aware MoE dispatch (see models/common.moe_apply)
        cfg_over["moe_local_groups"] = perf["moe_groups"]
    if perf.get("moe_combine"):
        cfg_over["moe_combine"] = perf["moe_combine"]
    if cfg_over != (overrides or {}):
        overrides = cfg_over
        api = get_model(arch, overrides=overrides)
        cfg = api.cfg

    def init_state(key):
        params = api.init(key)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt_state=opt.init(params))

    state_abs = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    state_shd = shd.params_shardings(state_abs, mesh, log,
                                     fsdp=True if full_dp else perf.get("fsdp", False))
    batch_abs = input_specs(arch, "train_4k", overrides=overrides)
    batch_shd = shd.batch_shardings(batch_abs, mesh, log, axes=baxes)

    step_fn = make_train_step(api.loss, tc)
    _, metrics_abs = jax.eval_shape(step_fn, state_abs, batch_abs)
    jitted = jax.jit(step_fn,
                     in_shardings=(state_shd, batch_shd),
                     out_shardings=(state_shd, _metrics_shardings(metrics_abs, mesh)),
                     donate_argnums=(0,))
    return jitted, (state_abs, batch_abs)


def build_prefill(arch: str, mesh, log, overrides=None):
    overrides = _perf_overrides(arch, overrides)
    api = get_model(arch, overrides=overrides)
    params_abs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    params_shd = shd.params_shardings(params_abs, mesh, log,
                                      fsdp=_perf(arch).get("fsdp", False))
    batch_abs = input_specs(arch, "prefill_32k", overrides=overrides)
    batch_shd = shd.batch_shardings(batch_abs, mesh, log)

    if api.cfg.family == "encdec":
        fwd = lambda p, batch: api.forward(p, batch, remat=True, last_only=True)
        args = (params_abs, batch_abs)
        in_shd = (params_shd, batch_shd)
    else:
        fwd = lambda p, tokens: api.forward(p, tokens, remat=True, last_only=True)
        args = (params_abs, batch_abs["tokens"])
        in_shd = (params_shd, batch_shd["tokens"])
    logits_abs = jax.eval_shape(fwd, *args)
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    out_shd = shd._sanitize((baxes, None, "model"), logits_abs.shape, mesh, log, "logits")
    jitted = jax.jit(fwd, in_shardings=in_shd,
                     out_shardings=jax.sharding.NamedSharding(mesh, out_shd))
    return jitted, args


def build_decode(arch: str, shape_name: str, mesh, log, overrides=None):
    overrides = _perf_overrides(arch, overrides)
    api = get_model(arch, overrides=overrides)
    params_abs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    params_shd = shd.params_shardings(params_abs, mesh, log,
                                      fsdp=_perf(arch).get("fsdp", False))
    cache_abs = cache_specs(arch, shape_name, overrides=overrides)
    cache_shd = shd.cache_shardings(cache_abs, mesh, log)
    toks_abs = input_specs(arch, shape_name, overrides=overrides)
    batch_shd = shd.batch_shardings(toks_abs["tokens"], mesh, log)

    def step(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    logits_abs, _ = jax.eval_shape(step, params_abs, cache_abs,
                                   toks_abs["tokens"], toks_abs["pos"])
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    lg_spec = shd._sanitize((baxes, None, "model"), logits_abs.shape, mesh, log, "logits")
    jitted = jax.jit(
        step,
        in_shardings=(params_shd, cache_shd, batch_shd, shd.replicated(mesh)),
        out_shardings=(jax.sharding.NamedSharding(mesh, lg_spec), cache_shd),
        donate_argnums=(1,),
    )
    return jitted, (params_abs, cache_abs, toks_abs["tokens"], toks_abs["pos"])


_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?\s*([a-z0-9]+\[[^\]]*\])?", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_LINE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of collective ops in optimized HLO, by type.

    HLO format: ``%name = f32[dims]{layout} all-gather(...)`` — the result
    shape follows '='.  Tuple results (start ops) are summed element-wise.
    NOTE: ops inside while bodies appear once; the roofline layer multiplies
    per-layer collectives by the trip count using the loop-structure metadata
    it gets from the model (see roofline/analysis.py).
    """
    out = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_LINE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_txt)
    return out


def build_lasso(dataset: str, mesh, log, steps: int = 50):
    """The paper's own workload: the registered ``jax_shard`` backend's
    whole-run program on a Table-2-sized design matrix (ShapeDtypeStruct
    stand-ins — no allocation).  Block padding (Kc, Kr) uses the dataset's
    average sparsity ×4 (a generous skew allowance)."""
    from repro.configs.paper_lasso import DATASETS
    from repro.core.solvers.jax_shard import shard_lowering

    ds = DATASETS[dataset]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rows = sizes.get("pod", 1) * sizes.get("data", 1)
    cols = sizes["model"]
    kc = max(8, int(ds.n * (ds.nnz_per_row / ds.d) / rows * 4))   # rows/col/block
    kr = max(8, int(ds.nnz_per_row / cols * 4))                    # cols/row/block
    return shard_lowering(ds.n, ds.d, mesh, steps=steps, kc=kc, kr=kr)


def _layer_points(arch: str):
    """Two layer counts for the roofline two-point FLOPs correction.

    The correction lowers UNROLLED (scan bodies are invisible to
    cost_analysis whatever the stacked size — verified: cost(L=2) == cost(L=4)
    under scan) at two small layer counts; the per-layer delta then
    extrapolates exactly for homogeneous stacks (roofline/analysis.py)."""
    cfg = get_config(arch)
    u = {"unroll_layers": True}
    if cfg.family == "encdec":
        mk = lambda l: {"n_layers": 2 * l, "enc_layers": l, "dec_layers": l, **u}
        return (2, mk(2)), (4, mk(4)), cfg.n_layers
    if cfg.layer_pattern:  # preserve the pattern-unit mix (e.g. "rra")
        n = len(cfg.layer_pattern)
        return ((n, {"n_layers": n, **u}), (2 * n, {"n_layers": 2 * n, **u}),
                cfg.n_layers)
    return (2, {"n_layers": 2, **u}), (4, {"n_layers": 4, **u}), cfg.n_layers


def _build(arch, shape_name, mesh, log, overrides=None):
    from repro.models import common as cm
    cm.BATCH_AXES = ("pod", "data")  # reset; build_train may widen for dp="full"
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return build_train(arch, mesh, log, overrides)
    if kind == "prefill":
        return build_prefill(arch, mesh, log, overrides)
    return build_decode(arch, shape_name, mesh, log, overrides)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             two_point: bool = False) -> dict:
    log: list = []
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    two_point_data = None
    with mesh:
        if arch == "paper-lasso":
            jitted, args = build_lasso(shape_name, mesh, log)
        else:
            jitted, args = _build(arch, shape_name, mesh, log)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        if two_point and arch != "paper-lasso" and not multi_pod:
            # roofline table is single-pod only — skip the extra compiles
            # on the 2×16×16 mesh
            (l1, ov1), (l2, ov2), l_full = _layer_points(arch)
            pts = {}
            for tag, (l, ov) in (("l1", (l1, ov1)), ("l2", (l2, ov2))):
                j, a = _build(arch, shape_name, mesh, [], overrides=ov)
                c = j.lower(*a).compile()
                ca = cost_analysis_dict(c)
                pts[tag] = {"layers": l,
                            "flops": float(ca.get("flops", 0)),
                            "bytes": float(ca.get("bytes accessed", 0))}
            pts["l_full"] = l_full
            two_point_data = pts
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    from repro.roofline.hlo import collective_bytes_nested
    coll = collective_bytes_nested(hlo)
    coll_flat = collective_bytes(hlo)  # once-per-loop-body (diagnostic)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compile_s": round(t1 - t0, 1),
        "flops": float(cost.get("flops", -1)) if cost else None,
        "bytes_accessed": float(cost.get("bytes accessed", -1)) if cost else None,
        "collective_bytes": coll,
        "collective_bytes_flat": coll_flat,
        "two_point": two_point_data,
        "fallbacks": log,
        "memory": {
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if mem is not None and hasattr(mem, k)
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all supported)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf PERF_OVERRIDES (FSDP + microbatch) "
                         "instead of the paper-faithful baseline config")
    ap.add_argument("--two-point", action="store_true",
                    help="also lower at 2 layer counts for the roofline "
                         "FLOPs correction (scan bodies count once)")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()
    global OPTIMIZED
    OPTIMIZED = args.optimized

    archs = [args.arch] if args.arch else ARCH_IDS
    results, failures = [], []
    for arch in archs:
        if arch == "paper-lasso":
            from repro.configs.paper_lasso import DATASETS
            shapes = [args.shape] if args.shape else list(DATASETS)
        else:
            shapes = [args.shape] if args.shape else supported_cells(arch)
        for shape_name in shapes:
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                tag = f"{arch} × {shape_name} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_cell(arch, shape_name, mp, two_point=args.two_point)
                    results.append(r)
                    mem_gb = r["memory"].get("temp_size_in_bytes", 0) / 2**30
                    print(f"[ok] {tag}: compile={r['compile_s']}s "
                          f"flops={r['flops']:.3e} temp/device={mem_gb:.2f}GiB "
                          f"coll={sum(r['collective_bytes'].values())/2**30:.2f}GiB",
                          flush=True)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append({"cell": tag, "error": str(e)})
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
            # incremental save so long sweeps are restartable
            with open(args.out, "w") as f:
                json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells ok, {len(failures)} failed → {args.out}")


if __name__ == "__main__":
    main()
