"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a leading pod=2 axis.

    Axis roles: "pod" — cross-pod data parallelism (lowest-bandwidth links get
    the lowest-frequency collective: one gradient all-reduce per step);
    "data" — in-pod data parallel / sequence sharding; "model" — tensor /
    expert parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if hasattr(jax.sharding, "AxisType"):  # jax ≥ 0.5 explicit-axis-type API
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
