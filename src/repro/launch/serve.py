"""Serving launcher — continuous-batching engine over any decoder arch.

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \\
      --requests 16 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.models.registry import get_model
    from repro.serve.engine import Request, ServeConfig, ServingEngine

    api = get_model(args.arch, smoke=args.smoke)
    if api.cfg.family == "encdec":
        raise SystemExit("enc-dec serving uses examples/serve_encdec path")
    params = api.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(api, params, ServeConfig(
        slots=args.slots, max_len=args.max_len,
        prefill_bucket=min(64, args.max_len)))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        engine.submit(Request(
            uid=i, prompt=rng.integers(1, api.cfg.vocab, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    finished = engine.run()
    wall = time.time() - t0
    gen_tokens = sum(len(r.generated) for r in finished)
    lat = [r.finished_at - r.submitted_at for r in finished]
    result = {
        "arch": args.arch, "requests": len(finished),
        "decode_steps": engine.steps, "generated_tokens": gen_tokens,
        "wall_s": round(wall, 2),
        "tokens_per_s": round(gen_tokens / wall, 1),
        "mean_latency_s": round(float(np.mean(lat)), 3),
        "p95_latency_s": round(float(np.percentile(lat, 95)), 3),
        "slot_utilization": round(gen_tokens / max(engine.steps * args.slots, 1), 3),
    }
    print(json.dumps(result, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
