"""Batched multi-problem solving — vmapped λ/ε sweeps (DESIGN.md §6).

Real deployments never fit one (λ, ε) problem: they sweep regularization ×
privacy grids over the *same* design matrix.  Run sequentially, every problem
re-pays the O(NS) setup (data coercion, ȳ/α₀ spmv sweeps) and its own chain
of kernel launches.  ``solve_many`` amortizes all of it:

    from repro.core.solvers import FWConfig, grid, solve_many
    configs = grid(FWConfig(backend="jax_sparse", steps=500, queue="bsls"),
                   lam=(10.0, 30.0, 50.0), epsilon=(0.1, 1.0))
    results = solve_many(X, y, configs)        # list[FWResult], input order

Mechanics:

  * configs are bucketed into **sweep groups** — same backend / steps /
    resolved queue / loss / interpret flag / mesh (everything that shapes
    the compiled program); λ, ε, δ and seed may vary freely inside a group;
  * ``X`` is coerced **once per data layout**, not once per config;
  * a ``jax_sparse`` group runs as a single jitted ``vmap`` of ``fw_scan``
    over stacked (λ, EM-scale, PRNG-key) triples — the whole sweep is one
    XLA program through the spmv / coord_update / bsls_draw kernels, with
    the config-independent ``fw_setup`` state computed once and broadcast;
  * a ``jax_shard`` group shares one block build + setup and re-enters one
    compiled scan (vmapped over the stacked scalars on a 1×1 mesh, where
    the whole stack fits one device program; sequential re-entries on real
    grids — λ/ε/key are traced either way, so never a recompile);
  * every other backend (and singleton groups) drains through the normal
    per-config adapter on the pre-coerced data — same results, no compile
    blow-up for host loops that would not benefit.

Parity is structural, not approximate: the batched path calls the *same*
``fw_scan`` the sequential backend closes over, with the per-config scalars
traced instead of constant — tests assert step-for-step identical coordinate
sequences on the same keys.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.solvers.config import FWConfig, FWResult
from repro.core.solvers.registry import (get_backend, resolve_data,
                                         resolve_queue)

# FWConfig fields that must agree within one vmapped sweep group: they are
# jit-static (shape the compiled scan) or flip a Python-level branch.  The
# complementary set — lam / epsilon / delta / seed — is what a group stacks.
GROUP_FIELDS = ("backend", "steps", "queue", "loss", "selection", "interpret",
                "mesh")


def grid(base: FWConfig | None = None, **axes) -> Tuple[FWConfig, ...]:
    """Cartesian product of FWConfig axes, for ``solve_many``.

    Each keyword is an FWConfig field; iterable values become sweep axes
    (crossed in the order given, last axis fastest), scalars are applied to
    every point::

        grid(lam=(10, 30), epsilon=(0.1, 1.0), backend="jax_sparse",
             queue="bsls", steps=200)   # -> 4 configs

    Strings are scalars, never axes.
    """
    base = base or FWConfig()

    def _scalar(k, v):
        if isinstance(v, str) or not isinstance(v, Iterable):
            return True
        # one mesh spec (a tuple of ints) is a value, not a sweep axis; a
        # sequence of tuples sweeps meshes
        return k == "mesh" and bool(v) and all(isinstance(x, int) for x in v)

    # mesh specs normalize to tuples (FWConfig.mesh must stay hashable for
    # solve_many/FitService grouping even when the caller wrote a list)
    fixed = {k: tuple(v) if k == "mesh" and _scalar(k, v) and v is not None
             else v
             for k, v in axes.items() if _scalar(k, v)}
    sweep = {k: tuple(tuple(x) if k == "mesh" else x for x in v)
             for k, v in axes.items() if k not in fixed}
    unknown = set(axes) - {f.name for f in dataclasses.fields(FWConfig)}
    if unknown:
        raise ValueError(f"unknown FWConfig field(s): {', '.join(sorted(unknown))}")
    base = dataclasses.replace(base, **fixed)
    if not sweep:
        return (base,)
    names = tuple(sweep)
    return tuple(
        dataclasses.replace(base, **dict(zip(names, point)))
        for point in itertools.product(*(sweep[k] for k in names)))


def group_key(config: FWConfig) -> Tuple:
    """Sweep-group bucket of a config (queue already resolved to native)."""
    return tuple(getattr(config, f) for f in GROUP_FIELDS)


# ---------------------------------------------------------------------------
# the vmapped jax_sparse sweep
# ---------------------------------------------------------------------------


def _sweep_scan(pcsr, pcsc, vbar0, qbar0, alpha0, lams, em_scales, keys,
                *, steps, loss, private, fused, interpret):
    """One compiled program for a whole sweep group: the vmapped T-step scan
    over shared setup state.  ``lams``/``em_scales``/``keys`` are stacked
    per-config; (v̄₀, q̄₀, α₀) come from ``fw_setup_jit`` — computed once per
    group, or replayed from a dataset store's persisted cache."""
    from repro.core.solvers.jax_sparse import fw_scan

    def one(lam, em_scale, key):
        return fw_scan(pcsr, pcsc, vbar0, qbar0, alpha0, lam, em_scale, key,
                       steps=steps, loss=loss, private=private, fused=fused,
                       interpret=interpret)

    return jax.vmap(one)(lams, em_scales, keys)


_sweep_scan_jit = jax.jit(
    _sweep_scan,
    static_argnames=("steps", "loss", "private", "fused", "interpret"))


def _solve_jax_sparse_group(
    data, y, configs: Sequence[FWConfig]
) -> List[FWResult]:
    """Run a compatible config group as one vmap-over-configs lax.scan."""
    from repro.core.solvers.jax_sparse import em_scale_for, fw_setup_jit
    from repro.core.solvers.prepared import PreparedDataset
    c0 = configs[0]
    if isinstance(data, PreparedDataset):
        pcsr, pcsc = data.pair
        setup = data.setup_for(y, c0.loss, c0.interpret)
    else:
        pcsr, pcsc = data
        setup = fw_setup_jit(pcsr, jnp.asarray(y, jnp.float32),
                             loss=c0.loss, interpret=c0.interpret)
    private = c0.queue == "two_level"
    fused = c0.loss == "logistic"
    n = pcsr.shape[0]
    dtype = pcsr.values.dtype
    lams = jnp.asarray([c.lam for c in configs], dtype)
    em_scales = jnp.asarray([em_scale_for(c, n) for c in configs], dtype)
    keys = jnp.stack([jax.random.PRNGKey(c.seed) for c in configs])
    w, gaps, coords = _sweep_scan_jit(
        pcsr, pcsc, *setup, lams, em_scales, keys,
        steps=c0.steps, loss=c0.loss, private=private, fused=fused,
        interpret=c0.interpret)
    return [FWResult(w=w[i], gaps=gaps[i], coords=coords[i],
                     losses=jnp.zeros_like(gaps[i]))
            for i in range(len(configs))]


# ---------------------------------------------------------------------------
# solve_many
# ---------------------------------------------------------------------------


def solve_many(X, y=None, configs: Sequence[FWConfig] = (), *,
               prepared: Optional[Dict[str, object]] = None) -> List[FWResult]:
    """Solve many FW problems over one (X, y); results in input order.

    ``X`` may be a ``DatasetStore``/``DatasetRef`` (labels then default to
    the store's own — the whole sweep reads one on-disk artifact).  Configs
    are grouped by ``GROUP_FIELDS`` (after queue resolution); each
    ``jax_sparse`` group of ≥ 2 runs as a single jitted vmapped scan, a
    ``jax_shard`` group shares one setup + compiled scan per mesh (vmapped
    on a 1×1 mesh), and other groups fall back to the sequential per-config
    backend — in every case the data coercion is hoisted and shared across
    the whole call.

    ``prepared`` is an optional caller-owned ``{data_format: coerced X}``
    cache: pass the same dict across calls (the fit service does, per
    drain) and each layout is coerced exactly once per service lifetime.
    """
    configs = list(configs)
    if not configs:
        return []
    X, y = resolve_data(X, y)
    resolved = []
    for c in configs:
        backend = get_backend(c.backend)
        resolved.append((backend, resolve_queue(backend, c)))

    if prepared is None:
        prepared = {}                 # data layout -> coerced X (once each)
    for backend, _ in resolved:
        if backend.data_format not in prepared:
            prepared[backend.data_format] = backend.prepare(X)

    groups: Dict[Tuple, List[int]] = {}
    for i, (_, cfg) in enumerate(resolved):
        groups.setdefault(group_key(cfg), []).append(i)

    results: List[FWResult | None] = [None] * len(configs)
    for members in groups.values():
        backend, _ = resolved[members[0]]
        data = prepared[backend.data_format]
        member_cfgs = [resolved[i][1] for i in members]
        if backend.name == "jax_sparse" and len(members) > 1:
            out = _solve_jax_sparse_group(data, y, member_cfgs)
        elif backend.name == "jax_shard" and len(members) > 1:
            from repro.core.solvers.jax_shard import solve_shard_group
            out = solve_shard_group(data, y, member_cfgs)
        else:
            out = [backend.fn(data, y, cfg) for cfg in member_cfgs]
        for i, res in zip(members, out):
            results[i] = res
    return results  # type: ignore[return-value]
