"""Batched multi-problem solving — vmapped λ/ε sweeps (DESIGN.md §6).

Real deployments never fit one (λ, ε) problem: they sweep regularization ×
privacy grids over the *same* design matrix.  Run sequentially, every problem
re-pays the O(NS) setup (data coercion, ȳ/α₀ spmv sweeps) and its own chain
of kernel launches.  ``solve_many`` amortizes all of it:

    from repro.core.solvers import FWConfig, grid, solve_many
    configs = grid(FWConfig(backend="jax_sparse", steps=500, queue="bsls"),
                   lam=(10.0, 30.0, 50.0), epsilon=(0.1, 1.0))
    results = solve_many(X, y, configs)        # list[FWResult], input order

Mechanics:

  * configs are bucketed into **sweep groups** — same backend / steps /
    resolved queue / loss / interpret flag / mesh (everything that shapes
    the compiled program); λ, ε, δ and seed may vary freely inside a group;
  * ``X`` is coerced **once per data layout**, not once per config;
  * a ``jax_sparse`` group shares the config-independent ``fw_setup`` state
    and one compiled scan through the spmv / coord_update / bsls_draw
    kernels — run as a single jitted ``vmap`` over stacked (λ, EM-scale,
    PRNG-key) triples, or as sequential re-entries of the width-free chunk
    program, whichever the §9 planner says is faster on this platform;
  * a ``jax_shard`` group shares one block build + setup and re-enters one
    compiled scan (vmapped over the stacked scalars on a 1×1 mesh, where
    the whole stack fits one device program; sequential re-entries on real
    grids — λ/ε/key are traced either way, so never a recompile);
  * every other backend (and singleton groups) drains through the normal
    per-config adapter on the pre-coerced data — same results, no compile
    blow-up for host loops that would not benefit.

Parity is structural, not approximate: the batched path calls the *same*
``fw_scan`` the sequential backend closes over, with the per-config scalars
traced instead of constant — tests assert step-for-step identical coordinate
sequences on the same keys.

Gap-adaptive scheduling (DESIGN.md §9) adds the **cohort** execution mode:
when a group's configs carry ``gap_tol``/``max_seconds``, the grid runs in
chunks of the shared compiled ``fw_scan_chunk`` and configs that converge
are *retired* between chunks, so the sweep stops paying for its slowest
member.  Which mode a group uses — one vmapped program vs sequential
re-entries of the width-free chunk program — is decided per problem by
``solvers.planner`` (measured per-iteration costs beat the model beat the
platform default); pass ``plan=`` to override.  Every mode runs the same
state machine on the same keys, so gap-certified results are independent of
the plan.  The one necessarily schedule-dependent knob is ``max_seconds``:
a wall-clock budget counts from when the config's execution starts — its
own ``solve()`` in sequential mode, the group's first chunk in cohort mode
(the lanes really do run concurrently) — so where a timeout lands depends
on how the grid was scheduled, as any wall-clock limit must.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.solvers.config import (STOP_GAP_TOL, STOP_MAX_SECONDS,
                                       STOP_MAX_STEPS, FWConfig, FWResult,
                                       check_gap_certificate)
from repro.core.solvers.planner import SolvePlan, record_cost
from repro.core.solvers.registry import (check_path_support,
                                         check_screening_support, get_backend,
                                         resolve_data, resolve_queue)

# FWConfig fields that must agree within one vmapped sweep group: they are
# jit-static (shape the compiled scan) or flip a Python-level branch.  The
# complementary set — lam / epsilon / delta / seed / gap_tol / max_seconds —
# is what a group stacks (the stopping knobs ride as traced scalars or
# host-side checks, so they never split a group).  The §13 screening knobs
# are group fields because a fired screen changes the problem *shape*: two
# screened members diverge to different widths (DP noise makes survivor sets
# seed-dependent), so a screened group can never be lane-stacked and must
# not mix with unscreened members.  ``lambdas`` (§14) is a group field
# because a λ-path is a different *control flow* — sequential-in-λ segments
# through shared global step slots — and only identical paths can share the
# fused-across-tenants schedule.
GROUP_FIELDS = ("backend", "steps", "queue", "loss", "selection", "interpret",
                "mesh", "chunk_steps", "screen_every", "screen_eps_frac",
                "lambdas")


def grid(base: FWConfig | None = None, **axes) -> Tuple[FWConfig, ...]:
    """Cartesian product of FWConfig axes, for ``solve_many``.

    Each keyword is an FWConfig field; iterable values become sweep axes
    (crossed in the order given, last axis fastest), scalars are applied to
    every point::

        grid(lam=(10, 30), epsilon=(0.1, 1.0), backend="jax_sparse",
             queue="bsls", steps=200)   # -> 4 configs

    Strings are scalars, never axes.
    """
    base = base or FWConfig()

    def _scalar(k, v):
        if isinstance(v, str) or not isinstance(v, Iterable):
            return True
        # one mesh spec (a tuple of ints) / one λ-path (a sequence of
        # numbers) is a value, not a sweep axis; a sequence of tuples
        # sweeps meshes/paths
        if k == "mesh":
            return bool(v) and all(isinstance(x, int) for x in v)
        if k == "lambdas":
            return bool(v) and all(isinstance(x, (int, float)) for x in v)
        return False

    # mesh/lambdas specs normalize to tuples (both FWConfig fields must stay
    # hashable for solve_many/FitService grouping even when the caller wrote
    # a list)
    fixed = {k: tuple(v) if k in ("mesh", "lambdas") and _scalar(k, v)
             and v is not None else v
             for k, v in axes.items() if _scalar(k, v)}
    sweep = {k: tuple(tuple(x) if k in ("mesh", "lambdas") else x for x in v)
             for k, v in axes.items() if k not in fixed}
    unknown = set(axes) - {f.name for f in dataclasses.fields(FWConfig)}
    if unknown:
        raise ValueError(f"unknown FWConfig field(s): {', '.join(sorted(unknown))}")
    base = dataclasses.replace(base, **fixed)
    if not sweep:
        return (base,)
    names = tuple(sweep)
    return tuple(
        dataclasses.replace(base, **dict(zip(names, point)))
        for point in itertools.product(*(sweep[k] for k in names)))


def group_key(config: FWConfig) -> Tuple:
    """Sweep-group bucket of a config (queue already resolved to native)."""
    return tuple(getattr(config, f) for f in GROUP_FIELDS)


# ---------------------------------------------------------------------------
# the vmapped jax_sparse sweep
# ---------------------------------------------------------------------------


def _sweep_scan(pcsr, pcsc, vbar0, qbar0, alpha0, lams, em_scales, keys,
                y=None, *, steps, loss, private, fused, interpret):
    """One compiled program for a whole sweep group: the vmapped T-step scan
    over shared setup state.  ``lams``/``em_scales``/``keys`` are stacked
    per-config; (v̄₀, q̄₀, α₀) come from ``fw_setup_jit`` — computed once per
    group, or replayed from a dataset store's persisted cache.  ``y`` is the
    shared label vector, broadcast across lanes (label-coupled objectives
    only; separable ones pass None)."""
    from repro.core.solvers.jax_sparse import fw_scan

    def one(lam, em_scale, key):
        w, gaps, coords, _ = fw_scan(
            pcsr, pcsc, vbar0, qbar0, alpha0, lam, em_scale, key, 0.0, y,
            steps=steps, loss=loss, private=private, fused=fused,
            interpret=interpret)
        return w, gaps, coords

    return jax.vmap(one)(lams, em_scales, keys)


_sweep_scan_jit = jax.jit(
    _sweep_scan,
    static_argnames=("steps", "loss", "private", "fused", "interpret"))


def _cohort_chunk(pcsr, pcsc, carry, lams, em_scales, gap_tols, t0,
                  y=None, *, steps, loss, private, fused, interpret):
    """One vmapped chunk of the cohort scheduler: every lane advances
    ``steps`` masked iterations from offset ``t0`` (lanes that already hold
    their certificate stay frozen, bit-for-bit)."""
    from repro.core.solvers.jax_sparse import fw_scan_chunk

    def one(carry_i, lam, em_scale, gap_tol):
        return fw_scan_chunk(pcsr, pcsc, carry_i, lam, em_scale, gap_tol, t0,
                             y, steps=steps, loss=loss, private=private,
                             fused=fused, interpret=interpret,
                             early_stop=True)

    return jax.vmap(one, in_axes=(0, 0, 0, 0))(carry, lams, em_scales,
                                               gap_tols)


_cohort_chunk_jit = jax.jit(
    _cohort_chunk,
    static_argnames=("steps", "loss", "private", "fused", "interpret"))


def _group_context(data, y, configs: Sequence[FWConfig]):
    """Shared (pcsr, pcsc, setup, scalars) of one jax_sparse sweep group."""
    from repro.core.solvers.jax_sparse import em_scale_for, fw_setup_jit
    from repro.core.solvers.prepared import PreparedDataset
    c0 = configs[0]
    if isinstance(data, PreparedDataset):
        pcsr, pcsc = data.pair
        # §11: replay the store's autotuned layout — parity-gated at tuning
        # time, so the whole group's iterates are bit-identical either way
        rec = data.tuning_for("jax_sparse", c0.loss)
        if rec is not None and rec.ell_width is not None:
            pcsc = data.tuned_pcsc(rec)
        setup = data.setup_for(y, c0.loss, c0.interpret)
    else:
        pcsr, pcsc = data
        setup = fw_setup_jit(pcsr, jnp.asarray(y, jnp.float32),
                             loss=c0.loss, interpret=c0.interpret)
    n = pcsr.shape[0]
    dtype = pcsr.values.dtype
    scalars = {
        "lams": jnp.asarray([c.lam for c in configs], dtype),
        "em_scales": jnp.asarray([em_scale_for(c, n) for c in configs],
                                 dtype),
        "gap_tols": jnp.asarray([c.gap_tol for c in configs], dtype),
        "keys": jnp.stack([jax.random.PRNGKey(c.seed) for c in configs]),
    }
    return pcsr, pcsc, setup, scalars


def _group_labels(c0: FWConfig, y):
    """Label operand for the group's scan: None for separable objectives
    (their compiled programs never read labels), the shared f32 vector for
    label-coupled ones."""
    if c0.loss_fn().separable:
        return None
    return jnp.asarray(y, jnp.float32)


def _group_stats(pcsr, pcsc):
    # planner.data_stats knows every pair layout (flat and §11 tiered)
    from repro.core.solvers.planner import data_stats
    return data_stats((pcsr, pcsc))


def _solve_jax_sparse_group(
    data, y, configs: Sequence[FWConfig]
) -> List[FWResult]:
    """Run a compatible fixed-T config group as one vmap-over-configs scan."""
    c0 = configs[0]
    pcsr, pcsc, setup, sc = _group_context(data, y, configs)
    private = c0.queue == "two_level"
    fused = True
    t0 = time.perf_counter()
    w, gaps, coords = _sweep_scan_jit(
        pcsr, pcsc, *setup, sc["lams"], sc["em_scales"], sc["keys"],
        _group_labels(c0, y),
        steps=c0.steps, loss=c0.loss, private=private, fused=fused,
        interpret=c0.interpret)
    jax.block_until_ready(w)
    record_cost(c0.backend, "vmap", jax.devices()[0].platform,
                _group_stats(pcsr, pcsc),
                (time.perf_counter() - t0) / (c0.steps * len(configs)),
                loss=c0.loss)
    return [FWResult(w=w[i], gaps=gaps[i], coords=coords[i],
                     losses=jnp.zeros_like(gaps[i]), stop_step=c0.steps,
                     stop_reason=STOP_MAX_STEPS)
            for i in range(len(configs))]


def _solve_jax_sparse_group_sequential(
    data, y, configs: Sequence[FWConfig]
) -> List[FWResult]:
    """Planner mode "sequential": per-config solves sharing one coerced
    layout + one setup + one compiled (width-free) scan program.  Each config
    stops exactly when its own certificate/timeout lands — no lane padding,
    no cohort granularity."""
    from repro.core.solvers.jax_sparse import jax_sparse_fw
    pcsr, pcsc, setup, _ = _group_context(data, y, configs)
    stats = _group_stats(pcsr, pcsc)
    platform = jax.devices()[0].platform
    y32 = jnp.asarray(y, jnp.float32)
    out = []
    for cfg in configs:
        t0 = time.perf_counter()
        res = jax_sparse_fw(pcsr, pcsc, y32, cfg, setup=setup)
        jax.block_until_ready(res.w)
        if cfg.screen_every == 0:
            # screened solves record per-chunk inside the §13 driver with
            # the geometry each chunk actually ran at; a whole-solve average
            # over shrinking D would poison the cost book
            ran = max(res.stop_step_or(cfg.steps), 1)
            record_cost(cfg.backend, "sequential", platform, stats,
                        (time.perf_counter() - t0) / ran, loss=cfg.loss)
        out.append(res)
    return out


def _solve_jax_sparse_group_cohort(
    data, y, configs: Sequence[FWConfig]
) -> List[FWResult]:
    """Gap-adaptive cohort scheduling (DESIGN.md §9): the group advances in
    chunks of one compiled vmapped ``fw_scan_chunk``; configs whose gap
    certificate (or wall-clock budget) lands are retired between chunks, so
    the grid stops paying for its slowest member.  Iterates are bit-identical
    to the sequential early-stopping path — same state machine, same keys —
    which the bench asserts at every config's stop step."""
    from repro.core.solvers.jax_sparse import fw_carry_init
    from repro.core.solvers.planner import cohort_widths
    from repro.core.solvers.stopping import resolve_chunk
    c0 = configs[0]
    pcsr, pcsc, setup, sc = _group_context(data, y, configs)
    stats = _group_stats(pcsr, pcsc)
    platform = jax.devices()[0].platform
    private = c0.queue == "two_level"
    fused = True
    y_scan = _group_labels(c0, y)
    n_cfg = len(configs)
    steps = c0.steps
    chunk = resolve_chunk(c0)
    d = pcsr.shape[1]
    dtype = pcsr.values.dtype

    init = jax.jit(jax.vmap(
        lambda s, k: fw_carry_init(d, dtype, *setup, s, k, private=private)))
    cur = init(sc["em_scales"], sc["keys"])          # stacked FWCarry

    gaps_buf = np.zeros((n_cfg, steps), np.asarray(sc["lams"]).dtype)
    coords_buf = np.full((n_cfg, steps), -1, np.int32)
    final: List[Optional[FWResult]] = [None] * n_cfg
    active = list(range(n_cfg))                      # config ids, lane order
    t0 = 0
    t_start = time.perf_counter()

    def retire(lane_carry, cfg_id: int, ran: int, reason_if_full: str):
        done = bool(lane_carry.done)
        stop = int(lane_carry.stop_at) if done else ran
        reason = STOP_GAP_TOL if done else reason_if_full
        w = np.asarray(lane_carry.w * lane_carry.w_m)
        final[cfg_id] = FWResult(
            w=jnp.asarray(w), gaps=jnp.asarray(gaps_buf[cfg_id]),
            coords=jnp.asarray(coords_buf[cfg_id]),
            losses=jnp.zeros((steps,), w.dtype), stop_step=stop,
            stop_reason=reason)
        obs.event("cohort.retire", config=cfg_id, stop_step=stop,
                  stop_reason=reason, survivors=len(active) - 1)
        obs.count("cohort.retired", reason=reason)

    widths = cohort_widths(n_cfg)        # pow-2 bucket schedule, full → 1
    while active and t0 < steps:
        c = min(chunk, steps - t0)
        width = min(w for w in widths if w >= len(active))
        # pad the cohort to a bucket width by repeating lane 0 (its copies'
        # outputs are discarded) — the grid re-enters ≤ log2(B) compiled
        # widths instead of one program per survivor count
        lane_sel = list(range(len(active))) + [0] * (width - len(active))
        cfg_sel = jnp.asarray([active[lane] for lane in lane_sel])
        padded = jax.tree_util.tree_map(
            lambda a: a[jnp.asarray(lane_sel)], cur)
        tw = time.perf_counter()
        padded, (g, j) = _cohort_chunk_jit(
            pcsr, pcsc, padded, sc["lams"][cfg_sel], sc["em_scales"][cfg_sel],
            sc["gap_tols"][cfg_sel], t0, y_scan,
            steps=c, loss=c0.loss, private=private, fused=fused,
            interpret=c0.interpret)
        jax.block_until_ready(g)
        dt = time.perf_counter() - tw
        record_cost(c0.backend, "vmap", platform, stats,
                    dt / (c * width), loss=c0.loss)
        obs.observe("cohort.chunk.seconds", dt)
        obs.count("cohort.chunk.steps", c * len(active))
        cur = jax.tree_util.tree_map(lambda a: a[: len(active)], padded)
        g_np, j_np = np.asarray(g), np.asarray(j)
        for lane, cfg_id in enumerate(active):
            gaps_buf[cfg_id, t0:t0 + c] = g_np[lane]
            coords_buf[cfg_id, t0:t0 + c] = j_np[lane]
        t0 += c
        elapsed = time.perf_counter() - t_start
        dones = np.asarray(cur.done)
        keep = []
        for lane, cfg_id in enumerate(active):
            timed_out = (configs[cfg_id].max_seconds is not None
                         and elapsed >= configs[cfg_id].max_seconds)
            if bool(dones[lane]) or timed_out or t0 >= steps:
                retire(jax.tree_util.tree_map(lambda a: a[lane], cur),
                       cfg_id, t0,
                       STOP_MAX_SECONDS
                       if (timed_out and not bool(dones[lane]))
                       else STOP_MAX_STEPS)
            else:
                keep.append(lane)
        if keep and keep != list(range(len(active))):
            cur = jax.tree_util.tree_map(lambda a: a[jnp.asarray(keep)], cur)
        active = [active[lane] for lane in keep]
    return final  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# λ-path groups (§14): sequential-in-λ, fused-across-tenants
# ---------------------------------------------------------------------------


def _solve_jax_sparse_path_group_sequential(
    data, y, configs: Sequence[FWConfig]
) -> List:
    """Per-config warm-started path drivers over one shared coercion +
    setup (each re-enters the same compiled chunk program anyway)."""
    from repro.core.solvers.path import jax_sparse_path
    pcsr, pcsc, setup, _ = _group_context(data, y, configs)
    y32 = jnp.asarray(y, jnp.float32)
    return [jax_sparse_path(pcsr, pcsc, y32, cfg, setup=setup)
            for cfg in configs]


def _solve_jax_sparse_path_group_fused(
    data, y, configs: Sequence[FWConfig]
) -> List:
    """Fused-across-tenants λ-path: every lane advances through the *same*
    fixed global step slots (segment k occupies [S_{k-1}, S_k) whether or
    not its certificate landed early — frozen lanes are bit-frozen no-ops),
    so one vmapped chunk program drives the whole group and the per-lane
    trajectories are bit-identical to the sequential path driver's.

    λ-paths are a GROUP_FIELDS member, so every lane shares lambdas /
    steps / budgets; ε (hence the EM scale), seed, and gap_tol stack.
    """
    from repro.core.solvers.jax_sparse import fw_carry_init
    from repro.core.solvers.path import PathResult, path_em_scale, path_plan
    from repro.core.solvers.stopping import resolve_chunk
    c0 = configs[0]
    pcsr, pcsc, setup, sc = _group_context(data, y, configs)
    stats = _group_stats(pcsr, pcsc)
    platform = jax.devices()[0].platform
    private = c0.queue == "two_level"
    fused = True
    y_scan = _group_labels(c0, y)
    n_cfg = len(configs)
    n, d = pcsr.shape
    dtype = pcsr.values.dtype
    plans = [path_plan(c, private=private) for c in configs]
    plan0 = plans[0]   # lambdas/steps are group fields → same budgets/offsets
    em_scales = jnp.asarray(
        [path_em_scale(c, p, n) for c, p in zip(configs, plans)], dtype)

    init = jax.jit(jax.vmap(
        lambda s, k: fw_carry_init(d, dtype, *setup, s, k, private=private)))
    cur = init(em_scales, sc["keys"])                # stacked FWCarry
    buf_dtype = np.asarray(sc["lams"]).dtype
    per_cfg: List[List[FWResult]] = [[] for _ in configs]

    for k, lam_k in enumerate(plan0.lambdas):
        budget, seg_off = plan0.budgets[k], plan0.offsets[k]
        if k:
            # warm restart per lane: un-freeze stopping flags, keep the rest
            cur = cur._replace(done=jnp.zeros(n_cfg, bool),
                               stop_at=jnp.zeros(n_cfg, jnp.int32))
        lams = jnp.full((n_cfg,), lam_k, dtype)
        chunk = resolve_chunk(dataclasses.replace(c0, steps=budget))
        gaps_buf = np.zeros((n_cfg, budget), buf_dtype)
        coords_buf = np.full((n_cfg, budget), -1, np.int32)
        t0 = 0
        while t0 < budget:
            c = min(chunk, budget - t0)
            tw = time.perf_counter()
            cur, (g, j) = _cohort_chunk_jit(
                pcsr, pcsc, cur, lams, em_scales, sc["gap_tols"],
                seg_off + t0, y_scan, steps=c, loss=c0.loss, private=private,
                fused=fused, interpret=c0.interpret)
            jax.block_until_ready(g)
            record_cost(c0.backend, "vmap", platform, stats,
                        (time.perf_counter() - tw) / (c * n_cfg),
                        loss=c0.loss)
            gaps_buf[:, t0:t0 + c] = np.asarray(g)
            coords_buf[:, t0:t0 + c] = np.asarray(j)
            t0 += c
            if bool(np.asarray(cur.done).all()):
                break    # remaining slots stay sentinel-padded, as the
                         # sequential driver's assemble_outputs would
        dones, stops = np.asarray(cur.done), np.asarray(cur.stop_at)
        for i in range(n_cfg):
            done_i = bool(dones[i])
            stop = int(stops[i]) - seg_off if done_i else budget
            w = cur.w[i] * cur.w_m[i]
            per_cfg[i].append(FWResult(
                w=w, gaps=jnp.asarray(gaps_buf[i]),
                coords=jnp.asarray(coords_buf[i]),
                losses=jnp.zeros((budget,), w.dtype), stop_step=stop,
                stop_reason=STOP_GAP_TOL if done_i else STOP_MAX_STEPS))
        if obs.enabled():
            obs.event("path.lambda", index=k, lam=float(lam_k),
                      budget=budget, offset=seg_off, lanes=n_cfg,
                      converged=int(dones.sum()))
    return [PathResult(plans[i].lambdas, per_cfg[i], plans[i])
            for i in range(n_cfg)]


def _run_path_group(backend, data, y, member_cfgs: Sequence[FWConfig],
                    plan: SolvePlan) -> List:
    """Dispatch one λ-path sweep group (§14).

    A path is sequential-in-λ by construction; across tenants it runs fused
    (one vmapped chunk program through shared global step slots) or
    sequential, per the same §9 mode machinery as plain sweep groups.
    """
    from repro.core.solvers.path import run_path
    if backend.name == "jax_sparse" and len(member_cfgs) > 1:
        mode = plan.mode
        if mode == "auto":
            from repro.core.solvers.planner import group_mode
            pcsr = (data.pcsr if hasattr(data, "pcsr") else data[0])
            pcsc = (data.pcsc if hasattr(data, "pcsc") else data[1])
            mode = group_mode(_group_stats(pcsr, pcsc), len(member_cfgs),
                              loss=member_cfgs[0].loss,
                              backend=member_cfgs[0].backend)
        if mode == "vmap":
            with obs.span("group.path", size=len(member_cfgs), mode="fused"):
                return _solve_jax_sparse_path_group_fused(data, y,
                                                          member_cfgs)
        with obs.span("group.path", size=len(member_cfgs),
                      mode="sequential"):
            return _solve_jax_sparse_path_group_sequential(data, y,
                                                           member_cfgs)
    with obs.span("group.path", size=len(member_cfgs), mode="sequential"):
        return [run_path(backend, data, y, cfg) for cfg in member_cfgs]


# ---------------------------------------------------------------------------
# solve_many
# ---------------------------------------------------------------------------


def _as_plan(plan: Union[None, str, SolvePlan]) -> SolvePlan:
    if plan is None or plan == "auto":
        return SolvePlan(mode="auto")
    if isinstance(plan, str):
        if plan not in ("vmap", "sequential"):
            raise ValueError(
                f"plan must be 'auto'/'vmap'/'sequential' or a SolvePlan; "
                f"got {plan!r}")
        return SolvePlan(mode=plan)
    return plan


def _run_jax_sparse_group(data, y, member_cfgs: Sequence[FWConfig],
                          plan: SolvePlan) -> List[FWResult]:
    """Dispatch one jax_sparse sweep group per the §9 plan."""
    if plan.chunk_steps is None and hasattr(data, "tuning_for"):
        # §11: the store's autotuned chunk length is the plan default
        rec = data.tuning_for("jax_sparse", member_cfgs[0].loss)
        if rec is not None and rec.chunk_steps is not None:
            plan = dataclasses.replace(plan, chunk_steps=rec.chunk_steps)
    if plan.chunk_steps is not None:
        # the plan's chunk is a default, not an override: a per-config pin
        # (which is a GROUP_FIELDS member, so uniform here) still wins
        member_cfgs = [c if c.chunk_steps is not None
                       else dataclasses.replace(c,
                                                chunk_steps=plan.chunk_steps)
                       for c in member_cfgs]
    if member_cfgs[0].screen_every > 0:
        # §13: once a screen fires, per-member geometry diverges (DP noise
        # makes survivor sets seed-dependent), so lanes can never be stacked
        # — screened groups always run the sequential mutable-geometry
        # driver, whatever the plan says.
        with obs.span("group.screened", size=len(member_cfgs)):
            return _solve_jax_sparse_group_sequential(data, y, member_cfgs)
    early = any(c.early_stopping for c in member_cfgs)
    mode = plan.mode
    if mode == "auto":
        from repro.core.solvers.planner import group_mode
        pcsr = (data.pcsr if hasattr(data, "pcsr") else data[0])
        pcsc = (data.pcsc if hasattr(data, "pcsc") else data[1])
        mode = group_mode(_group_stats(pcsr, pcsc), len(member_cfgs),
                          loss=member_cfgs[0].loss,
                          backend=member_cfgs[0].backend)
    if mode == "sequential":
        with obs.span("group.sequential", size=len(member_cfgs)):
            return _solve_jax_sparse_group_sequential(data, y, member_cfgs)
    if early:
        with obs.span("group.cohort", size=len(member_cfgs)):
            return _solve_jax_sparse_group_cohort(data, y, member_cfgs)
    with obs.span("group.vmap", size=len(member_cfgs)):
        return _solve_jax_sparse_group(data, y, member_cfgs)


def solve_many(X, y=None, configs: Sequence[FWConfig] = (), *,
               prepared: Optional[Dict[str, object]] = None,
               plan: Union[None, str, SolvePlan] = None) -> List[FWResult]:
    """Solve many FW problems over one (X, y); results in input order.

    ``X`` may be a ``DatasetStore``/``DatasetRef`` (labels then default to
    the store's own — the whole sweep reads one on-disk artifact).  Configs
    are grouped by ``GROUP_FIELDS`` (after queue resolution); each
    ``jax_sparse`` group of ≥ 2 runs on one shared coercion + setup +
    compiled scan, scheduled per the §9 execution plan — ``plan=None`` lets
    ``solvers.planner`` choose between the vmapped program (cohort-chunked
    with retirement when the group carries ``gap_tol``/``max_seconds``) and
    sequential re-entries; pass "vmap"/"sequential" or a ``SolvePlan`` to
    override.  A ``jax_shard`` group shares one setup + compiled scan per
    mesh (vmapped on a 1×1 mesh), and other groups fall back to the
    sequential per-config backend — in every case the data coercion is
    hoisted and shared across the whole call, and results are identical
    under every plan (same state machine, same keys).

    ``prepared`` is an optional caller-owned ``{data_format: coerced X}``
    cache: pass the same dict across calls (the fit service does, per
    drain) and each layout is coerced exactly once per service lifetime.

    Configs with ``lambdas`` set (§14 λ-paths) yield a ``PathResult`` at
    their position instead of an ``FWResult``; identical paths group and
    run fused across tenants where the planner allows.
    """
    configs = list(configs)
    if not configs:
        return []
    with obs.span("solve_many", configs=len(configs)) as sp:
        plan = _as_plan(plan)
        X, y = resolve_data(X, y)
        resolved = []
        auto_stats = None             # derived once, only if any config asks
        for c in configs:
            if c.backend == "auto":
                from repro.core.solvers.planner import (choose_backend,
                                                        data_stats)
                if auto_stats is None:
                    auto_stats = data_stats(X)
                c = dataclasses.replace(c,
                                        backend=choose_backend(auto_stats, c))
            check_gap_certificate(c)
            if c.screen_every:
                from repro.core.solvers.screening import check_screen_config
                check_screen_config(c)
            if c.lambdas is not None:
                from repro.core.solvers.path import check_path_config
                check_path_config(c)
            backend = get_backend(c.backend)
            check_screening_support(backend, c)
            check_path_support(backend, c)
            resolved.append((backend, resolve_queue(backend, c)))

        if prepared is None:
            prepared = {}             # data layout -> coerced X (once each)
        for backend, _ in resolved:
            if backend.data_format not in prepared:
                with obs.span("solve_many.coerce",
                              layout=backend.data_format):
                    prepared[backend.data_format] = backend.prepare(X)

        groups: Dict[Tuple, List[int]] = {}
        for i, (_, cfg) in enumerate(resolved):
            groups.setdefault(group_key(cfg), []).append(i)
        sp.set(groups=len(groups))

        results: List[FWResult | None] = [None] * len(configs)
        for members in groups.values():
            backend, _ = resolved[members[0]]
            data = prepared[backend.data_format]
            member_cfgs = [resolved[i][1] for i in members]
            with obs.span("solve_many.group", backend=backend.name,
                          size=len(members)):
                if member_cfgs[0].lambdas is not None:
                    # §14: λ-path groups get their own sequential-in-λ /
                    # fused-across-tenants schedule (and return PathResults)
                    out = _run_path_group(backend, data, y, member_cfgs,
                                          plan)
                elif backend.name == "jax_sparse" and len(members) > 1:
                    out = _run_jax_sparse_group(data, y, member_cfgs, plan)
                elif backend.name == "jax_shard" and len(members) > 1:
                    from repro.core.solvers.jax_shard import solve_shard_group
                    out = solve_shard_group(data, y, member_cfgs)
                else:
                    out = [backend.fn(data, y, cfg) for cfg in member_cfgs]
            for i, res in zip(members, out):
                results[i] = res
    return results  # type: ignore[return-value]
