"""``jax_sparse`` backend — Algorithm 2 as one device-resident kernel pipeline.

This is the paper's fast iteration finally wired end-to-end through the
Pallas kernels (DESIGN.md §5):

  * setup           — ``kernels/spmv`` ELL rmatvec builds ȳ and α₀ from the
                      padded CSR (one O(nnz) sweep each);
  * line 15 select  — ``kernels/bsls_draw`` two-level exponential-mechanism
                      draw (big step over √D group masses in XLA, little step
                      as the scalar-prefetch Pallas kernel that DMAs only the
                      winning group's row), or the lazy group-argmax for the
                      non-private queue;
  * lines 22-28     — ``kernels/coord_update`` fused sweep: one VMEM-resident
                      pass updates v̄, q̄, α and returns the g̃ increment,
                      instead of the four separate scatter/gather passes the
                      pure-jnp ``fw_jax`` path emits.

The T-iteration loop is a single ``lax.scan``, so the whole optimization
lowers to one XLA while-loop with the kernels inlined — jit/pjit-compilable
and droppable onto the production mesh.  On CPU containers the kernels run in
interpret mode (``config.interpret=True``, the default); on TPU pass
``interpret=False``.

State representation (w_m-rescaling) is identical to ``fw_sparse``/``fw_jax``
— see DESIGN.md §2 — so the non-private path takes the *same steps* as both,
which the cross-backend parity test asserts.

The module is factored for batched sweeps (DESIGN.md §6) and dataset stores
(§7): ``fw_setup`` builds the config-independent state (ȳ, v̄₀, q̄₀, α₀ —
one O(nnz) pass shared by every (λ, ε) problem on the same design matrix)
and ``fw_scan`` runs the T-step loop with λ, the EM scale and the PRNG key
as *traced* scalars.  The two stages are jitted **separately**
(``fw_setup_jit`` / ``fw_scan_jit``): ``solvers.batched`` vmaps ``fw_scan``
over stacked per-config scalars, and a ``repro.data.store.DatasetStore``
persists ``fw_setup_jit``'s output so warm solves skip the setup sweep and
replay bit-identical state — both reuse paths are exact because they feed
the very arrays this module would have computed.

Gap-adaptive scheduling (DESIGN.md §9) splits the scan once more:
``fw_carry_init`` builds the full loop carry and ``fw_scan_chunk`` advances
it ``steps`` iterations starting at a *traced* global offset ``t0`` — so one
compiled chunk program is re-entered until the run converges (the FW gap
certificate g_t ≤ ``gap_tol``), times out, or exhausts T.  Early stopping is
a **masked scan**: once a chunk step observes the certificate the carry
freezes (``jnp.where`` selects the old state bit-for-bit, the PRNG key stops
splitting so DP noise draws after the stop are never consumed) and the
outputs emit (gap=0, coord=-1) sentinels.  Chunk boundaries never change the
arithmetic — iterates are bit-identical to the single whole-run scan at every
prefix, which is what the early-stopping parity tests pin.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.dp.accountant import em_log_weight_scale
from repro.core.losses import get_loss
from repro.core.samplers.bsls_jax import tl_init, tl_update
from repro.core.samplers.group_argmax import ga_get_next, ga_init, ga_update
from repro.core.solvers.config import STOP_MAX_STEPS, FWConfig, FWResult
from repro.core.solvers.stopping import (assemble_outputs, drive_chunks,
                                         resolve_chunk)
from repro.core.sparse.formats import PaddedCSC, PaddedCSR, TieredCSC
from repro.kernels.bsls_draw.ops import two_level_draw
from repro.kernels.coord_update.ops import coord_update
from repro.kernels.coord_update.ref import coord_update_ref
from repro.kernels.spmv.ops import ell_rmatvec


def fw_setup(
    pcsr: PaddedCSR, y: jnp.ndarray, *, loss: str, interpret: bool
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Config-independent solve state: (v̄₀, q̄₀, α₀) via the spmv kernel.

    Depends only on (X, y, loss) — a λ/ε sweep over one design matrix
    computes this once and shares it across every problem in the batch.

    Separable objectives use the paper's ȳ decomposition; label-coupled ones
    carry the full row gradient in q̄ (α = Xᵀq̄/N with no ȳ term).
    """
    n = pcsr.shape[0]
    dtype = pcsr.values.dtype
    obj = get_loss(loss)
    vbar0 = jnp.zeros(n, dtype)
    if obj.separable:
        h = obj.split_grad
        ybar = ell_rmatvec(pcsr, y, interpret=interpret) / n
        qbar0 = h(vbar0)
        alpha0 = ell_rmatvec(pcsr, qbar0, interpret=interpret) / n - ybar
    else:
        qbar0 = obj.grad(vbar0, y)
        alpha0 = ell_rmatvec(pcsr, qbar0, interpret=interpret) / n
    return vbar0, qbar0, alpha0


class FWCarry(NamedTuple):
    """Full loop state of one Frank-Wolfe run, chunk-resumable.

    ``done``/``stop_at`` are the masked-scan early-stopping flags: once
    ``done`` flips, every later step is a frozen no-op and ``stop_at`` holds
    the number of iterations actually applied.
    """

    w: jnp.ndarray
    w_m: jnp.ndarray
    g_tilde: jnp.ndarray
    vbar: jnp.ndarray
    qbar: jnp.ndarray
    alpha: jnp.ndarray
    sampler: object
    key: jax.Array
    done: jnp.ndarray       # bool scalar
    stop_at: jnp.ndarray    # int32 scalar; valid when done


def fw_carry_init(
    d: int, dtype, vbar0, qbar0, alpha0, em_scale, key: jax.Array,
    *, private: bool,
) -> FWCarry:
    """Loop carry at t = 0 (``em_scale``/``key`` may be traced — vmappable)."""
    em_scale = jnp.asarray(em_scale, dtype)
    if private:
        sampler0 = tl_init(jnp.abs(alpha0) * em_scale)
    else:
        sampler0 = ga_init(jnp.abs(alpha0))
    return FWCarry(
        w=jnp.zeros(d, dtype), w_m=jnp.asarray(1.0, dtype),
        g_tilde=jnp.asarray(0.0, dtype), vbar=vbar0, qbar=qbar0, alpha=alpha0,
        sampler=sampler0, key=key, done=jnp.asarray(False),
        stop_at=jnp.asarray(0, jnp.int32))


def fw_scan_chunk(
    pcsr: PaddedCSR, pcsc, carry: FWCarry,
    lam, em_scale, gap_tol, t0, y=None,
    *, steps: int, loss: str, private: bool, fused: bool, interpret: bool,
    early_stop: bool = False,
) -> Tuple[FWCarry, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Advance the carry by ``steps`` iterations starting after global step
    ``t0``; returns (carry, (gaps, coords)) for this chunk.

    ``lam`` (L1 radius), ``em_scale`` (exponential-mechanism log-weight
    scale; 1.0 when non-private), ``gap_tol`` and ``t0`` may be traced
    scalars — the first two are the vmap axis of ``solvers.batched``, the
    offset is what lets one compiled chunk be re-entered across a run.
    Everything shape- or branch-affecting (``steps``/``private``/``fused``/
    ``interpret``/``early_stop``) is static, which is exactly what makes a
    sweep group batchable.

    With ``early_stop`` the scan is masked: the iteration that observes
    g_t ≤ gap_tol is still applied (the certificate speaks for the iterate it
    was computed from; applying one more FW step from a converged point stays
    inside the ball), after which the carry — PRNG key included, so no DP
    noise draw is ever consumed past the stop — freezes bit-for-bit and the
    outputs emit (0.0, -1).  ``gap_tol <= 0`` never triggers, so mixed
    cohorts are safe.

    ``y`` is the label vector, required (traced) for label-coupled
    objectives; separable objectives pass ``None`` so their compiled
    programs are unchanged.
    """
    n, d = pcsr.shape
    obj = get_loss(loss)
    if not obj.separable and y is None:
        raise ValueError(f"loss {loss!r} is label-coupled; pass y")
    h = obj.split_grad
    dtype = pcsr.values.dtype
    inv_n = 1.0 / n
    lam = jnp.asarray(lam, dtype)
    em_scale = jnp.asarray(em_scale, dtype)
    gap_tol = jnp.asarray(gap_tol, dtype)
    t0 = jnp.asarray(t0, jnp.int32)

    def step(carry: FWCarry, i):
        (w, w_m, g_tilde, vbar, qbar, alpha, sampler, key,
         done, stop_at) = carry
        t = (t0 + i).astype(dtype)
        key_next, sel_key = jax.random.split(key)
        # ---- line 15: select coordinate -------------------------------------
        if private:
            j = two_level_draw(sampler.c, sampler.v, sel_key, interpret=interpret)
            sampler_after_sel = sampler
        else:
            j, sampler_after_sel = ga_get_next(sampler)
        j = jnp.minimum(j, d - 1)
        a_j = alpha[j]
        # ---- lines 16-21 -----------------------------------------------------
        d_tilde = -lam * jnp.sign(a_j)
        d_tilde = jnp.where(a_j == 0, lam, d_tilde)
        gap = g_tilde - d_tilde * a_j
        eta = 2.0 / (t + 2.0)
        w_m = w_m * (1.0 - eta)
        w = w.at[j].add(eta * d_tilde / w_m)
        g_tilde = g_tilde * (1.0 - eta) + eta * d_tilde * a_j
        # ---- lines 22-28: one fused VMEM sweep ------------------------------
        def apply_tile(col):
            """Lines 22-29 on one column tile: the fused coordinate update
            plus the queue refresh of every touched coordinate.  The tile
            width is whatever the layout hands us — the flat (Kc,) lanes, or
            one tier of the autotuned split; padded lanes are inert either
            way, so the tier only changes how many zero lanes ride along."""
            rows, xvals, mask = col                      # (K,)
            row_idx = pcsr.indices[rows]                 # (K, Kr)
            row_val = pcsr.values[rows]                  # (K, Kr) — 0 at padding
            y_col = None if obj.separable else y[rows]
            if fused:
                vbar_t, qbar_t, alpha_t, g_delta = coord_update(
                    vbar, qbar, alpha, w, rows, xvals, mask, row_idx, row_val,
                    eta=eta, d_tilde=d_tilde, w_m=w_m, inv_n=inv_n,
                    loss=loss, y_col=y_col, interpret=interpret)
            else:
                vbar_t, qbar_t, alpha_t, g_delta = coord_update_ref(
                    vbar, qbar, alpha, w, rows, xvals, mask, row_idx, row_val,
                    eta=eta, d_tilde=d_tilde, w_m=w_m, inv_n=inv_n,
                    h=h if obj.separable else obj.grad, y_col=y_col)
            # line 29: refresh queue priorities for touched coordinates
            flat_idx = row_idx.reshape(-1)
            fresh = jnp.abs(alpha_t[flat_idx]) * (em_scale if private
                                                  else 1.0)
            if private:
                sampler_t = tl_update(sampler_after_sel, flat_idx, fresh)
            else:
                sampler_t = ga_update(sampler_after_sel, flat_idx, fresh)
            return vbar_t, qbar_t, alpha_t, g_delta, sampler_t

        if isinstance(pcsc, TieredCSC):
            # §11 tiered layout: the few heavy columns run the full-width
            # tile, everything else the narrow one — same sums, fewer lanes
            vbar, qbar, alpha, g_delta, sampler = jax.lax.cond(
                pcsc.is_heavy(j),
                lambda: apply_tile(pcsc.col_heavy(j)),
                lambda: apply_tile(pcsc.col_light(j)))
        else:
            vbar, qbar, alpha, g_delta, sampler = apply_tile(pcsc.col(j))
        g_tilde = g_tilde + g_delta
        new = FWCarry(w, w_m, g_tilde, vbar, qbar, alpha, sampler, key_next,
                      done, stop_at)
        if not early_stop:
            return new, (gap, j.astype(jnp.int32))
        # ---- §9 masked stopping: freeze frames once the certificate lands ---
        newly = jnp.logical_and(~done, jnp.logical_and(gap_tol > 0,
                                                       gap <= gap_tol))
        frozen = carry._replace(
            done=jnp.logical_or(done, newly),
            stop_at=jnp.where(newly, t0 + i, stop_at))
        merged = jax.tree_util.tree_map(
            lambda old, fresh_leaf: jnp.where(done, old, fresh_leaf),
            frozen,
            new._replace(done=frozen.done, stop_at=frozen.stop_at))
        out_gap = jnp.where(done, jnp.asarray(0.0, dtype), gap)
        out_j = jnp.where(done, -1, j.astype(jnp.int32))
        return merged, (out_gap, out_j)

    ts = jnp.arange(1, steps + 1, dtype=jnp.int32)
    return jax.lax.scan(step, carry, ts)


def fw_scan(
    pcsr: PaddedCSR, pcsc,
    vbar0: jnp.ndarray, qbar0: jnp.ndarray, alpha0: jnp.ndarray,
    lam, em_scale, key: jax.Array, gap_tol=0.0, y=None,
    *, steps: int, loss: str, private: bool, fused: bool, interpret: bool,
    early_stop: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Whole run as one scan; returns (w, gaps, coords, stop_step).

    ``stop_step`` is the number of iterations actually applied — ``steps``
    unless ``early_stop`` observed the gap certificate earlier.
    """
    dtype = pcsr.values.dtype
    carry0 = fw_carry_init(pcsr.shape[1], dtype, vbar0, qbar0, alpha0,
                           em_scale, key, private=private)
    carry, (gaps, coords) = fw_scan_chunk(
        pcsr, pcsc, carry0, lam, em_scale, gap_tol, 0, y,
        steps=steps, loss=loss, private=private, fused=fused,
        interpret=interpret, early_stop=early_stop)
    stop_step = jnp.where(carry.done, carry.stop_at,
                          jnp.asarray(steps, jnp.int32))
    return carry.w * carry.w_m, gaps, coords, stop_step


fw_setup_jit = jax.jit(fw_setup, static_argnames=("loss", "interpret"))
fw_scan_jit = jax.jit(
    fw_scan,
    static_argnames=("steps", "loss", "private", "fused", "interpret",
                     "early_stop"))
fw_scan_chunk_jit = jax.jit(
    fw_scan_chunk,
    static_argnames=("steps", "loss", "private", "fused", "interpret",
                     "early_stop"))
fw_carry_init_jit = jax.jit(fw_carry_init, static_argnames=("d", "dtype",
                                                            "private"))


def em_scale_for(config: FWConfig, n_rows: int) -> float:
    """EM log-weight scale ε'·N/(2L) when the (native) queue is the DP
    two-level sampler; 1.0 otherwise (priorities are then raw |α|).

    A screened run's selection mechanism only gets the solve share of the
    budget — ``ε·(1 − screen_eps_frac)`` when rounds are planned (§13) —
    so the scale shrinks accordingly; the screening queries spend the rest.
    """
    if config.queue != "two_level":
        return 1.0
    epsilon = config.epsilon
    if config.screen_every > 0:
        from repro.core.solvers.screening import solve_epsilon
        epsilon = solve_epsilon(config)
    return em_log_weight_scale(
        epsilon=epsilon, delta=config.delta, steps=config.steps,
        n_rows=n_rows, lipschitz=config.loss_fn().lipschitz)


def _chunked_fw(pcsr, pcsc, setup, config: FWConfig, em_scale: float,
                private: bool, fused: bool, y=None) -> FWResult:
    """Host-driven chunk loop: re-enter one compiled ``fw_scan_chunk`` until
    the gap certificate lands, ``max_seconds`` expires, or T is spent
    (shared driver/assembly contract: ``solvers.stopping``)."""
    dtype = pcsr.values.dtype
    carry0 = fw_carry_init_jit(pcsr.shape[1], dtype, *setup, em_scale,
                               jax.random.PRNGKey(config.seed),
                               private=private)

    def advance(carry, t0, c):
        return fw_scan_chunk_jit(
            pcsr, pcsc, carry, config.lam, em_scale, config.gap_tol, t0, y,
            steps=c, loss=config.loss, private=private, fused=fused,
            interpret=config.interpret, early_stop=True)

    carry, outs, stop_step, stop_reason = drive_chunks(
        advance, carry0, steps=config.steps, chunk=resolve_chunk(config),
        max_seconds=config.max_seconds, done_of=lambda cy: cy.done,
        stop_at_of=lambda cy: cy.stop_at)
    gaps, coords = assemble_outputs(outs, config.steps, (0.0, -1))
    return FWResult(w=carry.w * carry.w_m, gaps=gaps, coords=coords,
                    losses=jnp.zeros_like(gaps), stop_step=stop_step,
                    stop_reason=stop_reason)


def _screened_chunked_fw(pcsr, pcsc, setup, config: FWConfig,
                         em_scale: float, private: bool, fused: bool,
                         y=None) -> FWResult:
    """§13 screened chunk loop: the §9 driver with mutable problem geometry.

    The padded pair lives in a :class:`stopping.ChunkGeometry` cell that the
    ``advance`` closure reads per entry; at every ``screen_every``-th chunk
    boundary the ``respec`` hook runs the privatized screening query over
    the live |α|, repacks the pair/carry to the survivors and swaps the
    cell — the next chunk compiles once for the smaller D and every term
    that scales with the padded width (masked-scan freezes, w/α scatter,
    √D selection, the (G, M) sampler state) shrinks with it.  Outputs are
    translated to original feature ids per chunk (``out_map``), before the
    boundary's repack changes what current-space ids mean; the final w is
    scattered back to the full D₀.  Per-chunk times are fed to the planner
    cost book against the *current* geometry's stats, so the model sees the
    shrinking D, not the admission-time one.
    """
    import time as _time

    import numpy as np

    from repro.core.solvers.planner import data_stats, record_cost
    from repro.core.solvers.screening import (Screener, repack_carry,
                                              repack_pair)
    from repro.core.solvers.stopping import ChunkGeometry

    dtype = pcsr.values.dtype
    pad_col = (pcsc.full_width if isinstance(pcsc, TieredCSC)
               else int(pcsc.indices.shape[1]))
    geom = ChunkGeometry(operands=(pcsr, pcsc), d=pcsr.shape[1],
                         pad_row=int(pcsr.indices.shape[1]), pad_col=pad_col)
    scr = Screener(config, d=pcsr.shape[1], n_rows=pcsr.shape[0],
                   row_width=int(pcsr.indices.shape[1]), em_scale=em_scale,
                   private=private)
    carry0 = fw_carry_init_jit(pcsr.shape[1], dtype, *setup, em_scale,
                               jax.random.PRNGKey(config.seed),
                               private=private)
    platform = jax.devices()[0].platform
    stats_cache = {}

    def cur_stats():
        if geom.version not in stats_cache:
            stats_cache[geom.version] = data_stats(geom.operands)
        return stats_cache[geom.version]

    def advance(carry, t0, c):
        p, q = geom.operands
        tw = _time.perf_counter()
        carry, out = fw_scan_chunk_jit(
            p, q, carry, config.lam, em_scale, config.gap_tol, t0, y,
            steps=c, loss=config.loss, private=private, fused=fused,
            interpret=config.interpret, early_stop=True)
        jax.block_until_ready(out[0])
        record_cost("jax_sparse", "sequential", platform, cur_stats(),
                    (_time.perf_counter() - tw) / c, loss=config.loss)
        return carry, out

    def out_map(out, t0):
        gaps, coords = out
        return gaps, scr.map_coords(coords)

    def respec(carry, t0, n_chunks):
        if not scr.due(n_chunks):
            return None
        keep = scr.screen(np.abs(np.asarray(carry.alpha)),
                          np.asarray(carry.w) != 0)
        if keep is None:
            return None
        tw = _time.perf_counter()
        p2, q2 = repack_pair(*geom.operands, keep)
        carry2 = repack_carry(carry, keep, em_scale, private)
        pad2 = (q2.full_width if isinstance(q2, TieredCSC)
                else int(q2.indices.shape[1]))
        geom.swap((p2, q2), p2.shape[1],
                  pad_row=int(p2.indices.shape[1]), pad_col=pad2)
        info = scr.commit(keep, repack_seconds=_time.perf_counter() - tw)
        return carry2, info

    carry, outs, stop_step, stop_reason = drive_chunks(
        advance, carry0, steps=config.steps, chunk=resolve_chunk(config),
        max_seconds=config.max_seconds, done_of=lambda cy: cy.done,
        stop_at_of=lambda cy: cy.stop_at, respec=respec, out_map=out_map)
    gaps, coords = assemble_outputs(outs, config.steps, (0.0, -1))
    return FWResult(w=scr.expand(carry.w * carry.w_m), gaps=gaps,
                    coords=coords, losses=jnp.zeros_like(gaps),
                    stop_step=stop_step, stop_reason=stop_reason)


def jax_sparse_fw(
    pcsr: PaddedCSR, pcsc, y: jnp.ndarray, config: FWConfig,
    setup: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray] = None,
) -> FWResult:
    """One solve through the kernel pipeline (both stages jitted).

    ``setup`` injects a precomputed ``fw_setup`` state — the dataset-store
    warm path; it must be the (v̄₀, q̄₀, α₀) this function would have
    computed (``PreparedDataset`` guarantees that by construction).

    Fixed-T configs run the single whole-run scan exactly as before;
    early-stopping configs (``gap_tol``/``max_seconds``) go through the
    chunked driver — same arithmetic per step, so iterates are bit-identical
    at every prefix.
    """
    n, _ = pcsr.shape
    private = config.queue == "two_level"
    # Every registered objective lowers its own fused kernel.
    fused = True
    em_scale = em_scale_for(config, n)
    y_scan = None if config.loss_fn().separable else jnp.asarray(y)

    from repro import obs
    if setup is None:
        with obs.span("solve.setup", loss=config.loss):
            setup = fw_setup_jit(pcsr, y, loss=config.loss,
                                 interpret=config.interpret)
    if config.screen_every > 0:
        # §13: mutable-geometry chunked driver (subsumes early stopping)
        return _screened_chunked_fw(pcsr, pcsc, setup, config, em_scale,
                                    private, fused, y=y_scan)
    if config.early_stopping:
        return _chunked_fw(pcsr, pcsc, setup, config, em_scale, private,
                           fused, y=y_scan)
    vbar0, qbar0, alpha0 = setup
    with obs.span("solve.scan", steps=config.steps, private=private):
        w, gaps, coords, stop_step = fw_scan_jit(
            pcsr, pcsc, vbar0, qbar0, alpha0,
            config.lam, em_scale, jax.random.PRNGKey(config.seed), 0.0,
            y_scan, steps=config.steps, loss=config.loss, private=private,
            fused=fused, interpret=config.interpret)
    return FWResult(w=w, gaps=gaps, coords=coords,
                    losses=jnp.zeros_like(gaps), stop_step=config.steps,
                    stop_reason=STOP_MAX_STEPS)
