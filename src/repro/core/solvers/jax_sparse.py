"""``jax_sparse`` backend — Algorithm 2 as one device-resident kernel pipeline.

This is the paper's fast iteration finally wired end-to-end through the
Pallas kernels (DESIGN.md §5):

  * setup           — ``kernels/spmv`` ELL rmatvec builds ȳ and α₀ from the
                      padded CSR (one O(nnz) sweep each);
  * line 15 select  — ``kernels/bsls_draw`` two-level exponential-mechanism
                      draw (big step over √D group masses in XLA, little step
                      as the scalar-prefetch Pallas kernel that DMAs only the
                      winning group's row), or the lazy group-argmax for the
                      non-private queue;
  * lines 22-28     — ``kernels/coord_update`` fused sweep: one VMEM-resident
                      pass updates v̄, q̄, α and returns the g̃ increment,
                      instead of the four separate scatter/gather passes the
                      pure-jnp ``fw_jax`` path emits.

The T-iteration loop is a single ``lax.scan``, so the whole optimization
lowers to one XLA while-loop with the kernels inlined — jit/pjit-compilable
and droppable onto the production mesh.  On CPU containers the kernels run in
interpret mode (``config.interpret=True``, the default); on TPU pass
``interpret=False``.

State representation (w_m-rescaling) is identical to ``fw_sparse``/``fw_jax``
— see DESIGN.md §2 — so the non-private path takes the *same steps* as both,
which the cross-backend parity test asserts.

The module is factored for batched sweeps (DESIGN.md §6) and dataset stores
(§7): ``fw_setup`` builds the config-independent state (ȳ, v̄₀, q̄₀, α₀ —
one O(nnz) pass shared by every (λ, ε) problem on the same design matrix)
and ``fw_scan`` runs the T-step loop with λ, the EM scale and the PRNG key
as *traced* scalars.  The two stages are jitted **separately**
(``fw_setup_jit`` / ``fw_scan_jit``): ``solvers.batched`` vmaps ``fw_scan``
over stacked per-config scalars, and a ``repro.data.store.DatasetStore``
persists ``fw_setup_jit``'s output so warm solves skip the setup sweep and
replay bit-identical state — both reuse paths are exact because they feed
the very arrays this module would have computed.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dp.accountant import em_log_weight_scale
from repro.core.losses import get_loss
from repro.core.samplers.bsls_jax import tl_init, tl_update
from repro.core.samplers.group_argmax import ga_get_next, ga_init, ga_update
from repro.core.solvers.config import FWConfig, FWResult
from repro.core.sparse.formats import PaddedCSC, PaddedCSR
from repro.kernels.bsls_draw.ops import two_level_draw
from repro.kernels.coord_update.ops import coord_update
from repro.kernels.coord_update.ref import coord_update_ref
from repro.kernels.spmv.ops import ell_rmatvec


def fw_setup(
    pcsr: PaddedCSR, y: jnp.ndarray, *, loss: str, interpret: bool
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Config-independent solve state: (v̄₀, q̄₀, α₀) via the spmv kernel.

    Depends only on (X, y, loss) — a λ/ε sweep over one design matrix
    computes this once and shares it across every problem in the batch.
    """
    n = pcsr.shape[0]
    dtype = pcsr.values.dtype
    h = get_loss(loss).split_grad
    ybar = ell_rmatvec(pcsr, y, interpret=interpret) / n
    vbar0 = jnp.zeros(n, dtype)
    qbar0 = h(vbar0)
    alpha0 = ell_rmatvec(pcsr, qbar0, interpret=interpret) / n - ybar
    return vbar0, qbar0, alpha0


def fw_scan(
    pcsr: PaddedCSR, pcsc: PaddedCSC,
    vbar0: jnp.ndarray, qbar0: jnp.ndarray, alpha0: jnp.ndarray,
    lam, em_scale, key: jax.Array,
    *, steps: int, loss: str, private: bool, fused: bool, interpret: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """T Frank-Wolfe iterations; returns (w, gaps, coords).

    ``lam`` (L1 radius), ``em_scale`` (exponential-mechanism log-weight
    scale; 1.0 when non-private) and ``key`` may be traced scalars — this is
    the vmap axis of ``solvers.batched``.  Everything shape- or
    branch-affecting (``steps``/``private``/``fused``/``interpret``) is
    static, which is exactly what makes a sweep group batchable.
    """
    n, d = pcsr.shape
    h = get_loss(loss).split_grad
    dtype = pcsr.values.dtype
    inv_n = 1.0 / n
    lam = jnp.asarray(lam, dtype)
    em_scale = jnp.asarray(em_scale, dtype)

    if private:
        sampler0 = tl_init(jnp.abs(alpha0) * em_scale)
    else:
        sampler0 = ga_init(jnp.abs(alpha0))

    def step(carry, t):
        w, w_m, g_tilde, vbar, qbar, alpha, sampler, key = carry
        key, sel_key = jax.random.split(key)
        # ---- line 15: select coordinate -------------------------------------
        if private:
            j = two_level_draw(sampler.c, sampler.v, sel_key, interpret=interpret)
            sampler_after_sel = sampler
        else:
            j, sampler_after_sel = ga_get_next(sampler)
        j = jnp.minimum(j, d - 1)
        a_j = alpha[j]
        # ---- lines 16-21 -----------------------------------------------------
        d_tilde = -lam * jnp.sign(a_j)
        d_tilde = jnp.where(a_j == 0, lam, d_tilde)
        gap = g_tilde - d_tilde * a_j
        eta = 2.0 / (t + 2.0)
        w_m = w_m * (1.0 - eta)
        w = w.at[j].add(eta * d_tilde / w_m)
        g_tilde = g_tilde * (1.0 - eta) + eta * d_tilde * a_j
        # ---- lines 22-28: one fused VMEM sweep ------------------------------
        rows, xvals, mask = pcsc.col(j)                  # (Kc,)
        row_idx = pcsr.indices[rows]                     # (Kc, Kr)
        row_val = pcsr.values[rows]                      # (Kc, Kr) — 0 at padding
        if fused:
            vbar, qbar, alpha, g_delta = coord_update(
                vbar, qbar, alpha, w, rows, xvals, mask, row_idx, row_val,
                eta=eta, d_tilde=d_tilde, w_m=w_m, inv_n=inv_n,
                interpret=interpret)
        else:
            vbar, qbar, alpha, g_delta = coord_update_ref(
                vbar, qbar, alpha, w, rows, xvals, mask, row_idx, row_val,
                eta=eta, d_tilde=d_tilde, w_m=w_m, inv_n=inv_n, h=h)
        g_tilde = g_tilde + g_delta
        # ---- line 29: refresh queue priorities for touched coordinates ------
        flat_idx = row_idx.reshape(-1)
        fresh = jnp.abs(alpha[flat_idx]) * (em_scale if private else 1.0)
        if private:
            sampler = tl_update(sampler_after_sel, flat_idx, fresh)
        else:
            sampler = ga_update(sampler_after_sel, flat_idx, fresh)
        return (w, w_m, g_tilde, vbar, qbar, alpha, sampler, key), (gap, j)

    carry0 = (
        jnp.zeros(d, dtype), jnp.asarray(1.0, dtype), jnp.asarray(0.0, dtype),
        vbar0, qbar0, alpha0, sampler0, key,
    )
    ts = jnp.arange(1, steps + 1, dtype=dtype)
    (w, w_m, *_), (gaps, coords) = jax.lax.scan(step, carry0, ts)
    return w * w_m, gaps, coords


fw_setup_jit = jax.jit(fw_setup, static_argnames=("loss", "interpret"))
fw_scan_jit = jax.jit(
    fw_scan,
    static_argnames=("steps", "loss", "private", "fused", "interpret"))


def em_scale_for(config: FWConfig, n_rows: int) -> float:
    """EM log-weight scale ε'·N/(2L) when the (native) queue is the DP
    two-level sampler; 1.0 otherwise (priorities are then raw |α|)."""
    if config.queue != "two_level":
        return 1.0
    return em_log_weight_scale(
        epsilon=config.epsilon, delta=config.delta, steps=config.steps,
        n_rows=n_rows, lipschitz=config.loss_fn().lipschitz)


def jax_sparse_fw(
    pcsr: PaddedCSR, pcsc: PaddedCSC, y: jnp.ndarray, config: FWConfig,
    setup: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray] = None,
) -> FWResult:
    """One solve through the kernel pipeline (both stages jitted).

    ``setup`` injects a precomputed ``fw_setup`` state — the dataset-store
    warm path; it must be the (v̄₀, q̄₀, α₀) this function would have
    computed (``PreparedDataset`` guarantees that by construction).
    """
    n, _ = pcsr.shape
    private = config.queue == "two_level"
    # The fused kernel hardwires logistic h = σ; other losses fall back to the
    # jnp oracle (same math, unfused).
    fused = config.loss == "logistic"
    em_scale = em_scale_for(config, n)

    if setup is None:
        setup = fw_setup_jit(pcsr, y, loss=config.loss,
                             interpret=config.interpret)
    vbar0, qbar0, alpha0 = setup
    w, gaps, coords = fw_scan_jit(
        pcsr, pcsc, vbar0, qbar0, alpha0,
        config.lam, em_scale, jax.random.PRNGKey(config.seed),
        steps=config.steps, loss=config.loss, private=private, fused=fused,
        interpret=config.interpret)
    return FWResult(w=w, gaps=gaps, coords=coords,
                    losses=jnp.zeros_like(gaps))
