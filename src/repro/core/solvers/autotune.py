"""Per-dataset layout/chunk autotuner for the device hot path (DESIGN.md §11).

The paper's `O(NS + T√D log D + TS²)` iteration cost only materializes when
the padded ELL layout fits the dataset.  It usually doesn't: text-like
designs have power-law column popularity, so the flat ``PaddedCSC`` pad
width (the exact max column nnz) is ~8× the 99th-percentile column — the
``jax_sparse`` step pays a (K_c × K_r) tile that is >100× the true work
(the BENCH_shard ``block_waste: 119.9`` finding).  This module searches a
small, bounded candidate space per dataset **without ever changing the
arithmetic**:

  * **ELL tier width** — ``TieredCSC`` splits the flat CSC at width ``k``:
    a narrow (D, k) primary table plus a full-width table for the few
    columns wider than ``k``, dispatched per step by ``lax.cond``.  Every
    candidate must pass a **bitwise parity probe** (coords/w/gaps identical
    to the flat layout, private and non-private) before it is eligible —
    an exactness gate, not a tolerance: the DP selection distribution is
    untouched because the iterates are untouched.
  * **chunk_steps** — re-entry granularity of the §9 chunked driver
    (host dispatch overhead vs post-convergence waste).
  * **jax_shard block geometry (a, b)** — mesh grids measured per dataset
    (degenerate on 1-device containers, searched on real meshes).

Timings are steady-state: every candidate program is compiled and run once
before the timed repetitions.  Winners persist as a :class:`TuningRecord`
in the ``DatasetStore`` ``cache/`` next to the padded layout — keyed by
content hash + platform + backend + loss — and are replayed on warm opens
(``store.prepared()`` wires the loader; no re-search).  Measured per-iter
times also feed ``solvers.planner`` as high-priority warmed observations,
so ``backend="auto"`` and vmap-vs-sequential choices see real numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.solvers.config import FWConfig

TUNE_VERSION = 1
# bounded search: at most this many tier-width candidates per dataset
MAX_WIDTH_CANDIDATES = 4
# chunk lengths the chunked-driver search tries (plus the planner default)
CHUNK_CANDIDATES = (16, 32, 64)


@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """One dataset's tuning winner for (platform, backend, loss).

    ``ell_width`` of None means the flat layout won (or no candidate passed
    the parity probe); ``mesh`` is only set by the jax_shard search.  The
    record stores both per-iter timings so consumers (benches, the perf
    gate) can recompute the speedup it claims.
    """

    content_hash: str
    platform: str
    backend: str
    loss: str
    ell_width: Optional[int] = None
    chunk_steps: Optional[int] = None
    mesh: Optional[Tuple[int, int]] = None
    per_iter_default_ms: float = 0.0
    per_iter_tuned_ms: float = 0.0
    pass_parity: bool = True
    version: int = TUNE_VERSION

    @property
    def speedup(self) -> float:
        return self.per_iter_default_ms / max(self.per_iter_tuned_ms, 1e-12)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if self.mesh is not None:
            d["mesh"] = list(self.mesh)
        return d

    @classmethod
    def from_json(cls, d: dict) -> Optional["TuningRecord"]:
        if not isinstance(d, dict) or d.get("version") != TUNE_VERSION:
            return None
        d = dict(d)
        if d.get("mesh") is not None:
            d["mesh"] = tuple(int(v) for v in d["mesh"])
        try:
            return cls(**d)
        except TypeError:
            return None


def candidate_widths(pcsc, max_candidates: int = MAX_WIDTH_CANDIDATES
                     ) -> List[int]:
    """Power-of-two tier widths worth probing: from the first power of two
    at or above the 90th-percentile column nnz up to (exclusive) the flat
    pad width.  Bounded, and empty when the layout has no tail to split."""
    full = int(pcsc.indices.shape[1])
    cn = np.asarray(pcsc.nnz)
    if full <= 8 or cn.size == 0:
        return []
    lo = max(8, int(np.percentile(cn, 90)))
    cands = []
    w = 8
    while w < full and len(cands) < max_candidates:
        if w >= lo:
            cands.append(w)
        w *= 2
    return cands


def _bitwise_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


def _scan_once(pcsr, csc, setup, y_scan, *, steps, loss, lam, em_scale,
               private, interpret, seed=0):
    import jax

    from repro.core.solvers.jax_sparse import fw_scan_jit
    out = fw_scan_jit(pcsr, csc, *setup, lam, em_scale,
                      jax.random.PRNGKey(seed), 0.0, y_scan,
                      steps=steps, loss=loss, private=private, fused=True,
                      interpret=interpret)
    jax.block_until_ready(out[0])
    return out[:3]                       # (w, gaps, coords)


def probe_parity(pcsr, pcsc_default, csc_candidate, y, *, loss: str,
                 interpret: bool, steps: int = 32, lam: float = 20.0,
                 setup=None) -> bool:
    """The exactness gate: candidate layout must reproduce the flat layout's
    (w, gaps, coords) **bitwise**, on a private and a non-private run."""
    import jax.numpy as jnp

    from repro.core.losses import get_loss
    from repro.core.solvers.jax_sparse import em_scale_for, fw_setup_jit
    y32 = jnp.asarray(y, jnp.float32)
    if setup is None:
        setup = fw_setup_jit(pcsr, y32, loss=loss, interpret=interpret)
    y_scan = None if get_loss(loss).separable else y32
    for private in (False, True):
        cfg = FWConfig(steps=steps, epsilon=1.0, delta=1e-6,
                       queue="two_level" if private else "group_argmax")
        em = em_scale_for(cfg, pcsr.shape[0])
        kw = dict(steps=steps, loss=loss, lam=lam, em_scale=em,
                  private=private, interpret=interpret)
        ref = _scan_once(pcsr, pcsc_default, setup, y_scan, **kw)
        got = _scan_once(pcsr, csc_candidate, setup, y_scan, **kw)
        if not all(_bitwise_equal(r, g) for r, g in zip(ref, got)):
            return False
    return True


def _time_per_iter_ms(fn, steps: int, repeats: int = 3) -> float:
    """Best-of-N steady-state per-iteration time; ``fn`` must block."""
    fn()                                 # warm: compile excluded
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / steps * 1e3


def _time_layout(pcsr, csc, setup, y_scan, *, steps, loss, lam, em_scale,
                 private, interpret) -> float:
    kw = dict(steps=steps, loss=loss, lam=lam, em_scale=em_scale,
              private=private, interpret=interpret)
    return _time_per_iter_ms(
        lambda: _scan_once(pcsr, csc, setup, y_scan, **kw), steps)


def _tune_chunk(pcsr, csc, setup, y_scan, *, steps, loss, lam, em_scale,
                private, interpret) -> Optional[int]:
    """Pick the chunked-driver re-entry length: time a short chunked run at
    each candidate and keep the fastest (None = planner default wins)."""
    import jax

    from repro.core.solvers.jax_sparse import fw_carry_init_jit, \
        fw_scan_chunk_jit
    from repro.core.solvers.planner import default_chunk
    dtype = pcsr.values.dtype

    def run_chunked(chunk: int):
        carry = fw_carry_init_jit(pcsr.shape[1], dtype, *setup, em_scale,
                                  jax.random.PRNGKey(0), private=private)
        t0 = 0
        while t0 < steps:
            c = min(chunk, steps - t0)
            carry, _ = fw_scan_chunk_jit(
                pcsr, csc, carry, lam, em_scale, 0.0, t0, y_scan,
                steps=c, loss=loss, private=private, fused=True,
                interpret=interpret, early_stop=True)
            t0 += c
        jax.block_until_ready(carry.w)

    base = default_chunk(steps)
    cands = sorted({min(c, steps) for c in (base,) + CHUNK_CANDIDATES})
    timed = {c: _time_per_iter_ms(lambda c=c: run_chunked(c), steps)
             for c in cands}
    best = min(timed, key=timed.get)
    return None if best == base else int(best)


def _feed_planner(backend: str, stats, per_iter_ms: float, *, loss: str,
                  platform: str, modes: Sequence[str] = ("sequential",)
                  ) -> None:
    from repro.core.solvers.planner import record_measured
    for mode in modes:
        record_measured(backend, mode, platform, stats, per_iter_ms / 1e3,
                        loss=loss)


def tune_jax_sparse(pcsr, pcsc, y, *, loss: str = "logistic",
                    interpret: bool = True, steps: int = 24,
                    probe_steps: int = 32, lam: float = 20.0,
                    content_hash: str = "", platform: Optional[str] = None,
                    setup=None, tune_chunk: bool = True) -> TuningRecord:
    """Search tier widths (+ chunk length) for the kernel pipeline.

    Candidates that fail the bitwise parity probe are discarded before any
    timing; the flat layout always remains eligible, so the tuner can only
    return a layout that is both exact and at least as fast as measured.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.losses import get_loss
    from repro.core.solvers.jax_sparse import em_scale_for, fw_setup_jit
    from repro.core.solvers.planner import data_stats
    from repro.core.sparse.formats import tiered_from_padded
    plat = platform or jax.devices()[0].platform
    y32 = jnp.asarray(y, jnp.float32)
    if setup is None:
        setup = fw_setup_jit(pcsr, y32, loss=loss, interpret=interpret)
    y_scan = None if get_loss(loss).separable else y32
    cfg = FWConfig(steps=steps, epsilon=1.0, delta=1e-6, queue="two_level")
    em_private = em_scale_for(cfg, pcsr.shape[0])
    kw = dict(steps=steps, loss=loss, lam=lam, interpret=interpret)

    def per_iter(csc) -> float:
        # both selection rules, worst case kept: the tuned layout must not
        # regress either the private or the non-private hot path
        return max(
            _time_layout(pcsr, csc, setup, y_scan, em_scale=1.0,
                         private=False, **kw),
            _time_layout(pcsr, csc, setup, y_scan, em_scale=em_private,
                         private=True, **kw))

    default_ms = per_iter(pcsc)
    obs.event("autotune.candidate", backend="jax_sparse", loss=loss,
              candidate="flat", per_iter_ms=default_ms, parity=True)
    best_width, best_ms = None, default_ms
    for width in candidate_widths(pcsc):
        cand = tiered_from_padded(pcsc, width)
        if not probe_parity(pcsr, pcsc, cand, y32, loss=loss,
                            interpret=interpret, steps=probe_steps, lam=lam,
                            setup=setup):
            obs.event("autotune.candidate", backend="jax_sparse", loss=loss,
                      candidate=f"tiered-{width}", parity=False)
            continue                      # exactness gate: never eligible
        ms = per_iter(cand)
        obs.event("autotune.candidate", backend="jax_sparse", loss=loss,
                  candidate=f"tiered-{width}", per_iter_ms=ms, parity=True)
        if ms < best_ms:
            best_width, best_ms = width, ms
    winner = (tiered_from_padded(pcsc, best_width) if best_width is not None
              else pcsc)
    chunk = (_tune_chunk(pcsr, winner, setup, y_scan, em_scale=em_private,
                         private=True, **kw) if tune_chunk else None)
    stats = data_stats((pcsr, pcsc))
    _feed_planner("jax_sparse", stats, best_ms, loss=loss, platform=plat)
    obs.event("autotune.winner", backend="jax_sparse", loss=loss,
              ell_width=best_width, chunk_steps=chunk,
              per_iter_ms=best_ms,
              speedup=default_ms / max(best_ms, 1e-12))
    return TuningRecord(
        content_hash=content_hash, platform=plat, backend="jax_sparse",
        loss=loss, ell_width=best_width, chunk_steps=chunk, mesh=None,
        per_iter_default_ms=default_ms, per_iter_tuned_ms=best_ms,
        pass_parity=True)


def tune_jax_shard(src, y, *, loss: str = "logistic", steps: int = 24,
                   lam: float = 20.0, content_hash: str = "",
                   platform: Optional[str] = None) -> TuningRecord:
    """Search (a, b) block geometries for the sharded engine.

    Candidates are the factorizations of every device count ≤ the local
    device count — degenerate (just 1×1) on single-device containers, a
    real search on meshes.  Results are exact for every candidate (the
    collective schedule is parity-pinned per geometry), so only time
    decides; the winner also feeds the planner's cost book under the
    ``jax_shard`` key (the book the §9 mode choice reads for this backend).
    """
    import jax

    from repro.core.solvers.jax_shard import (make_shard_mesh, shard_em_scale,
                                              shard_program)
    from repro.core.solvers.planner import data_stats
    plat = platform or jax.devices()[0].platform
    n_dev = jax.device_count()
    cands = sorted({(a, b) for total in range(1, n_dev + 1)
                    for a in range(1, total + 1) if total % a == 0
                    for b in (total // a,)})
    cfg = FWConfig(steps=steps, lam=lam, queue="gumbel", epsilon=1.0,
                   delta=1e-6)
    em = shard_em_scale(cfg, src.shape[0])
    timings = {}
    for a, b in cands:
        mesh = make_shard_mesh(a, b)
        blocks = src.blocks(a, b)
        prog = shard_program(blocks, mesh, steps=steps, loss=loss,
                             selection="gumbel")
        import jax.numpy as jnp

        from repro.core.solvers.jax_shard import _pad_labels

        def run(mesh=mesh, blocks=blocks, prog=prog):
            with mesh:
                ypad = _pad_labels(y, blocks.padded[0])
                setup = prog.setup(blocks, ypad)
                out = prog.scan(blocks, ypad, *setup, jnp.float32(lam),
                                jnp.float32(em), jnp.float32(0.0),
                                jax.random.PRNGKey(0))
            jax.block_until_ready(out[0])

        timings[(a, b)] = _time_per_iter_ms(run, steps)
        obs.event("autotune.candidate", backend="jax_shard", loss=loss,
                  candidate=f"{a}x{b}", per_iter_ms=timings[(a, b)],
                  parity=True)
    best = min(timings, key=timings.get)
    obs.event("autotune.winner", backend="jax_shard", loss=loss,
              candidate=f"{best[0]}x{best[1]}",
              per_iter_ms=timings[best],
              speedup=timings[(1, 1)] / max(timings[best], 1e-12))
    default_ms = timings[(1, 1)]
    stats = data_stats(src.csr) if src.csr is not None else \
        data_stats(src.store)
    _feed_planner("jax_shard", stats, timings[best], loss=loss, platform=plat,
                  modes=("sequential", "vmap"))
    return TuningRecord(
        content_hash=content_hash, platform=plat, backend="jax_shard",
        loss=loss, ell_width=None, chunk_steps=None,
        mesh=best if best != (1, 1) else None,
        per_iter_default_ms=default_ms, per_iter_tuned_ms=timings[best],
        pass_parity=True)


def autotune(data, y=None, *, backend: str = "jax_sparse",
             loss: str = "logistic", interpret: bool = True,
             steps: int = 24, probe_steps: int = 32, lam: float = 20.0,
             force: bool = False) -> TuningRecord:
    """Tune ``backend`` for one dataset; persist + replay through its store.

    ``data`` may be anything ``solve`` accepts.  For a ``DatasetStore``/
    ``DatasetRef`` the winner lands in ``cache/autotune-*.json`` (guarded by
    the content hash) and warm calls — this function *and* every consumer
    that resolves tuning through ``PreparedDataset`` — replay it without
    re-searching; ``force=True`` re-runs the search and overwrites.
    """
    import jax

    from repro.core.solvers.prepared import PreparedDataset
    from repro.core.solvers.registry import as_padded, as_shard_source, \
        resolve_data
    plat = jax.devices()[0].platform
    data, y = resolve_data(data, y)
    store = data if hasattr(data, "autotune_load") else None
    if store is not None and not force:
        rec = store.autotune_load(backend, loss, plat)
        if rec is not None:
            obs.count("autotune.replayed", backend=backend)
            return rec
    if backend == "jax_sparse":
        prepared = as_padded(data)
        if isinstance(prepared, PreparedDataset):
            pcsr, pcsc = prepared.pair
            setup = prepared.setup_for(y, loss, interpret)
        else:
            pcsr, pcsc = prepared
            setup = None
        rec = tune_jax_sparse(
            pcsr, pcsc, y, loss=loss, interpret=interpret, steps=steps,
            probe_steps=probe_steps, lam=lam,
            content_hash=getattr(store, "content_hash", ""), platform=plat,
            setup=setup)
        if isinstance(prepared, PreparedDataset):
            prepared.set_tuning(rec)
    elif backend == "jax_shard":
        src = as_shard_source(data)
        rec = tune_jax_shard(
            src, y, loss=loss, steps=steps, lam=lam,
            content_hash=getattr(store, "content_hash", ""), platform=plat)
    else:
        raise ValueError(
            f"autotune supports jax_sparse/jax_shard, got {backend!r}")
    if store is not None:
        store.autotune_save(rec)
    return rec
