"""Shared solver configuration/result types (DESIGN.md §4).

``FWConfig`` is the single configuration dataclass every registered backend
consumes, and ``FWResult`` the single result pytree every backend returns.
Both classes used to live in ``repro.core.fw_dense``; they are defined here
so the registry, the backends, and user code all share one vocabulary, and
re-exported from ``fw_dense`` for backward compatibility.

The config is a frozen (hashable) dataclass so it can ride through ``jax.jit``
as a static argument — every field is a Python scalar.

Queue vs. selection: Algorithm 1 (the ``dense`` backend) names its coordinate
rule ``selection`` (argmax | noisy_max | gumbel); the sparse backends name
theirs ``queue`` (fib_heap | bsls | ... on host, two_level | group_argmax on
device).  ``FWConfig`` carries both; ``queue=None`` means "this backend's
non-private default".  The registry translates equivalent names between
backends (see ``registry.QUEUE_ALIASES``) so one config can be re-targeted by
changing only ``backend=``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.losses import Loss, get_loss

# FWResult.stop_reason values (DESIGN.md §9):
STOP_MAX_STEPS = "max_steps"      # ran the full T iterations
STOP_GAP_TOL = "gap_tol"          # duality-gap certificate reached gap_tol
STOP_MAX_SECONDS = "max_seconds"  # wall-clock budget exhausted


@dataclasses.dataclass(frozen=True)
class FWConfig:
    """One Frank-Wolfe run, declaratively.

    ``repro.core.solvers.solve(X, y, FWConfig(backend=...))`` is the single
    entry point; see ``registry.available_backends()`` for the choices.
    """

    backend: str = "dense"       # dense | jax_dense | host_sparse | jax_sparse
                                 # | jax_shard | auto (planner picks, §9)
    lam: float = 50.0            # L1 radius λ (paper default for speed runs)
    steps: int = 4000            # T (paper default)
    loss: str = "logistic"
    selection: str = "argmax"    # Alg-1 rule: argmax | noisy_max | gumbel
    queue: Optional[str] = None  # Alg-2 rule; None → backend non-private default
    epsilon: float = 1.0
    delta: float = 1e-6
    seed: int = 0
    interpret: bool = True       # Pallas interpret mode (True on CPU containers)
    # jax_shard only: (row shards, feature shards) of the device mesh the
    # blocked solve runs on; None → 1×1 (single device — must reproduce the
    # host oracle exactly, which is what makes parity testable everywhere).
    # Other backends ignore it.  A tuple keeps the config hashable/static.
    mesh: Optional[Tuple[int, int]] = None
    # Gap-adaptive early stopping (DESIGN.md §9).  gap_tol > 0 stops the run
    # once the FW duality-gap estimate g_t falls to ≤ gap_tol: the step that
    # produced the certificate is still applied, every later step is a frozen
    # no-op (bit-identical to a run of exactly stop_step iterations).  0.0
    # (the default) disables stopping and reproduces the fixed-T program.
    gap_tol: float = 0.0
    # Wall-clock budget in seconds; None → unlimited.  Enforced per-iteration
    # by the host loops (host_sparse) and at chunk boundaries by the chunked
    # drivers (dense, jax_sparse); unsupported inside the single-scan
    # jax_dense / jax_shard programs, which reject it loudly.
    max_seconds: Optional[float] = None
    # Scan-chunk length for the chunked early-stopping drivers and the
    # batched cohort scheduler; None → planner default (steps/8 clamped to
    # [8, 256]).  Chunking never changes iterates — only how often the host
    # checks for convergence/timeouts and retires finished configs.
    chunk_steps: Optional[int] = None
    # DP iterative screening (DESIGN.md §13).  screen_every = k > 0 runs a
    # privatized screening query every k chunk boundaries: coordinates whose
    # (noisy) |α| score falls far enough below the max are dropped and the
    # padded problem geometry is repacked to the survivors, so later chunks
    # pay O(D_surviving) instead of O(D).  0 (the default) disables screening
    # and reproduces today's programs bit-for-bit.  Unlike chunking, a fired
    # screen *changes the trajectory* (dropped coordinates can no longer be
    # selected), so the §9 parity-vs-prefix contract applies only while
    # screening is off or has not fired.
    screen_every: int = 0
    # Fraction of config.epsilon reserved for the screening queries when the
    # run is private; the solve's selection mechanism runs at the remaining
    # (1 - frac)·ε.  Composed under the same advanced-composition currency as
    # the EM draws — see screening.screen_plan.  Ignored while screening is
    # off or for non-private runs (which screen noise-free, charge-free).
    screen_eps_frac: float = 0.25
    # Regularization path — homotopy solving (DESIGN.md §14).  A strictly
    # decreasing λ-sequence turns the config into one warm-started path
    # solve: each λ continues from the previous λ's iterate/active set
    # inside the same compiled chunk program (``solve_path``; ``lam`` is
    # ignored).  ``steps`` is the first λ's cold budget; later λs get the
    # planner's warm fraction (``planner.path_budgets``), and for private
    # runs ``epsilon`` is split across the whole path at one uniform
    # advanced-composition rate (``path.path_plan``), charged up-front at
    # fit-service admission.  None (the default) keeps this an ordinary
    # single-λ config and changes nothing.
    lambdas: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        # normalize any λ-sequence to a tuple of floats: the config must stay
        # hashable (jit-static, sweep-group key) even when callers pass lists
        if self.lambdas is not None:
            object.__setattr__(self, "lambdas",
                               tuple(float(l) for l in self.lambdas))

    def loss_fn(self) -> Loss:
        return get_loss(self.loss)

    @property
    def early_stopping(self) -> bool:
        """True when this config can stop before ``steps`` iterations."""
        return self.gap_tol > 0.0 or self.max_seconds is not None


def check_gap_certificate(config: FWConfig) -> None:
    """Refuse ``gap_tol`` stopping when the objective cannot certify it.

    The FW duality gap g_t upper-bounds primal suboptimality only for
    smooth (curvature-bounded) objectives; an ``Objective`` registered with
    ``smooth=False`` has no valid gap certificate, so a config asking to
    stop on one is a contract error — refused up front (charge-free in the
    fit service) rather than silently mis-stopping.  Also surfaces unknown
    loss names early (``KeyError`` from the objective registry).
    """
    obj = config.loss_fn()
    if config.gap_tol > 0.0 and not obj.smooth:
        note = obj.curvature_note or "no curvature bound"
        raise ValueError(
            f"loss {config.loss!r} is not smooth ({note}): the FW gap "
            "certificate is invalid, so gap_tol early stopping is "
            "unavailable — run fixed steps or use max_seconds on a host "
            "backend")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FWResult:
    w: jnp.ndarray          # final iterate (D,)
    gaps: jnp.ndarray       # FW gap g_t per iteration (T,); 0 after stop_step
    coords: jnp.ndarray     # selected coordinate per iteration (T,); -1 after
                            # stop_step (frozen steps select nothing)
    losses: jnp.ndarray     # mean loss per iteration (T,); zeros if untracked
    # Gap-adaptive stopping report (DESIGN.md §9).  ``stop_step`` is the
    # number of FW iterations actually applied (== len(gaps) for a full run);
    # ``w`` is exactly the iterate a run of ``stop_step`` steps produces.
    # None means "the backend predates stopping" and is normalized by
    # ``stop_step_or`` / the registry adapters.
    stop_step: Optional[Union[int, jnp.ndarray]] = None
    stop_reason: str = STOP_MAX_STEPS  # max_steps | gap_tol | max_seconds

    def tree_flatten(self):
        return ((self.w, self.gaps, self.coords, self.losses, self.stop_step),
                self.stop_reason)

    @classmethod
    def tree_unflatten(cls, stop_reason, leaves):
        return cls(*leaves, stop_reason=stop_reason)

    @property
    def nnz(self) -> jnp.ndarray:
        return jnp.sum(self.w != 0)

    def stop_step_or(self, default: Optional[int] = None) -> int:
        """``stop_step`` as a Python int; falls back to len(gaps)."""
        if self.stop_step is None:
            return int(default if default is not None else self.gaps.shape[0])
        return int(self.stop_step)

    @property
    def gaps_valid(self) -> jnp.ndarray:
        """The gap trace up to (and including) the stopping step."""
        return self.gaps[: self.stop_step_or()]

    @property
    def coords_valid(self) -> jnp.ndarray:
        return self.coords[: self.stop_step_or()]
