"""Shared solver configuration/result types (DESIGN.md §4).

``FWConfig`` is the single configuration dataclass every registered backend
consumes, and ``FWResult`` the single result pytree every backend returns.
Both classes used to live in ``repro.core.fw_dense``; they are defined here
so the registry, the backends, and user code all share one vocabulary, and
re-exported from ``fw_dense`` for backward compatibility.

The config is a frozen (hashable) dataclass so it can ride through ``jax.jit``
as a static argument — every field is a Python scalar.

Queue vs. selection: Algorithm 1 (the ``dense`` backend) names its coordinate
rule ``selection`` (argmax | noisy_max | gumbel); the sparse backends name
theirs ``queue`` (fib_heap | bsls | ... on host, two_level | group_argmax on
device).  ``FWConfig`` carries both; ``queue=None`` means "this backend's
non-private default".  The registry translates equivalent names between
backends (see ``registry.QUEUE_ALIASES``) so one config can be re-targeted by
changing only ``backend=``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.losses import Loss, get_loss


@dataclasses.dataclass(frozen=True)
class FWConfig:
    """One Frank-Wolfe run, declaratively.

    ``repro.core.solvers.solve(X, y, FWConfig(backend=...))`` is the single
    entry point; see ``registry.available_backends()`` for the choices.
    """

    backend: str = "dense"       # dense | jax_dense | host_sparse | jax_sparse
    lam: float = 50.0            # L1 radius λ (paper default for speed runs)
    steps: int = 4000            # T (paper default)
    loss: str = "logistic"
    selection: str = "argmax"    # Alg-1 rule: argmax | noisy_max | gumbel
    queue: Optional[str] = None  # Alg-2 rule; None → backend non-private default
    epsilon: float = 1.0
    delta: float = 1e-6
    seed: int = 0
    interpret: bool = True       # Pallas interpret mode (True on CPU containers)
    # jax_shard only: (row shards, feature shards) of the device mesh the
    # blocked solve runs on; None → 1×1 (single device — must reproduce the
    # host oracle exactly, which is what makes parity testable everywhere).
    # Other backends ignore it.  A tuple keeps the config hashable/static.
    mesh: Optional[Tuple[int, int]] = None

    def loss_fn(self) -> Loss:
        return get_loss(self.loss)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FWResult:
    w: jnp.ndarray          # final iterate (D,)
    gaps: jnp.ndarray       # FW gap g_t per iteration (T,)
    coords: jnp.ndarray     # selected coordinate per iteration (T,)
    losses: jnp.ndarray     # mean loss per iteration (T,); zeros if untracked

    def tree_flatten(self):
        return (self.w, self.gaps, self.coords, self.losses), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @property
    def nnz(self) -> jnp.ndarray:
        return jnp.sum(self.w != 0)
