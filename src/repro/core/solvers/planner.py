"""Cost-model execution planner for the solver stack (DESIGN.md §9).

Three questions every solve/sweep has to answer before any XLA program runs:

  1. **Which backend?**  ``FWConfig(backend="auto")`` asks the planner to
     pick from the problem's shape: per-iteration work of Algorithm 1 is
     O(nnz + D) while Algorithm 2's padded tile is O(K_c·K_r + √D), so the
     crossover is a pure cost-model question — answered with the same
     three-term roofline machinery the dry-run audit uses
     (``repro.roofline.analysis.roofline_terms``), fed with per-iteration
     FLOP/byte counts instead of whole-model numbers.

  2. **Vmapped or sequential grid execution?**  A vmapped sweep is one
     program but pays every lane every step; re-entering the per-config scan
     is many dispatches but each lane stops exactly when it converges.  On
     accelerators the vmap lanes are nearly free (vector units are wide and
     idle); on CPU-interpret containers each lane costs ~a full sequential
     step (measured: the BENCH_sweep 0.7× regression this module exists to
     fix).  The planner picks per platform, and **measured** per-iteration
     costs recorded by the batched driver (``record_cost``) override the
     model whenever a matching observation exists.

  3. **What chunk length?**  Chunked execution (gap-adaptive early stopping,
     cohort retirement, ``max_seconds``) trades host round-trips against
     wasted post-convergence steps; ``steps/8`` clamped to [8, 256] keeps
     both under ~15%.

The planner never changes results — every plan runs the same state machine
with the same keys; only scheduling differs.  ε-accounting is likewise
untouched: admission charges by the resolved queue, not by the engine that
realizes it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.solvers.config import FWConfig
from repro.roofline.analysis import roofline_terms

# Effective per-chip rates fed to roofline_terms.  The TPU numbers live in
# repro.roofline.analysis; the CPU numbers are deliberately conservative
# (one wide core of a shared CI container) — only *ratios* between candidate
# plans matter here, not absolute seconds.
CPU_PEAK_FLOPS = 2.0e10
CPU_HBM_BW = 1.5e10
# Measured lane overhead of vmapping the kernel scan on CPU interpret mode:
# one extra lane costs ~this fraction of a full sequential step (the
# BENCH_sweep 0.7× finding: 8 lanes ≈ 8 × 1.4 sequential steps).
CPU_VMAP_LANE_OVERHEAD = 1.4
ACCEL_VMAP_LANE_OVERHEAD = 0.15


@dataclasses.dataclass(frozen=True)
class ProblemStats:
    """Shape facts the cost model consumes (cheap to derive, never solves)."""

    n: int
    d: int
    nnz: int
    kc: int   # max column nnz (Alg-2 tile height)
    kr: int   # max row nnz (Alg-2 tile width)

    @property
    def density(self) -> float:
        return self.nnz / max(self.n * self.d, 1)


# manifest-derived stats per store, keyed by content hash: deriving them is
# already O(1) metadata reads, but fit services re-ask on every admission
_STORE_STATS: Dict[str, "ProblemStats"] = {}


def store_stats(store) -> ProblemStats:
    """:class:`ProblemStats` for a ``DatasetStore`` from its metadata alone.

    n/d/nnz sit in the manifest; the ingest-pass column stats give the exact
    max column nnz (``df`` counts one hit per stored entry); the max row nnz
    comes from the manifest when the ingest recorded it, else from one O(N)
    sweep over the mmap'd shard indptrs.  Nothing here materializes values
    or indices — stats for an 8M×20M store cost a few metadata reads.
    """
    key = store.content_hash
    got = _STORE_STATS.get(key)
    if got is not None:
        return got
    kc = store.manifest.get("col_nnz_max")
    if kc is None:
        df = store.col_stats().df
        kc = int(df.max()) if df.size else 1
    kr = store.manifest.get("row_nnz_max")
    if kr is None:
        kr = 1
        for i in range(store.n_shards):
            indptr = np.load(store._shard_base(i) + ".indptr.npy",
                             mmap_mode="r")
            if indptr.shape[0] > 1:
                kr = max(kr, int(np.diff(indptr).max()))
    stats = ProblemStats(n=store.n, d=store.d, nnz=store.nnz,
                         kc=max(int(kc), 1), kr=max(int(kr), 1))
    _STORE_STATS[key] = stats
    return stats


def data_stats(X) -> ProblemStats:
    """Derive :class:`ProblemStats` from any layout ``solve`` accepts."""
    from repro.core.solvers.prepared import PreparedDataset
    from repro.core.sparse.formats import (HostCSR, PaddedCSC, PaddedCSR,
                                           TieredCSC)
    if isinstance(X, PreparedDataset):
        X = X.pair
    if (isinstance(X, tuple) and len(X) == 2
            and isinstance(X[0], PaddedCSR)
            and isinstance(X[1], (PaddedCSC, TieredCSC))):
        pcsr, pcsc = X
        n, d = pcsr.shape
        # a tiered CSC's cost-relevant tile height is the true max column
        # nnz — the full-width heavy tier, not the narrow light table
        kc = (pcsc.full_width if isinstance(pcsc, TieredCSC)
              else int(pcsc.indices.shape[1]))
        return ProblemStats(n=n, d=d, nnz=int(np.sum(np.asarray(pcsr.nnz))),
                            kc=kc, kr=int(pcsr.indices.shape[1]))
    if isinstance(X, HostCSR):
        row_nnz = np.diff(X.indptr)
        col_nnz = np.bincount(X.indices, minlength=X.shape[1])
        return ProblemStats(n=X.shape[0], d=X.shape[1], nnz=X.nnz,
                            kc=int(col_nnz.max()) if X.nnz else 1,
                            kr=int(row_nnz.max()) if X.nnz else 1)
    if getattr(X, "content_hash", None) is not None and hasattr(X, "manifest"):
        return store_stats(X)        # O(1) from metadata, never materializes
    if hasattr(X, "resolve"):                       # DatasetRef
        resolved, _ = X.resolve()
        return data_stats(resolved)
    arr = np.asarray(X)
    if arr.ndim == 2:
        nnz_mask = arr != 0
        row = nnz_mask.sum(axis=1)
        col = nnz_mask.sum(axis=0)
        return ProblemStats(n=arr.shape[0], d=arr.shape[1],
                            nnz=int(nnz_mask.sum()),
                            kc=int(col.max()) if col.size else 1,
                            kr=int(row.max()) if row.size else 1)
    raise TypeError(f"cannot derive problem stats from {type(X).__name__}")


# ---------------------------------------------------------------------------
# per-iteration cost model (FLOPs / bytes per FW step, by backend)
# ---------------------------------------------------------------------------


def step_costs(stats: ProblemStats, backend: str) -> Tuple[float, float]:
    """(flops, bytes) of one FW iteration — the paper's complexity table
    turned into roofline inputs.  Coefficients follow the analytic counts in
    ``fw_dense.dense_fw_flops`` / ``fw_sparse.sparse_fw_flops_estimate``."""
    n, d, nnz = stats.n, stats.d, stats.nnz
    if backend == "dense":
        flops = 4.0 * nnz + 4.0 * n + 6.0 * d
        bytes_ = 4.0 * (2.0 * nnz + 2.0 * n + 3.0 * d)
        return flops, bytes_
    # Alg-2 family: K_c×K_r fused tile + two-level/√D selection + O(K) queue
    # refresh.  jax_dense additionally touches the D-wide sampler state.
    tile = float(stats.kc) * float(stats.kr)
    sqrt_d = math.sqrt(max(d, 1))
    flops = 6.0 * tile + 4.0 * stats.kc + 3.0 * sqrt_d
    bytes_ = 4.0 * (3.0 * tile + 4.0 * stats.kc + 2.0 * sqrt_d)
    if backend == "jax_dense":
        flops += 2.0 * d
        bytes_ += 8.0 * d
    if backend == "jax_shard":
        # the blocked schedule trades the tile for per-shard lanes plus the
        # collective term (charged separately by callers that know the mesh)
        bytes_ += 4.0 * stats.kc
    return flops, bytes_


def step_time_model(stats: ProblemStats, backend: str,
                    platform: str) -> float:
    """Modeled seconds per FW iteration on ``platform`` (roofline bound)."""
    flops, bytes_ = step_costs(stats, backend)
    if platform == "cpu":
        terms = roofline_terms(flops=flops, bytes_accessed=bytes_,
                               collective_bytes=0.0, chips=1,
                               peak_flops=CPU_PEAK_FLOPS, hbm_bw=CPU_HBM_BW)
    else:
        terms = roofline_terms(flops=flops, bytes_accessed=bytes_,
                               collective_bytes=0.0, chips=1)
    return float(terms["t_bound_s"])


# ---------------------------------------------------------------------------
# measured-cost book: observations beat the model
# ---------------------------------------------------------------------------

# (backend, mode, platform, loss, n-bucket, d-bucket) -> smoothed s/step/lane
# Keyed per objective: the per-row gradient map changes the fused kernel's
# arithmetic (and label-coupled objectives add a gather), so observations of
# one loss never steer another's mode choice.
_COSTBOOK: Dict[tuple, float] = {}
# keys whose first (compile-tainted) observation has been discarded
_WARMED: set = set()


def _bucket(x: int) -> int:
    return int(math.log2(max(x, 1)))


def _cost_key(backend: str, mode: str, platform: str,
              stats: ProblemStats, loss: str = "logistic") -> tuple:
    return (backend, mode, platform, loss, _bucket(stats.n), _bucket(stats.d))


def record_cost(backend: str, mode: str, platform: str, stats: ProblemStats,
                seconds_per_step_lane: float, *,
                loss: str = "logistic") -> None:
    """Feed an observed per-step-per-lane time back into the planner (the
    batched drivers call this after every chunk/group).

    The very first observation per key is discarded: it times the XLA
    compile of a fresh program, which is orders of magnitude above steady
    state and would poison the mode choice for dozens of EWMA updates.
    """
    key = _cost_key(backend, mode, platform, stats, loss)
    if key not in _WARMED:
        _WARMED.add(key)
        return
    prev = _COSTBOOK.get(key)
    _COSTBOOK[key] = (seconds_per_step_lane if prev is None
                      else 0.7 * prev + 0.3 * seconds_per_step_lane)
    _gauge_drift(backend, mode, platform, stats, loss, seconds_per_step_lane)


def record_measured(backend: str, mode: str, platform: str,
                    stats: ProblemStats, seconds_per_step_lane: float, *,
                    loss: str = "logistic") -> None:
    """High-priority observation: the autotuner's warmed, best-of-N timings.

    Unlike :func:`record_cost` there is no first-observation discard (the
    tuner already excluded compiles) and no EWMA blending with whatever was
    there — a deliberate steady-state measurement simply becomes the book
    entry the next plan reads.
    """
    key = _cost_key(backend, mode, platform, stats, loss)
    _WARMED.add(key)
    _COSTBOOK[key] = float(seconds_per_step_lane)
    _gauge_drift(backend, mode, platform, stats, loss, seconds_per_step_lane)


def _gauge_drift(backend: str, mode: str, platform: str, stats: ProblemStats,
                 loss: str, seconds_per_step_lane: float) -> None:
    """Predicted-vs-measured gauge: measured seconds/step over the roofline
    model's prediction (> 1 means the model is optimistic).  Only evaluated
    when a collector is active — the model itself costs a few hundred flops
    we refuse to pay on the disabled path."""
    if not obs.enabled():
        return
    model = step_time_model(stats, backend, platform)
    if model > 0.0:
        obs.gauge("planner.drift", seconds_per_step_lane / model,
                  backend=backend, mode=mode, loss=loss)
    obs.observe("planner.step_seconds", seconds_per_step_lane,
                backend=backend, mode=mode)


def measured_cost(backend: str, mode: str, platform: str,
                  stats: ProblemStats, *,
                  loss: str = "logistic") -> Optional[float]:
    return _COSTBOOK.get(_cost_key(backend, mode, platform, stats, loss))


def clear_costbook() -> None:
    _COSTBOOK.clear()
    _WARMED.clear()


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SolvePlan:
    """How a sweep group executes — never *what* it computes.

    ``mode``: "vmap" runs the group as one vmapped chunked scan with
    power-of-two cohort retirement; "sequential" re-enters the width-free
    per-config chunk program (one compile for any grid size).  ``chunk_steps``
    of None defers to the per-config/planner default.
    """

    mode: str = "auto"                   # auto | vmap | sequential
    chunk_steps: Optional[int] = None
    backend: Optional[str] = None        # filled for backend="auto" configs
    notes: str = ""

    def resolved_mode(self, platform: Optional[str] = None) -> str:
        if self.mode != "auto":
            return self.mode
        return "sequential" if _platform(platform) == "cpu" else "vmap"


def _platform(platform: Optional[str] = None) -> str:
    if platform is not None:
        return platform
    import jax
    return jax.devices()[0].platform


def default_chunk(steps: int) -> int:
    return max(1, min(max(8, steps // 8), 256, steps))


# Warm λ-segments of a homotopy path re-solve from the previous λ's iterate,
# so they need only a fraction of the cold budget; steps/4 keeps the warm
# budget comfortably above the observed continuation cost on the benchmark
# twins while making a K-λ path cost ~(1 + (K-1)/4)·T instead of K·T.
PATH_WARM_DIV = 4


def path_budgets(steps: int, n_lambdas: int) -> Tuple[int, ...]:
    """Planner-predicted per-λ iteration budgets for a warm-started path.

    The first λ solves cold at the config's full ``steps`` budget; every
    later λ continues from the previous solution and gets the warm fraction
    (``steps // PATH_WARM_DIV``, clamped to [8, steps]).  Deterministic and
    shape-free by design: fit-service admission must price the exact same
    budgets the drivers later run (DESIGN.md §14).
    """
    if n_lambdas <= 0:
        return ()
    steps = int(steps)
    warm = max(1, min(steps, max(8, steps // PATH_WARM_DIV)))
    return (steps,) + (warm,) * (n_lambdas - 1)


def cohort_widths(width: int) -> Tuple[int, ...]:
    """Allowed vmap-cohort widths: powers of two down from the grid size.
    Retiring converged configs re-enters the next bucket instead of
    compiling one program per survivor count."""
    widths = []
    w = 1
    while w < width:
        widths.append(w)
        w *= 2
    widths.append(width)
    return tuple(sorted(set(widths), reverse=True))


def choose_backend(stats: ProblemStats, config: FWConfig,
                   platform: Optional[str] = None) -> str:
    """Resolve ``backend="auto"`` from the cost model.

    A config that names a mesh wants the sharded engine; otherwise the
    roofline-modeled per-iteration time decides between the Alg-1 dense scan
    (wins on small/dense designs where O(nnz + D) ≈ O(K_c·K_r)) and the
    Alg-2 kernel pipeline (wins everywhere the paper cares about — the
    sparse D ≫ N regime).
    """
    if config.mesh is not None and config.mesh != (1, 1):
        return "jax_shard"
    plat = _platform(platform)

    def per_iter(backend: str) -> float:
        # observed steady-state time beats the roofline model whenever the
        # tuner/driver has recorded one for this (backend, shape, loss) key
        got = measured_cost(backend, "sequential", plat, stats,
                            loss=config.loss)
        return got if got is not None else step_time_model(stats, backend,
                                                           plat)

    return "dense" if per_iter("dense") < per_iter("jax_sparse") \
        else "jax_sparse"


def group_mode(stats: ProblemStats, group_size: int,
               plan: Optional[SolvePlan] = None,
               platform: Optional[str] = None,
               loss: str = "logistic", backend: str = "jax_sparse") -> str:
    """vmap vs sequential for one sweep group: measured costs win, then the
    lane-overhead model, then the platform default.

    ``backend`` keys the cost-book lookup — a group running on the sharded
    engine must read (and its driver must record) ``jax_shard`` entries, not
    pollute/consult the ``jax_sparse`` book.
    """
    if plan is not None and plan.mode != "auto":
        return plan.mode
    if group_size < 2:
        return "sequential"
    plat = _platform(platform)
    seq = measured_cost(backend, "sequential", plat, stats, loss=loss)
    vm = measured_cost(backend, "vmap", plat, stats, loss=loss)
    if seq is not None and vm is not None:
        return "vmap" if vm < seq else "sequential"
    # First-order model: a B-lane vmap step costs lane·B sequential-step-
    # equivalents vs B + ~5% dispatch overhead for the loop — B cancels, so
    # without measurements the choice is a per-platform constant.  The grid
    # size matters again only through the measured branch above, which is
    # where the real signal lives.
    lane = (CPU_VMAP_LANE_OVERHEAD if plat == "cpu"
            else ACCEL_VMAP_LANE_OVERHEAD)
    return "vmap" if lane < 1.05 else "sequential"


def plan_for(X, configs: Sequence[FWConfig],
             platform: Optional[str] = None) -> SolvePlan:
    """One plan for a ``solve_many`` call (stats derived once from ``X``)."""
    stats = data_stats(X)
    plat = _platform(platform)
    steps = configs[0].steps if configs else 0
    backend = configs[0].backend if configs else "jax_sparse"
    mode = group_mode(stats, len(configs), platform=plat,
                      loss=configs[0].loss if configs else "logistic",
                      backend=backend if backend != "auto" else "jax_sparse")
    return SolvePlan(mode=mode, chunk_steps=default_chunk(steps) if steps
                     else None,
                     notes=f"platform={plat} n={stats.n} d={stats.d} "
                           f"nnz={stats.nnz} grid={len(configs)}")
