"""Unified Frank-Wolfe solver engine (DESIGN.md §4).

One API over every implementation of the paper's algorithms:

    from repro.core.solvers import FWConfig, solve
    res = solve(X, y, FWConfig(backend="jax_sparse", lam=30.0, steps=500))
    print(res.nnz, res.gaps[-1])

Backends (``available_backends()``): ``dense`` (Alg 1), ``jax_dense`` (Alg 2,
pure-jnp device scan), ``host_sparse`` (Alg 2, faithful host loop),
``jax_sparse`` (Alg 2 through the Pallas kernels).  New backends register via
``register``.
"""
from repro.core.solvers.config import FWConfig, FWResult  # noqa: F401
from repro.core.solvers.registry import (QUEUE_ALIASES, Backend,  # noqa: F401
                                         available_backends, backend_doc,
                                         get_backend, register, resolve_queue,
                                         solve)
