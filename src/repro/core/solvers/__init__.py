"""Unified Frank-Wolfe solver engine (DESIGN.md §4).

One API over every implementation of the paper's algorithms:

    from repro.core.solvers import FWConfig, solve
    res = solve(X, y, FWConfig(backend="jax_sparse", lam=30.0, steps=500))
    print(res.nnz, res.gaps[-1])

Backends (``available_backends()``): ``dense`` (Alg 1), ``jax_dense`` (Alg 2,
pure-jnp device scan), ``host_sparse`` (Alg 2, faithful host loop),
``jax_sparse`` (Alg 2 through the Pallas kernels), ``jax_shard`` (Alg 2
under feature sharding on ``FWConfig.mesh`` — DESIGN.md §8).  New backends
register via ``register``.

Sweeps — many (λ, ε) problems over one design matrix — go through
``solve_many``/``grid`` (solvers.batched): compatible ``jax_sparse`` and
``jax_shard`` configs run on one shared setup + compiled scan (vmapped
where the mesh allows), everything else drains sequentially on shared
coerced data:

    results = solve_many(X, y, grid(lam=(10., 30.), epsilon=(0.1, 1.0),
                                    backend="jax_sparse", queue="bsls"))

Gap-adaptive scheduling (DESIGN.md §9): ``FWConfig.gap_tol``/``max_seconds``
stop any backend early on the duality-gap certificate (surfaced as
``FWResult.stop_step``/``stop_reason``), sweeps retire converged configs
between chunks, and ``solvers.planner`` picks backend + execution mode from
a roofline cost model (``backend="auto"``, ``solve_many(plan=...)``).

Regularization paths (DESIGN.md §14): a strictly decreasing λ-sequence
solves as one warm-started homotopy run for roughly one solve's cost —
``solve_path(X, y, lambdas=(80., 40., 20.), config=cfg)`` (equivalently
``FWConfig(lambdas=...)`` through ``solve``/``solve_many``/``FitService``)
returns a ``PathResult`` of per-λ ``FWResult``s with gap certificates and
a deterministic up-front ε split across the path.
"""
from repro.core.solvers.batched import grid, solve_many  # noqa: F401
from repro.core.solvers.config import FWConfig, FWResult  # noqa: F401
from repro.core.solvers.path import (PathPlan, PathResult,  # noqa: F401
                                     check_path_config, path_plan, solve_path)
from repro.core.solvers.planner import SolvePlan, plan_for  # noqa: F401
from repro.core.solvers.registry import (QUEUE_ALIASES, Backend,  # noqa: F401
                                         available_backends, backend_doc,
                                         get_backend, register, resolve_queue,
                                         solve)
