"""Straight-line host oracle for the ``jax_sparse`` kernel pipeline.

``reference_fw`` replays ``jax_sparse.fw_scan``'s state machine eagerly —
no Pallas kernels, no ``lax.scan``, no incremental sampler bookkeeping:
the selection priorities are recomputed from |α| directly every step, and
the DP draw re-realizes ``kernels.bsls_draw.two_level_draw``'s
group-then-member Gumbel-max with the *same key stream* (one
``key, sel_key = split(key)`` per iteration; Gumbel shapes matching the
kernel's, so the same PRNG bits are consumed), so the selected coordinates
are bit-identical when the kernel pipeline is correct — for every
registered objective, private and non-private.

This is the per-loss correctness court of appeal the loss-parameterized
parity tests pin the engine against, the single-device sibling of
``repro.distributed.reference`` (same philosophy: eager execution gives an
independently-rounded trajectory; coords must still match exactly, weights
and gaps to float tolerance).

Direct |α| recomputation is exact, not an approximation: the engine's
two-level sampler refreshes exactly the coordinates whose α changed each
iteration (line 29 touches ``row_idx``; α changes nowhere else), so its
lazily-maintained priorities always equal ``em_scale·|α|`` on real
coordinates and −∞ on padding — what this oracle rebuilds from scratch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.losses import get_loss
from repro.core.samplers.bsls_jax import NEG_INF, _group_shape
from repro.core.sparse.formats import PaddedCSC, PaddedCSR


def _ell_rmatvec_ref(pcsr: PaddedCSR, q: jnp.ndarray) -> jnp.ndarray:
    """Eager Xᵀq over the padded ELL rows (padding lanes carry value 0)."""
    contrib = pcsr.values * q[:, None]
    return jnp.zeros((pcsr.shape[1],), pcsr.values.dtype).at[
        pcsr.indices.reshape(-1)].add(contrib.reshape(-1))


def reference_fw(pcsr: PaddedCSR, pcsc: PaddedCSC, y, *, lam: float,
                 steps: int, private: bool = False, em_scale: float = 1.0,
                 seed: int = 0, loss: str = "logistic"
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(w, gaps, coords) of the ``fw_scan`` schedule, replayed eagerly."""
    obj = get_loss(loss)
    n, d = pcsr.shape
    dtype = pcsr.values.dtype
    y = jnp.asarray(y, dtype)
    inv_n = 1.0 / n
    lam = jnp.asarray(lam, dtype)
    em_scale = jnp.asarray(em_scale, dtype)

    # fw_setup (Alg 2 lines 8-14); label-coupled objectives carry the full
    # row gradient in q̄ (no ȳ residual), mirroring jax_sparse.fw_setup
    vbar = jnp.zeros(n, dtype)
    if obj.separable:
        ybar = _ell_rmatvec_ref(pcsr, y) * inv_n
        qbar = obj.split_grad(vbar)
        alpha = _ell_rmatvec_ref(pcsr, qbar) * inv_n - ybar
    else:
        qbar = obj.grad(vbar, y)
        alpha = _ell_rmatvec_ref(pcsr, qbar) * inv_n

    g_grp, m_grp = _group_shape(d)
    w = jnp.zeros(d, dtype)
    w_m = jnp.asarray(1.0, dtype)
    g_tilde = jnp.asarray(0.0, dtype)
    key = jax.random.PRNGKey(seed)
    gaps, coords = [], []
    for step in range(1, steps + 1):
        t = jnp.asarray(step, dtype)
        key, sel_key = jax.random.split(key)
        # ---- line 15: select coordinate (exact priorities from |α|) ------
        if private:
            v = jnp.full((g_grp * m_grp,), NEG_INF, dtype).at[:d].set(
                jnp.abs(alpha) * em_scale).reshape(g_grp, m_grp)
            c = jax.scipy.special.logsumexp(v, axis=1)
            kg, km = jax.random.split(sel_key)
            g = jnp.argmax(c + jax.random.gumbel(kg, c.shape, jnp.float32))
            noise = jax.random.gumbel(km, (1, m_grp), jnp.float32)
            j = g * m_grp + jnp.argmax(v[g] + noise[0])
        else:
            j = jnp.argmax(jnp.abs(alpha))
        j = jnp.minimum(j, d - 1)
        a_j = alpha[j]
        # ---- lines 16-21 -------------------------------------------------
        d_tilde = jnp.where(a_j == 0, lam, -lam * jnp.sign(a_j))
        gaps.append(g_tilde - d_tilde * a_j)
        coords.append(j.astype(jnp.int32))
        eta = 2.0 / (t + 2.0)
        w_m = w_m * (1.0 - eta)
        w = w.at[j].add(eta * d_tilde / w_m)
        g_tilde = g_tilde * (1.0 - eta) + eta * d_tilde * a_j
        # ---- lines 22-28 (the fused kernel's sweep, unrolled) ------------
        rows, x_col, mask = pcsc.col(j)
        row_idx = pcsr.indices[rows]
        row_val = pcsr.values[rows]
        dv = jnp.where(mask, eta * d_tilde * x_col / w_m, 0.0)
        vbar = vbar.at[rows].add(dv)
        margins = w_m * vbar[rows]
        hm = (obj.split_grad(margins) if obj.separable
              else obj.grad(margins, y[rows]))
        gamma = jnp.where(mask, hm - qbar[rows], 0.0)
        qbar = qbar.at[rows].add(gamma)
        contrib = (gamma * inv_n)[:, None] * row_val
        alpha = alpha.at[row_idx.reshape(-1)].add(contrib.reshape(-1))
        dots = jnp.einsum("ck,ck->c", row_val, w[row_idx])
        g_tilde = g_tilde + w_m * jnp.sum((gamma * inv_n) * dots)
    return w * w_m, jnp.stack(gaps), jnp.stack(coords)
