"""Backend registry — the single entry point for every Frank-Wolfe solver.

    from repro.core.solvers import FWConfig, solve
    result = solve(X, y, FWConfig(backend="jax_sparse", lam=30.0, steps=500))

Backends register themselves with :func:`register`; the builtin four
(``dense``, ``jax_dense``, ``host_sparse``, ``jax_sparse``) are attached
lazily on first lookup so importing this module never drags in every solver
(and so ``fw_dense`` can import ``solvers.config`` without a cycle).

Each backend declares which data layout it consumes (``dense`` | ``host`` |
``padded``); :func:`solve` coerces the user's ``X`` — a ``HostCSR``, a dense
numpy/JAX matrix, a pre-built ``(PaddedCSR, PaddedCSC)`` pair, or a
``repro.data.store`` ``DatasetStore``/``DatasetRef`` — into that layout
once, up front.  Dataset refs also carry their own labels, so ``y`` may be
omitted; the store path reads shards off mmap and reuses the store's cached
padded layout and fw_setup state (DESIGN.md §7).  Queue names are translated
between backends via ``QUEUE_ALIASES`` so the same ``FWConfig`` can be
re-targeted by changing only ``backend=`` (DESIGN.md §4 documents the name
map).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.solvers.config import (FWConfig, FWResult,
                                       check_gap_certificate)
from repro.core.solvers.prepared import PreparedDataset
from repro.core.sparse.formats import (HostCSR, PaddedCSC, PaddedCSR,
                                       dense_to_host, host_to_padded)


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered solver: adapter fn + the data layout and queues it speaks."""

    name: str
    fn: Callable  # (data, y, config) -> FWResult
    data_format: str                 # dense | host | padded
    queues: Mapping[str, str]        # accepted queue name -> native name
    default_queue: Optional[str]     # used when config.queue is None
    doc: str = ""
    # §9: single-compiled-scan engines cannot watch a host clock, so they
    # reject FWConfig.max_seconds; declared here so admission layers (the
    # fit service) can refuse such configs *before* charging DP budget.
    supports_max_seconds: bool = True
    # §13: chunk-boundary screening needs a host-driven chunk loop with
    # mutable geometry; engines without one refuse screen_every up front
    # (again so admission can reject charge-free).
    supports_screening: bool = False
    # §14: warm-started λ-path (homotopy) solving needs a re-enterable
    # chunked driver whose carry survives across λ segments; engines
    # without one refuse FWConfig.lambdas up front (charge-free).
    supports_path: bool = False

    def prepare(self, X):
        """Coerce ``X`` into this backend's data layout (what solve() does
        internally); use it to hoist conversion out of timed/hot loops."""
        return _COERCE[self.data_format](X)


_REGISTRY: Dict[str, Backend] = {}
_BUILTINS_LOADED = False

# Equivalent coordinate-selection rules across implementations: left column is
# what the user may write, per-backend maps pick the native realization.
# (fib_heap ≡ group_argmax ≡ argmax: exact max of |α|.  bsls ≡ two_level ≡
# gumbel: the DP exponential mechanism.)
QUEUE_ALIASES: Mapping[str, Mapping[str, str]] = {
    "host": {
        "fib_heap": "fib_heap", "argmax": "argmax", "noisy_max": "noisy_max",
        "bsls": "bsls", "group_argmax": "fib_heap", "two_level": "bsls",
        "gumbel": "bsls",
    },
    "device": {
        "two_level": "two_level", "group_argmax": "group_argmax",
        "bsls": "two_level", "gumbel": "two_level",
        "fib_heap": "group_argmax", "argmax": "group_argmax",
    },
    # Alg 1 has no queue; queue names map onto its `selection` rule.
    "selection": {
        "argmax": "argmax", "fib_heap": "argmax", "group_argmax": "argmax",
        "noisy_max": "noisy_max",
        "gumbel": "gumbel", "bsls": "gumbel", "two_level": "gumbel",
    },
    # The sharded engine realizes the same two rules as collectives:
    # shard-then-member Gumbel-max (exact EM law) and exact argmax.  No
    # noisy_max port — report-noisy-max would need a D-wide Laplace draw,
    # exactly the O(D) traffic the blocked schedule exists to avoid.
    "shard": {
        "argmax": "argmax", "fib_heap": "argmax", "group_argmax": "argmax",
        "gumbel": "gumbel", "bsls": "gumbel", "two_level": "gumbel",
    },
}


def register(name: str, *, data_format: str, queues: Mapping[str, str],
             default_queue: Optional[str], doc: str = "",
             supports_max_seconds: bool = True,
             supports_screening: bool = False,
             supports_path: bool = False) -> Callable:
    """Decorator: add ``fn(data, y, config) -> FWResult`` under ``name``."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = Backend(name=name, fn=fn, data_format=data_format,
                                  queues=queues, default_queue=default_queue,
                                  doc=doc,
                                  supports_max_seconds=supports_max_seconds,
                                  supports_screening=supports_screening,
                                  supports_path=supports_path)
        return fn

    return deco


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        import repro.core.solvers.backends  # noqa: F401  (registers on import)
        _BUILTINS_LOADED = True


def available_backends() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> Backend:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver backend {name!r}; available: "
            f"{', '.join(available_backends())}") from None


def backend_doc(name: str) -> str:
    return get_backend(name).doc


# ---------------------------------------------------------------------------
# data coercion
# ---------------------------------------------------------------------------


def _is_padded_pair(X) -> bool:
    return (isinstance(X, tuple) and len(X) == 2
            and isinstance(X[0], PaddedCSR) and isinstance(X[1], PaddedCSC))


def _as_store(X):
    """The ``DatasetStore`` behind ``X``, or None (lazy import, no cycle)."""
    from repro.data.store import DatasetStore
    return X if isinstance(X, DatasetStore) else None


def resolve_data(X, y=None):
    """Resolve a ``DatasetRef``/``DatasetStore`` ``X`` into (source, labels).

    Plain matrices pass through unchanged (``y`` then required).  A ref with
    ``split="all"`` resolves to its open ``DatasetStore`` so the coercion
    layer can reuse the store's cached padded layout and setup state;
    train/test refs materialize the row subset.  An explicitly passed ``y``
    always wins over the store's labels.
    """
    from repro.data.store import DatasetRef, DatasetStore
    if isinstance(X, DatasetRef):
        X, ref_y = X.resolve()
        y = ref_y if y is None else y
    elif isinstance(X, DatasetStore):
        y = X.labels() if y is None else y
    if y is None:
        raise TypeError(
            "y is required unless X is a DatasetRef or DatasetStore "
            "(which carry their own labels)")
    return X, y


def as_host_csr(X) -> HostCSR:
    if isinstance(X, HostCSR):
        return X
    store = _as_store(X)
    if store is not None:
        return store.to_host_csr()   # mmap-backed, zero-copy per shard
    if isinstance(X, PreparedDataset):
        X = X.pair
    if _is_padded_pair(X):
        # O(nnz) rebuild from the padded lanes — never materialize N×D.
        pcsr = X[0]
        idx = np.asarray(pcsr.indices)
        val = np.asarray(pcsr.values, np.float64)
        nnz = np.asarray(pcsr.nnz)
        lane = np.arange(idx.shape[1])[None, :]
        mask = lane < nnz[:, None]
        rows = np.broadcast_to(np.arange(idx.shape[0])[:, None], idx.shape)
        from repro.core.sparse.formats import coo_to_host
        return coo_to_host(rows[mask], idx[mask], val[mask], pcsr.shape)
    if isinstance(X, (np.ndarray, jnp.ndarray)) and np.ndim(X) == 2:
        return dense_to_host(np.asarray(X))
    raise TypeError("X must be a HostCSR, a 2-D matrix, or a (PaddedCSR, "
                    f"PaddedCSC) pair; got {type(X).__name__}")


def as_dense_jax(X) -> jnp.ndarray:
    store = _as_store(X)
    if store is not None:
        # same arrays the in-memory path sees → identical iterates
        X = store.to_host_csr()
    if isinstance(X, HostCSR):
        return jnp.asarray(X.to_dense(), jnp.float32)
    if _is_padded_pair(X):
        return X[0]  # fw_dense consumes PaddedCSR natively
    if isinstance(X, (PaddedCSR, PreparedDataset)):
        return X if isinstance(X, PaddedCSR) else X.pcsr
    if np.ndim(X) == 2:
        return jnp.asarray(X, jnp.float32)
    raise TypeError("X must be a HostCSR, a 2-D matrix, a (PaddedCSR, "
                    "PaddedCSC) pair, or a DatasetStore/DatasetRef; "
                    f"got {type(X).__name__}")


def as_padded(X):
    """→ ``(PaddedCSR, PaddedCSC)``, or a ``PreparedDataset`` for dataset
    stores (same pair plus the persisted fw_setup cache; every padded
    backend accepts either)."""
    if isinstance(X, PreparedDataset):
        return X
    store = _as_store(X)
    if store is not None:
        return store.prepared()
    if _is_padded_pair(X):
        return X
    if isinstance(X, HostCSR):
        return host_to_padded(X)
    if isinstance(X, (np.ndarray, jnp.ndarray)) and np.ndim(X) == 2:
        return host_to_padded(dense_to_host(np.asarray(X)))
    raise TypeError("X must be a HostCSR, a 2-D matrix, a (PaddedCSR, "
                    "PaddedCSC) pair, or a DatasetStore/DatasetRef; "
                    f"got {type(X).__name__}")


def as_shard_source(X):
    """→ ``repro.distributed.ingest.ShardSource`` — the ``jax_shard``
    backend's deferred block coercion (the (a × b) grid is on the config,
    not the data, so bucketing happens at solve time, memoized per grid;
    dataset stores keep their identity so the block-layout cache applies)."""
    from repro.distributed.ingest import ShardSource
    return ShardSource.from_any(X)


_COERCE = {"dense": as_dense_jax, "host": as_host_csr, "padded": as_padded,
           "blocks": as_shard_source}


# ---------------------------------------------------------------------------
# solve
# ---------------------------------------------------------------------------


def resolve_queue(backend: Backend, config: FWConfig) -> FWConfig:
    """Fill in / translate ``config.queue`` for ``backend`` (see QUEUE_ALIASES)."""
    if config.queue is None:
        return dataclasses.replace(config, queue=backend.default_queue)
    try:
        native = backend.queues[config.queue]
    except KeyError:
        raise ValueError(
            f"backend {backend.name!r} does not support queue "
            f"{config.queue!r}; accepted: {', '.join(sorted(backend.queues))}"
        ) from None
    return dataclasses.replace(config, queue=native)


def check_screening_support(backend: Backend, config: FWConfig) -> None:
    """Refuse ``screen_every`` on engines without a mutable-geometry chunk
    loop (§13) — loudly and up front, so the fit service rejects such
    configs before charging any DP budget."""
    if config.screen_every > 0 and not backend.supports_screening:
        raise ValueError(
            f"backend {backend.name!r} does not support chunk-boundary "
            "screening (screen_every > 0): it has no host-driven chunk loop "
            "with mutable problem geometry — use the dense or jax_sparse "
            "backend, or set screen_every=0")


def check_path_support(backend: Backend, config: FWConfig) -> None:
    """Refuse ``lambdas`` on engines without a re-enterable chunked driver
    (§14) — loudly and up front, so the fit service rejects such configs
    before charging any DP budget."""
    if getattr(config, "lambdas", None) is not None and not backend.supports_path:
        raise ValueError(
            f"backend {backend.name!r} does not support warm-started λ-path "
            "(homotopy) solving (lambdas=...): it has no re-enterable chunked "
            "driver that can carry the iterate across λ segments — use the "
            "dense or jax_sparse backend, or solve each λ separately")


def solve(X, y=None, config: Optional[FWConfig] = None,
          **overrides) -> FWResult:
    """Run the configured Frank-Wolfe backend on (X, y).

    ``X``: HostCSR, dense (N, D) numpy/JAX matrix, a pre-built
    ``(PaddedCSR, PaddedCSC)`` pair, or a ``DatasetStore``/``DatasetRef``
    (in which case ``y`` defaults to the store's labels).  ``y``: (N,)
    labels in {0, 1}.  Keyword overrides are applied on top of ``config``
    (``solve(X, y, backend="jax_sparse", steps=100)``).

    ``backend="auto"`` defers the engine choice to the cost-model planner
    (DESIGN.md §9): the problem's shape statistics pick between the Alg-1
    dense scan, the Alg-2 kernel pipeline, and (when ``mesh`` names a real
    grid) the sharded engine.
    """
    from repro import obs
    config = config or FWConfig()
    if overrides:
        config = dataclasses.replace(config, **overrides)
    if config.lambdas is not None:
        # a λ-path config is one warm-started homotopy solve (§14); the
        # path entry point owns validation/accounting and returns the
        # per-λ FWResult sequence as a PathResult
        from repro.core.solvers.path import solve_path
        return solve_path(X, y, config=config)
    with obs.span("solve", loss=config.loss, steps=config.steps) as sp:
        check_gap_certificate(config)   # non-smooth loss + gap_tol/unknown
        if config.screen_every:
            from repro.core.solvers.screening import check_screen_config
            check_screen_config(config)
        X, y = resolve_data(X, y)
        if config.backend == "auto":
            with obs.span("solve.plan"):
                from repro.core.solvers.planner import (choose_backend,
                                                        data_stats)
                config = dataclasses.replace(
                    config, backend=choose_backend(data_stats(X), config))
        backend = get_backend(config.backend)
        check_screening_support(backend, config)
        config = resolve_queue(backend, config)
        sp.set(backend=backend.name, queue=config.queue)
        obs.count("solve.calls", backend=backend.name)
        with obs.span("solve.coerce", layout=backend.data_format):
            data = _COERCE[backend.data_format](X)
        with obs.span("solve.run", backend=backend.name):
            return backend.fn(data, y, config)
