"""The five builtin solver backends (DESIGN.md §4).

  dense        Alg 1 — dense-work FW, one lax.scan (repro.core.fw_dense).
               Accepts a dense device matrix or a PaddedCSR.
  jax_dense    Alg 2 state machine on device, dense vector updates: the pure
               jnp scan from repro.core.fw_jax (full-width scatter/logsumexp
               refreshes each iteration).
  host_sparse  Alg 2 faithful sequential host implementation with exact FLOP
               accounting (repro.core.fw_sparse; queues = Alg 3 / Alg 4 /
               ablations).
  jax_sparse   Alg 2 on device through the Pallas kernels (spmv /
               coord_update / bsls_draw) — the production sparse path.
  jax_shard    Alg 2 under feature sharding: the shard_map collective
               schedule of repro.distributed over an (a × b) BlockSparse
               grid named by FWConfig.mesh (DESIGN.md §8) — the scale-out
               path; a 1×1 mesh reproduces the host oracle exactly.

Each adapter normalizes its engine's native signature/result onto the shared
``(data, y, FWConfig) -> FWResult`` contract.  Imported lazily by
``registry._ensure_builtins``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.solvers.config import FWConfig, FWResult
from repro.core.solvers.prepared import PreparedDataset
from repro.core.solvers.registry import QUEUE_ALIASES, register


def _normalize_stop(res: FWResult, config: FWConfig) -> FWResult:
    """Fill stop_reason for jitted scans that can only report stop_step as a
    device scalar: a run that ended before T with gap_tol set stopped on the
    certificate (the masked scans have no other way to stop early)."""
    stop = res.stop_step_or(config.steps)
    res.stop_step = stop
    if stop < config.steps and res.stop_reason == "max_steps":
        res.stop_reason = "gap_tol"
    return res


@register("dense", data_format="dense", queues=QUEUE_ALIASES["selection"],
          default_queue=None, supports_screening=True, supports_path=True,
          doc="Alg 1 baseline: dense-work FW (O(nnz + D)/iter), device scan")
def _dense_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.fw_dense import (dense_fw_jit, dense_fw_screened,
                                     dense_fw_stopping)
    if config.queue is not None:  # queue name chosen → translate to selection
        config = dataclasses.replace(config, selection=config.queue, queue=None)
    y = jnp.asarray(y, jnp.float32)
    if config.screen_every > 0:   # §13: mutable-geometry chunked driver
        return dense_fw_screened(data, y, config)
    if config.early_stopping:     # §9: host-driven chunked masked scan
        return dense_fw_stopping(data, y, config)
    return _normalize_stop(dense_fw_jit(data, y, config), config)


@register("jax_dense", data_format="padded", queues=QUEUE_ALIASES["device"],
          default_queue="group_argmax", supports_max_seconds=False,
          doc="Alg 2 device scan, dense vector updates (pure jnp, no kernels)")
def _jax_dense_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.fw_jax import sparse_fw_jax_jit
    if config.max_seconds is not None:
        raise ValueError(
            "jax_dense runs as one compiled scan and cannot watch a wall "
            "clock; use gap_tol, or the dense/host_sparse/jax_sparse "
            "backends for max_seconds")
    pcsr, pcsc = data.pair if isinstance(data, PreparedDataset) else data
    res = sparse_fw_jax_jit(pcsr, pcsc, jnp.asarray(y, jnp.float32), config)
    return _normalize_stop(res, config)


@register("host_sparse", data_format="host", queues=QUEUE_ALIASES["host"],
          default_queue="fib_heap",
          doc="Alg 2 faithful host loop (Alg 3/4 queues, exact FLOP audit)")
def _host_sparse_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.fw_sparse import sparse_fw
    res = sparse_fw(
        data, np.asarray(y, np.float64), lam=config.lam, steps=config.steps,
        loss=config.loss, queue=config.queue, epsilon=config.epsilon,
        delta=config.delta, seed=config.seed, gap_tol=config.gap_tol,
        max_seconds=config.max_seconds)
    gaps = jnp.asarray(res.gaps, jnp.float32)
    return FWResult(w=jnp.asarray(res.w, jnp.float32), gaps=gaps,
                    coords=jnp.asarray(res.coords, jnp.int32),
                    losses=jnp.zeros_like(gaps),
                    stop_step=res.stop_step if res.stop_step is not None
                    else config.steps,
                    stop_reason=res.stop_reason)


@register("jax_shard", data_format="blocks", queues=QUEUE_ALIASES["shard"],
          default_queue="argmax", supports_max_seconds=False,
          doc="Alg 2 under feature sharding: shard_map collective schedule "
              "over BlockSparse blocks (FWConfig.mesh = (rows, features); "
              "1×1 reproduces the host oracle exactly)")
def _jax_shard_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.solvers.jax_shard import shard_fw
    return shard_fw(data, y, config)


@register("jax_sparse", data_format="padded", queues=QUEUE_ALIASES["device"],
          default_queue="group_argmax", supports_screening=True,
          supports_path=True,
          doc="Alg 2 device scan through the Pallas kernels "
              "(spmv + coord_update + bsls_draw)")
def _jax_sparse_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.solvers.jax_sparse import jax_sparse_fw
    setup = None
    if isinstance(data, PreparedDataset):
        # dataset-store path: replay the cached fw_setup state (bit-exact)
        setup = data.setup_for(y, config.loss, config.interpret)
        pcsr, pcsc = data.pair
        # §11: the store's autotuned layout/chunk winner, when one exists —
        # parity-gated at tuning time, so iterates are bit-identical
        rec = data.tuning_for("jax_sparse", config.loss)
        if rec is not None:
            if rec.ell_width is not None:
                pcsc = data.tuned_pcsc(rec)
            if config.chunk_steps is None and rec.chunk_steps is not None:
                config = dataclasses.replace(config,
                                             chunk_steps=rec.chunk_steps)
    else:
        pcsr, pcsc = data
    return jax_sparse_fw(pcsr, pcsc, jnp.asarray(y, jnp.float32), config,
                         setup=setup)
