"""The five builtin solver backends (DESIGN.md §4).

  dense        Alg 1 — dense-work FW, one lax.scan (repro.core.fw_dense).
               Accepts a dense device matrix or a PaddedCSR.
  jax_dense    Alg 2 state machine on device, dense vector updates: the pure
               jnp scan from repro.core.fw_jax (full-width scatter/logsumexp
               refreshes each iteration).
  host_sparse  Alg 2 faithful sequential host implementation with exact FLOP
               accounting (repro.core.fw_sparse; queues = Alg 3 / Alg 4 /
               ablations).
  jax_sparse   Alg 2 on device through the Pallas kernels (spmv /
               coord_update / bsls_draw) — the production sparse path.
  jax_shard    Alg 2 under feature sharding: the shard_map collective
               schedule of repro.distributed over an (a × b) BlockSparse
               grid named by FWConfig.mesh (DESIGN.md §8) — the scale-out
               path; a 1×1 mesh reproduces the host oracle exactly.

Each adapter normalizes its engine's native signature/result onto the shared
``(data, y, FWConfig) -> FWResult`` contract.  Imported lazily by
``registry._ensure_builtins``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.solvers.config import FWConfig, FWResult
from repro.core.solvers.prepared import PreparedDataset
from repro.core.solvers.registry import QUEUE_ALIASES, register


@register("dense", data_format="dense", queues=QUEUE_ALIASES["selection"],
          default_queue=None,
          doc="Alg 1 baseline: dense-work FW (O(nnz + D)/iter), device scan")
def _dense_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.fw_dense import dense_fw_jit
    if config.queue is not None:  # queue name chosen → translate to selection
        config = dataclasses.replace(config, selection=config.queue, queue=None)
    return dense_fw_jit(data, jnp.asarray(y, jnp.float32), config)


@register("jax_dense", data_format="padded", queues=QUEUE_ALIASES["device"],
          default_queue="group_argmax",
          doc="Alg 2 device scan, dense vector updates (pure jnp, no kernels)")
def _jax_dense_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.fw_jax import sparse_fw_jax_jit
    pcsr, pcsc = data.pair if isinstance(data, PreparedDataset) else data
    return sparse_fw_jax_jit(pcsr, pcsc, jnp.asarray(y, jnp.float32), config)


@register("host_sparse", data_format="host", queues=QUEUE_ALIASES["host"],
          default_queue="fib_heap",
          doc="Alg 2 faithful host loop (Alg 3/4 queues, exact FLOP audit)")
def _host_sparse_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.fw_sparse import sparse_fw
    res = sparse_fw(
        data, np.asarray(y, np.float64), lam=config.lam, steps=config.steps,
        loss=config.loss, queue=config.queue, epsilon=config.epsilon,
        delta=config.delta, seed=config.seed)
    gaps = jnp.asarray(res.gaps, jnp.float32)
    return FWResult(w=jnp.asarray(res.w, jnp.float32), gaps=gaps,
                    coords=jnp.asarray(res.coords, jnp.int32),
                    losses=jnp.zeros_like(gaps))


@register("jax_shard", data_format="blocks", queues=QUEUE_ALIASES["shard"],
          default_queue="argmax",
          doc="Alg 2 under feature sharding: shard_map collective schedule "
              "over BlockSparse blocks (FWConfig.mesh = (rows, features); "
              "1×1 reproduces the host oracle exactly)")
def _jax_shard_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.solvers.jax_shard import shard_fw
    return shard_fw(data, y, config)


@register("jax_sparse", data_format="padded", queues=QUEUE_ALIASES["device"],
          default_queue="group_argmax",
          doc="Alg 2 device scan through the Pallas kernels "
              "(spmv + coord_update + bsls_draw)")
def _jax_sparse_backend(data, y, config: FWConfig) -> FWResult:
    from repro.core.solvers.jax_sparse import jax_sparse_fw
    setup = None
    if isinstance(data, PreparedDataset):
        # dataset-store path: replay the cached fw_setup state (bit-exact)
        setup = data.setup_for(y, config.loss, config.interpret)
        pcsr, pcsc = data.pair
    else:
        pcsr, pcsc = data
    return jax_sparse_fw(pcsr, pcsc, jnp.asarray(y, jnp.float32), config,
                         setup=setup)
