"""``jax_shard`` backend — Algorithm 2 under feature sharding (DESIGN.md §8).

The registered face of ``repro.distributed``: one ``FWConfig`` whose
``mesh=(a, b)`` names the device grid (rows × features) runs the paper's
iteration as the shard_map collective schedule of
``distributed.fw_shard`` — shard-then-member Gumbel-max selection, lane
psums, α-delta reduction — over ``BlockSparse`` blocks built by
``distributed.ingest`` (store shards map straight onto blocks, with a
content-hash-guarded layout cache).

Program structure mirrors ``jax_sparse``: a config-independent ``setup``
pass plus a T-step ``scan`` whose (λ, EM scale, PRNG key) are traced — one
compile serves a whole (λ, ε) grid, and ``solvers.batched`` vmaps the sweep
where the mesh allows.  Compiled programs and meshes are memoized per
(grid, block shapes, static config) so repeated solves re-enter hot
executables.

On a 1×1 mesh every collective degenerates to the identity and the solve
reproduces the single-device oracle exactly (coords bit-identical) — pinned
in tests/test_jax_shard.py, which is what makes the backend testable on CPU
containers while the same code lowers onto the 16×16 / 2×16×16 production
meshes (``shard_lowering``, used by launch/dryrun.py and
benchmarks/perf_lasso.py).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.dp.accountant import em_log_weight_scale
from repro.core.solvers.config import FWConfig, FWResult
from repro.distributed.fw_shard import (DistFW, build_dist_fw,
                                        dist_fw_shardings)
from repro.distributed.ingest import ShardSource

PRIVATE_SELECTION = "gumbel"


@functools.lru_cache(maxsize=None)
def make_shard_mesh(a: int, b: int):
    """(a × b) ("data", "model") mesh over the first a·b local devices."""
    if a < 1 or b < 1:
        raise ValueError(f"mesh must be positive, got ({a}, {b})")
    if a * b > jax.device_count():
        raise ValueError(
            f"FWConfig.mesh=({a}, {b}) needs {a * b} devices but only "
            f"{jax.device_count()} are visible")
    if hasattr(jax.sharding, "AxisType"):  # jax ≥ 0.5 explicit-axis-type API
        return jax.make_mesh((a, b), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((a, b), ("data", "model"))


def mesh_grid(config: FWConfig, src: ShardSource = None) -> Tuple[int, int]:
    """The (a × b) grid for one solve: the config's pin, else the dataset's
    §11 autotuned geometry (when ``src`` is store-backed and a record
    exists), else 1×1."""
    if config.mesh is not None:
        return tuple(int(v) for v in config.mesh)
    store = getattr(src, "store", None)
    if store is not None and hasattr(store, "autotune_load"):
        rec = store.autotune_load("jax_shard", config.loss,
                                  jax.devices()[0].platform)
        if rec is not None and rec.mesh is not None:
            return tuple(int(v) for v in rec.mesh)
    return (1, 1)


def _record_shard_cost(src: ShardSource, mode: str, seconds_per_step_lane:
                       float, *, loss: str) -> None:
    """Feed the group timing to the planner under the **jax_shard** key (the
    mis-keying this module used to dodge by not recording at all)."""
    from repro.core.solvers.planner import data_stats, record_cost
    source = src.csr if src.csr is not None else src.store
    if source is None:
        return
    record_cost("jax_shard", mode, jax.devices()[0].platform,
                data_stats(source), seconds_per_step_lane, loss=loss)


def shard_em_scale(config: FWConfig, n_rows: int) -> float:
    """EM log-weight scale for the (native) ``gumbel`` selection — the same
    ``core.dp.accountant`` formula ``jax_sparse.em_scale_for`` uses, so the
    two engines' (ε, δ, T) semantics cannot drift."""
    if config.queue != PRIVATE_SELECTION:
        return 1.0
    return em_log_weight_scale(
        epsilon=config.epsilon, delta=config.delta, steps=config.steps,
        n_rows=n_rows, lipschitz=config.loss_fn().lipschitz)


# program memo: building shard_map + jit per call would recompile every
# solve.  Keyed on everything that shapes the lowered executable.
_PROGRAMS: Dict[tuple, DistFW] = {}
_VMAPPED: Dict[tuple, object] = {}


def _program_key(blocks_abs, mesh, steps, loss, selection, compress_topk,
                 early_stop):
    return (blocks_abs.csc_rows.shape, blocks_abs.csr_cols.shape,
            blocks_abs.shape, blocks_abs.padded, mesh.axis_names,
            mesh.devices.shape, steps, loss, selection, compress_topk,
            early_stop)


def shard_program(blocks_abs, mesh, *, steps: int, loss: str, selection: str,
                  compress_topk: int = 0, early_stop: bool = False) -> DistFW:
    """Memoized (setup, scan, whole) program for one block layout + mesh."""
    key = _program_key(blocks_abs, mesh, steps, loss, selection,
                       compress_topk, early_stop)
    if key not in _PROGRAMS:
        _PROGRAMS[key] = build_dist_fw(
            blocks_abs, mesh, steps=steps, loss=loss, selection=selection,
            compress_topk=compress_topk, early_stop=early_stop)
    return _PROGRAMS[key]


def vmapped_scan(blocks_abs, mesh, *, steps: int, loss: str, selection: str,
                 early_stop: bool = False):
    """jit(vmap(scan)) over stacked (λ, em_scale, gap_tol, key) — the batched
    sweep path on meshes where the whole stack fits one device program (1×1)."""
    key = _program_key(blocks_abs, mesh, steps, loss, selection, 0, early_stop)
    if key not in _VMAPPED:
        prog = shard_program(blocks_abs, mesh, steps=steps, loss=loss,
                             selection=selection, early_stop=early_stop)
        _VMAPPED[key] = jax.jit(jax.vmap(
            prog.scan, in_axes=(None, None, None, None, None, 0, 0, 0, 0)))
    return _VMAPPED[key]


def _pad_labels(y, n_pad: int) -> jnp.ndarray:
    y = jnp.asarray(y, jnp.float32)
    return jnp.zeros((n_pad,), jnp.float32).at[: y.shape[0]].set(y)


def _shard_result(w, gaps, coords, stop_step, d: int, steps: int) -> FWResult:
    stop = int(stop_step)
    return FWResult(w=w[:d], gaps=gaps, coords=coords,
                    losses=jnp.zeros_like(gaps), stop_step=stop,
                    stop_reason="gap_tol" if stop < steps else "max_steps")


def _reject_max_seconds(config: FWConfig) -> None:
    if config.max_seconds is not None:
        raise ValueError(
            "jax_shard runs as one compiled collective scan and cannot "
            "watch a wall clock; use gap_tol, or a host backend for "
            "max_seconds")


def shard_fw(src: ShardSource, y, config: FWConfig) -> FWResult:
    """One solve through the sharded collective schedule."""
    _reject_max_seconds(config)
    a, b = mesh_grid(config, src)
    mesh = make_shard_mesh(a, b)
    blocks = src.blocks(a, b)
    n, d = src.shape
    prog = shard_program(blocks, mesh, steps=config.steps, loss=config.loss,
                         selection=config.queue,
                         early_stop=config.gap_tol > 0)
    t0 = time.perf_counter()
    with mesh:
        ypad = _pad_labels(y, blocks.padded[0])
        with obs.span("shard.setup", mesh=f"{a}x{b}"):
            setup = prog.setup(blocks, ypad)
        with obs.span("shard.scan", mesh=f"{a}x{b}", steps=config.steps):
            w, gaps, coords, stop_step = prog.scan(
                blocks, ypad, *setup, jnp.float32(config.lam),
                jnp.float32(shard_em_scale(config, n)),
                jnp.float32(config.gap_tol),
                jax.random.PRNGKey(config.seed))
            jax.block_until_ready(w)
    _record_shard_cost(src, "sequential",
                       (time.perf_counter() - t0) / max(config.steps, 1),
                       loss=config.loss)
    return _shard_result(w, gaps, coords, stop_step, d, config.steps)


def solve_shard_group(src: ShardSource, y, configs) -> list:
    """A compatible config group on one shared setup: vmapped on a 1×1 mesh,
    sequential re-entries of the one compiled scan otherwise (λ/ε/gap_tol/key
    are traced either way, so the grid never recompiles)."""
    c0 = configs[0]
    for c in configs:
        _reject_max_seconds(c)
    a, b = mesh_grid(c0, src)
    mesh = make_shard_mesh(a, b)
    blocks = src.blocks(a, b)
    n, d = src.shape
    early = any(c.gap_tol > 0 for c in configs)
    prog = shard_program(blocks, mesh, steps=c0.steps, loss=c0.loss,
                         selection=c0.queue, early_stop=early)
    lams = jnp.asarray([c.lam for c in configs], jnp.float32)
    scales = jnp.asarray([shard_em_scale(c, n) for c in configs], jnp.float32)
    tols = jnp.asarray([c.gap_tol for c in configs], jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(c.seed) for c in configs])
    t0 = time.perf_counter()
    with mesh:
        ypad = _pad_labels(y, blocks.padded[0])
        with obs.span("shard.setup", mesh=f"{a}x{b}", size=len(configs)):
            setup = prog.setup(blocks, ypad)
        if a * b == 1:
            vscan = vmapped_scan(blocks, mesh, steps=c0.steps, loss=c0.loss,
                                 selection=c0.queue, early_stop=early)
            w, gaps, coords, stops = vscan(blocks, ypad, *setup, lams, scales,
                                           tols, keys)
            jax.block_until_ready(w)
            outs = [(w[i], gaps[i], coords[i], stops[i])
                    for i in range(len(configs))]
            mode = "vmap"
        else:
            outs = [prog.scan(blocks, ypad, *setup, lams[i], scales[i],
                              tols[i], keys[i])
                    for i in range(len(configs))]
            jax.block_until_ready(outs[-1][0])
            mode = "sequential"
    _record_shard_cost(
        src, mode,
        (time.perf_counter() - t0) / max(c0.steps * len(configs), 1),
        loss=c0.loss)
    return [_shard_result(w, g, c, s, d, c0.steps) for (w, g, c, s) in outs]


def shard_lowering(n: int, d: int, mesh, *, steps: int, kc: int, kr: int,
                   selection: str = "gumbel", compress_topk: int = 0,
                   loss: str = "logistic"):
    """(jitted whole-run fn, abstract args) for dry-run lowering.

    Builds ShapeDtypeStruct block specs for an (N × D) design on ``mesh``
    (rows over "pod"/"data", features over "model") and returns the
    registry backend's program ready for ``.lower(*args).compile()`` — what
    ``launch/dryrun.py --arch paper-lasso`` and ``benchmarks/perf_lasso.py``
    lower instead of any ad-hoc builder.  λ, the EM scale and the key are
    abstract traced scalars, matching the serving path.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.block_sparse import block_specs
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    a = 1
    for ax in ("pod", "data"):
        a *= sizes.get(ax, 1)
    b = sizes["model"]
    blocks_abs = block_specs(n, d, a, b, kc, kr)
    prog = shard_program(blocks_abs, mesh, steps=steps, loss=loss,
                         selection=selection, compress_topk=compress_topk)
    b_shd, y_shd = dist_fw_shardings(blocks_abs, mesh)
    repl = NamedSharding(mesh, P())
    jitted = jax.jit(prog.whole,
                     in_shardings=(b_shd, y_shd, repl, repl, repl, repl))
    f32 = jax.ShapeDtypeStruct
    key_abs = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    args = (blocks_abs, f32((blocks_abs.padded[0],), jnp.float32),
            f32((), jnp.float32), f32((), jnp.float32), f32((), jnp.float32),
            key_abs)
    return jitted, args
