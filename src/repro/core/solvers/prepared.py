"""Prepared device-resident dataset: padded layouts + cached solver setup.

``PreparedDataset`` is what the solver registry's padded coercion returns
for a ``repro.data.store.DatasetStore``: the ``(PaddedCSR, PaddedCSC)`` pair
plus a memo of the config-independent Frank-Wolfe setup state
``(v̄₀, q̄₀, α₀)`` per (loss, interpret) — the O(NS) spmv sweep
``jax_sparse.fw_setup`` would otherwise re-run on every solve.

Exactness contract: on a cache miss the setup is computed by the *same*
``fw_setup_jit`` the un-prepared ``jax_sparse`` path calls, then persisted
via the ``saver`` hook (the store writes it under ``<root>/cache/``).  A hit
therefore replays bit-identical arrays, which is why ``solve(store_ref)``
takes exactly the same iterates as ``solve(X_in_memory)`` — parity pinned in
``tests/test_solvers.py``.

The cached setup is keyed to the labels it was computed against: calling
``setup_for`` with different labels bypasses the cache and computes fresh
(never poisoning the persisted state).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.sparse.formats import PaddedCSC, PaddedCSR

SetupState = Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]  # (v̄₀, q̄₀, α₀)
SetupLoader = Callable[[str, bool], Optional[SetupState]]
SetupSaver = Callable[[str, bool, SetupState], None]
# (backend, loss, platform) -> persisted autotune.TuningRecord or None
TuningLoader = Callable[[str, str, str], Optional[object]]


@dataclasses.dataclass
class PreparedDataset:
    """Padded pair + per-loss setup cache, bound to one label vector."""

    pcsr: PaddedCSR
    pcsc: PaddedCSC
    y: np.ndarray                         # labels the setup cache is bound to
    loader: Optional[SetupLoader] = None  # disk-cache read hook (store)
    saver: Optional[SetupSaver] = None    # disk-cache write hook (store)
    tuning_loader: Optional[TuningLoader] = None   # §11 autotune replay hook
    _setup: Dict[Tuple[str, bool], SetupState] = dataclasses.field(
        default_factory=dict)
    # (backend, loss, platform) -> TuningRecord | None (None memoizes a miss)
    _tuning: Dict[Tuple[str, str, str], Optional[object]] = dataclasses.field(
        default_factory=dict)
    _tuned_csc: Dict[int, object] = dataclasses.field(default_factory=dict)

    @property
    def shape(self):
        return self.pcsr.shape

    @property
    def pair(self) -> Tuple[PaddedCSR, PaddedCSC]:
        return self.pcsr, self.pcsc

    def _bound_labels(self, y) -> bool:
        y = np.asarray(y, dtype=np.float64)
        return y.shape == self.y.shape and bool(np.array_equal(y, self.y))

    def setup_for(self, y, loss: str, interpret: bool) -> SetupState:
        """(v̄₀, q̄₀, α₀) for this dataset — cached, disk-backed, exact."""
        from repro.core.solvers.jax_sparse import fw_setup_jit
        if not self._bound_labels(y):
            # foreign labels: correct answer, but never cached
            return fw_setup_jit(self.pcsr, jnp.asarray(y, jnp.float32),
                                loss=loss, interpret=interpret)
        key = (loss, bool(interpret))
        if key not in self._setup:
            state = self.loader(loss, interpret) if self.loader else None
            if state is None:
                state = fw_setup_jit(self.pcsr,
                                     jnp.asarray(self.y, jnp.float32),
                                     loss=loss, interpret=interpret)
                if self.saver is not None:
                    self.saver(loss, interpret, state)
            self._setup[key] = tuple(jnp.asarray(s) for s in state)
        return self._setup[key]

    # ------------------------------------------------- §11 autotuned layout
    def tuning_for(self, backend: str, loss: str,
                   platform: Optional[str] = None):
        """The dataset's persisted autotune winner for (backend, loss) on
        the live platform, or None.  Misses are memoized too — a dataset
        with no tuning record costs one loader call per key, ever."""
        if platform is None:
            import jax
            platform = jax.devices()[0].platform
        key = (backend, loss, platform)
        if key not in self._tuning:
            rec = (self.tuning_loader(backend, loss, platform)
                   if self.tuning_loader else None)
            self._tuning[key] = rec
        return self._tuning[key]

    def set_tuning(self, record) -> None:
        """Install a freshly-searched record in-memory (the tuner's hook, so
        the session that ran the search also benefits from it)."""
        self._tuning[(record.backend, record.loss, record.platform)] = record

    def tuned_pcsc(self, record):
        """The CSC layout ``record`` names: the §11 tiered split at its
        ``ell_width``, memoized per width; the flat pair when untuned."""
        if record is None or record.ell_width is None:
            return self.pcsc
        width = int(record.ell_width)
        if width not in self._tuned_csc:
            from repro.core.sparse.formats import tiered_from_padded
            self._tuned_csc[width] = tiered_from_padded(self.pcsc, width)
        return self._tuned_csc[width]
