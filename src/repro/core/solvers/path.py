"""Regularization-path (homotopy) solving — a full λ-path for ~one solve's
cost (DESIGN.md §14).

``solve_path(X, y, lambdas=(λ₀ > λ₁ > ...), config=...)`` solves a strictly
decreasing λ-sequence by warm-starting each λ from the previous λ's full
solver carry.  The enabling fact is structural: the Frank-Wolfe gap
certificate is ``g_t = g̃ − d̃·α_j`` with ``d̃ = ±λ`` — the carried state
(iterate ``w``/``w_m``, gradient caches ``v̄``/``q̄``/``α``, the gap
estimator ``g̃``, the sampler, the PRNG key) is **λ-independent**, so a
converged carry at λ_{k-1} is a valid, nearly-converged starting carry at
λ_k.  Since λ is already a *traced* scalar of the chunked scan programs
(``jax_sparse.fw_scan_chunk``), every λ-segment re-enters the **same
compiled chunk** — zero recompiles across the path — and continues the
global 2/(t+2) step schedule instead of restarting it (η = 1 at t = 0 would
throw the warm iterate away).

Budgets and accounting are deterministic and planner-owned, mirroring the
§13 ``screen_plan`` idiom so fit-service admission can price the exact run:

  * ``planner.path_budgets(steps, K)`` gives the per-λ iteration budgets —
    the first λ solves cold at the full ``config.steps``, later λs get the
    warm fraction.  Segment k occupies the **fixed global step slots**
    [S_{k-1}, S_k) with S_k = Σ_{i≤k} budgets, even when the gap certificate
    stops it early (frozen steps are no-ops that consume neither arithmetic
    nor DP noise) — which keeps the η schedule deterministic and makes the
    fused-across-tenants group shape bit-identical to the sequential one.
  * For private runs the whole path is **one mechanism**: T_total = Σ T_k
    selections at the uniform advanced-composition rate
    ``ε' = ε / sqrt(8·T_total·log(1/δ))``.  Each λ-segment's share is
    ``ε_k = ε·sqrt(T_k/T_total)`` — chosen exactly so that
    ``per_step_epsilon(ε_k, δ, T_k) = ε'`` for every k: the EM log-weight
    scale is *identical across segments* and the sampler state carries over
    unchanged.  The split is computed up-front (``path_plan``), charged at
    admission, and recorded in the audit ledger.

The result is a :class:`PathResult`: one per-λ :class:`FWResult` each with
its own gap trace/certificate, coordinate trail, and stop report.  Backends
without a re-enterable chunked driver refuse ``lambdas`` charge-free via the
registry's ``supports_path`` flag (``dense`` and ``jax_sparse`` support it).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.dp.accountant import em_log_weight_scale, per_step_epsilon
from repro.core.solvers.config import (FWConfig, FWResult,
                                       check_gap_certificate)
from repro.core.solvers.planner import path_budgets
from repro.core.solvers.prepared import PreparedDataset
from repro.core.solvers.registry import (_COERCE, check_path_support,
                                         get_backend, resolve_data,
                                         resolve_queue)
from repro.core.solvers.stopping import (assemble_outputs, drive_chunks,
                                         resolve_chunk)


def check_path_config(config: FWConfig) -> None:
    """Validate a λ-path config — loudly and up front, so the fit service
    rejects bad paths before charging any DP budget."""
    lambdas = config.lambdas
    if lambdas is None or len(lambdas) == 0:
        raise ValueError("a λ-path needs a non-empty lambdas sequence "
                         "(FWConfig(lambdas=(λ₀, λ₁, ...)))")
    if any(l <= 0 for l in lambdas):
        raise ValueError(f"path lambdas must be positive; got {lambdas}")
    if any(b >= a for a, b in zip(lambdas, lambdas[1:])):
        raise ValueError(
            "path lambdas must be strictly decreasing (the warm start "
            f"continues from inside the shrinking L1 ball); got {lambdas}")
    if config.screen_every > 0:
        raise ValueError(
            "screening (screen_every > 0) cannot be combined with a λ-path: "
            "coordinates screened out at one λ may re-enter at a smaller λ, "
            "so the §13 drop rule is unsound mid-path — screen per λ "
            "separately or set screen_every=0")
    if config.max_seconds is not None:
        raise ValueError(
            "max_seconds is ambiguous for a multi-λ path (per segment or "
            "whole path?) and would break the deterministic up-front "
            "ε split — use gap_tol for per-λ early stopping instead")


@dataclasses.dataclass(frozen=True)
class PathPlan:
    """Deterministic execution/accounting plan of one λ-path (§14).

    Pure arithmetic on the config — admission, the drivers, and the audit
    ledger all reproduce the same plan, which is what makes the up-front
    charge honest (mirrors ``screening.ScreenPlan``).
    """

    lambdas: Tuple[float, ...]
    budgets: Tuple[int, ...]       # per-λ iteration budgets (planner)
    offsets: Tuple[int, ...]       # global step slot each segment starts at
    total_steps: int               # Σ budgets = EM selections composed
    eps_per_step: float            # uniform per-selection rate ε'; 0.0 if
                                   # the plan was built non-private
    eps_lambdas: Tuple[float, ...]  # per-λ ε share: ε_k = ε·sqrt(T_k/T_tot)


def path_plan(config: FWConfig, *, private: bool) -> PathPlan:
    """Budgets + deterministic ε split for ``config.lambdas`` (§14).

    ``private`` mirrors ``screen_plan``: the fit service prices with
    ``private=True`` (it only charges private queues anyway); non-private
    plans carry the full ε per segment (unused — no mechanism runs).
    """
    check_path_config(config)
    lambdas = config.lambdas
    budgets = path_budgets(config.steps, len(lambdas))
    offsets, acc = [], 0
    for b in budgets:
        offsets.append(acc)
        acc += b
    total = acc
    if not private:
        return PathPlan(lambdas=lambdas, budgets=budgets,
                        offsets=tuple(offsets), total_steps=total,
                        eps_per_step=0.0,
                        eps_lambdas=(config.epsilon,) * len(lambdas))
    eps_step = per_step_epsilon(config.epsilon, config.delta, total)
    eps_lams = tuple(config.epsilon * math.sqrt(b / total) for b in budgets)
    return PathPlan(lambdas=lambdas, budgets=budgets, offsets=tuple(offsets),
                    total_steps=total, eps_per_step=eps_step,
                    eps_lambdas=eps_lams)


def segment_config(config: FWConfig, plan: PathPlan, k: int) -> FWConfig:
    """The standalone single-λ config equivalent to path segment ``k``:
    λ_k at budget T_k and ε share ε_k.  Segment 0 of a path is bit-identical
    to ``solve(X, y, segment_config(cfg, plan, 0))`` — the parity contract
    ``tests/test_path.py`` pins; later segments differ only by their warm
    starting carry."""
    return dataclasses.replace(
        config, lam=plan.lambdas[k], steps=plan.budgets[k],
        epsilon=plan.eps_lambdas[k], lambdas=None)


class PathResult:
    """A solved λ-path: one :class:`FWResult` per λ, plus the plan that
    priced it.  Sequence-like over (λ, result) positions."""

    def __init__(self, lambdas: Tuple[float, ...],
                 results: Sequence[FWResult], plan: PathPlan):
        self.lambdas = tuple(lambdas)
        self.results = tuple(results)
        self.plan = plan

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, k: int) -> FWResult:
        return self.results[k]

    @property
    def final(self) -> FWResult:
        """The smallest-λ (last) solution."""
        return self.results[-1]

    def __repr__(self) -> str:
        return (f"PathResult(K={len(self.results)}, "
                f"lambdas={self.lambdas}, "
                f"total_steps={self.plan.total_steps})")


def _final_gap(result: FWResult) -> float:
    gaps = result.gaps_valid
    return float(gaps[-1]) if gaps.shape[0] else float("nan")


def _emit_lambda_event(k: int, lam: float, plan: PathPlan, result: FWResult,
                       seconds: float) -> None:
    from repro import obs
    if not obs.enabled():
        return
    obs.event("path.lambda", index=k, lam=float(lam),
              budget=plan.budgets[k], offset=plan.offsets[k],
              stop_step=result.stop_step_or(plan.budgets[k]),
              stop_reason=result.stop_reason, gap=_final_gap(result),
              eps_lambda=float(plan.eps_lambdas[k]), seconds=seconds)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def path_em_scale(config: FWConfig, plan: PathPlan, n_rows: int) -> float:
    """EM log-weight scale for a private path — **one** value for every
    segment, by construction of the ε split: per_step_epsilon(ε_k, δ, T_k)
    is the same uniform rate for all k.  Evaluated through segment 0's
    (ε₀, T₀) so it is bitwise the scale a standalone ``solve`` of
    ``segment_config(cfg, plan, 0)`` computes (the parity contract)."""
    if config.queue != "two_level":
        return 1.0
    return em_log_weight_scale(
        epsilon=plan.eps_lambdas[0], delta=config.delta,
        steps=plan.budgets[0], n_rows=n_rows,
        lipschitz=config.loss_fn().lipschitz)


def jax_sparse_path(pcsr, pcsc, y, config: FWConfig, plan: PathPlan = None,
                    setup=None) -> PathResult:
    """Warm-started λ-path through the Pallas kernel pipeline.

    One :class:`jax_sparse.FWCarry` is threaded across every λ-segment;
    between segments only the §9 stopping flags (``done``/``stop_at``) are
    reset — ``w``, the gradient caches, the sampler, and the PRNG key carry
    over untouched.  Every segment re-enters the **same** compiled
    ``fw_scan_chunk`` (λ, the EM scale, gap_tol, and the global offset are
    all traced), so the path costs zero extra compiles over a single solve.
    """
    from repro import obs
    from repro.core.solvers.jax_sparse import (fw_carry_init_jit,
                                               fw_scan_chunk_jit,
                                               fw_setup_jit)
    from repro.core.solvers.planner import data_stats, record_cost

    private = config.queue == "two_level"
    if plan is None:
        plan = path_plan(config, private=private)
    fused = True
    n, d = pcsr.shape
    dtype = pcsr.values.dtype
    em_scale = path_em_scale(config, plan, n)
    y_scan = None if config.loss_fn().separable else jnp.asarray(y)
    if setup is None:
        with obs.span("solve.setup", loss=config.loss):
            setup = fw_setup_jit(pcsr, y, loss=config.loss,
                                 interpret=config.interpret)
    carry = fw_carry_init_jit(d, dtype, *setup, em_scale,
                              jax.random.PRNGKey(config.seed),
                              private=private)
    platform = jax.devices()[0].platform
    stats = data_stats((pcsr, pcsc))

    results: List[FWResult] = []
    for k, lam_k in enumerate(plan.lambdas):
        budget, seg_off = plan.budgets[k], plan.offsets[k]
        if k:
            # warm restart: un-freeze the stopping flags, keep everything else
            carry = carry._replace(done=jnp.asarray(False),
                                   stop_at=jnp.asarray(0, jnp.int32))

        def advance(carry, t0, c, _lam=lam_k, _off=seg_off):
            return fw_scan_chunk_jit(
                pcsr, pcsc, carry, _lam, em_scale, config.gap_tol,
                _off + t0, y_scan, steps=c, loss=config.loss,
                private=private, fused=fused, interpret=config.interpret,
                early_stop=True)

        t_seg = time.perf_counter()
        chunk = resolve_chunk(dataclasses.replace(config, steps=budget))
        carry, outs, stop_step, stop_reason = drive_chunks(
            advance, carry, steps=budget, chunk=chunk, max_seconds=None,
            done_of=lambda cy: cy.done,
            stop_at_of=lambda cy, _off=seg_off: cy.stop_at - _off)
        jax.block_until_ready(carry.w)
        dt = time.perf_counter() - t_seg
        record_cost("jax_sparse", "sequential", platform, stats,
                    dt / max(stop_step, 1), loss=config.loss)
        gaps, coords = assemble_outputs(outs, budget, (0.0, -1))
        result = FWResult(w=carry.w * carry.w_m, gaps=gaps, coords=coords,
                          losses=jnp.zeros_like(gaps), stop_step=stop_step,
                          stop_reason=stop_reason)
        results.append(result)
        _emit_lambda_event(k, lam_k, plan, result, dt)
    return PathResult(plan.lambdas, results, plan)


def dense_path(X, y, config: FWConfig, plan: PathPlan = None) -> PathResult:
    """Warm-started λ-path on the Alg-1 dense engine.

    The dense carry is just ``(w, key, done, stop_at)`` — the gradient is
    recomputed from w each step, so the warm start is the iterate alone.
    Alg 1 derives its noise scales from the (static) config, so each segment
    re-enters a per-(λ_k, T_k, ε_k) compiled chunk — correctness-first;
    the zero-recompile fast path is ``jax_sparse``.
    """
    from repro.core.fw_dense import _carry0, _dense_chunk_jit, _n_cols

    if config.queue is not None:   # registry queue name → Alg-1 selection
        config = dataclasses.replace(config, selection=config.queue,
                                     queue=None)
    private = config.selection in ("noisy_max", "gumbel")
    if plan is None:
        plan = path_plan(config, private=private)
    y = jnp.asarray(y, jnp.float32)
    carry = _carry0(X, _n_cols(X), config)

    results: List[FWResult] = []
    for k, lam_k in enumerate(plan.lambdas):
        budget, seg_off = plan.budgets[k], plan.offsets[k]
        seg_cfg = segment_config(config, plan, k)
        if k:
            carry = (carry[0], carry[1], jnp.asarray(False),
                     jnp.asarray(0, jnp.int32))

        def advance(carry, t0, c, _cfg=seg_cfg, _off=seg_off):
            return _dense_chunk_jit(X, y, carry, _off + t0,
                                    config=_cfg, chunk=c)

        t_seg = time.perf_counter()
        carry, outs, stop_step, stop_reason = drive_chunks(
            advance, carry, steps=budget, chunk=resolve_chunk(seg_cfg),
            max_seconds=None, done_of=lambda cy: cy[2],
            stop_at_of=lambda cy, _off=seg_off: cy[3] - _off)
        dt = time.perf_counter() - t_seg
        gaps, coords, losses = assemble_outputs(outs, budget, (0.0, -1, 0.0))
        result = FWResult(w=carry[0], gaps=gaps, coords=coords,
                          losses=losses, stop_step=stop_step,
                          stop_reason=stop_reason)
        results.append(result)
        _emit_lambda_event(k, lam_k, plan, result, dt)
    return PathResult(plan.lambdas, results, plan)


def run_path(backend, data, y, config: FWConfig) -> PathResult:
    """Dispatch one already-coerced, queue-resolved path config to its
    backend driver (what ``solve_path`` and the batched group runner call;
    benches call it directly to keep coercion out of timed regions)."""
    if backend.name == "jax_sparse":
        setup = None
        if isinstance(data, PreparedDataset):
            # dataset-store path: cached fw_setup replay + §11 tuned layout
            setup = data.setup_for(y, config.loss, config.interpret)
            pcsr, pcsc = data.pair
            rec = data.tuning_for("jax_sparse", config.loss)
            if rec is not None:
                if rec.ell_width is not None:
                    pcsc = data.tuned_pcsc(rec)
                if config.chunk_steps is None and rec.chunk_steps is not None:
                    config = dataclasses.replace(
                        config, chunk_steps=rec.chunk_steps)
        else:
            pcsr, pcsc = data
        return jax_sparse_path(pcsr, pcsc, jnp.asarray(y, jnp.float32),
                               config, setup=setup)
    if backend.name == "dense":
        return dense_path(data, y, config)
    raise ValueError(     # unreachable past check_path_support; kept loud
        f"backend {backend.name!r} has no path driver")


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def solve_path(X, y=None, lambdas=None, config: Optional[FWConfig] = None,
               **overrides) -> PathResult:
    """Solve a full regularization path in ~one solve's budget (§14).

    ``lambdas`` (or ``config.lambdas``) is the strictly decreasing
    λ-sequence; everything else — data layouts accepted, queue translation,
    ``backend="auto"`` planning — behaves exactly like :func:`solve`.
    Returns a :class:`PathResult` of per-λ :class:`FWResult`\\ s.
    """
    from repro import obs
    config = config or FWConfig()
    if overrides:
        config = dataclasses.replace(config, **overrides)
    if lambdas is not None:
        config = dataclasses.replace(config, lambdas=tuple(lambdas))
    if config.lambdas is None:
        raise ValueError("solve_path needs a λ-sequence: pass lambdas=... "
                         "or a config with lambdas set")
    with obs.span("solve_path", loss=config.loss,
                  n_lambdas=len(config.lambdas)) as sp:
        check_gap_certificate(config)
        check_path_config(config)
        X, y = resolve_data(X, y)
        if config.backend == "auto":
            with obs.span("solve.plan"):
                from repro.core.solvers.planner import (choose_backend,
                                                        data_stats)
                config = dataclasses.replace(
                    config, backend=choose_backend(data_stats(X), config))
        backend = get_backend(config.backend)
        check_path_support(backend, config)
        config = resolve_queue(backend, config)
        sp.set(backend=backend.name, queue=config.queue)
        obs.count("path.solves", backend=backend.name)
        with obs.span("solve.coerce", layout=backend.data_format):
            data = _COERCE[backend.data_format](X)
        with obs.span("solve.run", backend=backend.name):
            return run_path(backend, data, y, config)
