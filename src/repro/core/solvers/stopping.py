"""Shared host-side machinery for gap-adaptive early stopping (DESIGN.md §9).

The masked chunk scans live with their backends (``fw_dense._dense_chunk``,
``jax_sparse.fw_scan_chunk``); what they have in common is the *driver*: a
host loop that re-enters one compiled chunk until the on-device ``done``
flag lands, the wall clock runs out, or T is spent — then assembles the
full-length sentinel-padded output arrays.  That contract (0.0-padded gaps,
-1-padded coords, ``stop_step``/``stop_reason`` resolution) is defined once
here so the backends cannot drift apart.

Mutable problem geometry (DESIGN.md §13): the operands a chunk program runs
over are no longer fixed for the life of a run.  A driver that screens
features between chunks holds its padded pair in a :class:`ChunkGeometry`
cell, reads it inside ``advance``, and swaps it from the ``respec`` hook —
the next ``advance`` re-enters a freshly compiled (then cached-per-shape)
program over the smaller problem.  ``out_map`` lets such drivers translate
each chunk's outputs back into a stable index space *before* the boundary's
repack changes what the indices mean.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.solvers.config import (STOP_GAP_TOL, STOP_MAX_SECONDS,
                                       STOP_MAX_STEPS, FWConfig)


def resolve_chunk(config: FWConfig) -> int:
    """Chunk length for the chunked drivers/cohort scheduler: the config's
    pin if present, else the planner default (one policy, defined once in
    ``planner.default_chunk``)."""
    from repro.core.solvers.planner import default_chunk
    if config.chunk_steps is not None:
        return max(1, min(int(config.chunk_steps), config.steps))
    return default_chunk(config.steps)


@dataclasses.dataclass
class ChunkGeometry:
    """The per-chunk problem geometry of a chunked run — first-class and
    mutable.

    ``advance`` closures read the current ``operands`` (e.g. the padded
    ELL/CSC pair) through this cell instead of closing over fixed arrays;
    a ``respec`` hook (the §13 screening repack) swaps them between chunks
    via :meth:`swap`.  ``d``/``pad_row``/``pad_col`` are the shape facts the
    cost model and obs trail read per chunk; ``version`` counts swaps —
    each new shape compiles the chunk program once, then re-enters the
    cached executable like any other chunk.
    """

    operands: tuple
    d: int
    pad_row: int = 0
    pad_col: int = 0
    version: int = 0

    def swap(self, operands: tuple, d: int, pad_row: int = 0,
             pad_col: int = 0) -> None:
        self.operands = operands
        self.d = int(d)
        self.pad_row = int(pad_row)
        self.pad_col = int(pad_col)
        self.version += 1


def drive_chunks(
    advance: Callable,      # (carry, t0, chunk_len) -> (carry, outs tuple)
    carry,
    *,
    steps: int,
    chunk: int,
    max_seconds: Optional[float],
    done_of: Callable,      # carry -> device bool: certificate landed
    stop_at_of: Callable,   # carry -> device int: steps applied at freeze
    clock: Callable[[], float] = time.perf_counter,
    respec: Optional[Callable] = None,
    out_map: Optional[Callable] = None,
) -> Tuple[object, List[Tuple[jnp.ndarray, ...]], int, str]:
    """Re-enter one compiled masked chunk until the run ends.

    Returns ``(carry, chunk_outputs, stop_step, stop_reason)`` where
    ``chunk_outputs`` is the list of per-chunk output tuples in order.

    The ``max_seconds`` clock starts *after* the first chunk returns: that
    chunk's wall time is dominated by the XLA compile of the program every
    later chunk re-enters, which is a one-off cost of the process, not of
    this run — charging it would make any budget shorter than the compile
    stop every run after one chunk regardless of optimization progress.
    ``clock`` injects the time source (tests drive timeout behavior with a
    fake clock instead of sleeping real wall time).

    ``respec`` is the §13 mutable-geometry hook: called at each interior
    chunk boundary the run will continue past (never after ``done``, a
    timeout, or the final chunk) as ``respec(carry, t0, n_chunks)``.  It
    returns ``None`` to continue unchanged, or ``(new_carry, info)`` after
    swapping the geometry its ``advance`` closure reads — ``info`` (a dict)
    lands on the ``chunks.respec`` obs event.  A respec'd chunk recompiles
    for its new shape; that cost is charged to ``max_seconds`` like any
    warm chunk (only the first chunk's compile is excluded).

    ``out_map`` maps each chunk's output tuple before buffering, as
    ``out_map(out, t0)`` with ``t0`` the chunk's starting step — it runs
    *before* the boundary's ``respec``, so drivers whose geometry mutates
    can translate outputs into the stable original index space using the
    mapping the chunk actually ran under.
    """
    from repro import obs
    outs: List[Tuple[jnp.ndarray, ...]] = []
    t0, stop_reason = 0, STOP_MAX_STEPS
    t_start: Optional[float] = None
    n_chunks = 0
    t_prev = clock()
    while t0 < steps:
        c = min(chunk, steps - t0)
        carry, out = advance(carry, t0, c)
        out = out if isinstance(out, tuple) else (out,)
        if out_map is not None:
            out = out_map(out, t0)
        outs.append(out)
        t0 += c
        n_chunks += 1
        done = bool(done_of(carry))         # blocks: the chunk has run
        now = clock()
        if obs.enabled():
            if n_chunks == 1:
                # compile-dominated cold chunk: tracked as its own gauge so
                # it never skews the steady-state chunk histogram
                obs.gauge("chunk.first_seconds", now - t_prev)
            else:
                obs.observe("chunk.seconds", now - t_prev)
            obs.count("chunk.steps", c)
        t_prev = now
        if done:
            stop_reason = STOP_GAP_TOL
            break
        if t_start is None:                 # cold chunk: compile excluded
            t_start = now
        elif max_seconds is not None and now - t_start >= max_seconds:
            stop_reason = STOP_MAX_SECONDS
            break
        if respec is not None and t0 < steps:
            swapped = respec(carry, t0, n_chunks)
            if swapped is not None:
                carry, info = swapped
                if obs.enabled():
                    obs.event("chunks.respec", t0=t0, chunks=n_chunks,
                              **(info or {}))
    stop_step = (int(stop_at_of(carry)) if bool(done_of(carry)) else t0)
    if obs.enabled():
        obs.event("chunks.stop", stop_step=stop_step, stop_reason=stop_reason,
                  chunks=n_chunks, steps_requested=steps)
        obs.count("chunks.stopped", reason=stop_reason)
    return carry, outs, stop_step, stop_reason


def assemble_outputs(
    chunk_outputs: Sequence[Tuple[jnp.ndarray, ...]], steps: int,
    pad_values: Sequence,
) -> Tuple[jnp.ndarray, ...]:
    """Concatenate per-chunk output streams and sentinel-pad each to the
    static length ``steps`` (``pad_values[i]`` per stream — 0.0 for gaps,
    -1 for coords...).  Steps the scan ran past the stop inside the final
    chunk are already sentinel-masked by the scan itself."""
    streams = []
    for i, pad in enumerate(pad_values):
        parts = [out[i] for out in chunk_outputs]
        # zero-chunk runs must still honor each stream's dtype contract
        # (int32 coords, float gaps) — the sentinel value carries it
        arr = (jnp.concatenate(parts) if parts
               else jnp.zeros((0,), jnp.asarray(pad).dtype))
        ran = arr.shape[0]
        if ran < steps:
            filler = jnp.full((steps - ran,), pad, arr.dtype)
            arr = jnp.concatenate([arr, filler])
        streams.append(arr)
    return tuple(streams)
