"""Shared host-side machinery for gap-adaptive early stopping (DESIGN.md §9).

The masked chunk scans live with their backends (``fw_dense._dense_chunk``,
``jax_sparse.fw_scan_chunk``); what they have in common is the *driver*: a
host loop that re-enters one compiled chunk until the on-device ``done``
flag lands, the wall clock runs out, or T is spent — then assembles the
full-length sentinel-padded output arrays.  That contract (0.0-padded gaps,
-1-padded coords, ``stop_step``/``stop_reason`` resolution) is defined once
here so the backends cannot drift apart.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.solvers.config import (STOP_GAP_TOL, STOP_MAX_SECONDS,
                                       STOP_MAX_STEPS, FWConfig)


def resolve_chunk(config: FWConfig) -> int:
    """Chunk length for the chunked drivers/cohort scheduler: the config's
    pin if present, else the planner default (one policy, defined once in
    ``planner.default_chunk``)."""
    from repro.core.solvers.planner import default_chunk
    if config.chunk_steps is not None:
        return max(1, min(int(config.chunk_steps), config.steps))
    return default_chunk(config.steps)


def drive_chunks(
    advance: Callable,      # (carry, t0, chunk_len) -> (carry, outs tuple)
    carry,
    *,
    steps: int,
    chunk: int,
    max_seconds: Optional[float],
    done_of: Callable,      # carry -> device bool: certificate landed
    stop_at_of: Callable,   # carry -> device int: steps applied at freeze
) -> Tuple[object, List[Tuple[jnp.ndarray, ...]], int, str]:
    """Re-enter one compiled masked chunk until the run ends.

    Returns ``(carry, chunk_outputs, stop_step, stop_reason)`` where
    ``chunk_outputs`` is the list of per-chunk output tuples in order.

    The ``max_seconds`` clock starts *after* the first chunk returns: that
    chunk's wall time is dominated by the XLA compile of the program every
    later chunk re-enters, which is a one-off cost of the process, not of
    this run — charging it would make any budget shorter than the compile
    stop every run after one chunk regardless of optimization progress.

    Per-chunk wall times, the first-chunk (compile-dominated) cost, and the
    final stop verdict are reported to the obs layer when a collector is
    active — host-side reads of already-materialized state, never inside
    the compiled chunk itself.
    """
    from repro import obs
    outs: List[Tuple[jnp.ndarray, ...]] = []
    t0, stop_reason = 0, STOP_MAX_STEPS
    t_start: Optional[float] = None
    n_chunks = 0
    t_prev = time.perf_counter()
    while t0 < steps:
        c = min(chunk, steps - t0)
        carry, out = advance(carry, t0, c)
        outs.append(out if isinstance(out, tuple) else (out,))
        t0 += c
        n_chunks += 1
        done = bool(done_of(carry))         # blocks: the chunk has run
        now = time.perf_counter()
        if obs.enabled():
            if n_chunks == 1:
                # compile-dominated cold chunk: tracked as its own gauge so
                # it never skews the steady-state chunk histogram
                obs.gauge("chunk.first_seconds", now - t_prev)
            else:
                obs.observe("chunk.seconds", now - t_prev)
            obs.count("chunk.steps", c)
        t_prev = now
        if done:
            stop_reason = STOP_GAP_TOL
            break
        if t_start is None:                 # cold chunk: compile excluded
            t_start = now
        elif max_seconds is not None and now - t_start >= max_seconds:
            stop_reason = STOP_MAX_SECONDS
            break
    stop_step = (int(stop_at_of(carry)) if bool(done_of(carry)) else t0)
    obs.event("chunks.stop", stop_step=stop_step, stop_reason=stop_reason,
              chunks=n_chunks, steps_requested=steps)
    obs.count("chunks.stopped", reason=stop_reason)
    return carry, outs, stop_step, stop_reason


def assemble_outputs(
    chunk_outputs: Sequence[Tuple[jnp.ndarray, ...]], steps: int,
    pad_values: Sequence,
) -> Tuple[jnp.ndarray, ...]:
    """Concatenate per-chunk output streams and sentinel-pad each to the
    static length ``steps`` (``pad_values[i]`` per stream — 0.0 for gaps,
    -1 for coords...).  Steps the scan ran past the stop inside the final
    chunk are already sentinel-masked by the scan itself."""
    streams = []
    for i, pad in enumerate(pad_values):
        parts = [out[i] for out in chunk_outputs]
        # zero-chunk runs must still honor each stream's dtype contract
        # (int32 coords, float gaps) — the sentinel value carries it
        arr = (jnp.concatenate(parts) if parts
               else jnp.zeros((0,), jnp.asarray(pad).dtype))
        ran = arr.shape[0]
        if ran < steps:
            filler = jnp.full((steps - ran,), pad, arr.dtype)
            arr = jnp.concatenate([arr, filler])
        streams.append(arr)
    return tuple(streams)
