"""DP iterative screening between solver chunks (DESIGN.md §13).

The paper's per-iteration cost is dominated by terms in the padded feature
count D (the √D·log D selection term, the O(D)-wide masked-scan freezes, the
w/α scatter lanes), and every compiled chunk of the §9 driver pays for the
*full* padded D even after most features are provably inactive.  Following
the iterative-screening idea of Khanna et al. (*Differentially Private
Iterative Screening Rules for Linear Regression*, PAPERS.md), this module
discards inactive features **mid-solve**, at the chunk boundaries the
stopping driver already re-enters:

  1. **query** — the screening score of coordinate j is |α_j|, the same
     gradient statistic the FW selection step ranks.  A private round
     releases the decision through per-coordinate Laplace noise
     ``Lap(Δ₁/ε_round)`` where ``Δ₁ = 2·L·Kr/N`` bounds the L1 sensitivity
     of the α vector under a one-row change (a row touches at most Kr
     coordinates, each by ≤ 2L/N, L the loss's Lipschitz bound — the same
     per-coordinate sensitivity the EM draws use).  Keeping a *threshold
     decision* computed from the noisy vector is post-processing, so each
     round is ε_round-DP.
  2. **rule** — keep j iff its noisy score is within ``margin`` of the noisy
     max, where ``margin = TAIL_LOG_MASS/em_scale + NOISE_SLACK·b``: the
     first term bounds the selection-probability mass the EM sampler could
     ever put on a dropped coordinate (a coordinate τ em-units below the max
     is selected with odds ≤ e^{-τ} per draw), the second absorbs the
     screening noise itself.  The support of w and a minimum survivor floor
     are always kept, so the continued problem *contains* the solution path
     built so far.
  3. **repack** — survivors are compacted into a fresh padded ELL/CSC pair
     (pad widths shrink to the survivors' true maxima), the carry is
     column-subset, and the sampler state is rebuilt from the live |α|
     values — value-exact, because both sampler inits are pure functions of
     the priority vector.

ε-composition: a run planning R screening rounds at total budget ε splits
it as ``ε_screen = screen_eps_frac·ε`` (spread over the R rounds by the
same advanced-composition rule the EM draws use) and runs the solve's
selection mechanism at ``ε_solve = ε − ε_screen``.  Both sub-budgets are
charged up-front at admission (``FitService``), so the composed release is
(ε, δ)-DP no matter where the run actually stops.  Non-private runs screen
noise-free (no ε split, no charge).

Exactness of continuation: with supp(w) ⊆ survivors, X_S·w_S = X·w, so
v̄/q̄ are untouched by the repack and the restricted α_S dynamics are
exactly the full dynamics observed on S.  What screening *does* change is
the selection domain — a dropped coordinate can never be chosen again — so
the §9 parity-vs-prefix contract holds only until the first round fires
(``screen_every=0``, the default, keeps every existing program bit-exact).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.dp.accountant import per_step_epsilon
from repro.core.solvers.config import FWConfig
from repro.core.sparse.formats import (PaddedCSC, PaddedCSR, TieredCSC,
                                       tiered_from_padded)

# Survivor floor: never screen below max(DEFAULT_MIN_KEEP, √D₀) coordinates —
# the later FW iterations need a working set, and √D is the natural group
# granularity of the two-level sampler.
DEFAULT_MIN_KEEP = 16
# Keep margin in units of the Laplace scale b: a true score more than
# NOISE_SLACK·b below the threshold is dropped despite the noise w.h.p.,
# one above survives w.h.p. (P[|Lap(b)| > 4b] ≈ 1.8%).
NOISE_SLACK = 4.0
# Keep margin in EM log-weight units: a coordinate TAIL_LOG_MASS em-units
# below the max carries ≤ e^-TAIL_LOG_MASS ≈ 1e-3 of the max's selection
# odds per draw, so the dropped set is (numerically) invisible to the
# sampler the solve would have run.
TAIL_LOG_MASS = 7.0
# Non-private rule: keep scores within this fraction of the max (plus the
# support/floor guarantees) — no noise, no ε charge.
NP_KEEP_FRACTION = 0.5


@dataclasses.dataclass(frozen=True)
class ScreenPlan:
    """The ε ledger of one screened run, fixed before the first iteration.

    ``rounds`` is planned deterministically from (steps, chunk, screen_every)
    — never from how far the run actually gets — so admission can charge the
    whole composed release up-front.  Early stopping only *under*-uses it.
    """

    rounds: int          # screening rounds the schedule can fire
    eps_solve: float     # budget left to the selection mechanism
    eps_screen: float    # total screening budget (0 when rounds == 0)
    eps_round: float     # per-round pure-DP budget (advanced composition)


def check_screen_config(config: FWConfig) -> None:
    """Refuse malformed screening knobs up front (charge-free in the fit
    service): ``screen_every`` must be a non-negative chunk count and the ε
    fraction must leave both phases a positive budget."""
    if config.screen_every < 0:
        raise ValueError(
            f"screen_every must be >= 0, got {config.screen_every}")
    if config.screen_every == 0:
        return
    if not 0.0 < config.screen_eps_frac < 1.0:
        raise ValueError(
            "screen_eps_frac must be in (0, 1) so both the screening "
            f"queries and the solve keep a positive ε share; got "
            f"{config.screen_eps_frac}")


def screening_rounds(steps: int, chunk: int, screen_every: int) -> int:
    """Rounds the chunk schedule can fire: one per ``screen_every`` interior
    chunk boundaries (the final boundary ends the run — nothing to repack)."""
    if screen_every <= 0:
        return 0
    n_chunks = -(-steps // max(chunk, 1))
    return max(0, (n_chunks - 1) // screen_every)


def screen_plan(config: FWConfig, *, private: bool) -> ScreenPlan:
    """Split ``config.epsilon`` between screening rounds and the solve.

    The R rounds compose like R extra mechanism invocations at their own
    advanced-composition rate: ``ε_round = ε_screen/√(8R·log(1/δ))`` — the
    same currency ``per_step_epsilon`` denominates the EM draws in, which is
    what lets ``FitService._charged_steps`` price both phases in one pool.
    Non-private runs (and schedules that can never fire) keep the full ε
    for the solve.
    """
    check_screen_config(config)
    from repro.core.solvers.stopping import resolve_chunk
    rounds = screening_rounds(config.steps, resolve_chunk(config),
                              config.screen_every)
    if not private or rounds == 0:
        return ScreenPlan(rounds=rounds, eps_solve=config.epsilon,
                          eps_screen=0.0, eps_round=0.0)
    eps_screen = config.epsilon * config.screen_eps_frac
    eps_solve = config.epsilon - eps_screen
    return ScreenPlan(
        rounds=rounds, eps_solve=eps_solve, eps_screen=eps_screen,
        eps_round=per_step_epsilon(eps_screen, config.delta, rounds))


def solve_epsilon(config: FWConfig) -> float:
    """ε available to the selection mechanism of a *private* screened run
    (the full ``config.epsilon`` when screening is off or can never fire).
    The single place the DP backends read the split from."""
    if config.screen_every <= 0:
        return config.epsilon
    return screen_plan(config, private=True).eps_solve


# ---------------------------------------------------------------------------
# geometry repack: column-subset the padded pair, exactly
# ---------------------------------------------------------------------------


def _csc_full_arrays(pcsc) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Full-width numpy (indices, values, nnz) of any CSC layout — the §11
    tiered split is re-flattened (heavy rows overwrite their truncated light
    copies) so the repack sees every entry exactly once."""
    if isinstance(pcsc, TieredCSC):
        d = pcsc.indices.shape[0]
        full = pcsc.full_width
        ci = np.zeros((d, full), np.int32)
        cv = np.zeros((d, full), np.float32)
        ci[:, : pcsc.width] = np.asarray(pcsc.indices)
        cv[:, : pcsc.width] = np.asarray(pcsc.values)
        cn = np.asarray(pcsc.nnz)
        heavy = np.flatnonzero(cn > pcsc.width)
        if heavy.size:
            slots = np.asarray(pcsc.heavy_slot)[heavy]
            ci[heavy] = np.asarray(pcsc.heavy_indices)[slots]
            cv[heavy] = np.asarray(pcsc.heavy_values)[slots]
        return ci, cv, cn
    return (np.asarray(pcsc.indices), np.asarray(pcsc.values),
            np.asarray(pcsc.nnz))


def repack_csr(pcsr: PaddedCSR, keep: np.ndarray) -> PaddedCSR:
    """Column-subset repack of the padded ELL rows.

    Surviving entries are remapped to the compacted column ids and compacted
    to the front of each row (stable order — the per-row entry order every
    kernel reduction sees is preserved); the pad width shrinks to the
    survivors' true max row nnz.  Dropped/padding lanes become the canonical
    inert (index=0, value=0) padding.
    """
    keep = np.asarray(keep, bool)
    sel = np.flatnonzero(keep)
    remap = np.zeros(keep.size, np.int64)
    remap[sel] = np.arange(sel.size)
    ri = np.asarray(pcsr.indices)
    rv = np.asarray(pcsr.values)
    rn = np.asarray(pcsr.nnz)
    lane = np.arange(ri.shape[1])[None, :]
    live = (lane < rn[:, None]) & keep[ri]
    new_idx = np.where(live, remap[ri], 0).astype(np.int32)
    new_val = np.where(live, rv, 0).astype(rv.dtype)
    order = np.argsort(~live, axis=1, kind="stable")
    rn_new = live.sum(axis=1).astype(np.int32)
    k_row = max(1, int(rn_new.max()) if rn_new.size else 1)
    new_idx = np.take_along_axis(new_idx, order, axis=1)[:, :k_row]
    new_val = np.take_along_axis(new_val, order, axis=1)[:, :k_row]
    return PaddedCSR(jnp.asarray(new_idx), jnp.asarray(new_val),
                     jnp.asarray(rn_new), (pcsr.shape[0], int(sel.size)))


def repack_pair(
    pcsr: PaddedCSR, pcsc, keep: np.ndarray
) -> Tuple[PaddedCSR, Union[PaddedCSC, TieredCSC]]:
    """Repack both padded layouts to the surviving columns.

    The CSC side is a row (= column-major) subset with the pad width cut to
    the survivors' max column nnz; a §11 tiered input is re-tiered at its
    original light width when the survivors still exceed it (the tuner's
    choice outlives the repack), else collapses to the flat layout.
    """
    keep = np.asarray(keep, bool)
    sel = np.flatnonzero(keep)
    new_csr = repack_csr(pcsr, keep)
    ci, cv, cn = _csc_full_arrays(pcsc)
    ci2, cv2, cn2 = ci[sel], cv[sel], cn[sel].astype(np.int32)
    k_col = max(1, int(cn2.max()) if cn2.size else 1)
    flat = PaddedCSC(jnp.asarray(ci2[:, :k_col].astype(np.int32)),
                     jnp.asarray(cv2[:, :k_col].astype(np.float32)),
                     jnp.asarray(cn2), (pcsr.shape[0], int(sel.size)))
    if isinstance(pcsc, TieredCSC) and pcsc.width < k_col:
        return new_csr, tiered_from_padded(flat, pcsc.width)
    return new_csr, flat


def repack_dense(X, keep: np.ndarray):
    """Column-subset an Alg-1 design (dense device matrix or PaddedCSR)."""
    if isinstance(X, PaddedCSR):
        return repack_csr(X, keep)
    return jnp.asarray(X)[:, np.flatnonzero(np.asarray(keep, bool))]


def repack_carry(carry, keep: np.ndarray, em_scale, private: bool):
    """Column-subset a ``jax_sparse.FWCarry`` to the survivors.

    w/α are sliced; v̄/q̄/g̃ are row-space and — because supp(w) is always
    kept — already equal to the restricted problem's state.  The sampler is
    *rebuilt* from the live |α| values, which is value-exact: ``tl_update``
    recomputes every group logsumexp from the value table each step, and the
    lazy argmax ratchet re-derives its bounds from the same priorities, so
    both inits reproduce the state the restricted run would hold.
    """
    from repro.core.samplers.bsls_jax import tl_init
    from repro.core.samplers.group_argmax import ga_init
    sel = jnp.asarray(np.flatnonzero(np.asarray(keep, bool)))
    w = carry.w[sel]
    alpha = carry.alpha[sel]
    if private:
        sampler = tl_init(jnp.abs(alpha) * jnp.asarray(em_scale, alpha.dtype))
    else:
        sampler = ga_init(jnp.abs(alpha))
    return carry._replace(w=w, alpha=alpha, sampler=sampler)


# ---------------------------------------------------------------------------
# the per-run orchestrator
# ---------------------------------------------------------------------------


class Screener:
    """Bookkeeping of one screened run: the DP keep rule, the cumulative
    original-index map, round/ε accounting, and the obs trail.

    Backends own the representation-specific glue (what a "score" or a
    "repack" is for their carry); this class owns everything that must not
    drift between them: when a round is due, how the noisy decision is made,
    and how results map back to the original feature space.
    """

    def __init__(self, config: FWConfig, *, d: int, n_rows: int,
                 row_width: int, em_scale: float, private: bool):
        check_screen_config(config)
        if config.screen_every <= 0:
            raise ValueError("Screener requires screen_every > 0")
        self.config = config
        self.private = bool(private)
        self.plan = screen_plan(config, private=private)
        self.d0 = int(d)
        self.sel = np.arange(self.d0, dtype=np.int64)   # current -> original
        self.rounds_done = 0
        lipschitz = config.loss_fn().lipschitz
        # L1 sensitivity of the α release under a one-row change: ≤ row_width
        # touched coordinates, each moved by ≤ 2L/N.
        self.sensitivity = 2.0 * lipschitz * int(row_width) / max(int(n_rows), 1)
        self.noise_b = (self.sensitivity / self.plan.eps_round
                        if self.private and self.plan.rounds else 0.0)
        self.em_scale = float(em_scale)
        self.min_keep = max(DEFAULT_MIN_KEEP, math.isqrt(self.d0))

    # ------------------------------------------------------------- schedule
    @property
    def d_current(self) -> int:
        return int(self.sel.size)

    def due(self, n_chunks: int) -> bool:
        """Is a round due at the boundary after chunk ``n_chunks``?  (The
        driver only asks at boundaries the run will continue past.)"""
        return (self.rounds_done < self.plan.rounds
                and n_chunks % self.config.screen_every == 0)

    # ----------------------------------------------------------------- rule
    def screen(self, scores: np.ndarray,
               support: np.ndarray) -> Optional[np.ndarray]:
        """Run one screening round over the current-space ``scores`` (|α|).

        Returns the keep mask, or None when every coordinate survives (the
        round is still consumed — its noisy query was asked and its ε
        spent).  ``support`` marks coordinates that must survive (supp(w)).
        """
        scores = np.asarray(scores, np.float64)
        support = np.asarray(support, bool)
        d = scores.shape[0]
        if self.private:
            rng = np.random.default_rng(
                (int(self.config.seed) & 0xFFFFFFFF, self.rounds_done,
                 0x5C12EE))
            noisy = scores + rng.laplace(0.0, self.noise_b, d)
            margin = (TAIL_LOG_MASS / max(self.em_scale, 1e-12)
                      + NOISE_SLACK * self.noise_b)
            keep = noisy >= noisy.max() - margin
        else:
            noisy = scores
            keep = scores >= NP_KEEP_FRACTION * scores.max()
        keep |= support
        floor = min(self.min_keep, d)
        if int(keep.sum()) < floor:
            # rank by the same (noisy) release — post-processing, no extra ε
            top = np.argpartition(noisy, d - floor)[d - floor:]
            keep[top] = True
        if keep.all():
            self.rounds_done += 1
            if obs.enabled():
                obs.event("screen.round", round=self.rounds_done,
                          survivors=d, dropped=0,
                          eps_round=self.plan.eps_round, repacked=False)
            return None
        return keep

    def commit(self, keep: np.ndarray, *, repack_seconds: float) -> dict:
        """Record a fired round: fold ``keep`` into the original-index map
        and emit the survivor/timing trail.  Returns the round's obs facts
        (the driver forwards them to the ``chunks.respec`` event)."""
        keep = np.asarray(keep, bool)
        kept = np.flatnonzero(keep)
        dropped = int(keep.size - kept.size)
        self.sel = self.sel[kept]
        self.rounds_done += 1
        if obs.enabled():
            obs.event("screen.round", round=self.rounds_done,
                      survivors=int(kept.size), dropped=dropped,
                      eps_round=self.plan.eps_round,
                      repack_seconds=round(repack_seconds, 6), repacked=True)
            obs.gauge("screen.survivors", int(kept.size))
            obs.observe("screen.repack_seconds", repack_seconds)
            obs.count("screen.rounds")
        return {"round": self.rounds_done, "survivors": int(kept.size),
                "dropped": dropped}

    # ------------------------------------------------------------ index map
    def map_coords(self, coords) -> jnp.ndarray:
        """Chunk-output coordinates (current space) → original feature ids,
        -1 sentinels passing through.  Must be applied with the ``sel``
        active when the chunk *ran* — the driver's ``out_map`` hook fires
        before the boundary's repack, which is exactly that."""
        c = np.asarray(coords)
        safe = np.clip(c, 0, max(self.sel.size - 1, 0))
        return jnp.asarray(np.where(c >= 0, self.sel[safe], -1)
                           .astype(np.int32))

    def expand(self, w) -> jnp.ndarray:
        """Survivor-space iterate → original D₀-length vector (zeros on the
        screened-out coordinates, which the kept-support invariant makes
        exact, not approximate)."""
        w = np.asarray(w)
        full = np.zeros(self.d0, w.dtype)
        full[self.sel] = w
        return jnp.asarray(full)
