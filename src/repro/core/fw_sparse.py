"""Algorithm 2 — Fast Sparse-Aware Frank-Wolfe (faithful host implementation).

Line-for-line realization of the paper's Algorithm 2 over exact HostCSR /
HostCSC, with the queue abstraction of line 6 pluggable:

  * ``FibHeapQueue``   (Alg 3)  → non-private, deterministic
  * ``BSLSSampler``    (Alg 4)  → DP exponential mechanism, O(√D log D)/draw
  * ``NoisyMaxQueue``            → DP ablation (sparse updates, O(D) select)
  * ``ExactArgmaxQueue``         → non-private ablation

State (paper notation): stored weights ``w̃`` with multiplicative scale
``w_m`` (true iterate = w_m·w̃), row scores ``v̄`` (true = w_m·v̄), row
gradient parts ``q̄ = h(w_m·v̄)``, column gradients ``α = (Xᵀ(q̄) − ȳ)/N``
(mean-normalized, matching fw_dense), FW gap accumulator ``g̃ = ⟨α, w_true⟩``.

Pseudocode typos fixed (recorded per DESIGN.md):
  * line 20 is ``w̃⁽ʲ⁾ += η·d̃/w_m`` (after the w_m update of line 19);
  * line 24's ``q̄⁽ʲ⁾`` is the *row* entry ``q̄⁽ⁱ⁾``.

Every floating-point operation on data-shaped values is counted in ``flops``
so Fig. 2/4 can be reproduced exactly.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from repro.core.dp.accountant import fw_noise_scale, per_step_epsilon
from repro.core.losses import get_loss
from repro.core.samplers.base import ExactArgmaxQueue, NoisyMaxQueue
from repro.core.samplers.bsls import BSLSSampler
from repro.core.samplers.fib_heap import FibHeapQueue
from repro.core.sparse.formats import HostCSC, HostCSR


def _row_grad_np(loss_name: str, y: np.ndarray):
    """float64 per-row gradient map over row subsets: ``h(m, rows)``.

    Separable objectives ignore the rows (``h(m) = split_grad(m)``); the
    label-coupled ones gather their labels (``grad(m, y[rows])``).  ``rows``
    may be an index array or a scalar row id.
    """
    obj = get_loss(loss_name)
    if obj.separable:
        h_np = obj.split_grad_np
        if h_np is None:
            raise ValueError(f"loss {loss_name!r} has no numpy twin")
        return lambda m, rows: h_np(m)
    g_np = obj.grad_np
    if g_np is None:
        raise ValueError(f"loss {loss_name!r} has no numpy twin")
    return lambda m, rows: g_np(m, y[rows])


@dataclasses.dataclass
class SparseFWResult:
    w: np.ndarray
    gaps: np.ndarray
    coords: np.ndarray
    flops: int
    queue_work: int
    pops: Optional[int] = None   # FibHeap Fig-3 accounting
    # §9 gap-adaptive stopping: iterations actually applied + why the loop
    # ended (max_steps | gap_tol | max_seconds).  gaps/coords keep length T
    # with 0.0 / -1 sentinels past stop_step, matching the device scans.
    stop_step: Optional[int] = None
    stop_reason: str = "max_steps"

    @property
    def nnz(self) -> int:
        return int(np.sum(self.w != 0))


def sparse_fw(
    X_csr: HostCSR,
    y: np.ndarray,
    *,
    lam: float = 50.0,
    steps: int = 4000,
    loss: str = "logistic",
    queue: str = "fib_heap",       # fib_heap | bsls | noisy_max | argmax
    epsilon: float = 1.0,
    delta: float = 1e-6,
    seed: int = 0,
    X_csc: Optional[HostCSC] = None,
    fast: bool = True,             # vectorized inner loop (identical math);
                                   # False = paper-line-by-line per-row path
    gap_tol: float = 0.0,          # §9: stop once g_t ≤ gap_tol (0 = never)
    max_seconds: Optional[float] = None,  # §9: wall-clock budget
) -> SparseFWResult:
    n, d = X_csr.shape
    y = np.asarray(y)
    h = _row_grad_np(loss, y)
    loss_obj = get_loss(loss)
    csc = X_csc if X_csc is not None else X_csr.tocsc()
    flops = 0

    # --- DP scaling (paper Alg 2 line 5, derived per core/dp/accountant.py) --
    private = queue in ("bsls", "noisy_max")
    if private:
        eps_step = per_step_epsilon(epsilon, delta, steps)
        em_scale = eps_step * n / (2.0 * loss_obj.lipschitz)   # logits per |α|
        lap_b = fw_noise_scale(epsilon=epsilon, delta=delta, steps=steps,
                               lam=lam, lipschitz=loss_obj.lipschitz, n_rows=n)
    else:
        em_scale, lap_b = 0.0, 0.0

    # --- state ---------------------------------------------------------------
    w = np.zeros(d)            # stored w̃
    w_m = 1.0
    g_tilde = 0.0
    ybar = X_csr.rmatvec(y) / n
    flops += 2 * X_csr.nnz + d

    vbar = np.zeros(n)         # stored v̄ (true = w_m·v̄)
    qbar = h(np.zeros(n), slice(None))   # q̄ = h(0) at w = 0
    z0 = X_csr.rmatvec(qbar) / n
    # separable: α = Xᵀq̄/N − ȳ; label-coupled: q̄ already carries the label
    alpha = z0 - ybar if loss_obj.separable else z0
    flops += 2 * X_csr.nnz + n + 2 * d

    # --- queue ----------------------------------------------------------------
    if queue == "fib_heap":
        Q = FibHeapQueue(d, magnitude=lambda j: abs(alpha[j]))
        Q.add_all(np.abs(alpha))
    elif queue == "argmax":
        Q = ExactArgmaxQueue(d)
        Q.add_all(np.abs(alpha))
    elif queue == "noisy_max":
        Q = NoisyMaxQueue(d, noise_scale=lap_b / lam, seed=seed)  # |α| units
        Q.add_all(np.abs(alpha))
    elif queue == "bsls":
        Q = BSLSSampler(np.abs(alpha) * em_scale, seed=seed)
    else:
        raise ValueError(f"unknown queue {queue!r}")

    gaps = np.zeros(steps)
    coords = np.zeros(steps, dtype=np.int64)

    indptr, indices, data = X_csr.indptr, X_csr.indices, X_csr.data
    scale = em_scale if private else 1.0

    stop_step, stop_reason = steps, "max_steps"
    t_start = time.perf_counter()
    for t in range(1, steps + 1):
        # line 15: select coordinate
        if queue == "bsls":
            j = Q.sample_fast() if fast else Q.sample()
        else:
            j = Q.get_next()
        # lines 16-17: direction coordinate and gap
        d_tilde = -lam * np.sign(alpha[j]) if alpha[j] != 0 else lam
        g_t = g_tilde - d_tilde * alpha[j]
        gaps[t - 1] = g_t
        coords[t - 1] = j
        # lines 18-21: scale update + single-coordinate write
        eta = 2.0 / (t + 2.0)
        w_m *= (1.0 - eta)
        w[j] += eta * d_tilde / w_m
        g_tilde = g_tilde * (1.0 - eta) + eta * d_tilde * alpha[j]
        flops += 8
        # lines 22-28: propagate through rows holding feature j
        rows, xvals = csc.col(j)
        if fast:
            # vectorized over the column's rows — identical arithmetic to the
            # per-row loop below (rows are unique; α adds commute), the per-
            # element work moved from the interpreter to the vector unit.
            vbar[rows] += eta * d_tilde * xvals / w_m            # line 23
            gamma = h(w_m * vbar[rows], rows) - qbar[rows]       # line 24
            qbar[rows] += gamma                                  # line 25
            starts, ends = indptr[rows], indptr[rows + 1]
            sizes = (ends - starts).astype(np.int64)
            total = int(sizes.sum())
            if total:
                # ragged gather: flat positions of every touched row's nnz
                seg0 = np.repeat(starts - np.concatenate(
                    ([0], np.cumsum(sizes)[:-1])), sizes)
                flat = seg0 + np.arange(total)
                cols_f = indices[flat]
                contrib = np.repeat(gamma / n, sizes) * data[flat]
                np.add.at(alpha, cols_f, contrib)                # line 26
                g_tilde += w_m * float(contrib @ w[cols_f])      # line 27
                touched_idx = np.unique(cols_f)
                Q.update_batch(touched_idx,
                               np.abs(alpha[touched_idx]) * scale)  # line 29
            flops += 6 * rows.shape[0] + 4 * total
        else:
            touched: dict = {}
            for i_idx in range(rows.shape[0]):
                i = rows[i_idx]
                x_ij = xvals[i_idx]
                vbar[i] += eta * d_tilde * x_ij / w_m          # line 23
                gamma = h(w_m * vbar[i], i) - qbar[i]          # line 24 (q̄⁽ⁱ⁾)
                qbar[i] += gamma                               # line 25
                r_idx, r_val = X_csr.row(i)
                contrib = (gamma / n) * r_val
                alpha[r_idx] += contrib                        # line 26
                g_tilde += (gamma / n) * float(r_val @ w[r_idx]) * w_m  # line 27
                flops += 6 + 4 * r_idx.shape[0]
                for jj in r_idx:
                    touched[int(jj)] = None
            # line 29: push refreshed priorities for every gradient updated
            for k in touched:
                Q.update(k, abs(alpha[k]) * scale)

        # ---- §9 early stopping: the certificate-producing step t stays
        # applied; the break matches the device scans' masked freeze exactly.
        # The comparison is made at float32 — the precision of the reported
        # gap trace and of the device engines — so the stopping decision is
        # a pure function of the gaps a caller can observe.
        if gap_tol > 0 and np.float32(g_t) <= np.float32(gap_tol):
            stop_step, stop_reason = t, "gap_tol"
            break
        if max_seconds is not None and time.perf_counter() - t_start >= max_seconds:
            stop_step, stop_reason = t, "max_seconds"
            break

    if stop_step < steps:
        coords[stop_step:] = -1        # sentinel, matching the device scans

    w_true = w * w_m
    pops = Q.pops if isinstance(Q, FibHeapQueue) else None
    return SparseFWResult(
        w=w_true, gaps=gaps, coords=coords, flops=flops,
        queue_work=getattr(Q, "work", 0) or getattr(Q, "items_scanned", 0),
        pops=pops, stop_step=stop_step, stop_reason=stop_reason,
    )


def sparse_fw_flops_estimate(n: int, d: int, nnz: int, steps: int,
                             s_r: float, s_c: float, w_nnz: int) -> int:
    """Analytic complexity of Alg 2+3: O(N·S_c + T‖w*‖₀log D + T·S_r·S_c)."""
    setup = 4 * nnz
    per_iter = int(s_r * (6 + 4 * s_c)) + int(3 * w_nnz * math.log2(max(d, 2)))
    return setup + steps * per_iter
