"""Privacy accounting for DP Frank-Wolfe (paper §B.2).

The paper composes T exponential-mechanism (equivalently, Laplace
report-noisy-max) selections.  Each selection scores every L1-ball vertex
``s ∈ {±λ e_j}`` with ``u(j) = <s, ∇L(w; D)>`` whose sensitivity is

    Δu = L · λ / N

(L = L1-Lipschitz constant of the loss, λ = L1 radius, N = dataset rows).
Advanced composition over T steps with target (ε, δ) gives the per-step pure
budget

    ε' = ε / sqrt(8 · T · log(1/δ)).

The Laplace report-noisy-max implementation draws
``Lap(2Δu/ε') = Lap(2λL·sqrt(8T log(1/δ)) / (N·ε))`` per coordinate — the
paper's Algorithm 1 writes the equivalent
``Lap(λL·sqrt(8T log(1/δ))/(N·ε))`` on the *halved* exponent convention; we
keep scale/2 vs scale consistent through ``fw_noise_scale`` so both the dense
baseline and the BSLS sampler draw from the same mechanism.
"""
from __future__ import annotations

import dataclasses
import math


def per_step_epsilon(epsilon: float, delta: float, steps: int) -> float:
    """ε' from advanced composition: ε = 2ε'·sqrt(2T·log(1/δ))."""
    if epsilon <= 0 or not (0 < delta < 1) or steps <= 0:
        raise ValueError("need ε>0, 0<δ<1, T>0")
    return epsilon / math.sqrt(8.0 * steps * math.log(1.0 / delta))


def em_log_weight_scale(
    *, epsilon: float, delta: float, steps: int, n_rows: int, lipschitz: float
) -> float:
    """Log-weight scale of the per-step exponential mechanism: ε'·N/(2L).

    Every DP selection path scores coordinate j with ``scale · |α_j|`` where
    ``scale = ε'·N/(2L)`` (utility sensitivity L/N at per-step budget ε' from
    advanced composition).  This is the single place that formula lives:
    ``jax_sparse.em_scale_for`` (single-device two-level sampler) and the
    ``jax_shard`` distributed Gumbel-max both call it, so the (ε, δ, T) →
    scale semantics of the two engines can never drift — pinned in
    ``tests/test_jax_shard.py``.
    """
    return per_step_epsilon(epsilon, delta, steps) * n_rows / (2.0 * lipschitz)


def fw_noise_scale(
    *, epsilon: float, delta: float, steps: int, lam: float, lipschitz: float, n_rows: int
) -> float:
    """Scale b of the per-coordinate Laplace noise for report-noisy-max.

    Matches the paper's Algorithm 1 annotation:
        b = λ·L·sqrt(8·T·log(1/δ)) / (N·ε)
    which equals Δu / ε' with Δu = λL/N and ε' from advanced composition.
    """
    eps_step = per_step_epsilon(epsilon, delta, steps)
    sensitivity = lam * lipschitz / n_rows
    return sensitivity / eps_step


@dataclasses.dataclass
class PrivacyAccountant:
    """Tracks cumulative privacy spend across FW runs / restarts.

    Frameworks restart from checkpoints; the accountant is serialized with the
    training state so a resumed run cannot silently exceed its budget.
    """

    epsilon: float
    delta: float
    total_steps: int
    spent_steps: int = 0

    def __post_init__(self):
        self.per_step = per_step_epsilon(self.epsilon, self.delta, self.total_steps)

    def spend(self, steps: int = 1) -> None:
        if self.spent_steps + steps > self.total_steps:
            raise RuntimeError(
                f"privacy budget exhausted: {self.spent_steps}+{steps} > {self.total_steps}"
            )
        self.spent_steps += steps

    @property
    def remaining_steps(self) -> int:
        return self.total_steps - self.spent_steps

    def spent_epsilon(self) -> float:
        """ε consumed so far under advanced composition at the planned T."""
        if self.spent_steps == 0:
            return 0.0
        return 2.0 * self.per_step * math.sqrt(2.0 * self.spent_steps * math.log(1.0 / self.delta))

    def to_state(self) -> dict:
        return dict(
            epsilon=self.epsilon,
            delta=self.delta,
            total_steps=self.total_steps,
            spent_steps=self.spent_steps,
        )

    @classmethod
    def from_state(cls, state: dict) -> "PrivacyAccountant":
        return cls(**state)
