from repro.core.dp.accountant import PrivacyAccountant, fw_noise_scale, per_step_epsilon  # noqa: F401
from repro.core.dp.mechanisms import (  # noqa: F401
    exponential_mechanism_probs,
    gumbel_argmax,
    laplace_noisy_argmax,
)
