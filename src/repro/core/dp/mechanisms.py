"""DP selection mechanisms used by Frank-Wolfe coordinate selection.

Three equivalent-in-law implementations of private argmax over scores u(j):

* ``laplace_noisy_argmax`` — report-noisy-max with Laplace noise (the paper's
  Algorithm 1 annotation; pure-DP per step).
* ``exponential_mechanism_probs`` — the exact softmax law
  P(j) ∝ exp(ε'·u(j) / (2Δu)); used as the oracle distribution in tests.
* ``gumbel_argmax`` — samples the exponential mechanism exactly via the
  Gumbel-max trick (argmax_j s_j + G_j with G_j ~ Gumbel(0,1) samples
  softmax(s)); this is the TPU-native dense path: one vectorized pass,
  no sequential stream.

The BSLS sampler (core/samplers) samples the *same law* with O(√D) work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def laplace_noisy_argmax(key: jax.Array, scores: jnp.ndarray, noise_scale: float) -> jnp.ndarray:
    """Report-noisy-max: argmax_j scores_j + Lap(noise_scale)."""
    u = jax.random.uniform(key, scores.shape, minval=-0.5 + 1e-12, maxval=0.5)
    lap = -noise_scale * jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))
    return jnp.argmax(scores + lap)


def exponential_mechanism_probs(scores: jnp.ndarray, eps_step: float, sensitivity: float) -> jnp.ndarray:
    """Exact selection probabilities of the exponential mechanism."""
    logits = scores * (eps_step / (2.0 * sensitivity))
    return jax.nn.softmax(logits)


def em_logits(scores: jnp.ndarray, eps_step: float, sensitivity: float) -> jnp.ndarray:
    """Log-scale weights fed to samplers: ε'·u/(2Δu)."""
    return scores * (eps_step / (2.0 * sensitivity))


def gumbel_argmax(key: jax.Array, logits: jnp.ndarray) -> jnp.ndarray:
    """Exact softmax sampling via Gumbel-max; logits already scaled by ε'/(2Δu)."""
    g = jax.random.gumbel(key, logits.shape)
    return jnp.argmax(logits + g)
