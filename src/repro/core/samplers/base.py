"""Queue protocol for Frank-Wolfe coordinate selection (paper Alg 2 line 6).

A queue sees *scores* (non-negative priorities, already scaled for the DP
mechanism where applicable) and answers ``get_next()`` — the coordinate to
update.  The two brute-force queues below are the paper's ablation baselines
("Alg. 2" column of Table 3 = sparse updates + O(D) noisy-max selection).
"""
from __future__ import annotations

from typing import Iterable, Protocol, Tuple

import numpy as np


class Queue(Protocol):
    def add(self, j: int, priority: float) -> None: ...
    def update(self, j: int, priority: float) -> None: ...
    def get_next(self) -> int: ...
    # cost counters for the benchmark harness
    work: int


class ExactArgmaxQueue:
    """Non-private O(D) argmax over live priorities (dense baseline queue)."""

    def __init__(self, d: int):
        self.p = np.zeros(d)
        self.work = 0

    def add(self, j: int, priority: float) -> None:
        self.p[j] = priority

    def add_all(self, priorities: np.ndarray) -> None:
        self.p[:] = priorities

    def update(self, j: int, priority: float) -> None:
        self.p[j] = priority
        self.work += 1

    def get_next(self) -> int:
        self.work += self.p.shape[0]
        return int(np.argmax(self.p))


class NoisyMaxQueue:
    """Laplace report-noisy-max over live priorities — O(D) per call.

    This is the paper's "Alg. 2 (noisy-max ablation)": sparse state updates
    but brute-force private selection.  ``noise_scale`` is the Laplace b from
    ``core.dp.accountant.fw_noise_scale`` (priorities are the λ|α| scores).
    """

    def __init__(self, d: int, noise_scale: float, seed: int = 0):
        self.p = np.zeros(d)
        self.b = float(noise_scale)
        self.rng = np.random.default_rng(seed)
        self.work = 0

    def add(self, j: int, priority: float) -> None:
        self.p[j] = priority

    def add_all(self, priorities: np.ndarray) -> None:
        self.p[:] = priorities

    def update(self, j: int, priority: float) -> None:
        self.p[j] = priority
        self.work += 1

    def get_next(self) -> int:
        d = self.p.shape[0]
        self.work += d
        noise = self.rng.laplace(0.0, self.b, size=d) if self.b > 0 else 0.0
        return int(np.argmax(self.p + noise))


def batch_update(queue, updates: Iterable[Tuple[int, float]]) -> None:
    for j, v in updates:
        queue.update(j, v)


# vectorized batch updates (the host fast path in fw_sparse uses these; the
# per-item ``update`` remains for the faithful line-by-line variant)
def _dense_update_batch(self, idx: np.ndarray, priorities: np.ndarray) -> None:
    self.p[idx] = priorities
    self.work += int(idx.shape[0])


ExactArgmaxQueue.update_batch = _dense_update_batch
NoisyMaxQueue.update_batch = _dense_update_batch
