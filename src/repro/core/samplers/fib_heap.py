"""Fibonacci heap + the paper's Algorithm 3 queue (non-private selection).

The heap is a textbook Fibonacci min-heap (O(1) amortized insert /
decrease-key, O(log n) amortized extract-min).  Algorithm 3 keys items on the
*negated* gradient magnitude and only ever decreases keys (i.e. only reacts
when |α⁽ʲ⁾| grows), so stored priorities are stale **upper bounds** on the
true magnitude.  ``get_next`` pops until the best live magnitude seen beats
the next stale bound — correct because bounds only overestimate.

This structure is pointer-chasing and inherently host-side; it is the
deterministic oracle for the TPU-adapted lazy group-argmax
(``samplers/group_argmax.py``) per DESIGN.md §2.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np


class _Node:
    __slots__ = ("key", "item", "parent", "child", "left", "right", "degree", "mark")

    def __init__(self, key: float, item: int):
        self.key = key
        self.item = item
        self.parent: Optional[_Node] = None
        self.child: Optional[_Node] = None
        self.left = self
        self.right = self
        self.degree = 0
        self.mark = False


class FibonacciHeap:
    """Min-heap over (key, item) with decrease_key."""

    def __init__(self):
        self.min: Optional[_Node] = None
        self.n = 0
        self.nodes: Dict[int, _Node] = {}

    def __len__(self) -> int:
        return self.n

    def __contains__(self, item: int) -> bool:
        return item in self.nodes

    def key_of(self, item: int) -> float:
        return self.nodes[item].key

    # -- root-list helpers ---------------------------------------------------
    @staticmethod
    def _splice(a: _Node, b: _Node) -> None:
        """Insert node b into a's circular list (after a)."""
        b.left = a
        b.right = a.right
        a.right.left = b
        a.right = b

    @staticmethod
    def _remove(x: _Node) -> None:
        x.left.right = x.right
        x.right.left = x.left
        x.left = x.right = x

    # -- public ops ----------------------------------------------------------
    def insert(self, item: int, key: float) -> None:
        if item in self.nodes:
            raise KeyError(f"item {item} already present")
        node = _Node(key, item)
        self.nodes[item] = node
        if self.min is None:
            self.min = node
        else:
            self._splice(self.min, node)
            if key < self.min.key:
                self.min = node
        self.n += 1

    def peek(self):
        if self.min is None:
            return None
        return self.min.key, self.min.item

    def extract_min(self):
        z = self.min
        if z is None:
            return None
        # promote children to root list
        if z.child is not None:
            children: List[_Node] = []
            c = z.child
            while True:
                children.append(c)
                c = c.right
                if c is z.child:
                    break
            for c in children:
                self._remove(c)
                c.parent = None
                c.mark = False
                self._splice(z, c)
            z.child = None
        if z.right is z:  # only root (children, if any, were already promoted)
            self.min = None
        else:
            self.min = z.right
            self._remove(z)
            self._consolidate()
        self.n -= 1
        del self.nodes[z.item]
        return z.key, z.item

    def _consolidate(self) -> None:
        import math

        max_degree = int(math.log2(max(self.n, 2))) + 2
        buckets: List[Optional[_Node]] = [None] * (max_degree + 2)
        roots: List[_Node] = []
        c = self.min
        while True:
            roots.append(c)
            c = c.right
            if c is self.min:
                break
        for x in roots:
            d = x.degree
            while d < len(buckets) and buckets[d] is not None:
                y = buckets[d]
                if y.key < x.key:
                    x, y = y, x
                # make y a child of x
                self._remove(y)
                y.parent = x
                y.mark = False
                if x.child is None:
                    x.child = y
                    y.left = y.right = y
                else:
                    self._splice(x.child, y)
                x.degree += 1
                buckets[d] = None
                d = x.degree
            if d >= len(buckets):
                buckets.extend([None] * (d - len(buckets) + 1))
            buckets[d] = x
        # rebuild root list & min pointer
        self.min = None
        for b in buckets:
            if b is None:
                continue
            b.left = b.right = b
            if self.min is None:
                self.min = b
            else:
                self._splice(self.min, b)
                if b.key < self.min.key:
                    self.min = b

    def decrease_key(self, item: int, key: float) -> None:
        x = self.nodes[item]
        if key > x.key:
            raise ValueError("new key larger than current key")
        x.key = key
        y = x.parent
        if y is not None and x.key < y.key:
            self._cut(x, y)
            self._cascading_cut(y)
        if x.key < self.min.key:
            self.min = x

    def _cut(self, x: _Node, y: _Node) -> None:
        if x.right is x:
            y.child = None
        else:
            if y.child is x:
                y.child = x.right
            self._remove(x)
        y.degree -= 1
        x.parent = None
        x.mark = False
        self._splice(self.min, x)

    def _cascading_cut(self, y: _Node) -> None:
        z = y.parent
        if z is None:
            return
        if not y.mark:
            y.mark = True
        else:
            self._cut(y, z)
            self._cascading_cut(z)


class FibHeapQueue:
    """Paper Algorithm 3: lazy stale-upper-bound queue over |α| magnitudes.

    ``magnitude(j)`` must return the *live* |α⁽ʲ⁾| (the queue stores stale
    bounds).  Keys are negated magnitudes (min-heap → max-magnitude first).
    """

    def __init__(self, d: int, magnitude: Callable[[int], float]):
        self.heap = FibonacciHeap()
        self.magnitude = magnitude
        self.d = d
        self.pops = 0          # Fig. 3 accounting: total pops across calls
        self.calls = 0
        self.work = 0          # comparable "touched items" counter

    def add(self, j: int, priority: float) -> None:
        self.heap.insert(j, -priority)

    def add_all(self, priorities: np.ndarray) -> None:
        for j in range(self.d):
            self.add(j, float(priorities[j]))

    def update(self, j: int, priority: float) -> None:
        """Only decrease keys (= increase priority bound); else leave stale."""
        self.work += 1
        key = -priority
        if j in self.heap:
            if key < self.heap.key_of(j):
                self.heap.decrease_key(j, key)
        else:  # item was popped and not yet re-inserted (shouldn't happen mid-iteration)
            self.heap.insert(j, key)

    def get_next(self) -> int:
        self.calls += 1
        best_j = -1
        best_mag = -np.inf
        popped: List[int] = []
        while True:
            top = self.heap.extract_min()
            self.pops += 1
            self.work += 1
            if top is None:
                break
            _, c = top
            popped.append(c)
            mag_c = self.magnitude(c)
            if mag_c > best_mag:
                best_mag = mag_c
                best_j = c
            nxt = self.heap.peek()
            if nxt is None or best_mag >= -nxt[0]:
                break
        # re-insert popped items with fresh (live) priorities
        for c in popped:
            self.heap.insert(c, -self.magnitude(c))
        return best_j


def _fib_update_batch(self, idx, priorities) -> None:
    """Per-item under the hood — a pointer heap has no vector form; kept so
    the fast fw_sparse path can treat all queues uniformly."""
    for j, v in zip(idx, priorities):
        self.update(int(j), float(v))


FibHeapQueue.update_batch = _fib_update_batch
