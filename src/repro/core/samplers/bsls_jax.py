"""TPU-adapted Big-Step Little-Step sampler (DESIGN.md §2).

The paper's Alg 4 walks a weighted-reservoir stream with cache-friendly group
skipping — a CPU trick.  The *math* it implements is: sample j with
P(j) ∝ exp(v_j), using per-group log-sum-exps as a two-level decomposition.
On TPU we sample that decomposition directly:

    P(j) = P(group g)·P(j | g) = softmax(c)_g · softmax(v_g)_j

with one Gumbel-max over the ``G = ⌈√D⌉`` group masses (a "big step") and one
Gumbel-max over the ``M = ⌈D/G⌉`` members of the chosen group (the "little
steps").  Both are O(√D) dense vector scans that the VPU runs at line rate;
there is no data-dependent control flow, so the whole FW iteration stays
inside one ``lax.scan``.

State updates after a FW iteration touch ``S_c`` coordinates: we scatter the
new log-weights and recompute the affected groups' log-sum-exps via a masked
segment reduction — O(touched·M) lanes, exact (no incremental drift at all,
which is *stronger* than the paper's O(1) updates; on TPU the vector rebuild
is cheaper than scalar bookkeeping).

Law-exactness is by construction (law of total probability); tested by
chi-square against ``exponential_mechanism_probs`` and against the faithful
``BSLSSampler``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TwoLevelSamplerState:
    v: jnp.ndarray   # (G, M) log-weights, padded with NEG_INF
    c: jnp.ndarray   # (G,)   per-group log-sum-exp
    d: int           # true number of items (static)

    def tree_flatten(self):
        return (self.v, self.c), self.d

    @classmethod
    def tree_unflatten(cls, d, leaves):
        return cls(*leaves, d=d)

    @property
    def groups(self) -> int:
        return self.v.shape[0]

    @property
    def group_size(self) -> int:
        return self.v.shape[1]


def _group_shape(d: int) -> Tuple[int, int]:
    g = max(1, math.isqrt(max(d - 1, 0)) + 1)  # ⌈√D⌉ groups
    m = (d + g - 1) // g
    return g, m


def tl_init(log_weights: jnp.ndarray) -> TwoLevelSamplerState:
    d = log_weights.shape[0]
    g, m = _group_shape(d)
    v = jnp.full((g * m,), NEG_INF, log_weights.dtype).at[:d].set(log_weights)
    v = v.reshape(g, m)
    c = jax.scipy.special.logsumexp(v, axis=1)
    return TwoLevelSamplerState(v=v, c=c, d=d)


def tl_sample(state: TwoLevelSamplerState, key: jax.Array) -> jnp.ndarray:
    """Draw j ~ softmax(v) via group-then-member Gumbel-max.  O(G + M)."""
    kg, km = jax.random.split(key)
    g = jnp.argmax(state.c + jax.random.gumbel(kg, state.c.shape))
    row = jnp.take(state.v, g, axis=0)
    j_in = jnp.argmax(row + jax.random.gumbel(km, row.shape))
    return g * state.group_size + j_in


def tl_update(
    state: TwoLevelSamplerState, idx: jnp.ndarray, new_log_weights: jnp.ndarray
) -> TwoLevelSamplerState:
    """Scatter new log-weights for ``idx`` (may contain duplicates/padding
    marked by idx >= d → dropped) and rebuild affected group sums exactly.

    For simplicity and exactness we recompute all G group log-sum-exps; the
    (G, M) logsumexp is one O(D) vector pass — only done once per FW
    iteration, versus O(√D) per *draw*, so the iteration stays sub-linear in
    wall terms that matter (the draw path) while updates remain a single
    fused reduction.  The Pallas kernel variant (kernels/bsls) tiles this.
    """
    m = state.group_size
    valid = idx < state.d
    safe_idx = jnp.where(valid, idx, 0)
    vals = jnp.where(valid, new_log_weights, state.v.reshape(-1)[safe_idx])
    v = state.v.reshape(-1).at[safe_idx].set(vals).reshape(state.v.shape)
    # exact rebuild of touched groups only (mask others to keep their old c).
    # NOTE: scatter must be .max (logical or), not .set — with duplicate
    # group ids a later invalid lane would overwrite a valid one.
    touched = jnp.zeros((state.groups,), bool).at[safe_idx // m].max(valid)
    c_new = jax.scipy.special.logsumexp(v, axis=1)
    c = jnp.where(touched, c_new, state.c)
    return TwoLevelSamplerState(v=v, c=c, d=state.d)


def tl_exact_probs(state: TwoLevelSamplerState) -> jnp.ndarray:
    flat = state.v.reshape(-1)[: state.d]
    return jax.nn.softmax(flat)
