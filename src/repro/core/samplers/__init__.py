from repro.core.samplers.base import (  # noqa: F401
    ExactArgmaxQueue,
    NoisyMaxQueue,
    Queue,
)
from repro.core.samplers.fib_heap import FibonacciHeap, FibHeapQueue  # noqa: F401
from repro.core.samplers.bsls import BSLSSampler  # noqa: F401
from repro.core.samplers.bsls_jax import TwoLevelSamplerState, tl_init, tl_sample, tl_update  # noqa: F401
from repro.core.samplers.group_argmax import GroupArgmaxState, ga_init, ga_get_next, ga_update  # noqa: F401
