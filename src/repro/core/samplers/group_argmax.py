"""TPU-native equivalent of the Fibonacci-heap queue (paper Alg 3).

The heap's insight — priorities may go stale as long as they only
*overestimate*, with lazy repair on pop — transfers to a flat two-level
structure: per-group stale maxima ``m_g`` (upper bounds on the group's true
max |α|).  ``get_next``:

  1. pick g* = argmax m_g          (O(√D))
  2. true max inside g*            (O(√D)), repair m_{g*} to the truth
  3. if the repaired m_{g*} still beats every other bound → done, else loop.

Exactly like Alg 3, each repair can only lower a bound, and the loop ends
when the best *verified* value dominates all remaining (over-)estimates — so
the returned index is the exact argmax.  Expected pops mirror the paper's
≤ 3‖w*‖₀ observation because only coordinates whose gradients grew carry
fresh bounds.

Updates are increase-only (O(1) scatter-max); decreases are ignored — that is
what makes the bounds stale-but-safe.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GroupArgmaxState:
    p: jnp.ndarray      # (G, M) live priorities (|α| magnitudes), padded NEG_INF
    bound: jnp.ndarray  # (G,)   stale upper bounds on each group's max
    d: int

    def tree_flatten(self):
        return (self.p, self.bound), self.d

    @classmethod
    def tree_unflatten(cls, d, leaves):
        return cls(*leaves, d=d)

    @property
    def group_size(self) -> int:
        return self.p.shape[1]


def ga_init(priorities: jnp.ndarray) -> GroupArgmaxState:
    d = priorities.shape[0]
    g = max(1, math.isqrt(max(d - 1, 0)) + 1)
    m = (d + g - 1) // g
    p = jnp.full((g * m,), NEG_INF, priorities.dtype).at[:d].set(priorities).reshape(g, m)
    return GroupArgmaxState(p=p, bound=jnp.max(p, axis=1), d=d)


def ga_update(state: GroupArgmaxState, idx: jnp.ndarray, priorities: jnp.ndarray) -> GroupArgmaxState:
    """Scatter live priorities; bounds only ratchet upward (stale-safe)."""
    m = state.group_size
    valid = idx < state.d
    safe_idx = jnp.where(valid, idx, 0)
    vals = jnp.where(valid, priorities, state.p.reshape(-1)[safe_idx])
    p = state.p.reshape(-1).at[safe_idx].set(vals).reshape(state.p.shape)
    bound = state.bound.at[safe_idx // m].max(jnp.where(valid, vals, NEG_INF))
    return GroupArgmaxState(p=p, bound=bound, d=state.d)


def ga_get_next(state: GroupArgmaxState) -> Tuple[jnp.ndarray, GroupArgmaxState]:
    """Lazy-repair argmax; returns (flat index, state with repaired bounds)."""

    def cond(carry):
        bound, _best_j, best_v, _pops = carry
        return jnp.max(bound) > best_v

    def body(carry):
        bound, best_j, best_v, pops = carry
        g = jnp.argmax(bound)
        row = jnp.take(state.p, g, axis=0)
        j_in = jnp.argmax(row)
        true_max = row[j_in]
        bound = bound.at[g].set(true_max)  # repair: bound → truth
        better = true_max > best_v
        best_j = jnp.where(better, g * state.group_size + j_in, best_j)
        best_v = jnp.where(better, true_max, best_v)
        return bound, best_j, best_v, pops + 1

    init = (state.bound, jnp.array(-1, jnp.int32), jnp.array(NEG_INF, state.p.dtype),
            jnp.array(0, jnp.int32))
    bound, best_j, _best_v, _pops = jax.lax.while_loop(cond, body, init)
    return best_j, GroupArgmaxState(p=state.p, bound=bound, d=state.d)
