"""Algorithm 4 — the Big-Step Little-Step exponential-mechanism sampler.

Draws ``j ~ P(j) ∝ exp(v_j)`` over D fixed items (v = EM log-weights,
i.e. ε'·score/(2Δu)) in ``O(√D log D)`` expected time per draw with ``O(1)``
weight updates, by running the A-ExpJ weighted-reservoir walk of Efraimidis &
Spirakis over the item stream and skipping whole groups whose total mass lies
below the current jump target ("big steps"), descending to items only inside
the group where the jump lands ("little steps").

All state is log-scale (paper §3.3): per-group log-sum-exps ``c`` and the
global log-sum ``z_Σ``; every exponentiation subtracts ``z_Σ`` (log-sum-exp
trick) so weights live in (0, 1].  Incremental O(1) updates can suffer
catastrophic cancellation when a group's dominant item shrinks, so — as a
production hardening the paper's Java artifact handles implicitly via exact
recomputation thresholds — each group tracks an error budget and is rebuilt
exactly when it degrades (counted in ``rebuilds``; amortized O(1)).

The sampler is *law-exact*: A-ExpJ's single-reservoir walk returns an index
with probability exactly proportional to its weight, and group skipping only
changes the order in which cumulative mass is accounted, not the crossing
point.  Validated against ``exponential_mechanism_probs`` by chi-square in
tests/test_samplers.py.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

_TINY = 1e-15  # paper footnote 4: floor so every item keeps a nonzero chance


class BSLSSampler:
    """Big-Step Little-Step sampler over fixed log-weights.

    Args:
      log_weights: (D,) initial log-scale priorities (EM logits).
      seed: RNG seed.
      group_size: members per group; default ⌈√D⌉ (paper: √D groups of √D).
    """

    def __init__(self, log_weights: np.ndarray, seed: int = 0, group_size: Optional[int] = None):
        v = np.asarray(log_weights, dtype=np.float64).copy()
        self.d = v.shape[0]
        self.m = int(group_size or max(1, math.isqrt(self.d - 1) + 1))  # ⌈√D⌉
        self.g = (self.d + self.m - 1) // self.m
        # pad to full groups with -inf (zero weight)
        pad = self.g * self.m - self.d
        if pad:
            v = np.concatenate([v, np.full(pad, -np.inf)])
        self.v = v
        self.rng = np.random.default_rng(seed)
        self.c = np.empty(self.g)           # per-group log-sum-exp
        self.z = 0.0                        # global log-sum-exp z_Σ
        self._err = np.zeros(self.g)        # cancellation budget per group
        self.rebuilds = 0
        self.items_scanned = 0              # little-step cost counter
        self.groups_stepped = 0             # big-step cost counter
        self.draws = 0
        self._rebuild_all()

    # -- log-sum-exp maintenance ----------------------------------------------
    def _group_lse(self, k: int) -> float:
        seg = self.v[k * self.m : (k + 1) * self.m]
        hi = np.max(seg)
        if not np.isfinite(hi):
            return -np.inf
        return hi + math.log(np.sum(np.exp(seg - hi)))

    def _rebuild_all(self) -> None:
        for k in range(self.g):
            self.c[k] = self._group_lse(k)
        finite = self.c[np.isfinite(self.c)]
        hi = np.max(finite)
        self.z = hi + math.log(np.sum(np.exp(finite - hi)))
        self._err[:] = 0.0

    def _rebuild_group(self, k: int) -> None:
        self.rebuilds += 1
        old = self.c[k]
        self.c[k] = self._group_lse(k)
        self._err[k] = 0.0
        # refresh z from group sums (O(√D)); keeps z consistent with c
        finite = self.c[np.isfinite(self.c)]
        hi = np.max(finite)
        self.z = hi + math.log(np.sum(np.exp(finite - hi)))
        del old

    def update(self, i: int, new_log_weight: float) -> None:
        """O(1) amortized: log-scale add/subtract on the group and global sums
        (paper Alg 4 lines 31-36)."""
        if not (0 <= i < self.d):
            raise IndexError(i)
        v_cur = self.v[i]
        v_new = float(new_log_weight)
        if v_new == v_cur:
            return
        self.v[i] = v_new
        k = i // self.m
        ck = self.c[k]
        if not np.isfinite(ck):
            # group previously empty-weight; new value defines it
            self._rebuild_group(k)
            return
        # c_k' = log( exp(c_k) - exp(v_cur) + exp(v_new) )  at c_k scale
        delta = -_safe_exp(v_cur - ck) + _safe_exp(v_new - ck)
        arg = 1.0 + delta
        self._err[k] += abs(delta)
        if arg <= 1e-9 or self._err[k] > 1e6:
            self._rebuild_group(k)
            return
        dck = math.log(arg)
        self.c[k] = ck + dck
        # z update with the same trick
        dz = -_safe_exp(v_cur - self.z) + _safe_exp(v_new - self.z)
        argz = 1.0 + dz
        if argz <= 1e-9:
            self._rebuild_all()
            self.rebuilds += 1
            return
        self.z = self.z + math.log(argz)

    # -- sampling ---------------------------------------------------------------
    def sample(self) -> int:
        """One A-ExpJ pass over the D items with group skipping.

        Weights w_i = exp(v_i - z_Σ) ∈ (0,1], Σ w_i = 1 (up to drift).  The
        walk keeps a current winner ``j`` with threshold key ``T_w`` and an
        exponential jump ``X_w`` of cumulative weight to skip before the next
        winner change; groups whose remaining mass < X_w are skipped whole.
        """
        self.draws += 1
        rng = self.rng
        v, c, z, m = self.v, self.c, self.z, self.m

        def w_item(i: int) -> float:
            return max(_safe_exp(v[i] - z), _TINY)

        def w_group(k: int) -> float:
            return _safe_exp(c[k] - z)

        # initialize with item 0 (paper lines 2-5)
        j = 0
        log_tw = math.log(rng.uniform(1e-300, 1.0)) / w_item(0)  # log T_w = log(U)/w_0
        i = 1                     # stream position (next unvisited item)
        o = w_item(0)             # offset: mass already consumed in group 0
        x_w = math.log(rng.uniform(1e-300, 1.0)) / log_tw  # jump mass (>0)

        while i < self.d:
            k = i // m
            in_group_pos = i - k * m
            # mass of group k not yet visited
            if in_group_pos == 0:
                o = 0.0
            rem = w_group(k) - o
            if rem < x_w:
                # ---- Big step: skip the rest of this group (lines 8-12)
                x_w -= max(rem, 0.0)
                i = (k + 1) * m
                o = 0.0
                self.groups_stepped += 1
                continue
            # ---- Little steps inside group k (lines 13-17)
            crossed = False
            while i < min((k + 1) * m, self.d):
                wi = w_item(i)
                self.items_scanned += 1
                if wi >= x_w:
                    crossed = True
                    break
                x_w -= wi
                o += wi
                i += 1
            if not crossed:
                # group mass said the jump lands here but item walk ran past the
                # end (drift between c[k] and Σ items); treat as big step
                o = 0.0
                continue
            # new winner at position i (lines 18-27)
            j = i
            wi = w_item(i)
            o += wi
            i += 1
            # fresh threshold: T_w' = U(T_w^{w_j}, 1)^{1/w_j}   (log-scale)
            t_w = math.exp(log_tw * wi)  # = T_w^{w_j} ∈ (0,1); paper line 21
            u = rng.uniform(min(t_w, 1.0 - 1e-16), 1.0)
            log_tw = math.log(max(u, 1e-300)) / wi
            x_w = math.log(rng.uniform(1e-300, 1.0)) / log_tw
        if j >= self.d:
            j = self.d - 1
        return int(j)

    # -- vectorized fast path ---------------------------------------------------
    def sample_fast(self) -> int:
        """Two-level inverse-CDF draw — the vectorized form of the Big-Step
        Little-Step walk.  The group-mass cumsum *is* the big step (whole
        groups are skipped by `searchsorted` in one vector op); the in-group
        cumsum is the little step (one linear scan of √D items).  Same law
        (P(k) ∝ exp(c_k), P(j|k) ∝ exp(v_j − c_k)) with √D-vector work per
        draw and perfect cache behavior — the paper's insight mapped to a
        vector ISA instead of a scalar CPU walk."""
        self.draws += 1
        cw = _safe_exp_vec(self.c - self.z)
        cum = np.cumsum(cw)
        self.groups_stepped += self.g
        k = min(int(np.searchsorted(cum, self.rng.uniform(0.0, cum[-1]))),
                self.g - 1)
        seg = _safe_exp_vec(self.v[k * self.m:(k + 1) * self.m] - self.c[k])
        cum2 = np.cumsum(seg)
        self.items_scanned += self.m
        j = min(int(np.searchsorted(cum2, self.rng.uniform(0.0, cum2[-1]))),
                self.m - 1)
        return int(k * self.m + j)

    def update_batch(self, idx: np.ndarray, new_log_weights: np.ndarray) -> None:
        """Exact vectorized batch update: scatter new log-weights, rebuild the
        affected groups' log-sum-exps and the global sum — no incremental
        drift at all (stronger than the paper's O(1) updates; on a vector
        unit the segment rebuild is cheaper than scalar bookkeeping)."""
        idx = np.asarray(idx, dtype=np.int64)
        self.v[idx] = np.asarray(new_log_weights, dtype=np.float64)
        groups = np.unique(idx // self.m)
        seg = self.v.reshape(self.g, self.m)[groups]          # (Gt, m)
        hi = np.max(seg, axis=1)
        finite = np.isfinite(hi)
        out = np.full(groups.shape[0], -np.inf)
        out[finite] = hi[finite] + np.log(
            np.sum(np.exp(seg[finite] - hi[finite][:, None]), axis=1))
        self.c[groups] = out
        fin = self.c[np.isfinite(self.c)]
        top = np.max(fin)
        self.z = top + math.log(np.sum(np.exp(fin - top)))

    # -- diagnostics --------------------------------------------------------------
    def exact_probs(self) -> np.ndarray:
        vv = self.v[: self.d]
        hi = np.max(vv)
        p = np.exp(vv - hi)
        return p / p.sum()

    def cost_per_draw(self) -> float:
        if self.draws == 0:
            return 0.0
        return (self.items_scanned + self.groups_stepped) / self.draws


def _safe_exp(x: float) -> float:
    if x > 700.0:
        return math.inf
    if x < -745.0:
        return 0.0
    return math.exp(x)


def _safe_exp_vec(x: np.ndarray) -> np.ndarray:
    return np.exp(np.clip(x, -745.0, 700.0))
