from repro.core.sparse.formats import (  # noqa: F401
    HostCSC,
    HostCSR,
    PaddedCSC,
    PaddedCSR,
    TieredCSC,
    coo_to_host,
    dense_to_host,
    dense_to_padded,
    tiered_from_padded,
)
