from repro.core.sparse.formats import (  # noqa: F401
    HostCSC,
    HostCSR,
    PaddedCSC,
    PaddedCSR,
    coo_to_host,
    dense_to_host,
    dense_to_padded,
)
