"""Sparse matrix containers used throughout the framework.

Two families:

* ``HostCSR`` / ``HostCSC`` — exact variable-length compressed formats in
  numpy.  These back the *faithful* sequential algorithms (paper Alg 2/3/4)
  where per-row / per-column iteration order matters and shapes may be ragged.

* ``PaddedCSR`` / ``PaddedCSC`` — fixed-shape ELL-style padded layouts in JAX
  arrays.  TPUs want static shapes and contiguous vector lanes, so each row
  (column) is padded to the max nnz; padding entries carry ``index = 0`` and
  ``value = 0`` which makes gathers safe and contributes nothing to reductions.
  This is the §Hardware-adaptation replacement for the paper's linked CSR: the
  asymptotic nnz-proportional work is preserved (padded nnz, see
  ``padding_overhead``) while every op lowers to gather / segment-sum that the
  VPU executes at line rate.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Shape = Tuple[int, int]


# ---------------------------------------------------------------------------
# Host (numpy, exact) formats
# ---------------------------------------------------------------------------


class HostCSR:
    """Compressed sparse row; numpy; exact (no padding)."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, data: np.ndarray, shape: Shape):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int64)
        self.data = np.asarray(data, dtype=np.float64)
        self.shape = (int(shape[0]), int(shape[1]))
        if self.indptr.shape != (self.shape[0] + 1,):
            raise ValueError("bad indptr length")

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.indices[lo:hi], self.data[lo:hi]

    def matvec(self, w: np.ndarray) -> np.ndarray:
        out = np.zeros(self.shape[0])
        for i in range(self.shape[0]):
            idx, val = self.row(i)
            out[i] = val @ w[idx]
        return out

    def rmatvec(self, q: np.ndarray) -> np.ndarray:
        out = np.zeros(self.shape[1])
        for i in range(self.shape[0]):
            idx, val = self.row(i)
            out[idx] += val * q[i]
        return out

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape)
        for i in range(self.shape[0]):
            idx, val = self.row(i)
            out[i, idx] = val
        return out

    def tocsc(self) -> "HostCSC":
        n, d = self.shape
        counts = np.zeros(d + 1, dtype=np.int64)
        for j in self.indices:
            counts[j + 1] += 1
        indptr = np.cumsum(counts)
        indices = np.empty(self.nnz, dtype=np.int64)
        data = np.empty(self.nnz)
        fill = indptr[:-1].copy()
        for i in range(n):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            for p in range(lo, hi):
                j = self.indices[p]
                indices[fill[j]] = i
                data[fill[j]] = self.data[p]
                fill[j] += 1
        return HostCSC(indptr, indices, data, self.shape)


class HostCSC:
    """Compressed sparse column; numpy; exact."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, data: np.ndarray, shape: Shape):
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int64)
        self.data = np.asarray(data, dtype=np.float64)
        self.shape = (int(shape[0]), int(shape[1]))
        if self.indptr.shape != (self.shape[1] + 1,):
            raise ValueError("bad indptr length")

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def col(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[j], self.indptr[j + 1]
        return self.indices[lo:hi], self.data[lo:hi]

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape)
        for j in range(self.shape[1]):
            idx, val = self.col(j)
            out[idx, j] = val
        return out


def coo_to_host(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, shape: Shape) -> HostCSR:
    """Build a HostCSR from COO triplets (duplicates are summed)."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    # merge duplicates
    if rows.size:
        keep = np.ones(rows.size, dtype=bool)
        same = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        if same.any():
            # accumulate into the first of each run
            out_r, out_c, out_v = [], [], []
            i = 0
            while i < rows.size:
                k = i + 1
                acc = vals[i]
                while k < rows.size and rows[k] == rows[i] and cols[k] == cols[i]:
                    acc += vals[k]
                    k += 1
                out_r.append(rows[i])
                out_c.append(cols[i])
                out_v.append(acc)
                i = k
            rows = np.array(out_r, dtype=np.int64)
            cols = np.array(out_c, dtype=np.int64)
            vals = np.array(out_v)
        del keep
    counts = np.bincount(rows, minlength=shape[0])
    indptr = np.concatenate([[0], np.cumsum(counts)])
    return HostCSR(indptr, cols, vals, shape)


def dense_to_host(x: np.ndarray) -> HostCSR:
    rows, cols = np.nonzero(x)
    return coo_to_host(rows, cols, x[rows, cols], x.shape)


# ---------------------------------------------------------------------------
# Padded (JAX, fixed-shape) formats
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PaddedCSR:
    """ELL-style padded CSR: ``indices/values`` are (N, K) with K = max row nnz.

    Padding: ``index = 0, value = 0`` — safe for gathers, inert in sums.
    ``nnz`` keeps true per-row counts for masked iteration and FLOP audits.
    """

    indices: jnp.ndarray  # (N, K) int32 column ids
    values: jnp.ndarray   # (N, K) float
    nnz: jnp.ndarray      # (N,)  int32
    shape: Shape          # static (N, D)

    def tree_flatten(self):
        return (self.indices, self.values, self.nnz), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    def matvec(self, w: jnp.ndarray) -> jnp.ndarray:
        """X · w — gather + row reduction; O(N·K) lanes of work."""
        return jnp.einsum("nk,nk->n", self.values, w[self.indices])

    def rmatvec(self, q: jnp.ndarray) -> jnp.ndarray:
        """Xᵀ · q — scatter-add over padded lanes; O(N·K)."""
        flat_idx = self.indices.reshape(-1)
        flat_val = (self.values * q[:, None]).reshape(-1)
        return jnp.zeros(self.shape[1], self.values.dtype).at[flat_idx].add(flat_val)

    def to_dense(self) -> jnp.ndarray:
        n, d = self.shape
        out = jnp.zeros((n, d), self.values.dtype)
        rows = jnp.broadcast_to(jnp.arange(n)[:, None], self.indices.shape)
        return out.at[rows.reshape(-1), self.indices.reshape(-1)].add(self.values.reshape(-1))

    @property
    def padding_overhead(self) -> float:
        """padded-lanes / true-nnz; 1.0 = no waste."""
        true = float(jnp.sum(self.nnz))
        return float(self.indices.size) / max(true, 1.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PaddedCSC:
    """Padded CSC: per-column row ids.  Column j's rows = ``indices[j]``."""

    indices: jnp.ndarray  # (D, K) int32 row ids
    values: jnp.ndarray   # (D, K) float
    nnz: jnp.ndarray      # (D,)  int32
    shape: Shape          # static (N, D)

    def tree_flatten(self):
        return (self.indices, self.values, self.nnz), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    def col(self, j) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Rows/values/mask of column j (traced-index friendly)."""
        idx = jnp.take(self.indices, j, axis=0)
        val = jnp.take(self.values, j, axis=0)
        k = jnp.take(self.nnz, j)
        mask = jnp.arange(idx.shape[0]) < k
        return idx, val, mask


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TieredCSC:
    """Two-tier padded CSC: the autotuner's exact-arithmetic ELL split.

    Power-law column popularity makes a single pad width pay for its tail:
    the rcv1-like regime has max column nnz ~8× its 99th percentile, so the
    flat ``PaddedCSC`` tile spends >100× the true nnz in padded lanes.  The
    tiered layout keeps a narrow ``(D, k)`` primary table for the common case
    and a full-width ``(H, K)`` heavy table holding the few columns whose
    nnz exceeds ``k`` verbatim; per-step dispatch (``lax.cond`` on the true
    column count) picks the tier.  No entry is dropped and padding stays
    ``index = 0, value = 0``, so every tile pass computes the same sums as
    the flat layout — the tuner's bitwise parity probe pins that per dataset.
    """

    indices: jnp.ndarray        # (D, k) light-tier row ids (heavy cols truncated)
    values: jnp.ndarray         # (D, k)
    nnz: jnp.ndarray            # (D,) TRUE per-column counts (never clamped)
    heavy_slot: jnp.ndarray     # (D,) int32 row in the heavy table (0 if light)
    heavy_indices: jnp.ndarray  # (H, K) full-width rows of the heavy columns
    heavy_values: jnp.ndarray   # (H, K)
    shape: Shape                # static (N, D)

    def tree_flatten(self):
        return ((self.indices, self.values, self.nnz, self.heavy_slot,
                 self.heavy_indices, self.heavy_values), self.shape)

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    @property
    def width(self) -> int:
        """Light-tier pad width k (the tuner's search knob)."""
        return int(self.indices.shape[1])

    @property
    def full_width(self) -> int:
        """Heavy-tier pad width = the flat layout's exact max column nnz."""
        return int(self.heavy_indices.shape[1])

    def is_heavy(self, j) -> jnp.ndarray:
        return jnp.take(self.nnz, j) > self.width

    def col_light(self, j) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Column j through the narrow tier (valid when nnz[j] <= width)."""
        idx = jnp.take(self.indices, j, axis=0)
        val = jnp.take(self.values, j, axis=0)
        k = jnp.take(self.nnz, j)
        mask = jnp.arange(idx.shape[0]) < k
        return idx, val, mask

    def col_heavy(self, j) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Column j through the full-width tier (exact for every column)."""
        slot = jnp.take(self.heavy_slot, j)
        idx = jnp.take(self.heavy_indices, slot, axis=0)
        val = jnp.take(self.heavy_values, slot, axis=0)
        k = jnp.take(self.nnz, j)
        mask = jnp.arange(idx.shape[0]) < k
        return idx, val, mask


def tiered_from_padded(pcsc: PaddedCSC, width: int) -> TieredCSC:
    """Split a flat ``PaddedCSC`` into the two-tier layout at ``width``.

    Exact by construction: columns with nnz <= width move to the narrow
    table unchanged (their truncated lanes were all padding); wider columns
    keep their full lanes in the heavy table and are dispatched there.
    """
    full = int(pcsc.indices.shape[1])
    width = int(width)
    if not 1 <= width < full:
        raise ValueError(f"tier width must be in [1, {full}), got {width}")
    ci = np.asarray(pcsc.indices)
    cv = np.asarray(pcsc.values)
    cn = np.asarray(pcsc.nnz)
    heavy_cols = np.flatnonzero(cn > width)
    h = max(1, heavy_cols.size)            # keep the table non-empty (jit-safe)
    heavy_idx = np.zeros((h, full), ci.dtype)
    heavy_val = np.zeros((h, full), cv.dtype)
    heavy_slot = np.zeros(ci.shape[0], np.int32)
    if heavy_cols.size:
        heavy_idx[: heavy_cols.size] = ci[heavy_cols]
        heavy_val[: heavy_cols.size] = cv[heavy_cols]
        heavy_slot[heavy_cols] = np.arange(heavy_cols.size, dtype=np.int32)
    return TieredCSC(
        indices=jnp.asarray(ci[:, :width]), values=jnp.asarray(cv[:, :width]),
        nnz=jnp.asarray(cn), heavy_slot=jnp.asarray(heavy_slot),
        heavy_indices=jnp.asarray(heavy_idx),
        heavy_values=jnp.asarray(heavy_val), shape=pcsc.shape)


def _pad_rows(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray, n_major: int, k: int):
    out_idx = np.zeros((n_major, k), dtype=np.int32)
    out_val = np.zeros((n_major, k), dtype=np.float32)
    nnz = np.diff(indptr).astype(np.int32)
    for i in range(n_major):
        lo, hi = indptr[i], indptr[i + 1]
        out_idx[i, : hi - lo] = indices[lo:hi]
        out_val[i, : hi - lo] = data[lo:hi]
    return out_idx, out_val, nnz


def dense_to_padded(x: np.ndarray) -> Tuple[PaddedCSR, PaddedCSC]:
    """Convert a dense numpy matrix into both padded layouts."""
    csr = dense_to_host(np.asarray(x))
    return host_to_padded(csr)


def host_to_padded(csr: HostCSR) -> Tuple[PaddedCSR, PaddedCSC]:
    n, d = csr.shape
    csc = csr.tocsc()
    k_row = int(max(1, np.max(np.diff(csr.indptr)) if csr.nnz else 1))
    k_col = int(max(1, np.max(np.diff(csc.indptr)) if csc.nnz else 1))
    ri, rv, rn = _pad_rows(csr.indptr, csr.indices, csr.data, n, k_row)
    ci, cv, cn = _pad_rows(csc.indptr, csc.indices, csc.data, d, k_col)
    pcsr = PaddedCSR(jnp.asarray(ri), jnp.asarray(rv), jnp.asarray(rn), (n, d))
    pcsc = PaddedCSC(jnp.asarray(ci), jnp.asarray(cv), jnp.asarray(cn), (n, d))
    return pcsr, pcsc
