"""TPU-adapted Algorithm 2 — sparse-aware Frank-Wolfe as one ``lax.scan``.

Faithful port of the paper's sparse update structure onto fixed-shape padded
sparse formats (DESIGN.md §2):

  * coordinate selection — ``two_level`` (DP exponential mechanism via the
    hierarchical sampler, the TPU form of Alg 4) or ``group_argmax``
    (non-private lazy-bound argmax, the TPU form of Alg 3);
  * per-iteration work is a *static* ``K_col × K_row`` gather/scatter tile —
    the padded version of the paper's O(S_r·S_c) inner loop (lines 22-28);
  * the multiplicative-scale tricks (w_m, shared v̄ scale, incremental g̃)
    are identical to the host implementation.

The entire T-iteration optimization lowers to a single XLA while-loop, so it
can be jit/pjit-compiled, checkpointed mid-scan (via the trainer's chunked
driver), and dry-run on the production mesh.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.core.dp.accountant import per_step_epsilon
from repro.core.fw_dense import FWConfig, FWResult
from repro.core.samplers.bsls_jax import tl_init, tl_sample, tl_update
from repro.core.samplers.group_argmax import ga_get_next, ga_init, ga_update
from repro.core.sparse.formats import PaddedCSC, PaddedCSR


@dataclasses.dataclass(frozen=True)
class SparseJaxConfig(FWConfig):
    queue: str = "two_level"   # two_level (DP) | group_argmax (non-private)


def sparse_fw_jax(
    pcsr: PaddedCSR, pcsc: PaddedCSC, y: jnp.ndarray, config: SparseJaxConfig
) -> FWResult:
    n, d = pcsr.shape
    lam = config.lam
    loss = config.loss_fn()
    h = loss.split_grad
    separable = loss.separable
    private = config.queue == "two_level"
    if private:
        eps_step = per_step_epsilon(config.epsilon, config.delta, config.steps)
        em_scale = eps_step * n / (2.0 * loss.lipschitz)
    else:
        em_scale = 1.0  # priorities are raw |α|

    dtype = pcsr.values.dtype

    # ---- first-iteration dense pass (paper Alg 2 lines 8-14) ----------------
    # Separable objectives use the ȳ decomposition; label-coupled ones carry
    # the full row gradient in q̄ (α = Xᵀq̄/N, no ȳ term).
    w0 = jnp.zeros(d, dtype)
    vbar0 = jnp.zeros(n, dtype)
    if separable:
        ybar = pcsr.rmatvec(y) / n
        qbar0 = h(vbar0)
        alpha0 = pcsr.rmatvec(qbar0) / n - ybar
    else:
        qbar0 = loss.grad(vbar0, y)
        alpha0 = pcsr.rmatvec(qbar0) / n

    if private:
        sampler0 = tl_init(jnp.abs(alpha0) * em_scale)
    else:
        sampler0 = ga_init(jnp.abs(alpha0))

    # §9 masked early stopping (``config`` is jit-static, so this is a
    # compile-time branch; the tol=0 program is untouched).
    masked = config.gap_tol > 0

    def step(carry, t_int):
        w, w_m, g_tilde, vbar, qbar, alpha, sampler, key, done, stop_at = carry
        t = t_int.astype(dtype)
        key_next, sel_key = jax.random.split(key)
        # ---- line 15: select coordinate -------------------------------------
        if private:
            j = tl_sample(sampler, sel_key)
            sampler_after_sel = sampler
        else:
            j, sampler_after_sel = ga_get_next(sampler)
        j = jnp.minimum(j, d - 1)
        a_j = alpha[j]
        # ---- lines 16-21 -----------------------------------------------------
        d_tilde = -lam * jnp.sign(a_j)
        d_tilde = jnp.where(a_j == 0, lam, d_tilde)
        gap = g_tilde - d_tilde * a_j
        eta = 2.0 / (t + 2.0)
        w_m_new = w_m * (1.0 - eta)
        w_new = w.at[j].add(eta * d_tilde / w_m_new)
        g_tilde_new = g_tilde * (1.0 - eta) + eta * d_tilde * a_j
        # ---- lines 22-28: propagate through rows holding feature j ----------
        rows, xvals, mask = pcsc.col(j)                   # (Kc,)
        dv = jnp.where(mask, eta * d_tilde * xvals / w_m_new, 0.0)
        vbar_new = vbar.at[rows].add(dv)
        margins = w_m_new * vbar_new[rows]
        hm = h(margins) if separable else loss.grad(margins, y[rows])
        gamma = jnp.where(mask, hm - qbar[rows], 0.0)
        qbar_new = qbar.at[rows].add(gamma)
        row_idx = pcsr.indices[rows]                      # (Kc, Kr)
        row_val = pcsr.values[rows]                       # (Kc, Kr) — 0 at padding
        contrib = (gamma / n)[:, None] * row_val
        alpha_new = alpha.at[row_idx.reshape(-1)].add(contrib.reshape(-1))
        # line 27: g̃ += Σᵢ (γᵢ/n)·⟨X[i,:], w̃⟩·w_m
        wg = w_new[row_idx]                               # (Kc, Kr)
        g_tilde_new = g_tilde_new + w_m_new * jnp.sum(
            (gamma / n) * jnp.einsum("ck,ck->c", row_val, wg))
        # ---- line 29: refresh queue priorities for touched coordinates ------
        flat_idx = row_idx.reshape(-1)
        fresh = jnp.abs(alpha_new[flat_idx]) * (em_scale if private else 1.0)
        if private:
            sampler_new = tl_update(sampler_after_sel, flat_idx, fresh)
        else:
            sampler_new = ga_update(sampler_after_sel, flat_idx, fresh)
        j = j.astype(jnp.int32)
        new = (w_new, w_m_new, g_tilde_new, vbar_new, qbar_new, alpha_new,
               sampler_new, key_next)
        if not masked:
            return new + (done, stop_at), (gap, j)
        newly = jnp.logical_and(~done, gap <= config.gap_tol)
        old = (w, w_m, g_tilde, vbar, qbar, alpha, sampler, key)
        kept = jax.tree_util.tree_map(
            lambda o, fresh_leaf: jnp.where(done, o, fresh_leaf), old, new)
        out = (jnp.where(done, jnp.asarray(0.0, dtype), gap),
               jnp.where(done, -1, j))
        return kept + (jnp.logical_or(done, newly),
                       jnp.where(newly, t_int, stop_at)), out

    carry0 = (
        w0, jnp.asarray(1.0, dtype), jnp.asarray(0.0, dtype),
        vbar0, qbar0, alpha0, sampler0, jax.random.PRNGKey(config.seed),
        jnp.asarray(False), jnp.asarray(0, jnp.int32),
    )
    ts = jnp.arange(1, config.steps + 1, dtype=jnp.int32)
    (w, w_m, *rest), (gaps, coords) = jax.lax.scan(step, carry0, ts)
    done, stop_at = rest[-2], rest[-1]
    stop_step = jnp.where(done, stop_at, jnp.asarray(config.steps, jnp.int32))
    w_true = w * w_m
    return FWResult(w=w_true, gaps=gaps, coords=coords,
                    losses=jnp.zeros_like(gaps), stop_step=stop_step,
                    stop_reason="max_steps")


sparse_fw_jax_jit = jax.jit(sparse_fw_jax, static_argnames=("config",))
