"""Algorithm 1 — Standard (dense-work) Frank-Wolfe over the L1 ball.

This is the paper's baseline: COPT-style "sparse aware" only in the matrix
products (lines 2/4/6 exploit nnz), but the gradient vector α, direction d and
iterate w are all touched densely every iteration → O(T·N·S_c + T·D).

It is written as a single ``lax.scan`` so the whole T-iteration loop runs
on-device, and it accepts either a dense ``jnp.ndarray`` design matrix or a
``PaddedCSR`` (whose matvec/rmatvec exploit nnz exactly like COPT does).

Selection rules:
  * ``argmax``    — non-private Frank-Wolfe.
  * ``noisy_max`` — Laplace report-noisy-max (the paper's Alg 1 annotation).
  * ``gumbel``    — exact exponential mechanism via Gumbel-max; same law the
                    BSLS sampler draws from (used for DP equivalence tests).
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

from repro.core.dp.accountant import fw_noise_scale, per_step_epsilon
from repro.core.solvers.config import (STOP_MAX_STEPS,  # noqa: F401  (canonical home; re-exported for compat)
                                       FWConfig, FWResult)
from repro.core.sparse.formats import PaddedCSR

Design = Union[jnp.ndarray, PaddedCSR]


def _matvec(X: Design, w: jnp.ndarray) -> jnp.ndarray:
    return X.matvec(w) if isinstance(X, PaddedCSR) else X @ w


def _rmatvec(X: Design, q: jnp.ndarray) -> jnp.ndarray:
    return X.rmatvec(q) if isinstance(X, PaddedCSR) else X.T @ q


def _n_rows(X: Design) -> int:
    return X.shape[0]


def _n_cols(X: Design) -> int:
    return X.shape[1]


def _dense_step(X: Design, y: jnp.ndarray, config: FWConfig, masked: bool):
    """One Algorithm-1 iteration as a scan body over the extended carry
    ``(w, key, done, stop_at)``.

    With ``masked`` (the §9 early-stopping form) the iteration that observes
    gap ≤ gap_tol is still applied, after which the carry — PRNG key included
    — freezes bit-for-bit and the outputs emit (0.0, -1, 0.0) sentinels.
    """
    loss = config.loss_fn()
    n, d = _n_rows(X), _n_cols(X)
    lam = config.lam

    # Per-coordinate Laplace scale / EM logit scale (paper Alg 1 & Alg 2 l.5).
    if config.selection in ("noisy_max", "gumbel"):
        b = fw_noise_scale(
            epsilon=config.epsilon, delta=config.delta, steps=config.steps,
            lam=lam, lipschitz=loss.lipschitz, n_rows=n,
        )
        eps_step = per_step_epsilon(config.epsilon, config.delta, config.steps)
        # EM logits = ε'·u/(2Δu) with u = λ|α|, Δu = λL/N  →  |α|·ε'·N/(2L).
        em_scale = eps_step * n / (2.0 * loss.lipschitz)
    else:
        b, em_scale = 0.0, 0.0

    # Separable objectives precompute the label part of the gradient; the
    # label-coupled ones evaluate the full row gradient each pass.
    separable = loss.separable
    ybar = _rmatvec(X, y) / n if separable else None

    def step(carry, t_int):
        w, key, done, stop_at = carry
        t = t_int.astype(jnp.float32)
        key_next, sel_key = jax.random.split(key)
        v = _matvec(X, w)                        # O(N·S_c)
        if separable:
            q = loss.split_grad(v)               # O(N)
            alpha = _rmatvec(X, q) / n - ybar    # O(N·S_c) + O(D)
        else:
            q = loss.grad(v, y)                  # O(N)
            alpha = _rmatvec(X, q) / n           # O(N·S_c) + O(D)
        mean_loss = jnp.mean(loss.value(v, y))

        score = lam * jnp.abs(alpha)
        if config.selection == "argmax":
            j = jnp.argmax(score)
        elif config.selection == "noisy_max":
            u01 = jax.random.uniform(sel_key, (d,), minval=-0.5 + 1e-12, maxval=0.5)
            lap = -b * jnp.sign(u01) * jnp.log1p(-2.0 * jnp.abs(u01))
            j = jnp.argmax(score + lap)
        elif config.selection == "gumbel":
            g = jax.random.gumbel(sel_key, (d,))
            j = jnp.argmax(jnp.abs(alpha) * em_scale + g)
        else:
            raise ValueError(f"unknown selection {config.selection!r}")

        a_j = alpha[j]
        s_j = -lam * jnp.sign(a_j)               # LMO vertex coordinate value
        d_vec = -w
        d_vec = d_vec.at[j].add(s_j)
        gap = -jnp.vdot(alpha, d_vec)            # g_t = ⟨α,w⟩ + λ|α_j|
        eta = 2.0 / (t + 2.0)
        w_next = w + eta * d_vec                 # = (1-η)w + η·s
        j = j.astype(jnp.int32)
        if not masked:
            return (w_next, key_next, done, stop_at), (gap, j, mean_loss)
        newly = jnp.logical_and(~done, gap <= config.gap_tol)
        out = (jnp.where(done, 0.0, gap), jnp.where(done, -1, j),
               jnp.where(done, 0.0, mean_loss))
        carry = (jnp.where(done, w, w_next), jnp.where(done, key, key_next),
                 jnp.logical_or(done, newly),
                 jnp.where(newly, t_int, stop_at))
        return carry, out

    return step


def _carry0(X: Design, d: int, config: FWConfig):
    dtype = X.values.dtype if isinstance(X, PaddedCSR) else X.dtype
    return (jnp.zeros(d, dtype=dtype), jax.random.PRNGKey(config.seed),
            jnp.asarray(False), jnp.asarray(0, jnp.int32))


def dense_fw(X: Design, y: jnp.ndarray, config: FWConfig) -> FWResult:
    """Run Algorithm 1 for ``config.steps`` iterations (one lax.scan).

    Mean-normalized objective (1/N)Σ L(w·xᵢ, yᵢ); selection scores are
    λ·|α⁽ʲ⁾| with sensitivity Δu = λ·L/N, so DP noise scales follow the
    paper's formulas exactly (see core/dp/accountant.py).

    ``config.gap_tol > 0`` runs the masked early-stopping form of the same
    scan; ``max_seconds`` needs the host-driven :func:`dense_fw_stopping`
    (this function is jit-compiled whole, so it cannot watch a clock).
    """
    d = _n_cols(X)
    masked = config.gap_tol > 0
    step = _dense_step(X, y, config, masked)
    (w, _, done, stop_at), (gaps, coords, losses) = jax.lax.scan(
        step, _carry0(X, d, config),
        jnp.arange(1, config.steps + 1, dtype=jnp.int32))
    stop_step = jnp.where(done, stop_at, jnp.asarray(config.steps, jnp.int32))
    return FWResult(w=w, gaps=gaps, coords=coords, losses=losses,
                    stop_step=stop_step, stop_reason=STOP_MAX_STEPS)


dense_fw_jit = jax.jit(dense_fw, static_argnames=("config",))


def _dense_chunk(X, y, carry, t0, *, config: FWConfig, chunk: int):
    """``chunk`` masked iterations from global offset ``t0`` (re-enterable)."""
    step = _dense_step(X, y, config, masked=config.gap_tol > 0)
    ts = jnp.asarray(t0, jnp.int32) + jnp.arange(1, chunk + 1, dtype=jnp.int32)
    return jax.lax.scan(step, carry, ts)


_dense_chunk_jit = jax.jit(_dense_chunk, static_argnames=("config", "chunk"))


def dense_fw_stopping(X: Design, y: jnp.ndarray, config: FWConfig) -> FWResult:
    """Algorithm 1 with gap-adaptive early stopping (DESIGN.md §9).

    A host loop re-enters one compiled masked chunk of the Alg-1 scan,
    breaking as soon as the gap certificate lands or ``max_seconds`` runs
    out — same per-step arithmetic as :func:`dense_fw`, so the stopped
    iterate equals the fixed-T run's prefix.  Driver and sentinel-padding
    contract are shared with every chunked backend
    (``solvers.stopping``).
    """
    from repro.core.solvers.stopping import (assemble_outputs, drive_chunks,
                                             resolve_chunk)
    y = jnp.asarray(y)

    def advance(carry, t0, c):
        return _dense_chunk_jit(X, y, carry, t0, config=config, chunk=c)

    carry, outs, stop_step, stop_reason = drive_chunks(
        advance, _carry0(X, _n_cols(X), config), steps=config.steps,
        chunk=resolve_chunk(config), max_seconds=config.max_seconds,
        done_of=lambda cy: cy[2], stop_at_of=lambda cy: cy[3])
    gaps, coords, losses = assemble_outputs(outs, config.steps,
                                            (0.0, -1, 0.0))
    return FWResult(w=carry[0], gaps=gaps, coords=coords, losses=losses,
                    stop_step=stop_step, stop_reason=stop_reason)


def dense_fw_screened(X: Design, y: jnp.ndarray, config: FWConfig) -> FWResult:
    """Algorithm 1 with DP iterative screening between chunks (§13).

    Same chunked host loop as :func:`dense_fw_stopping`, but the design
    matrix lives in a mutable :class:`ChunkGeometry` cell: every
    ``screen_every``-th boundary recomputes α from the current iterate on
    the host, runs the privatized keep rule, column-subsets the design (a
    dense slice, or the padded-ELL repack for ``PaddedCSR`` inputs) and the
    carry, and re-enters the chunk program at the smaller D.  The selection
    mechanism runs at the solve share ε·(1 − screen_eps_frac) of the budget
    (the chunk program is compiled against a reduced-ε config — Alg 1
    derives its noise scales from the config, not a traced scalar); the
    screening queries spend the rest.  Coordinates in the outputs and the
    final ``w`` are mapped back to original feature ids.
    """
    import time as _time

    import numpy as np

    from repro.core.solvers.screening import (Screener, repack_dense,
                                              solve_epsilon)
    from repro.core.solvers.stopping import (ChunkGeometry, assemble_outputs,
                                             drive_chunks, resolve_chunk)
    y = jnp.asarray(y)
    loss = config.loss_fn()
    n, d0 = _n_rows(X), _n_cols(X)
    private = config.selection in ("noisy_max", "gumbel")
    run_cfg = (dataclasses.replace(config, epsilon=solve_epsilon(config))
               if private else config)
    em_scale = (per_step_epsilon(run_cfg.epsilon, run_cfg.delta,
                                 run_cfg.steps) * n / (2.0 * loss.lipschitz)
                if private else 0.0)
    row_width = (int(X.indices.shape[1]) if isinstance(X, PaddedCSR) else d0)
    scr = Screener(config, d=d0, n_rows=n, row_width=row_width,
                   em_scale=em_scale, private=private)
    geom = ChunkGeometry(operands=(X,), d=d0, pad_row=row_width)

    def advance(carry, t0, c):
        return _dense_chunk_jit(geom.operands[0], y, carry, t0,
                                config=run_cfg, chunk=c)

    def out_map(out, t0):
        gap, j, mean_loss = out
        return gap, scr.map_coords(j), mean_loss

    def alpha_now(Xc, w):
        v = _matvec(Xc, w)
        if loss.separable:
            q = loss.split_grad(v) - y
        else:
            q = loss.grad(v, y)
        return np.abs(np.asarray(_rmatvec(Xc, q))) / n

    def respec(carry, t0, n_chunks):
        if not scr.due(n_chunks):
            return None
        w = carry[0]
        keep = scr.screen(alpha_now(geom.operands[0], w),
                          np.asarray(w) != 0)
        if keep is None:
            return None
        tw = _time.perf_counter()
        X2 = repack_dense(geom.operands[0], keep)
        w2 = jnp.asarray(np.asarray(w)[np.flatnonzero(keep)])
        pad2 = (int(X2.indices.shape[1]) if isinstance(X2, PaddedCSR)
                else int(X2.shape[1]))
        geom.swap((X2,), X2.shape[1], pad_row=pad2)
        info = scr.commit(keep, repack_seconds=_time.perf_counter() - tw)
        return (w2, carry[1], carry[2], carry[3]), info

    carry, outs, stop_step, stop_reason = drive_chunks(
        advance, _carry0(X, d0, config), steps=config.steps,
        chunk=resolve_chunk(config), max_seconds=config.max_seconds,
        done_of=lambda cy: cy[2], stop_at_of=lambda cy: cy[3],
        respec=respec, out_map=out_map)
    gaps, coords, losses = assemble_outputs(outs, config.steps,
                                            (0.0, -1, 0.0))
    return FWResult(w=scr.expand(carry[0]), gaps=gaps, coords=coords,
                    losses=losses, stop_step=stop_step,
                    stop_reason=stop_reason)


def dense_fw_flops(n: int, d: int, nnz: int, steps: int) -> int:
    """Analytic FLOP count of Algorithm 1 (paper Fig. 2/4 accounting).

    Per iteration: matvec (2·nnz) + split grad (≈4N) + rmatvec (2·nnz)
    + α assembly (D) + |α| scoring (D) + direction/gap/update (≈4D).
    """
    per_iter = 4 * nnz + 4 * n + 6 * d
    return steps * per_iter + 2 * nnz  # + one-time ȳ
