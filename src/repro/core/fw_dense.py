"""Algorithm 1 — Standard (dense-work) Frank-Wolfe over the L1 ball.

This is the paper's baseline: COPT-style "sparse aware" only in the matrix
products (lines 2/4/6 exploit nnz), but the gradient vector α, direction d and
iterate w are all touched densely every iteration → O(T·N·S_c + T·D).

It is written as a single ``lax.scan`` so the whole T-iteration loop runs
on-device, and it accepts either a dense ``jnp.ndarray`` design matrix or a
``PaddedCSR`` (whose matvec/rmatvec exploit nnz exactly like COPT does).

Selection rules:
  * ``argmax``    — non-private Frank-Wolfe.
  * ``noisy_max`` — Laplace report-noisy-max (the paper's Alg 1 annotation).
  * ``gumbel``    — exact exponential mechanism via Gumbel-max; same law the
                    BSLS sampler draws from (used for DP equivalence tests).
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from repro.core.dp.accountant import fw_noise_scale, per_step_epsilon
from repro.core.solvers.config import FWConfig, FWResult  # noqa: F401  (canonical home; re-exported for compat)
from repro.core.sparse.formats import PaddedCSR

Design = Union[jnp.ndarray, PaddedCSR]


def _matvec(X: Design, w: jnp.ndarray) -> jnp.ndarray:
    return X.matvec(w) if isinstance(X, PaddedCSR) else X @ w


def _rmatvec(X: Design, q: jnp.ndarray) -> jnp.ndarray:
    return X.rmatvec(q) if isinstance(X, PaddedCSR) else X.T @ q


def _n_rows(X: Design) -> int:
    return X.shape[0]


def _n_cols(X: Design) -> int:
    return X.shape[1]


def dense_fw(X: Design, y: jnp.ndarray, config: FWConfig) -> FWResult:
    """Run Algorithm 1 for ``config.steps`` iterations.

    Mean-normalized objective (1/N)Σ L(w·xᵢ, yᵢ); selection scores are
    λ·|α⁽ʲ⁾| with sensitivity Δu = λ·L/N, so DP noise scales follow the
    paper's formulas exactly (see core/dp/accountant.py).
    """
    loss = config.loss_fn()
    n, d = _n_rows(X), _n_cols(X)
    lam = config.lam

    # Per-coordinate Laplace scale / EM logit scale (paper Alg 1 & Alg 2 l.5).
    if config.selection in ("noisy_max", "gumbel"):
        b = fw_noise_scale(
            epsilon=config.epsilon, delta=config.delta, steps=config.steps,
            lam=lam, lipschitz=loss.lipschitz, n_rows=n,
        )
        eps_step = per_step_epsilon(config.epsilon, config.delta, config.steps)
        # EM logits = ε'·u/(2Δu) with u = λ|α|, Δu = λL/N  →  |α|·ε'·N/(2L).
        em_scale = eps_step * n / (2.0 * loss.lipschitz)
    else:
        b, em_scale = 0.0, 0.0

    ybar = _rmatvec(X, y) / n  # precomputed label part of the gradient

    def step(carry, t):
        w, key = carry
        key, sel_key = jax.random.split(key)
        v = _matvec(X, w)                        # O(N·S_c)
        q = loss.split_grad(v)                   # O(N)
        alpha = _rmatvec(X, q) / n - ybar        # O(N·S_c) + O(D)
        mean_loss = jnp.mean(loss.value(v, y))

        score = lam * jnp.abs(alpha)
        if config.selection == "argmax":
            j = jnp.argmax(score)
        elif config.selection == "noisy_max":
            u01 = jax.random.uniform(sel_key, (d,), minval=-0.5 + 1e-12, maxval=0.5)
            lap = -b * jnp.sign(u01) * jnp.log1p(-2.0 * jnp.abs(u01))
            j = jnp.argmax(score + lap)
        elif config.selection == "gumbel":
            g = jax.random.gumbel(sel_key, (d,))
            j = jnp.argmax(jnp.abs(alpha) * em_scale + g)
        else:
            raise ValueError(f"unknown selection {config.selection!r}")

        a_j = alpha[j]
        s_j = -lam * jnp.sign(a_j)               # LMO vertex coordinate value
        d_vec = -w
        d_vec = d_vec.at[j].add(s_j)
        gap = -jnp.vdot(alpha, d_vec)            # g_t = ⟨α,w⟩ + λ|α_j|
        eta = 2.0 / (t + 2.0)
        w = w + eta * d_vec                      # = (1-η)w + η·s
        return (w, key), (gap, j, mean_loss)

    dtype = X.values.dtype if isinstance(X, PaddedCSR) else X.dtype
    w0 = jnp.zeros(d, dtype=dtype)
    key0 = jax.random.PRNGKey(config.seed)
    (w, _), (gaps, coords, losses) = jax.lax.scan(
        step, (w0, key0), jnp.arange(1, config.steps + 1, dtype=jnp.float32)
    )
    return FWResult(w=w, gaps=gaps, coords=coords, losses=losses)


dense_fw_jit = jax.jit(dense_fw, static_argnames=("config",))


def dense_fw_flops(n: int, d: int, nnz: int, steps: int) -> int:
    """Analytic FLOP count of Algorithm 1 (paper Fig. 2/4 accounting).

    Per iteration: matvec (2·nnz) + split grad (≈4N) + rmatvec (2·nnz)
    + α assembly (D) + |α| scoring (D) + direction/gap/update (≈4D).
    """
    per_iter = 4 * nnz + 4 * n + 6 * d
    return steps * per_iter + 2 * nnz  # + one-time ȳ
