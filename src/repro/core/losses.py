"""Loss functions for L1-constrained (LASSO) generalized linear models.

The paper (Raff, Khanna & Lu, NeurIPS 2023) uses the logistic loss to avoid
exploiting closed-form linear-regression updates; squared loss is included
because the authors note the results transfer to linear regression.

Conventions
-----------
Labels are y ∈ {0, 1}.  A model scores a row with ``m = w · x`` and the
per-row loss is ``L(m, y)``.  ``grad`` returns dL/dm (the scalar "row
gradient" called q̄ in the paper's Algorithm 1/2).

The L1-Lipschitz constant ``L`` enters the DP sensitivity Δu = L·λ/N and the
Laplace/exponential mechanism scales, so each loss carries it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    """A scalar margin loss with its gradient and Lipschitz metadata.

    Attributes:
      value: ``(margins, labels) -> per-row loss`` (elementwise).
      grad: ``(margins, labels) -> dL/dmargin`` (elementwise).
      split_grad: ``margins -> h(margins)`` with ``dL/dm = h(m) - y``.  This
        is the decomposition the paper's Algorithms 1/2 exploit: the
        label-dependent part ``ȳ = Xᵀy`` is precomputed once, and only the
        ``q̄ = h(v̄)`` part is updated each iteration.
      lipschitz: bound on |dL/dmargin| assuming features in [-1, 1]; this is
        the ``L`` of the paper's noise scale ``λ·L·sqrt(8T log(1/δ))/(N·ε)``.
      curvature_note: how the FW curvature constant Γ is bounded.
      name: identifier used by configs.
    """

    name: str
    value: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    grad: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    split_grad: Callable[[jnp.ndarray], jnp.ndarray]
    lipschitz: float
    curvature_note: str = ""

    def mean_value(self, margins: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
        return jnp.mean(self.value(margins, labels))


def _logistic_value(m, y):
    # log(1 + exp(m)) - y*m, computed stably via softplus.
    return jax.nn.softplus(m) - y * m


def _logistic_grad(m, y):
    # sigmoid(m) - y
    return jax.nn.sigmoid(m) - y


LOGISTIC = Loss(
    name="logistic",
    value=_logistic_value,
    grad=_logistic_grad,
    split_grad=jax.nn.sigmoid,
    lipschitz=1.0,  # |sigmoid(m) - y| <= 1
    curvature_note="Γ_L <= λ² · max_i ‖x_i‖∞² / 4 for logistic loss",
)


def _squared_value(m, y):
    return 0.5 * (m - y) ** 2


def _squared_grad(m, y):
    return m - y


SQUARED = Loss(
    name="squared",
    value=_squared_value,
    grad=_squared_grad,
    split_grad=lambda m: m,
    # Unbounded in general; bounded by max |m - y| on the L1 ball with
    # features in [-1,1]: |m| <= λ, so L <= λ + 1.  Callers may override.
    lipschitz=1.0,
    curvature_note="Γ = λ² · max eig(XᵀX)/N for squared loss",
)

LOSSES = {l.name: l for l in (LOGISTIC, SQUARED)}


def get_loss(name: str) -> Loss:
    try:
        return LOSSES[name]
    except KeyError:
        raise KeyError(f"unknown loss {name!r}; have {sorted(LOSSES)}") from None
