"""Objectives for L1-constrained (LASSO) generalized linear models.

The paper (Raff, Khanna & Lu, NeurIPS 2023) uses the logistic loss to avoid
exploiting closed-form linear-regression updates; squared loss is included
because the authors note the results transfer to linear regression.  The
engine itself is loss-agnostic: only the per-row gradient ``h`` and the
L1-Lipschitz constant enter Algorithm 2 and the DP noise scale, so new
objectives (smoothed LAD, huber, smoothed hinge) plug into every backend.

Conventions
-----------
Labels are y ∈ {0, 1}.  A model scores a row with ``m = w · x`` and the
per-row loss is ``L(m, y)``.  ``grad`` returns dL/dm (the scalar "row
gradient" called q̄ in the paper's Algorithm 1/2).

Separable vs. label-coupled gradients
-------------------------------------
Logistic and squared losses satisfy ``dL/dm = h(m) − y``: the
label-dependent part ``ȳ = Xᵀy/N`` is precomputed once and only the
``q̄ = h(v̄)`` half is updated each iteration (the decomposition Algorithms
1/2 exploit).  Objectives whose gradient couples margin and label (LAD,
huber, hinge) set ``split_grad=None``; the engine then carries the full
``q̄_i = grad(m_i, y_i)`` and drops the ȳ term (``label_weight == 0``).
Both forms keep the same sparse update structure — only the per-row map
changes — so every backend serves both through ``Objective.h``.

The L1-Lipschitz constant ``L`` enters the DP sensitivity Δu = L·λ/N and the
Laplace/exponential mechanism scales, so each objective carries it.  The
``smooth`` flag gates duality-gap certificates: FW's gap bound assumes a
curvature (smoothness) constant, so gap-based early stopping
(``FWConfig.gap_tol > 0``) is refused for non-smooth objectives.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Objective:
    """A scalar margin loss with its gradient, host twins, and DP metadata.

    Attributes:
      value: ``(margins, labels) -> per-row loss`` (elementwise, traceable).
      grad: ``(margins, labels) -> dL/dmargin`` (elementwise, traceable).
      split_grad: ``margins -> h(margins)`` with ``dL/dm = h(m) - y``, or
        ``None`` when the gradient does not separate from the label.
      grad_np: float64 numpy twin of ``grad`` for the faithful host backend.
      split_grad_np: float64 numpy twin of ``split_grad`` (None when
        ``split_grad`` is None).
      lipschitz: bound on |dL/dmargin| assuming features in [-1, 1]; this is
        the ``L`` of the paper's noise scale ``λ·L·sqrt(8T log(1/δ))/(N·ε)``.
      smooth: whether dL/dm is Lipschitz in m (C¹ loss) — required for the
        FW duality-gap certificate, hence for ``gap_tol`` early stopping.
      curvature_note: how the FW curvature constant Γ is bounded.
      name: identifier used by configs.
    """

    name: str
    value: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    grad: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    split_grad: Optional[Callable[[jnp.ndarray], jnp.ndarray]]
    lipschitz: float
    grad_np: Optional[Callable[[np.ndarray, np.ndarray], np.ndarray]] = None
    split_grad_np: Optional[Callable[[np.ndarray], np.ndarray]] = None
    smooth: bool = True
    curvature_note: str = ""

    @property
    def separable(self) -> bool:
        """True when dL/dm = split_grad(m) − y (logistic/squared form)."""
        return self.split_grad is not None

    @property
    def label_weight(self) -> float:
        """Coefficient of the precomputed ȳ = Xᵀy/N term in α updates."""
        return 1.0 if self.separable else 0.0

    def h(self, margins: jnp.ndarray, labels: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """The per-row map q̄ tracks: ``split_grad(m)`` for separable
        objectives, the full ``grad(m, y)`` otherwise."""
        if self.separable:
            return self.split_grad(margins)
        if labels is None:
            raise ValueError(
                f"objective {self.name!r} is label-coupled; h() needs labels")
        return self.grad(margins, labels)

    def h_np(self, margins: np.ndarray, labels: Optional[np.ndarray] = None) -> np.ndarray:
        """float64 numpy twin of ``h`` for the faithful host backend."""
        if self.separable:
            if self.split_grad_np is None:
                raise ValueError(f"objective {self.name!r} has no numpy twin")
            return self.split_grad_np(margins)
        if self.grad_np is None:
            raise ValueError(f"objective {self.name!r} has no numpy twin")
        if labels is None:
            raise ValueError(
                f"objective {self.name!r} is label-coupled; h_np() needs labels")
        return self.grad_np(margins, labels)

    def mean_value(self, margins: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
        return jnp.mean(self.value(margins, labels))


# Back-compat alias: the engine grew up calling this ``Loss``.
Loss = Objective


def _logistic_value(m, y):
    # log(1 + exp(m)) - y*m, computed stably via softplus.
    return jax.nn.softplus(m) - y * m


def _logistic_grad(m, y):
    # sigmoid(m) - y
    return jax.nn.sigmoid(m) - y


LOGISTIC = Objective(
    name="logistic",
    value=_logistic_value,
    grad=_logistic_grad,
    split_grad=jax.nn.sigmoid,
    grad_np=lambda m, y: 1.0 / (1.0 + np.exp(-m)) - y,
    split_grad_np=lambda m: 1.0 / (1.0 + np.exp(-m)),
    lipschitz=1.0,  # |sigmoid(m) - y| <= 1
    curvature_note="Γ_L <= λ² · max_i ‖x_i‖∞² / 4 for logistic loss",
)


def _squared_value(m, y):
    return 0.5 * (m - y) ** 2


def _squared_grad(m, y):
    return m - y


SQUARED = Objective(
    name="squared",
    value=_squared_value,
    grad=_squared_grad,
    split_grad=lambda m: m,
    grad_np=lambda m, y: m - y,
    split_grad_np=lambda m: m,
    # Unbounded in general; bounded by max |m - y| on the L1 ball with
    # features in [-1,1]: |m| <= λ, so L <= λ + 1.  Callers may override.
    lipschitz=1.0,
    curvature_note="Γ = λ² · max eig(XᵀX)/N for squared loss",
)


# --- smoothed least-absolute-deviation (pseudo-Huber) ----------------------
# |r| is not differentiable at 0, which would void the gap certificate and
# break the traced kernels' finite-difference contracts; the pseudo-Huber
# smoothing sqrt(r² + μ²) − μ is C∞, → |r| as μ → 0, and keeps |grad| ≤ 1.
_LAD_MU = 0.25


def _lad_value(m, y):
    r = m - y
    return jnp.sqrt(r * r + _LAD_MU * _LAD_MU) - _LAD_MU


def _lad_grad(m, y):
    r = m - y
    return r / jnp.sqrt(r * r + _LAD_MU * _LAD_MU)


LAD = Objective(
    name="lad",
    value=_lad_value,
    grad=_lad_grad,
    split_grad=None,  # r/√(r²+μ²) does not separate into h(m) − y
    grad_np=lambda m, y: (m - y) / np.sqrt((m - y) ** 2 + _LAD_MU * _LAD_MU),
    lipschitz=1.0,  # |r|/√(r²+μ²) < 1
    curvature_note="Γ <= λ²·max_i ‖x_i‖∞²/μ (pseudo-Huber second derivative ≤ 1/μ)",
)


# --- huber ------------------------------------------------------------------
# δ = 0.5 deliberately gives L = 0.5 ≠ 1.0 so the per-loss sensitivity path
# through accountant.em_log_weight_scale is exercised (and pinned) by a loss
# whose scale differs from logistic's.
_HUBER_DELTA = 0.5


def _huber_value(m, y):
    r = m - y
    a = jnp.abs(r)
    return jnp.where(a <= _HUBER_DELTA, 0.5 * r * r,
                     _HUBER_DELTA * (a - 0.5 * _HUBER_DELTA))


def _huber_grad(m, y):
    return jnp.clip(m - y, -_HUBER_DELTA, _HUBER_DELTA)


HUBER = Objective(
    name="huber",
    value=_huber_value,
    grad=_huber_grad,
    split_grad=None,  # clip(m − y, ·) does not separate into h(m) − y
    grad_np=lambda m, y: np.clip(m - y, -_HUBER_DELTA, _HUBER_DELTA),
    lipschitz=_HUBER_DELTA,  # |clip(r, −δ, δ)| <= δ
    curvature_note="Γ <= λ²·max_i ‖x_i‖∞² (huber second derivative ≤ 1)",
)


# --- smoothed hinge (Rennie & Srebro 2005) ----------------------------------
# SVM-style margin loss on ỹ = 2y − 1 ∈ {−1, +1}, quadratically smoothed on
# the hinge corner so it stays C¹ (gap certificates remain valid).
def _smoothed_hinge_value(m, y):
    z = (2.0 * y - 1.0) * m
    return jnp.where(z <= 0.0, 0.5 - z,
                     jnp.where(z < 1.0, 0.5 * (1.0 - z) ** 2, 0.0))


def _smoothed_hinge_grad(m, y):
    yt = 2.0 * y - 1.0
    z = yt * m
    dz = jnp.where(z <= 0.0, -1.0, jnp.where(z < 1.0, z - 1.0, 0.0))
    return yt * dz


def _smoothed_hinge_grad_np(m, y):
    yt = 2.0 * y - 1.0
    z = yt * m
    dz = np.where(z <= 0.0, -1.0, np.where(z < 1.0, z - 1.0, 0.0))
    return yt * dz


SMOOTHED_HINGE = Objective(
    name="smoothed_hinge",
    value=_smoothed_hinge_value,
    grad=_smoothed_hinge_grad,
    split_grad=None,  # gradient depends on the sign flip ỹ·m
    grad_np=_smoothed_hinge_grad_np,
    lipschitz=1.0,  # |dz| <= 1
    curvature_note="Γ <= λ²·max_i ‖x_i‖∞² (quadratic zone second derivative = 1)",
)


OBJECTIVES = {o.name: o for o in (LOGISTIC, SQUARED, LAD, HUBER, SMOOTHED_HINGE)}
# Back-compat alias (same dict object — registration is visible through both).
LOSSES = OBJECTIVES


def register_objective(obj: Objective) -> Objective:
    """Register a custom objective so configs can name it; returns it."""
    if obj.name in OBJECTIVES:
        raise ValueError(f"objective {obj.name!r} already registered")
    OBJECTIVES[obj.name] = obj
    return obj


def get_objective(name: str) -> Objective:
    try:
        return OBJECTIVES[name]
    except KeyError:
        raise KeyError(f"unknown loss {name!r}; have {sorted(OBJECTIVES)}") from None


# Back-compat alias.
get_loss = get_objective
