"""Core library: the paper's sparse-aware DP Frank-Wolfe, in JAX.

Public API:
  * ``solvers.solve`` / ``FWConfig`` / ``FWResult`` — the unified engine; all
    implementations below are registered backends (dense | jax_dense |
    host_sparse | jax_sparse)
  * ``dense_fw``                       — Algorithm 1 (standard, baseline)
  * ``sparse_fw``                      — Algorithm 2 (faithful host, exact FLOP audit)
  * ``SparseJaxConfig`` / ``sparse_fw_jax`` — Algorithm 2, TPU-adapted scan
  * ``solvers.jax_sparse``             — Algorithm 2 through the Pallas kernels
  * samplers: ``FibHeapQueue`` (Alg 3), ``BSLSSampler`` (Alg 4),
    two-level TPU sampler, lazy group-argmax
  * DP: ``PrivacyAccountant``, ``fw_noise_scale``, mechanisms
"""
from repro.core.fw_dense import FWConfig, FWResult, dense_fw, dense_fw_flops  # noqa: F401
from repro.core.fw_jax import SparseJaxConfig, sparse_fw_jax  # noqa: F401
from repro.core.fw_sparse import SparseFWResult, sparse_fw  # noqa: F401
from repro.core.losses import LOGISTIC, SQUARED, Loss, get_loss  # noqa: F401
from repro.core.solvers import available_backends, solve  # noqa: F401
