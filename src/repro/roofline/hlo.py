"""Optimized-HLO parsing for the roofline analysis.

``compiled.cost_analysis()`` and a flat text scan both count a while-loop
body **once**, but a scanned transformer executes its body L times — so
collective bytes (and FLOPs) hiding inside ``lax.scan`` loops would be
undercounted by L×.  This parser reconstructs the computation graph of the
optimized HLO text, extracts each while loop's trip count from the constant
bound in its condition computation, and sums collective result-shape bytes
with nested trip-count multipliers.

Loops whose bound is data-dependent (e.g. flash attention's causal
block-skipping) have no constant bound; they get multiplier 1 — conservative,
and correct for our programs because no collective ops live inside those
loops (asserted by tests/test_roofline.py on a sharded example).
"""
from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions (older
    releases return a one-element list of per-device dicts, newer a dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# headers like: %region_0.1_spmd (param: (s32[], f32[16,64])) -> (...) {
# — params may contain NESTED parens (tuple-typed while state), so match
# greedily up to the -> rather than assuming a single paren group.
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"\bwhile\(")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
# XLA annotates whiles it has unrolled/analyzed:  backend_config=
# {"known_trip_count":{"n":"12"}} — authoritative when present.
_KNOWN_TRIPS = re.compile(r"known_trip_count[^0-9]*(\d+)")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name → list of body lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_HEADER.match(line)  # headers start at column 0
        if m and (line.rstrip().endswith("{") or "->" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if stripped == "}":
                cur = None
                continue
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the condition computation (scan bound)."""
    best = 1
    for line in cond_lines:
        for v in _CONST_RE.findall(line):
            best = max(best, int(v))
    return best


def collective_bytes_nested(hlo_text: str) -> Dict[str, float]:
    comps = parse_hlo_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return {}

    memo: Dict[str, Dict[str, float]] = {}

    def comp_bytes(name: str, depth: int = 0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if depth > 32 or name not in comps:
            return {}
        total: Dict[str, float] = {}
        for line in comps[name]:
            if "-done" not in line:
                m = _COLL_RE.search(line)
                if m:
                    shape_txt, kind = m.group(1), m.group(2)
                    total[kind] = total.get(kind, 0) + _shape_bytes(shape_txt)
            if _WHILE_RE.search(line):
                mc, mb = _COND_RE.search(line), _BODY_RE.search(line)
                if mc and mb:
                    mk = _KNOWN_TRIPS.search(line)
                    trips = (int(mk.group(1)) if mk
                             else _trip_count(comps.get(mc.group(1), [])))
                    inner = comp_bytes(mb.group(1), depth + 1)
                    for k, v in inner.items():
                        total[k] = total.get(k, 0) + trips * v
            # calls / fusions / conditionals referencing other computations
            for attr in ("to_apply=", "calls="):
                if attr in line:
                    mname = re.search(attr + r"%?([\w.\-]+)", line)
                    if mname:
                        inner = comp_bytes(mname.group(1), depth + 1)
                        for k, v in inner.items():
                            total[k] = total.get(k, 0) + v
        memo[name] = total
        return total

    return comp_bytes(entry)
