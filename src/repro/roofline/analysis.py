"""Three-term roofline model over dry-run artifacts (deliverable g).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (bidirectional ⇒ 2× per-link bytes/s in the collective
term; a 2-D torus gives each chip multiple links, but we charge the single
busiest link — conservative).

``compiled.cost_analysis()`` counts a while-loop body **once**; scanned
transformers execute theirs L (layers) × M (microbatches) times.  The
collective side is fixed by roofline/hlo.py's trip-count-aware parser.  For
FLOPs/bytes we use the two-point method: lower the same cell at two layer
counts and extrapolate

    per_layer = (cost(L₂) − cost(L₁)) / (L₂ − L₁)
    total     = cost(L₁) + (L − L₁) · per_layer

which is exact for layer-homogeneous stacks (all ours are, per group).
benchmarks/roofline_table.py drives this.

``roofline_terms`` is also the cost kernel of the solver-scheduling planner
(``repro.core.solvers.planner``, DESIGN.md §9): per-FW-iteration FLOP/byte
counts are fed through the same three-term bound — with the planner's
conservative CPU constants substituted via the ``peak_flops``/``hbm_bw``
keywords on host platforms — to choose between Alg-1/Alg-2 engines and
between vmapped and sequential sweep execution.
"""
from __future__ import annotations

from typing import Dict, Optional

# TPU v5e
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link per direction


def roofline_terms(*, flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
                   ici_bw: float = ICI_BW) -> Dict[str, float]:
    """The three roofline times (seconds) + dominant bottleneck.

    ``flops``/``bytes_accessed`` are per-device (that's what
    cost_analysis() of an SPMD module reports), so the per-chip rates apply
    directly; ``collective_bytes`` is per-device bytes crossing its busiest
    link (2× for bidirectional links).
    """
    t_comp = flops / peak_flops
    t_mem = bytes_accessed / hbm_bw
    t_coll = collective_bytes / (2.0 * ici_bw)
    terms = {"t_compute_s": t_comp, "t_memory_s": t_mem,
             "t_collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    terms["bottleneck"] = {"t_compute_s": "compute", "t_memory_s": "memory",
                           "t_collective_s": "collective"}[dominant]
    terms["t_bound_s"] = max(t_comp, t_mem, t_coll)
    terms["roofline_fraction"] = (t_comp / terms["t_bound_s"]
                                  if terms["t_bound_s"] > 0 else 0.0)
    return terms


def model_flops(n_params: float, tokens: float, *, active_params: Optional[float] = None,
                training: bool = True) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference); MoE uses N_active."""
    n = active_params if active_params is not None else n_params
    return (6.0 if training else 2.0) * n * tokens


def two_point_total(cost_l1: float, cost_l2: float, l1: int, l2: int,
                    l_target: int) -> float:
    """Extrapolate a per-layer-homogeneous cost to the full layer count."""
    per_layer = (cost_l2 - cost_l1) / max(l2 - l1, 1)
    return cost_l1 + (l_target - l1) * per_layer
