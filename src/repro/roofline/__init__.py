from repro.roofline.hlo import collective_bytes_nested, parse_hlo_computations  # noqa: F401
