"""Fault-tolerant checkpointing (no orbax offline — built from scratch).

Design for 1000+ node runs:

* **atomic**: write to ``<dir>/tmp.<step>``, fsync, then ``os.rename`` to
  ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint;
* **rotation**: keep the most recent ``keep`` checkpoints + every
  ``keep_every`` multiple (cold storage anchors);
* **elastic restore**: arrays are saved as *global* host arrays keyed by
  pytree path; ``restore`` re-places them under any target sharding/mesh, so
  a run checkpointed on (pod=2, data=16, model=16) resumes on a different
  data-axis size (elastic scaling) or a single host (debugging);
* **metadata**: step, privacy-accountant state (DP budget survives restarts),
  mesh shape, and a content manifest for integrity checking.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any


def _key_str(path) -> str:
    parts = []
    for p in path:
        v = getattr(p, "key", None)
        if v is None:
            v = getattr(p, "idx", None)
        if v is None:
            v = getattr(p, "name", None)     # GetAttrKey (TrainState fields)
        parts.append(str(p if v is None else v))
    return "/".join(parts)


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key_str(path)] = np.asarray(leaf)
    return flat


def save_pytree(tree, path: str, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    flat = _flatten_with_paths(tree)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if metadata is not None:
        mtmp = path + ".meta.tmp"
        with open(mtmp, "w") as f:
            json.dump(metadata, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, path + ".meta.json")


def restore_pytree(template, path: str, shardings=None):
    """Restore into the structure of ``template``.  ``shardings``: optional
    pytree (matching template) of jax.sharding.Sharding for elastic re-place."""
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = [
        _key_str(path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(template)[0]
    ]
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for key, tmpl, shd in zip(paths, leaves_t, shard_leaves):
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"checkpoint mismatch at {key}: {arr.shape} vs {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, keep_every: int = 0):
        self.dir = directory
        self.keep = keep
        self.keep_every = keep_every
        os.makedirs(directory, exist_ok=True)

    def _step_dirs(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and name.endswith(".npz"):
                try:
                    steps.append(int(name[5:-4]))
                except ValueError:
                    pass
        return sorted(steps)

    def save(self, state, metadata: Optional[dict] = None) -> str:
        step = int(np.asarray(jax.tree.leaves(state)[0])) if metadata is None else metadata.get("step", 0)
        try:
            step = int(np.asarray(state.step))
        except AttributeError:
            pass
        meta = dict(metadata or {})
        meta["step"] = step
        path = os.path.join(self.dir, f"step_{step}.npz")
        save_pytree(state, path, meta)
        self._rotate()
        return path

    def _rotate(self):
        steps = self._step_dirs()
        if len(steps) <= self.keep:
            return
        for s in steps[: -self.keep]:
            if self.keep_every and s % self.keep_every == 0:
                continue  # cold-storage anchor
            for suffix in (".npz", ".npz.meta.json"):
                p = os.path.join(self.dir, f"step_{s}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    def latest_step(self) -> Optional[int]:
        steps = self._step_dirs()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}.npz")
        state = restore_pytree(template, path, shardings)
        meta_path = path + ".meta.json"
        meta = {}
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
        return state, meta
