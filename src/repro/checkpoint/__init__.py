from repro.checkpoint.checkpointer import Checkpointer, restore_pytree, save_pytree  # noqa: F401
