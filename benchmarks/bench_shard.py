"""Shard bench — ``jax_sparse`` (kernel scan) vs ``jax_shard`` (collective
schedule) on one device (DESIGN.md §8).

Both engines are the same Algorithm-2 state machine; on a 1×1 mesh every
collective in ``jax_shard`` degenerates to the identity, so the two must
take **identical non-private steps** — the step-parity audit asserts exact
coordinate equality and float-tolerance weights/gaps against the faithful
host engine as referee.  The wall-clock columns then isolate what the
blocked layout itself costs/saves per sparsity regime (rcv1: short rows,
news20: long rows / D ≫ N) before any communication enters:

  * per-iteration time of each engine (steady state, compile excluded);
  * block padding waste (padded/true nnz) vs the ELL pair's overhead — the
    memory price of the static (Kc, Kr) block shape;
  * a private solve on each engine (same ε-semantics via
    ``core.dp.accountant``) — law-level sanity: finite weights on the L1
    ball with exploring selections, since realizations differ by design.

Output: one row per dataset into BENCH_shard.json (``run.py --only shard``;
uploaded as a CI artifact alongside the sweep/ingest benches).
"""
from __future__ import annotations

import time

import numpy as np


def _time_solve(backend, data, y, cfg, steps: int) -> tuple:
    res = backend.fn(data, y, cfg)                 # warmup (compile)
    np.asarray(res.w)
    t0 = time.time()
    res = backend.fn(data, y, cfg)
    np.asarray(res.w)                              # block on device work
    return res, (time.time() - t0) / steps * 1e3


def run(datasets=("rcv1", "news20"), steps: int = 60, lam: float = 20.0,
        epsilon: float = 1.0, mesh: tuple = (1, 1)):
    from benchmarks.common import load_problem
    from repro.core.solvers import FWConfig, get_backend, resolve_queue

    mesh = tuple(int(m) for m in mesh)
    out = {"steps": steps, "lam": lam, "mesh": list(mesh), "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        row = {"n": prob.X.shape[0], "d": prob.X.shape[1],
               "density": prob.X.nnz / (prob.X.shape[0] * prob.X.shape[1])}
        results, prepared = {}, {}
        for bname in ("jax_sparse", "jax_shard"):
            backend = get_backend(bname)
            cfg = resolve_queue(backend, FWConfig(
                backend=bname, lam=lam, steps=steps,
                mesh=mesh if bname == "jax_shard" else None))
            data = prepared[bname] = backend.prepare(prob.X)
            res, per_iter_ms = _time_solve(backend, data, prob.y, cfg, steps)
            results[bname] = res
            row[f"per_iter_ms_{bname}"] = round(per_iter_ms, 2)
            if bname == "jax_shard":
                # waste of the grid actually benchmarked, plus the 1×1
                # figure every report has carried (comparable across meshes)
                row["block_waste"] = round(data.blocks(*mesh).waste, 2)
                row["block_waste_1x1"] = round(data.blocks(1, 1).waste, 2)

        # ---- step-parity audit: identical non-private trajectories -------
        a, b = results["jax_sparse"], results["jax_shard"]
        coords_equal = bool(np.array_equal(np.asarray(a.coords),
                                           np.asarray(b.coords)))
        max_w_dev = float(np.max(np.abs(np.asarray(a.w) - np.asarray(b.w))))
        max_gap_dev = float(np.max(np.abs(np.asarray(a.gaps)
                                          - np.asarray(b.gaps))))
        row.update(
            max_w_dev=max_w_dev, max_gap_dev=max_gap_dev,
            pass_parity=bool(coords_equal and max_w_dev < 1e-4
                             and max_gap_dev < 1e-4),
            shard_over_sparse=round(
                row["per_iter_ms_jax_shard"]
                / max(row["per_iter_ms_jax_sparse"], 1e-9), 2))

        # ---- private solves: same accountant semantics, law-level sanity -
        for bname in ("jax_sparse", "jax_shard"):
            backend = get_backend(bname)
            cfg = resolve_queue(backend, FWConfig(
                backend=bname, lam=lam, steps=steps, queue="bsls",
                epsilon=epsilon, delta=1e-6,
                mesh=mesh if bname == "jax_shard" else None))
            res = backend.fn(prepared[bname], prob.y, cfg)
            w = np.asarray(res.w)
            row[f"dp_ok_{bname}"] = bool(
                np.isfinite(w).all()
                and np.abs(w).sum() <= lam * (1 + 1e-5)
                and len(set(np.asarray(res.coords).tolist())) > 5)
        row["pass_dp"] = bool(row["dp_ok_jax_sparse"]
                              and row["dp_ok_jax_shard"])

        out["datasets"][name] = row
        print(f"[shard] {name}: sparse {row['per_iter_ms_jax_sparse']} "
              f"ms/iter, shard {row['per_iter_ms_jax_shard']} ms/iter "
              f"(waste {row['block_waste']}x)  parity={row['pass_parity']} "
              f"dp={row['pass_dp']}", flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
