"""Vectorized-numpy Algorithm 1 (standard sparse-aware DP Frank-Wolfe).

The Table-3 wall-clock baseline.  Fairness notes: the sparse products use
``np.add.reduceat`` over CSR (vectorized, no Python loop — a *stronger*
baseline than the paper's per-row Java loops), while the per-iteration O(D)
work (α assembly, noising/scoring all D coordinates, dense direction) is
exactly what the paper's Alg 1 does and is what Alg 2+4 eliminates.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.dp.accountant import fw_noise_scale
from repro.core.sparse.formats import HostCSR


@dataclasses.dataclass
class HostAlg1Result:
    w: np.ndarray
    gaps: np.ndarray
    coords: np.ndarray
    wall_s: float
    flops: int


def _csr_matvec(X: HostCSR, w: np.ndarray) -> np.ndarray:
    prod = X.data * w[X.indices]
    # reduceat needs non-empty segments: guard empty rows via indptr clipping
    out = np.add.reduceat(np.concatenate([prod, [0.0]]),
                          np.minimum(X.indptr[:-1], prod.shape[0]))
    out[np.diff(X.indptr) == 0] = 0.0
    return out


def _csr_rmatvec(X: HostCSR, q: np.ndarray) -> np.ndarray:
    row_ids = np.repeat(np.arange(X.shape[0]), np.diff(X.indptr))
    return np.bincount(X.indices, weights=X.data * q[row_ids],
                       minlength=X.shape[1])


def host_alg1(X: HostCSR, y: np.ndarray, *, lam: float = 50.0,
              steps: int = 1000, epsilon: float = 0.0, delta: float = 1e-6,
              seed: int = 0) -> HostAlg1Result:
    """ε > 0 → Laplace report-noisy-max (the paper's DP Alg 1); else argmax."""
    n, d = X.shape
    rng = np.random.default_rng(seed)
    b = (fw_noise_scale(epsilon=epsilon, delta=delta, steps=steps, lam=lam,
                        lipschitz=1.0, n_rows=n) if epsilon > 0 else 0.0)
    ybar = _csr_rmatvec(X, y) / n
    w = np.zeros(d)
    gaps = np.empty(steps)
    coords = np.empty(steps, np.int64)
    nnz = X.nnz
    flops = 2 * nnz + d
    t0 = time.time()
    for t in range(1, steps + 1):
        v = _csr_matvec(X, w)                         # O(nnz)
        q = 1.0 / (1.0 + np.exp(-v))                  # O(N)
        alpha = _csr_rmatvec(X, q) / n - ybar         # O(nnz + D)
        score = lam * np.abs(alpha)                   # O(D)
        if b > 0.0:
            score = score + rng.laplace(0.0, b, d)    # O(D) — DP noise on all D
        j = int(np.argmax(score))                     # O(D)
        s_j = -lam * np.sign(alpha[j]) if alpha[j] != 0 else lam
        dvec = -w                                     # O(D)
        dvec[j] += s_j
        gaps[t - 1] = -alpha @ dvec                   # O(D)
        coords[t - 1] = j
        eta = 2.0 / (t + 2.0)
        w = w + eta * dvec                            # O(D)
        flops += 4 * nnz + 4 * n + 6 * d
    return HostAlg1Result(w=w, gaps=gaps, coords=coords,
                          wall_s=time.time() - t0, flops=flops)
