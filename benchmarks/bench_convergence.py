"""Figure 1 — convergence gap g_t of Alg 1 vs Alg 2 over iterations.

Claim reproduced: the two traces are near-identical (Alg 2 takes the same
steps up to near-ties; identical final quality)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import load_problem, run_backend
from benchmarks.host_alg1 import host_alg1


def run(datasets=("rcv1", "news20"), steps: int = 300, lam: float = 50.0,
        backend: str = "host_sparse") -> Dict:
    out = {"figure": "1", "claim": "Alg2 converges to the same solution as Alg1",
           "alg2_backend": backend, "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        r1 = host_alg1(prob.X, prob.y, lam=lam, steps=steps)
        r2 = run_backend(prob, backend, lam=lam, steps=steps, queue="fib_heap")
        g1, g2 = np.asarray(r1.gaps), np.asarray(r2.gaps)
        c1, c2 = np.asarray(r1.coords), np.asarray(r2.coords)
        same_prefix = int(np.argmax(c1 != c2)) if (c1 != c2).any() else steps
        rel_final = abs(g1[-1] - g2[-1]) / max(abs(g1[-1]), 1e-12)
        out["datasets"][name] = {
            "steps": steps,
            "identical_step_prefix": same_prefix,
            "final_gap_alg1": float(g1[-1]),
            "final_gap_alg2": float(g2[-1]),
            "final_gap_rel_diff": float(rel_final),
            "gap_trace_alg1": g1[:: max(steps // 20, 1)].tolist(),
            "gap_trace_alg2": g2[:: max(steps // 20, 1)].tolist(),
            "pass": bool(rel_final < 0.5),
        }
    return out
