"""Table 4 — accuracy / AUC / sparsity at high privacy (ε = 0.1) with a large
iteration budget, λ scaled up (the paper uses λ=5000, T=400k at full scale;
the CPU twins use proportionally scaled T).

Claim reproduced: non-trivial accuracy at ε = 0.1 *because* many iterations
are affordable, and the solution stays sparse (nnz ≤ T ≪ D)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import accuracy_auc, load_problem, run_backend, sparsity_pct


def run(datasets=("rcv1", "news20", "url"), steps: int = 2000,
        lam: float = 200.0, epsilon: float = 0.1,
        backend: str = "host_sparse") -> Dict:
    out = {"table": "4",
           "claim": "non-trivial accuracy at ε=0.1 via many cheap iterations",
           "backend": backend, "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        delta = 1.0 / prob.X.shape[0] ** 2
        r = run_backend(prob, backend, lam=lam, steps=steps, queue="bsls",
                        epsilon=epsilon, delta=delta)
        acc, auc = accuracy_auc(prob.X, prob.y, np.asarray(r.w))
        # non-private reference ceiling at the same budget
        r_np = run_backend(prob, backend, lam=lam, steps=steps,
                           queue="fib_heap")
        acc_np, _ = accuracy_auc(prob.X, prob.y, np.asarray(r_np.w))
        out["datasets"][name] = {
            "epsilon": epsilon, "steps": steps, "lambda": lam,
            "accuracy_pct": round(100 * acc, 2),
            "auc_pct": round(100 * auc, 2),
            "sparsity_pct": round(sparsity_pct(r.w), 2),
            "nonprivate_accuracy_pct": round(100 * acc_np, 2),
            "nnz": int(r.nnz),
            "pass": bool(acc > 0.55 and r.nnz <= steps + 1),
        }
    return out
