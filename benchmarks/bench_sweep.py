"""Sweep bench — fixed-T sequential ``solve()`` vs gap-adaptive ``solve_many()``.

The paper's experiments (and any real deployment) fit a grid of (λ, ε)
problems over one design matrix.  Without a stopping certificate a user must
run every config for all T iterations — that is the **sequential fixed-T
baseline** timed here (the naive loop a user would write).  The gap-adaptive
scheduler (DESIGN.md §9) instead stops each config the moment its FW
duality-gap certificate lands and retires it from the batch, so the grid
stops paying for its slowest member; ``batched_s`` times that path through
``solve_many`` with the planner choosing the execution mode.

Per-config stopping targets are derived from the baseline's own gap traces
(the prefix-minimum at a target step, spread across the grid so configs
converge at different times), which makes the audit exact:

  * ``pass_stop``   — every config's ``stop_step`` equals the first index at
    which the baseline trace crosses its tolerance (+1): the scheduler stops
    exactly where the full run says it should;
  * ``pass_parity`` — batched coords/weights are identical to a sequential
    early-stopped ``solve()`` of the same config (same state machine, same
    keys), and the coords prefix matches the fixed-T baseline's.

All programs are compile-warmed before any timing (``warmup_s``), so the
speedup compares steady-state scheduling, not compilation accidents.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


def _pick_tols(seq_results, steps: int, frac_lo: float, frac_hi: float):
    """Per-config gap tolerances whose first crossing lands *at* a stop
    target spread across the grid.

    Noisy DP gap traces attain their prefix minimum early, so "min of the
    first k steps" collapses every stop to step ~1 and the bench would only
    measure truncation, not scheduling.  Instead each config's tolerance is
    the trace value at the first step ≥ its target that sets a **strict new
    running minimum** — every earlier gap is larger, so the first crossing
    is exactly that step, and the grid genuinely converges at spread-out
    times (the premise of cohort retirement)."""
    n_cfg = len(seq_results)
    tols, expected = [], []
    for i, res in enumerate(seq_results):
        gaps = np.asarray(res.gaps)
        frac = frac_lo + (frac_hi - frac_lo) * (i / max(n_cfg - 1, 1))
        target = max(1, min(int(steps * frac), steps - 1))
        run_min = np.minimum.accumulate(gaps)
        strict = np.zeros(steps, bool)
        strict[0] = True
        strict[1:] = gaps[1:] < run_min[:-1]
        cands = np.nonzero(strict)[0]
        at_or_after = cands[cands >= target]
        k = int(at_or_after[0]) if at_or_after.size else int(cands[-1])
        tol = max(float(gaps[k]), 1e-7)
        tols.append(tol)
        expected.append(int(np.argmax(gaps <= np.float32(tol))) + 1)
    return tols, expected


def run(datasets=("rcv1", "news20"), lams=(10.0, 20.0, 40.0, 80.0),
        epsilons=(0.5, 2.0), steps: int = 60, backend: str = "jax_sparse",
        stop_fracs=(0.3, 0.9)):
    """``datasets`` entries are either a name (logistic loss) or a
    ``(name, loss)`` pair — e.g. ``("rcv1", "huber")`` sweeps the same grid
    under a non-logistic registered objective (result row ``rcv1_huber``),
    so the perf gate pins scheduling + parity per loss, not just for the
    paper's logistic runs."""
    from benchmarks.common import load_problem
    from repro.core.solvers import FWConfig, grid, solve, solve_many
    from repro.core.solvers.planner import plan_for

    out = {"grid": {"lam": list(lams), "epsilon": list(epsilons)},
           "steps": steps, "backend": backend, "datasets": {}}
    for entry in datasets:
        name, loss = entry if isinstance(entry, tuple) else (entry, "logistic")
        row_key = name if loss == "logistic" else f"{name}_{loss}"
        prob = load_problem(name)
        configs = grid(FWConfig(backend=backend, steps=steps, queue="bsls",
                                delta=1e-6, loss=loss),
                       lam=lams, epsilon=epsilons)

        # ---- warm every compiled program off the clock -------------------
        t0 = time.time()
        solve(prob.X, prob.y, configs[0])                       # fixed-T scan
        solve(prob.X, prob.y,
              dataclasses.replace(configs[0], gap_tol=1e30))    # chunked scan
        warmup_s = time.time() - t0

        # ---- sequential fixed-T baseline (no certificate → all T steps) --
        t0 = time.time()
        seq = [solve(prob.X, prob.y, c) for c in configs]
        _ = [np.asarray(r.w) for r in seq]           # block on device work
        sequential_s = time.time() - t0

        # ---- gap-adaptive configs from the observed traces ---------------
        tols, expected_stop = _pick_tols(seq, steps, *stop_fracs)
        adaptive = [dataclasses.replace(c, gap_tol=t)
                    for c, t in zip(configs, tols)]

        # sequential early-stopped reference (parity oracle + its own time)
        t0 = time.time()
        seq_adaptive = [solve(prob.X, prob.y, c) for c in adaptive]
        _ = [np.asarray(r.w) for r in seq_adaptive]
        sequential_adaptive_s = time.time() - t0

        # ---- the scheduler under test ------------------------------------
        plan = plan_for(prob.X, adaptive)
        t0 = time.time()
        batched = solve_many(prob.X, prob.y, adaptive)
        _ = [np.asarray(r.w) for r in batched]
        batched_s = time.time() - t0

        stop_steps = [r.stop_step_or(steps) for r in batched]
        stop_ok = (stop_steps == expected_stop
                   and stop_steps == [r.stop_step_or(steps)
                                      for r in seq_adaptive]
                   and all(r.stop_reason == "gap_tol" for r in batched))
        # parity at each config's stop step: identical to the sequential
        # early-stopped run, and a true prefix of the fixed-T baseline
        max_w_dev = max(
            float(np.max(np.abs(np.asarray(b.w) - np.asarray(s.w))))
            for b, s in zip(batched, seq_adaptive))
        coords_equal = all(
            np.array_equal(np.asarray(b.coords), np.asarray(s.coords))
            for b, s in zip(batched, seq_adaptive))
        prefix_equal = all(
            np.array_equal(np.asarray(b.coords)[:ss],
                           np.asarray(f.coords)[:ss])
            for b, f, ss in zip(batched, seq, stop_steps))
        row = {
            "loss": loss,
            "n": prob.X.shape[0], "d": prob.X.shape[1],
            "density": prob.X.nnz / (prob.X.shape[0] * prob.X.shape[1]),
            "configs": len(configs),
            "plan_mode": plan.resolved_mode(),
            "warmup_s": round(warmup_s, 2),
            "sequential_s": round(sequential_s, 2),
            "sequential_adaptive_s": round(sequential_adaptive_s, 2),
            "batched_s": round(batched_s, 2),
            "sweep_speedup": round(sequential_s / max(batched_s, 1e-9), 2),
            "stop_steps": stop_steps,
            "mean_stop_frac": round(float(np.mean(stop_steps)) / steps, 3),
            "max_w_dev": max_w_dev,
            "pass_stop": bool(stop_ok),
            "pass_parity": bool(coords_equal and prefix_equal
                                and max_w_dev == 0.0),
        }
        out["datasets"][row_key] = row
        print(f"[sweep] {row_key}: {len(configs)} cfgs  "
              f"seq-fixed {sequential_s:.1f}s  batched-adaptive "
              f"{batched_s:.1f}s  ({row['sweep_speedup']}x)  "
              f"stops={stop_steps}  parity={row['pass_parity']}  "
              f"stop_audit={row['pass_stop']}", flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
