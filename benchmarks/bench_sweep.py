"""Sweep bench — sequential ``solve()`` vs batched ``solve_many()``.

The paper's experiments (and any real deployment) fit a grid of (λ, ε)
problems over one design matrix.  This bench times both paths end-to-end on
the paper's sparsity regimes — the API a user would actually call, so the
sequential side pays per-call coercion/compile exactly as a naive loop does,
and the batched side pays one coercion + one vmapped compile.

Output row per dataset: grid shape, wall-clock for both paths, speedup, and
a parity audit (max |Δw| between the batched and sequential solutions on
identical keys — must sit at float tolerance, it is the same state machine).
"""
from __future__ import annotations

import time

import numpy as np


def run(datasets=("rcv1", "news20"), lams=(10.0, 20.0, 40.0, 80.0),
        epsilons=(0.5, 2.0), steps: int = 60, backend: str = "jax_sparse"):
    from benchmarks.common import load_problem
    from repro.core.solvers import FWConfig, grid, solve, solve_many

    out = {"grid": {"lam": list(lams), "epsilon": list(epsilons)},
           "steps": steps, "backend": backend, "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        configs = grid(FWConfig(backend=backend, steps=steps, queue="bsls",
                                delta=1e-6),
                       lam=lams, epsilon=epsilons)

        t0 = time.time()
        batched = solve_many(prob.X, prob.y, configs)
        _ = [np.asarray(r.w) for r in batched]       # block on device work
        batched_s = time.time() - t0

        t0 = time.time()
        seq = [solve(prob.X, prob.y, c) for c in configs]
        _ = [np.asarray(r.w) for r in seq]
        sequential_s = time.time() - t0

        max_w_dev = max(
            float(np.max(np.abs(np.asarray(b.w) - np.asarray(s.w))))
            for b, s in zip(batched, seq))
        coords_equal = all(
            np.array_equal(np.asarray(b.coords), np.asarray(s.coords))
            for b, s in zip(batched, seq))
        row = {
            "n": prob.X.shape[0], "d": prob.X.shape[1],
            "density": prob.X.nnz / (prob.X.shape[0] * prob.X.shape[1]),
            "configs": len(configs),
            "sequential_s": round(sequential_s, 2),
            "batched_s": round(batched_s, 2),
            "sweep_speedup": round(sequential_s / max(batched_s, 1e-9), 2),
            "max_w_dev": max_w_dev,
            "pass_parity": bool(coords_equal and max_w_dev < 1e-4),
        }
        out["datasets"][name] = row
        print(f"[sweep] {name}: {len(configs)} cfgs  "
              f"seq {sequential_s:.1f}s  batched {batched_s:.1f}s  "
              f"({row['sweep_speedup']}x)  parity={row['pass_parity']}",
              flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
