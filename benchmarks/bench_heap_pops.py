"""Figure 3 — Fibonacci-heap pops per getNext call, relative to ‖w*‖₀.

Claim reproduced: the ratio stays small (≤ ~3 in the paper), i.e. the lazy
stale-bound queue rarely needs to repair more than a handful of entries."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import load_problem
from repro.core.fw_sparse import sparse_fw


def run(datasets=("rcv1", "url"), steps: int = 400, lam: float = 50.0) -> Dict:
    out = {"figure": "3", "claim": "pops per selection ≲ 3·‖w*‖₀ overall",
           "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        r = sparse_fw(prob.X, prob.y, lam=lam, steps=steps, queue="fib_heap")
        nnz = max(r.nnz, 1)
        pops_per_call = r.pops / steps
        ratio = r.pops / (steps * nnz)
        out["datasets"][name] = {
            "total_pops": int(r.pops),
            "pops_per_getnext": float(pops_per_call),
            "solution_nnz": int(nnz),
            "pops_over_nnz_ratio": float(ratio),
            "pass": bool(ratio <= 3.0),
        }
    return out
