"""One registry of benchmark suite entries — names, runners, perf-gate rules.

``run.py`` (which benches exist, what ``--only`` accepts, fast/full knobs)
and ``check.py`` (which ``BENCH_*.json`` artifacts are gated, by what rules)
used to carry separately hand-maintained tables, and they drifted: at one
point ``docs/BENCHMARKS.md`` documented ``--only`` names ``run.py`` did not
recognize.  This module is now the single source of truth — ``run.py``
builds its suite from :data:`SUITE` and validates ``--only`` against
:func:`names`; ``check.py`` derives its ``SPEC`` from :func:`gate_spec`;
``docs/BENCHMARKS.md`` lists the same names.

Each :class:`BenchSpec` bundles:

  * ``name``  — the suite key; the artifact is ``BENCH_<name>.json``;
  * ``title`` — one-liner for ``--help`` and the docs table;
  * ``run``   — ``(fast, backend, dryrun_json) -> result doc`` with lazy
    imports, so listing the suite never imports jax;
  * ``gate``  — ``check.py`` rule tuples (empty = artifact is informational,
    not gated).  Rule kinds: ``("flags",)`` | ``("min"|"max", metric, bound)``
    | ``("rel_min"|"rel_max", metric, factor)`` (relative bands are skipped
    in ``--mode full``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    name: str
    title: str
    run: Callable[[bool, str, str], dict]
    gate: Tuple[tuple, ...] = ()


def _fig1(fast, backend, dryrun_json):
    from benchmarks import bench_convergence
    return bench_convergence.run(
        datasets=("rcv1",) if fast else ("rcv1", "news20"),
        steps=150 if fast else 300, backend=backend or "host_sparse")


def _fig2_4(fast, backend, dryrun_json):
    from benchmarks import bench_flops
    return bench_flops.run(
        datasets=("rcv1",) if fast else ("rcv1", "news20", "kdda"),
        steps=150 if fast else 300)


def _fig3(fast, backend, dryrun_json):
    from benchmarks import bench_heap_pops
    return bench_heap_pops.run(
        datasets=("rcv1",) if fast else ("rcv1", "url"),
        steps=200 if fast else 400)


def _table3(fast, backend, dryrun_json):
    from benchmarks import bench_speedup
    return bench_speedup.run(
        datasets=("rcv1", "url") if fast else
        ("rcv1", "news20", "url", "web", "kdda"),
        steps=100 if fast else 200)


def _table4(fast, backend, dryrun_json):
    from benchmarks import bench_accuracy
    return bench_accuracy.run(
        datasets=("rcv1",) if fast else ("rcv1", "news20", "url"),
        steps=800 if fast else 2000, backend=backend or "host_sparse")


def _sweep(fast, backend, dryrun_json):
    from benchmarks import bench_sweep
    return bench_sweep.run(
        datasets=("rcv1", "news20", ("rcv1", "huber")),
        lams=(10.0, 20.0, 40.0, 80.0), epsilons=(0.5, 2.0),
        steps=40 if fast else 120, backend=backend or "jax_sparse")


def _shard(fast, backend, dryrun_json):
    from benchmarks import bench_shard
    return bench_shard.run(
        datasets=("rcv1",) if fast else ("rcv1", "news20"),
        steps=30 if fast else 80)


def _autotune(fast, backend, dryrun_json):
    from benchmarks import bench_autotune
    return bench_autotune.run(
        datasets=("rcv1",) if fast else ("rcv1", "news20"),
        steps=20 if fast else 40)


def _screening(fast, backend, dryrun_json):
    from benchmarks import bench_screening
    return bench_screening.run(
        datasets=("rcv1",) if fast else ("rcv1", "url"),
        steps=240 if fast else 320)


def _path(fast, backend, dryrun_json):
    from benchmarks import bench_path
    return bench_path.run(
        datasets=("rcv1",) if fast else ("rcv1", "url"),
        steps=120 if fast else 240)


def _ingest(fast, backend, dryrun_json):
    from benchmarks import bench_ingest
    return bench_ingest.run(
        datasets=("rcv1_like",) if fast else ("rcv1_like", "url_small_like"),
        steps=30 if fast else 80, backend=backend or "jax_sparse")


def _scaling(fast, backend, dryrun_json):
    from benchmarks import bench_scaling
    return bench_scaling.run(
        d_values=(10_000, 100_000) if fast else
        (10_000, 100_000, 400_000, 800_000),
        steps=100 if fast else 150)


def _roofline(fast, backend, dryrun_json):
    from benchmarks import roofline_table
    return roofline_table.run(dryrun_json)


SUITE: Tuple[BenchSpec, ...] = (
    BenchSpec("fig1_convergence", "Fig 1: Alg 1 vs Alg 2 gap traces", _fig1),
    BenchSpec("fig2_4_flops", "Fig 2/4: FLOPs-reduction factor", _fig2_4),
    BenchSpec("fig3_heap_pops", "Fig 3: heap pops / ‖w*‖₀", _fig3),
    BenchSpec("table3_speedup",
              "Table 3: DP wall-clock speedup (Alg 2+4, ablation)", _table3),
    BenchSpec("table4_accuracy",
              "Table 4: accuracy/AUC/sparsity at ε = 0.1", _table4),
    BenchSpec("sweep", "batched solve_many() vs sequential solve() loop",
              _sweep, gate=(
                  ("flags",),
                  # the §9 tentpole invariant: gap-adaptive batched
                  # scheduling must beat the fixed-T sequential loop it
                  # replaced, on every dataset
                  ("min", "sweep_speedup", 1.0),
                  ("rel_min", "sweep_speedup", 0.5),
              )),
    BenchSpec("shard", "jax_sparse vs jax_shard + step-parity audit",
              _shard, gate=(
                  ("flags",),
                  # jax_shard per-iter cost relative to jax_sparse on the
                  # 1×1 CPU mesh (lower is better; same-run timing ratio)
                  ("rel_max", "shard_over_sparse", 3.0),
              )),
    BenchSpec("autotune", "§11 layout/chunk autotuner gains + parity gate",
              _autotune, gate=(
                  ("flags",),   # pass_tuned_parity: bitwise, never a timing
                  # the §11 search must never pick a layout slower than the
                  # flat default, and on the power-law text regimes it must
                  # find a real win (ISSUE-7: ≤ 0.8× default on rcv1)
                  ("max", "tuned_over_default", 0.8),
                  ("min", "tuned_speedup", 1.0),
                  ("rel_min", "tuned_speedup", 0.5),
              )),
    BenchSpec("screening", "§13 DP iterative screening vs plain chunked solve",
              _screening, gate=(
                  ("flags",),   # pass_utility (equal-ε accuracy audit)
                                # + pass_coords (original-index contract)
                  # the §13 tentpole invariant: mid-solve screening must make
                  # the private solve ≥ 1.5× faster at equal total ε
                  ("min", "screen_speedup", 1.5),
                  ("rel_min", "screen_speedup", 0.5),
              )),
    BenchSpec("path", "§14 warm-started λ-path vs per-λ from-scratch solves",
              _path, gate=(
                  ("flags",),   # pass_utility + pass_gap + pass_eps_split
                  # the §14 tentpole invariant: the homotopy path must solve
                  # the whole λ-grid ≥ 2× faster than independent per-λ
                  # solves at equal total ε
                  ("min", "path_speedup", 2.0),
                  ("rel_min", "path_speedup", 0.5),
              )),
    BenchSpec("ingest", "dataset-store ingest + cold/warm prepare",
              _ingest, gate=(
                  ("flags",),
                  # warm store opens must keep skipping the setup sweep
                  ("min", "warm_setup_speedup", 2.0),
                  ("rel_min", "warm_setup_speedup", 0.25),
              )),
    BenchSpec("scaling_beyond", "speedup vs D beyond the paper's grid",
              _scaling),
    BenchSpec("roofline", "three-term cost model from dryrun_results.json",
              _roofline),
)


def names() -> Tuple[str, ...]:
    return tuple(s.name for s in SUITE)


def gate_spec() -> Dict[str, List[tuple]]:
    """check.py's SPEC: gated artifact file → rule list."""
    return {f"BENCH_{s.name}.json": list(s.gate) for s in SUITE if s.gate}
