"""λ-path bench — §14 warm-started homotopy vs per-λ from-scratch solves.

Two arms of the same private ``jax_sparse`` fit at **equal total ε** on a
held-out split of each dataset twin:

  * ``path``    — one ``run_path`` call over the decreasing λ-grid: the
    first λ solves cold at the full budget, every later λ warm-starts from
    the previous carry at the planner's warm budget, all segments re-enter
    one compiled chunk;
  * ``scratch`` — one independent solve per λ, each at the full budget and
    at ε/√K, so the K solves compose to exactly the path's total ε at the
    same uniform per-selection rate (advanced composition).

Reported per dataset: steady-state wall time of each arm (both arms run
twice, second pass timed — deterministic seeding makes the passes
identical), the headline ``path_speedup`` ratio, and three audits:

  * ``pass_utility`` — on a non-private run of the same grid, every warm
    segment's held-out accuracy is within ``UTILITY_TOL`` of a cold solve
    given the same iteration budget: warm-starting must not cost solution
    quality, λ by λ, measured without DP noise in the way;
  * ``pass_utility_dp`` — the same per-λ audit on the equal-ε private
    arms, at the wider ``DP_UTILITY_TOL``: at twin scale N every private
    fit sits in a ±0.05 chance band around ~0.5 held-out accuracy (so does
    the *non-private* fit — see the committed BENCH_screening baseline),
    and the path and scratch arms are *different* mechanisms whose chance
    fluctuations don't cancel the way bench_screening's same-mechanism
    arms do — the DP audit therefore only catches systematic collapse,
    not twin-scale weather;
  * ``pass_gap`` — every warm segment's final duality gap is no worse than
    the cold-at-equal-budget solve's (the §14 claim: the carry is worth
    its budget); segment 0 must also match its standalone single-λ solve
    bit-for-bit (``pass_parity`` — the ``segment_config`` contract);
  * ``pass_eps_split`` — the plan's per-λ ε shares all sit at the single
    uniform per-selection rate (machine-independent accounting identity).

Output: BENCH_path.json (``run.py --only path``; gated by ``check.py`` on
``path_speedup`` ≥ 2 and the audit flags — see benchmarks/suite.py).
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.bench_screening import TRAIN_FRACTION, UTILITY_TOL, _row_split

GAP_SLACK = 1.5      # warm-segment gap vs cold-at-equal-budget gap
GAP_FLOOR = 0.05     # absolute slack when the cold gap is already tiny
DP_UTILITY_TOL = 0.10  # twin-scale chance band (see docstring)
# decreasing ball radii bracketing the twins' operating point (λ ≈ 30, the
# screening bench's): big radii amplify the EM selection noise at twin N
# (weight η·λ lands on every noisy pick), tiny radii underfit — either way
# both arms drop to chance accuracy and the utility audit compares noise
LAMBDAS = (50.0, 40.0, 32.0, 26.0, 21.0, 17.0)


def _timed(fn):
    """Steady-state wall: warm pass compiles, second pass is timed."""
    fn()
    t0 = time.time()
    out = fn()
    return out, time.time() - t0


def _final_gaps(results):
    return [float(r.gaps_valid[-1]) for r in results]


def run(datasets=("rcv1", "url"), steps: int = 240, lambdas=LAMBDAS,
        epsilon: float = 12.0, delta: float = 1e-6, chunk_steps: int = 40):
    from benchmarks.common import accuracy_auc, load_problem
    from repro.core.dp.accountant import per_step_epsilon
    from repro.core.solvers import FWConfig, get_backend
    from repro.core.solvers.path import path_plan, run_path, segment_config
    from repro.core.solvers.registry import resolve_queue

    lambdas = tuple(lambdas)
    k_lams = len(lambdas)
    # ε/√K per scratch solve ⇒ per_step_epsilon(ε/√K, δ, T) =
    # per_step_epsilon(ε, δ, K·T): the K solves compose to the path's total
    # ε at the same uniform rate — the comparison is ε-fair by construction
    eps_scratch = epsilon / math.sqrt(k_lams)
    out = {"steps": steps, "lambdas": list(lambdas), "epsilon": epsilon,
           "delta": delta, "chunk_steps": chunk_steps, "datasets": {}}
    backend = get_backend("jax_sparse")
    for name in datasets:
        prob = load_problem(name)
        n, d = prob.X.shape
        n_train = int(n * TRAIN_FRACTION)
        X_train, X_test = _row_split(prob.X, n_train)
        y_train, y_test = prob.y[:n_train], prob.y[n_train:]
        data = backend.prepare(X_train)

        path_cfg = resolve_queue(backend, FWConfig(
            backend="jax_sparse", queue="bsls", lam=lambdas[0], steps=steps,
            epsilon=epsilon, delta=delta, chunk_steps=chunk_steps,
            lambdas=lambdas))
        plan = path_plan(path_cfg, private=True)
        scratch_cfgs = [resolve_queue(backend, FWConfig(
            backend="jax_sparse", queue="bsls", lam=lam, steps=steps,
            epsilon=eps_scratch, delta=delta, chunk_steps=chunk_steps))
            for lam in lambdas]

        # --- timed private arms at equal total ε --------------------------
        path_res, t_path = _timed(
            lambda: run_path(backend, data, y_train, path_cfg))
        scratch_res, t_scratch = _timed(
            lambda: [backend.fn(data, y_train, c) for c in scratch_cfgs])

        # --- gap + utility audit: non-private grid vs cold at equal budgets
        np_cfg = resolve_queue(backend, FWConfig(
            backend="jax_sparse", queue="group_argmax", lam=lambdas[0],
            steps=steps, chunk_steps=chunk_steps, lambdas=lambdas))
        np_path = run_path(backend, data, y_train, np_cfg)
        cold = [backend.fn(data, y_train, segment_config(np_cfg,
                                                         np_path.plan, k))
                for k in range(k_lams)]
        gap_warm, gap_cold = _final_gaps(np_path), _final_gaps(cold)
        pass_gap = bool(all(
            gw <= max(GAP_SLACK * gc, GAP_FLOOR)
            for gw, gc in zip(gap_warm, gap_cold)))
        pass_parity = bool(np.array_equal(np.asarray(np_path[0].w),
                                          np.asarray(cold[0].w)))
        accs_warm = [accuracy_auc(X_test, y_test, np.asarray(r.w))[0]
                     for r in np_path]
        accs_cold = [accuracy_auc(X_test, y_test, np.asarray(r.w))[0]
                     for r in cold]
        pass_utility = bool(all(
            aw >= ac - UTILITY_TOL
            for aw, ac in zip(accs_warm, accs_cold)))

        # --- utility + accounting audits on the private arms --------------
        accs_path = [accuracy_auc(X_test, y_test, np.asarray(r.w))[0]
                     for r in path_res]
        accs_scr = [accuracy_auc(X_test, y_test, np.asarray(r.w))[0]
                    for r in scratch_res]
        pass_utility_dp = bool(all(
            ap >= asc - DP_UTILITY_TOL
            for ap, asc in zip(accs_path, accs_scr)))
        pass_eps_split = bool(all(
            abs(per_step_epsilon(e, delta, b) - plan.eps_per_step)
            <= 1e-9 * plan.eps_per_step
            for e, b in zip(plan.eps_lambdas, plan.budgets)))

        row = {
            "n": n, "d": d, "train_rows": n_train, "n_lambdas": k_lams,
            "steps_path": plan.total_steps, "steps_scratch": k_lams * steps,
            "seconds_path": round(t_path, 3),
            "seconds_scratch": round(t_scratch, 3),
            "path_speedup": round(t_scratch / max(t_path, 1e-9), 2),
            "per_lambda": [
                {"lam": lam, "budget": plan.budgets[k],
                 "eps_lambda": round(plan.eps_lambdas[k], 4),
                 "acc_path": round(accs_path[k], 4),
                 "acc_scratch": round(accs_scr[k], 4),
                 "acc_warm": round(accs_warm[k], 4),
                 "acc_cold": round(accs_cold[k], 4),
                 "gap_warm": round(gap_warm[k], 4),
                 "gap_cold": round(gap_cold[k], 4),
                 "nnz_path": int(path_res[k].nnz)}
                for k, lam in enumerate(lambdas)],
            "pass_utility": pass_utility,
            "pass_utility_dp": pass_utility_dp,
            "pass_gap": pass_gap,
            "pass_parity": pass_parity,
            "pass_eps_split": pass_eps_split,
        }
        out["datasets"][name] = row
        print(f"[path] {name}: path {row['seconds_path']}s "
              f"({plan.total_steps} steps), scratch "
              f"{row['seconds_scratch']}s ({k_lams * steps} steps) → "
              f"{row['path_speedup']}x  utility={pass_utility} "
              f"dp={pass_utility_dp} gap={pass_gap} parity={pass_parity} "
              f"eps={pass_eps_split}", flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
