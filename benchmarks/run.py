"""Benchmark orchestrator — one bench per paper table/figure (deliverable d).

  Fig 1    bench_convergence   Alg 1 vs Alg 2 gap traces
  Fig 2/4  bench_flops         FLOPs-reduction factor
  Fig 3    bench_heap_pops     heap pops / ‖w*‖₀
  Table 3  bench_speedup       DP wall-clock speedup (Alg 2+4, ablation)
  Table 4  bench_accuracy      accuracy/AUC/sparsity at ε = 0.1
  (sweeps) bench_sweep         sequential solve() vs batched solve_many()
  (store)  bench_ingest        dataset-store ingest + cold/warm prepare
  (shard)  bench_shard         jax_sparse vs jax_shard + step-parity audit
  (§11)    bench_autotune      layout/chunk autotuner gains + parity gate
  (§13)    bench_screening     DP iterative screening vs plain chunked solve
  (§14)    bench_path          warm λ-path vs per-λ from-scratch solves
  §Roofline roofline_table     three-term model from dryrun_results.json

The suite itself — names, runners, perf-gate rules — lives in
``benchmarks.suite`` (shared with ``check.py``, so ``--only`` and the gate
can never drift apart again).

``python -m benchmarks.run [--fast] [--only NAME] [--backend B]`` — results
to BENCH_<name>.json per bench + aggregate bench_results.json + stdout
summary.  ``--only`` is a substring filter over ``suite.names()`` and
rejects a filter that matches nothing.  The whole run executes under a
``repro.obs`` telemetry session: solver spans, planner drift and cache
counters land in ``BENCH_telemetry.jsonl`` next to the result JSONs (render
with ``python -m repro.obs.report BENCH_telemetry.jsonl``).  ``--backend``
retargets the Alg-2 side of the registry-aware benches (fig1 convergence,
table4 accuracy) onto any engine from
``repro.core.solvers.available_backends()``; the FLOP/heap-audit benches are
pinned to the host engine (see docs/BENCHMARKS.md).
"""
from __future__ import annotations

import argparse
import json
import time
import traceback


def main():
    from benchmarks.suite import SUITE, names

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer steps/datasets")
    ap.add_argument("--only", default=None,
                    help="substring filter over the suite names: "
                         + ", ".join(names()))
    ap.add_argument("--out", default="bench_results.json")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    ap.add_argument("--backend", default=None,
                    help="solver registry backend for the Alg-2 side of "
                         "registry-aware benches (default: host_sparse; the "
                         "sweep bench defaults to jax_sparse, the only "
                         "engine with a batched fast path)")
    args = ap.parse_args()

    from repro.core.solvers import available_backends

    if args.backend is not None and args.backend not in available_backends():
        ap.error(f"--backend {args.backend!r} not in {available_backends()}")
    if args.only and not any(args.only in n for n in names()):
        ap.error(f"--only {args.only!r} matches no bench; choose a "
                 f"substring of: {', '.join(names())}")

    fast = args.fast
    from repro import obs
    results, failures = {}, []
    with obs.session(jsonl_path="BENCH_telemetry.jsonl",
                     meta={"harness": "benchmarks.run",
                           "fast": fast, "only": args.only or ""}):
        for spec in SUITE:
            name = spec.name
            if args.only and args.only not in name:
                continue
            t0 = time.time()
            print(f"[bench] {name} ...", flush=True)
            try:
                with obs.span("bench", bench=name):
                    results[name] = spec.run(fast, args.backend,
                                             args.dryrun_json)
                results[name]["bench_seconds"] = round(time.time() - t0, 1)
                with open(f"BENCH_{name}.json", "w") as f:
                    json.dump(results[name], f, indent=1)
                print(f"[bench] {name} done in "
                      f"{results[name]['bench_seconds']}s "
                      f"→ BENCH_{name}.json", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append({"bench": name, "error": str(e)})
                traceback.print_exc()
    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    print("telemetry artifact → BENCH_telemetry.jsonl "
          "(render: python -m repro.obs.report BENCH_telemetry.jsonl)")

    # ---- summary ---------------------------------------------------------
    print("\n=== benchmark summary ===")
    for name, r in results.items():
        if "datasets" in r:
            for ds, row in r["datasets"].items():
                passes = {k: v for k, v in row.items()
                          if k.startswith("pass") or k.endswith("gt1")}
                keys = [k for k in ("flops_reduction_total", "speedup_alg2+4",
                                    "accuracy_pct", "pops_over_nnz_ratio",
                                    "final_gap_rel_diff", "sweep_speedup",
                                    "ingest_s", "warm_setup_speedup",
                                    "shard_over_sparse", "block_waste",
                                    "tuned_over_default", "tuned_speedup",
                                    "screen_speedup", "selected_coords",
                                    "path_speedup")
                        if k in row]
                kv = {k: row[k] for k in keys}
                for eps_k in ("eps_1.0", "eps_0.1"):
                    if eps_k in row:
                        kv[f"speedup@{eps_k[4:]}"] = row[eps_k]["speedup_alg2+4"]
                print(f"  {name:18s} {ds:8s} {kv} {passes}")
        elif "points" in r:
            sp = ", ".join(f"D={p['d']}: {p['speedup']}x" for p in r["points"])
            print(f"  {name:18s} {sp} (monotone={r['monotone_in_d']})")
        elif "rows" in r:
            print(f"  {name:18s} {len(r['rows'])} roofline rows "
                  f"(see EXPERIMENTS.md §Roofline)")
        elif "skipped" in r:
            print(f"  {name:18s} SKIPPED: {r['skipped']}")
    if failures:
        print(f"  {len(failures)} benches FAILED")
        raise SystemExit(1)
    print("all benches ok →", args.out)


if __name__ == "__main__":
    main()
