"""Benchmark orchestrator — one bench per paper table/figure (deliverable d).

  Fig 1    bench_convergence   Alg 1 vs Alg 2 gap traces
  Fig 2/4  bench_flops         FLOPs-reduction factor
  Fig 3    bench_heap_pops     heap pops / ‖w*‖₀
  Table 3  bench_speedup       DP wall-clock speedup (Alg 2+4, ablation)
  Table 4  bench_accuracy      accuracy/AUC/sparsity at ε = 0.1
  (sweeps) bench_sweep         sequential solve() vs batched solve_many()
  (store)  bench_ingest        dataset-store ingest + cold/warm prepare
  (shard)  bench_shard         jax_sparse vs jax_shard + step-parity audit
  (§11)    bench_autotune      layout/chunk autotuner gains + parity gate
  §Roofline roofline_table     three-term model from dryrun_results.json

``python -m benchmarks.run [--fast] [--only NAME] [--backend B]`` — results
to BENCH_<name>.json per bench + aggregate bench_results.json + stdout
summary.  The whole run executes under a ``repro.obs`` telemetry session:
solver spans, planner drift and cache counters land in
``BENCH_telemetry.jsonl`` next to the result JSONs (render with
``python -m repro.obs.report BENCH_telemetry.jsonl``).  ``--backend`` retargets the Alg-2 side of the registry-aware
benches (fig1 convergence, table4 accuracy) onto any engine from
``repro.core.solvers.available_backends()``; the FLOP/heap-audit benches are
pinned to the host engine (see docs/BENCHMARKS.md).
"""
from __future__ import annotations

import argparse
import json
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer steps/datasets")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="bench_results.json")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    ap.add_argument("--backend", default=None,
                    help="solver registry backend for the Alg-2 side of "
                         "registry-aware benches (default: host_sparse; the "
                         "sweep bench defaults to jax_sparse, the only "
                         "engine with a batched fast path)")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_autotune, bench_convergence,
                            bench_flops, bench_heap_pops, bench_ingest,
                            bench_scaling, bench_screening, bench_shard,
                            bench_speedup, bench_sweep, roofline_table)
    from repro.core.solvers import available_backends

    if args.backend is not None and args.backend not in available_backends():
        ap.error(f"--backend {args.backend!r} not in {available_backends()}")
    alg2_backend = args.backend or "host_sparse"

    fast = args.fast
    suite = {
        "fig1_convergence": lambda: bench_convergence.run(
            datasets=("rcv1",) if fast else ("rcv1", "news20"),
            steps=150 if fast else 300, backend=alg2_backend),
        "fig2_4_flops": lambda: bench_flops.run(
            datasets=("rcv1",) if fast else ("rcv1", "news20", "kdda"),
            steps=150 if fast else 300),
        "fig3_heap_pops": lambda: bench_heap_pops.run(
            datasets=("rcv1",) if fast else ("rcv1", "url"),
            steps=200 if fast else 400),
        "table3_speedup": lambda: bench_speedup.run(
            datasets=("rcv1", "url") if fast else
            ("rcv1", "news20", "url", "web", "kdda"),
            steps=100 if fast else 200),
        "table4_accuracy": lambda: bench_accuracy.run(
            datasets=("rcv1",) if fast else ("rcv1", "news20", "url"),
            steps=800 if fast else 2000, backend=alg2_backend),
        "sweep": lambda: bench_sweep.run(
            datasets=("rcv1", "news20", ("rcv1", "huber")),
            lams=(10.0, 20.0, 40.0, 80.0), epsilons=(0.5, 2.0),
            steps=40 if fast else 120,
            backend=args.backend or "jax_sparse"),
        "shard": lambda: bench_shard.run(
            datasets=("rcv1",) if fast else ("rcv1", "news20"),
            steps=30 if fast else 80),
        "autotune": lambda: bench_autotune.run(
            datasets=("rcv1",) if fast else ("rcv1", "news20"),
            steps=20 if fast else 40),
        "screening": lambda: bench_screening.run(
            datasets=("rcv1",) if fast else ("rcv1", "url"),
            steps=240 if fast else 320),
        "ingest": lambda: bench_ingest.run(
            datasets=("rcv1_like",) if fast else
            ("rcv1_like", "url_small_like"),
            steps=30 if fast else 80,
            backend=args.backend or "jax_sparse"),
        "scaling_beyond": lambda: bench_scaling.run(
            d_values=(10_000, 100_000) if fast else
            (10_000, 100_000, 400_000, 800_000),
            steps=100 if fast else 150),
        "roofline": lambda: roofline_table.run(args.dryrun_json),
    }
    from repro import obs
    results, failures = {}, []
    with obs.session(jsonl_path="BENCH_telemetry.jsonl",
                     meta={"harness": "benchmarks.run",
                           "fast": fast, "only": args.only or ""}):
        for name, fn in suite.items():
            if args.only and args.only not in name:
                continue
            t0 = time.time()
            print(f"[bench] {name} ...", flush=True)
            try:
                with obs.span("bench", bench=name):
                    results[name] = fn()
                results[name]["bench_seconds"] = round(time.time() - t0, 1)
                with open(f"BENCH_{name}.json", "w") as f:
                    json.dump(results[name], f, indent=1)
                print(f"[bench] {name} done in "
                      f"{results[name]['bench_seconds']}s "
                      f"→ BENCH_{name}.json", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append({"bench": name, "error": str(e)})
                traceback.print_exc()
    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    print("telemetry artifact → BENCH_telemetry.jsonl "
          "(render: python -m repro.obs.report BENCH_telemetry.jsonl)")

    # ---- summary ---------------------------------------------------------
    print("\n=== benchmark summary ===")
    for name, r in results.items():
        if "datasets" in r:
            for ds, row in r["datasets"].items():
                passes = {k: v for k, v in row.items()
                          if k.startswith("pass") or k.endswith("gt1")}
                keys = [k for k in ("flops_reduction_total", "speedup_alg2+4",
                                    "accuracy_pct", "pops_over_nnz_ratio",
                                    "final_gap_rel_diff", "sweep_speedup",
                                    "ingest_s", "warm_setup_speedup",
                                    "shard_over_sparse", "block_waste",
                                    "tuned_over_default", "tuned_speedup",
                                    "screen_speedup", "selected_coords")
                        if k in row]
                kv = {k: row[k] for k in keys}
                for eps_k in ("eps_1.0", "eps_0.1"):
                    if eps_k in row:
                        kv[f"speedup@{eps_k[4:]}"] = row[eps_k]["speedup_alg2+4"]
                print(f"  {name:18s} {ds:8s} {kv} {passes}")
        elif "points" in r:
            sp = ", ".join(f"D={p['d']}: {p['speedup']}x" for p in r["points"])
            print(f"  {name:18s} {sp} (monotone={r['monotone_in_d']})")
        elif "rows" in r:
            print(f"  {name:18s} {len(r['rows'])} roofline rows "
                  f"(see EXPERIMENTS.md §Roofline)")
        elif "skipped" in r:
            print(f"  {name:18s} SKIPPED: {r['skipped']}")
    if failures:
        print(f"  {len(failures)} benches FAILED")
        raise SystemExit(1)
    print("all benches ok →", args.out)


if __name__ == "__main__":
    main()
