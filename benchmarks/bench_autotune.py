"""Autotune bench — §11 layout/chunk search on the device hot path.

The flat padded CSC pays the exact max column nnz on *every* step; on
power-law text designs (the paper's Table-2 regime) that is ~8× the 99th
percentile column, which is why BENCH_shard found the flagship kernels 3×
behind the blocked engine on the same device.  This bench runs the §11
autotuner on each dataset twin and reports what the tiered split buys:

  * ``per_iter_ms_default`` / ``per_iter_ms_tuned`` — steady-state kernel
    scan times (warmed compiles, best-of-N, worst case over the private and
    non-private selection rules — both from the tuner's own search);
  * ``tuned_over_default`` — the gate metric: the acceptance bar is ≤ 0.8
    on the rcv1 twin (the tuner must never *pick* a slower layout, so this
    is ≤ 1.0 by construction; < 1 means the search found a real win);
  * ``pass_tuned_parity`` — the exactness invariant, re-verified here
    independently of the tuner's internal gate: (w, gaps, coords) of the
    tuned layout are **bitwise** equal to the flat layout's, private and
    non-private, so the DP selection distribution is untouched.

Output: one row per dataset into BENCH_autotune.json
(``run.py --only autotune``; uploaded as a CI artifact and gated by
``benchmarks.check`` against the committed baseline).
"""
from __future__ import annotations

import time


def run(datasets=("rcv1",), steps: int = 24, lam: float = 20.0):
    from benchmarks.common import load_problem
    from repro.core.solvers.autotune import probe_parity, tune_jax_sparse
    from repro.core.sparse.formats import host_to_padded, tiered_from_padded

    out = {"steps": steps, "lam": lam, "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        pcsr, pcsc = host_to_padded(prob.X)
        t0 = time.time()
        rec = tune_jax_sparse(pcsr, pcsc, prob.y, steps=steps, lam=lam,
                              probe_steps=steps)
        tune_s = time.time() - t0
        if rec.ell_width is not None:
            winner = tiered_from_padded(pcsc, rec.ell_width)
            parity = probe_parity(pcsr, pcsc, winner, prob.y,
                                  loss="logistic", interpret=True,
                                  steps=steps, lam=lam)
        else:
            parity = True            # flat layout won: nothing to compare
        row = {
            "n": prob.X.shape[0], "d": prob.X.shape[1],
            "pad_width": int(pcsc.indices.shape[1]),
            "ell_width": rec.ell_width,
            "chunk_steps": rec.chunk_steps,
            "per_iter_ms_default": round(rec.per_iter_default_ms, 3),
            "per_iter_ms_tuned": round(rec.per_iter_tuned_ms, 3),
            "tuned_over_default": round(
                rec.per_iter_tuned_ms / max(rec.per_iter_default_ms, 1e-9),
                3),
            "tuned_speedup": round(rec.speedup, 2),
            "tune_seconds": round(tune_s, 1),
            "pass_tuned_parity": bool(parity),
        }
        out["datasets"][name] = row
        print(f"[autotune] {name}: pad {row['pad_width']} -> tier "
              f"{row['ell_width']}, {row['per_iter_ms_default']} -> "
              f"{row['per_iter_ms_tuned']} ms/iter "
              f"({row['tuned_speedup']}x)  parity="
              f"{row['pass_tuned_parity']}", flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
