"""§Perf hillclimb target 3 — the paper's own workload at pod scale.

Lowers the registry's ``jax_shard`` backend (kdda-sized: N=8.4M, D=20.2M) on
the 16×16 mesh under different exchange strategies and reports per-iteration
collective bytes + roofline terms:

  dense      α-delta psum over the data axis (D/B floats · T iters)
  topk_k     error-feedback top-k all_gather (2k floats · rows · T)

Also profiles the single-device solver backends through the registry
(``--local-backends jax_dense jax_sparse jax_shard``): per-iteration wall
clock of each engine on a CPU twin of the dataset, so the collective model
above can be
combined with measured per-shard compute.  ``--sweep-grid N`` additionally
profiles an N-config λ/ε sweep two ways — sequential ``solve()`` loop vs one
vmapped ``solve_many()`` batch — the multi-tenant traffic shape the fit
service drains (DESIGN.md §6).

Run inside the dry-run device environment:
  PYTHONPATH=src python -m benchmarks.perf_lasso
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def profile_local_backends(backends, dataset: str = "kdda", steps: int = 30):
    """Wall-clock per FW iteration for each registry backend on a CPU twin.

    Data coercion (e.g. host_to_padded) is hoisted out of the timed window,
    and a warmup solve absorbs trace + XLA compile (steps is jit-static, so
    the warmup must use the identical config to hit the jit cache) — the
    reported ms/iter is solver iterations only.
    """
    from benchmarks.common import load_problem
    from repro.core.solvers import FWConfig, get_backend, resolve_queue

    prob = load_problem(dataset)
    out = {}
    for name in backends:
        backend = get_backend(name)
        cfg = resolve_queue(backend, FWConfig(backend=name, lam=50.0,
                                              steps=steps))
        data = backend.prepare(prob.X)
        r = backend.fn(data, prob.y, cfg)           # warmup (compile)
        _ = float(jnp.sum(r.w))
        t0 = time.time()
        r = backend.fn(data, prob.y, cfg)
        _ = float(jnp.sum(r.w))                     # block on device work
        per_iter_ms = (time.time() - t0) / steps * 1e3
        out[name] = {"steps": steps, "per_iter_ms": round(per_iter_ms, 2),
                     "final_gap": float(r.gaps[-1])}
        print(f"[local] {name}: {per_iter_ms:.2f} ms/iter", flush=True)
    return out


def profile_sweep(grid_size: int, dataset: str = "kdda", steps: int = 30):
    """Sequential vs batched wall clock on an N-config λ/ε grid.

    Both sides re-use a hot jit cache (warmup run excluded) so the number is
    steady-state serving throughput, not compile time.  The sequential side
    still re-enters the registry per config — per-call coercion included —
    because that is what a naive sweep loop pays.
    """
    from benchmarks.common import load_problem
    from repro.core.solvers import FWConfig, grid, solve, solve_many

    prob = load_problem(dataset)
    lams = tuple(10.0 * (1 + i) for i in range((grid_size + 1) // 2))
    configs = grid(FWConfig(backend="jax_sparse", steps=steps, queue="bsls"),
                   lam=lams, epsilon=(0.5, 2.0))[:grid_size]
    assert len(configs) == grid_size

    warm = solve_many(prob.X, prob.y, configs)      # warmup (compile)
    jax.block_until_ready([r.w for r in warm])
    t0 = time.time()
    res = solve_many(prob.X, prob.y, configs)
    _ = [float(jnp.sum(r.w)) for r in res]
    batched_s = time.time() - t0

    # warm every config: FWConfig is a static jit argument, so each distinct
    # (λ, ε) is its own cache entry — warming only configs[0] would leave
    # N-1 compiles inside the timed window
    for c in configs:
        solve(prob.X, prob.y, c).w.block_until_ready()
    t0 = time.time()
    for c in configs:
        _ = float(jnp.sum(solve(prob.X, prob.y, c).w))
    sequential_s = time.time() - t0

    out = {"dataset": dataset, "configs": len(configs), "steps": steps,
           "sequential_s": round(sequential_s, 2),
           "batched_s": round(batched_s, 2),
           "sweep_speedup": round(sequential_s / max(batched_s, 1e-9), 2)}
    print(f"[sweep] {len(configs)} cfgs: seq {sequential_s:.1f}s, "
          f"batched {batched_s:.1f}s ({out['sweep_speedup']}x)", flush=True)
    return out


def run(dataset: str = "kdda", steps: int = 50):
    """Lower the registered ``jax_shard`` backend's whole-run program on the
    16×16 production mesh under the three exchange strategies and audit the
    per-iteration collective traffic (same program the registry serves)."""
    from repro.configs.paper_lasso import DATASETS
    from repro.core.solvers.jax_shard import shard_lowering
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.hlo import (collective_bytes_nested,
                                    cost_analysis_dict)

    ds = DATASETS[dataset]
    mesh = make_production_mesh()
    rows, cols = 16, 16
    kc = max(8, int(ds.n * (ds.nnz_per_row / ds.d) / rows * 4))
    kr = max(8, int(ds.nnz_per_row / cols * 4))

    results = {}
    with mesh:
        for tag, k in [("dense", 0), ("topk_256", 256), ("topk_64", 64)]:
            jitted, args = shard_lowering(ds.n, ds.d, mesh, steps=steps,
                                          kc=kc, kr=kr, compress_topk=k)
            compiled = jitted.lower(*args).compile()
            coll = collective_bytes_nested(compiled.as_text())
            cost = cost_analysis_dict(compiled)
            results[tag] = {
                "collective_bytes_per_step": {
                    kk: vv / steps for kk, vv in coll.items()},
                "total_collective_per_iter_kb": sum(coll.values()) / steps / 1024,
                "flops_per_iter": cost.get("flops", 0) / steps,
                "temp_bytes": compiled.memory_analysis().temp_size_in_bytes,
            }
            print(tag, json.dumps(results[tag], indent=1), flush=True)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="kdda")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--local-backends", nargs="*", default=(),
                    help="registry backends to wall-clock profile locally "
                         "(e.g. jax_dense jax_sparse host_sparse)")
    ap.add_argument("--local-steps", type=int, default=30,
                    help="FW iterations for the local backend profile")
    ap.add_argument("--sweep-grid", type=int, default=0,
                    help="profile an N-config λ/ε sweep: sequential solve() "
                         "vs one batched solve_many()")
    ap.add_argument("--skip-mesh", action="store_true",
                    help="only run the local profiles")
    args = ap.parse_args()
    out = {}
    if args.local_backends:
        out["local_backends"] = profile_local_backends(
            args.local_backends, dataset=args.dataset, steps=args.local_steps)
    if args.sweep_grid:
        out["sweep"] = profile_sweep(
            args.sweep_grid, dataset=args.dataset, steps=args.local_steps)
    if not args.skip_mesh:
        out["mesh"] = run(dataset=args.dataset, steps=args.steps)
    with open("perf_lasso.json", "w") as f:
        json.dump(out, f, indent=1)
