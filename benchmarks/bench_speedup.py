"""Table 3 — wall-clock speedup of Alg 2+4 (BSLS) and Alg 2+noisy-max over
the standard DP Frank-Wolfe (Alg 1), at ε ∈ {1, 0.1}.

Claim reproduced: large speedups that *grow as ε shrinks* (more noise → the
selected coordinates are sparser on average → less work per iteration), the
paper's headline 10×–2200× effect at paper scale; the CPU twins reproduce the
ordering and the ε-trend at smaller magnitudes (documented in EXPERIMENTS.md)."""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import load_problem
from benchmarks.host_alg1 import host_alg1
from repro.core.fw_sparse import sparse_fw


def _timed(fn):
    t0 = time.time()
    r = fn()
    return r, time.time() - t0


def run(datasets=("rcv1", "news20", "url", "web", "kdda"), steps: int = 200,
        lam: float = 50.0) -> Dict:
    out = {"table": "3", "claim": "Alg2+4 speedup over Alg1, growing as ε ↓",
           "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        row = {}
        for eps in (1.0, 0.1):
            r1, t1 = _timed(lambda: host_alg1(
                prob.X, prob.y, lam=lam, steps=steps, epsilon=eps))
            r24, t24 = _timed(lambda: sparse_fw(
                prob.X, prob.y, lam=lam, steps=steps, queue="bsls",
                epsilon=eps))
            r2n, t2n = _timed(lambda: sparse_fw(
                prob.X, prob.y, lam=lam, steps=steps, queue="noisy_max",
                epsilon=eps))
            row[f"eps_{eps}"] = {
                "alg1_s": round(t1, 3),
                "alg2+4_s": round(t24, 3),
                "alg2_noisymax_s": round(t2n, 3),
                "speedup_alg2+4": round(t1 / max(t24, 1e-9), 2),
                "speedup_alg2_ablation": round(t1 / max(t2n, 1e-9), 2),
            }
        s1 = row["eps_1.0"]["speedup_alg2+4"]
        s01 = row["eps_0.1"]["speedup_alg2+4"]
        row["speedup_gt1"] = bool(s1 > 1.0 and s01 > 1.0)
        row["ablation_slower_than_full"] = bool(
            row["eps_0.1"]["speedup_alg2_ablation"] <= s01 * 1.2)
        out["datasets"][name] = row
    return out
