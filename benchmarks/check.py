"""Perf gate — diff fresh BENCH_*.json against committed baselines.

Nothing in CI used to *fail* when a bench regressed, which is how the
batched sweep shipped (and stayed) slower than the sequential loop.  This
gate makes performance an invariant:

  * **parity/correctness flags** (every ``pass_*`` key) must be True — these
    are machine-independent statements about iterates, never timings;
  * **absolute floors** hold on any machine because they are dimensionless
    ratios of two timings taken on the *same* machine in the *same* run
    (e.g. ``sweep_speedup ≥ 1.0``: the gap-adaptive scheduler must never be
    slower than the naive fixed-T loop it replaces);
  * **relative bands** compare those ratios against the committed baseline
    with generous noise margins (CI containers are noisy; a 2× drift in a
    speedup ratio is a regression, a 20% wobble is weather).

Usage:

    python -m benchmarks.check                   # gate fresh vs baselines
    python -m benchmarks.check --mode full       # nightly: skip relative
                                                 # bands (baselines are
                                                 # --fast-mode numbers)
    python -m benchmarks.check --update          # refresh baselines from
                                                 # the fresh JSONs (commit
                                                 # the diff deliberately)

Exit status is non-zero on any violation; every violation is printed.
docs/BENCHMARKS.md §Perf-gate documents the refresh procedure.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
from typing import List, Optional

BASELINE_DIR = pathlib.Path(__file__).parent / "baselines"

# Gated artifacts and their rules come from the one suite registry shared
# with run.py (benchmarks/suite.py) — adding a bench there with a ``gate``
# wires it into both the runner and this gate, so they cannot drift.
# Rule kinds: ("flags",) | ("min"|"max", metric, bound)
#           | ("rel_min"|"rel_max", metric, factor)   [skipped in full mode]
from benchmarks.suite import gate_spec  # noqa: E402

SPEC = gate_spec()


def _rows(doc: dict):
    return (doc.get("datasets") or {}).items()


def check_bench(name: str, fresh: dict, baseline: Optional[dict],
                mode: str) -> List[str]:
    """All violations of ``name``'s rules (empty list = gate passes)."""
    errors = []
    base_rows = dict(_rows(baseline)) if baseline else {}
    for ds, row in _rows(fresh):
        base = base_rows.get(ds, {})
        for rule in SPEC[name]:
            kind = rule[0]
            if kind == "flags":
                for k, v in row.items():
                    if k.startswith("pass") and v is not True:
                        errors.append(f"{name}:{ds}: flag {k} is {v!r}")
                continue
            _, metric, bound = rule
            got = row.get(metric)
            if got is None:
                errors.append(f"{name}:{ds}: metric {metric} missing")
                continue
            if kind == "min" and got < bound:
                errors.append(
                    f"{name}:{ds}: {metric}={got} below floor {bound}")
            elif kind == "max" and got > bound:
                errors.append(
                    f"{name}:{ds}: {metric}={got} above ceiling {bound}")
            elif kind in ("rel_min", "rel_max"):
                if mode == "full":
                    continue            # baselines are --fast numbers
                ref = base.get(metric)
                if ref is None:
                    continue            # new dataset/metric: absolute rules
                                        # still applied above
                if kind == "rel_min" and got < ref * bound:
                    errors.append(
                        f"{name}:{ds}: {metric}={got} < {bound}× baseline "
                        f"({ref})")
                if kind == "rel_max" and got > ref * bound:
                    errors.append(
                        f"{name}:{ds}: {metric}={got} > {bound}× baseline "
                        f"({ref})")
    # a bench that silently dropped a baseline dataset is also a regression
    for ds in base_rows:
        if ds not in dict(_rows(fresh)):
            errors.append(f"{name}: baseline dataset {ds!r} missing from "
                          f"fresh results")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the freshly produced "
                         "BENCH_*.json files")
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--mode", choices=("fast", "full"), default="fast",
                    help="full = nightly non-fast benches: relative bands "
                         "vs the --fast baselines are skipped")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh JSONs over the baselines instead of "
                         "gating (then commit the diff)")
    ap.add_argument("--report", action="store_true",
                    help="also render the run's telemetry artifact "
                         "(BENCH_telemetry.jsonl in --fresh-dir) next to "
                         "the gate verdict")
    args = ap.parse_args(argv)

    fresh_dir = pathlib.Path(args.fresh_dir)
    base_dir = pathlib.Path(args.baseline_dir)
    if args.update:
        base_dir.mkdir(parents=True, exist_ok=True)
        updated = 0
        for name in SPEC:
            src = fresh_dir / name
            if src.exists():
                shutil.copy(src, base_dir / name)
                print(f"[check] baseline updated: {base_dir / name}")
                updated += 1
        if updated == 0:
            print(f"[check] no fresh BENCH_*.json found in {fresh_dir} — "
                  "nothing updated; run `python -m benchmarks.run` first")
            return 2
        return 0

    if args.report:
        tel = fresh_dir / "BENCH_telemetry.jsonl"
        if tel.exists():
            from repro.obs.report import render_path
            print(render_path(str(tel)))
            print()
        else:
            print(f"[check] no telemetry artifact at {tel} — run "
                  "`python -m benchmarks.run` to produce one")

    all_errors, checked = [], 0
    for name in SPEC:
        src = fresh_dir / name
        if not src.exists():
            print(f"[check] {name}: not present, skipped")
            continue
        fresh = json.loads(src.read_text())
        base_path = base_dir / name
        baseline = (json.loads(base_path.read_text())
                    if base_path.exists() else None)
        errors = check_bench(name, fresh, baseline, args.mode)
        checked += 1
        status = "OK" if not errors else f"{len(errors)} violation(s)"
        print(f"[check] {name}: {status}")
        all_errors.extend(errors)
    for e in all_errors:
        print(f"  FAIL {e}")
    if checked == 0:
        print("[check] nothing to check — run `python -m benchmarks.run` "
              "first")
        return 2
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
