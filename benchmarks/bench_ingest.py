"""Ingest bench — dataset store vs in-memory data path (DESIGN.md §7).

Measures the lifecycle the store exists for, per Table-2 regime:

  * **ingest** — libsvm text → streaming parse → sharded store (+ column
    stats + content hash), the one-time O(NS) cost;
  * **cold prepare** — first open: mmap shards, build the padded device
    layout, run the ``fw_setup`` spmv sweep (persisted to ``cache/``);
  * **warm prepare** — a fresh open of the same store: mmap + padding again
    but the setup sweep is *replayed from disk* — this is the per-process
    steady state every later solve/tenant pays;
  * **in-memory baseline** — what every solve pays today without the store:
    ``as_padded`` coercion + the ``fw_setup`` sweep on an in-memory matrix.

Acceptance (ISSUE 3): warm prepare < in-memory coercion+setup — the cached
column stats / setup state make the O(NS) sweep an ingest-time cost.  A
parity audit asserts the solve-from-store coordinate sequence is identical
to the in-memory solve (same config, same keys).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np


def _block(setup):
    for arr in setup:
        np.asarray(arr)


def run(datasets=("rcv1_like", "url_small_like"), steps: int = 40,
        backend: str = "jax_sparse", loss: str = "logistic"):
    import jax.numpy as jnp

    from repro.core.solvers import FWConfig, solve
    from repro.core.solvers.jax_sparse import fw_setup_jit
    from repro.core.solvers.registry import as_padded
    from repro.data.registry import get_spec
    from repro.data.sparse_io import iter_libsvm, write_libsvm
    from repro.data.store import DatasetStore

    out = {"steps": steps, "backend": backend, "datasets": {}}
    cfg = FWConfig(backend=backend, lam=20.0, steps=steps, queue="bsls",
                   epsilon=1.0, delta=1e-6)
    for name in datasets:
        spec = get_spec(name)
        X, y = spec.generate()
        tmp = tempfile.mkdtemp(prefix=f"bench_ingest_{name}_")
        try:
            svm_path = os.path.join(tmp, f"{name}.svm")
            write_libsvm(svm_path, X, y)

            # ---- ingest: streaming text -> sharded store -----------------
            t0 = time.time()
            store = DatasetStore.write(
                os.path.join(tmp, "store"), iter_libsvm(svm_path),
                n_cols=X.shape[1], rows_per_shard=spec.rows_per_shard)
            ingest_s = time.time() - t0

            # ---- warm up the fw_setup compile (untimed) so every prepare
            # number below — in-memory, cold store, warm store — measures
            # steady-state work, not first-call tracing ---------------------
            pcsr, _ = as_padded(X)
            _block(fw_setup_jit(pcsr, jnp.asarray(y, jnp.float32),
                                loss=loss, interpret=cfg.interpret))

            # ---- in-memory baseline: what every solve re-pays without the
            # store (padding coercion + the O(nnz) setup spmv sweep) --------
            t0 = time.time()
            pcsr, _ = as_padded(X)
            setup = fw_setup_jit(pcsr, jnp.asarray(y, jnp.float32),
                                 loss=loss, interpret=cfg.interpret)
            _block(setup)
            inmem_prepare_s = time.time() - t0
            t0 = time.time()
            r_mem = solve(X, y, cfg)
            np.asarray(r_mem.w)
            inmem_solve_s = time.time() - t0

            # ---- cold store: mmap + padding + setup sweep (persisted) ----
            t0 = time.time()
            cold = DatasetStore.open(store.root)
            prep = cold.prepared()
            _block(prep.setup_for(cold.labels(), loss, cfg.interpret))
            cold_prepare_s = time.time() - t0
            t0 = time.time()
            r_cold = solve(cold, config=cfg)
            np.asarray(r_cold.w)
            cold_solve_s = time.time() - t0

            # ---- warm store: fresh open, setup replayed from cache/ ------
            t0 = time.time()
            warm = DatasetStore.open(store.root)
            prep = warm.prepared()
            _block(prep.setup_for(warm.labels(), loss, cfg.interpret))
            warm_prepare_s = time.time() - t0
            t0 = time.time()
            r_warm = solve(warm, config=cfg)
            np.asarray(r_warm.w)
            warm_solve_s = time.time() - t0

            parity = bool(
                np.array_equal(np.asarray(r_mem.coords),
                               np.asarray(r_warm.coords))
                and np.array_equal(np.asarray(r_mem.coords),
                                   np.asarray(r_cold.coords)))
            row = {
                "n": store.n, "d": store.d, "nnz": store.nnz,
                "shards": store.n_shards,
                "libsvm_mb": round(os.path.getsize(svm_path) / 2**20, 2),
                "ingest_s": round(ingest_s, 3),
                "ingest_rows_per_s": round(store.n / max(ingest_s, 1e-9)),
                "cold_prepare_s": round(cold_prepare_s, 3),
                "warm_prepare_s": round(warm_prepare_s, 3),
                "inmem_prepare_s": round(inmem_prepare_s, 3),
                "cold_solve_s": round(cold_solve_s, 3),
                "warm_solve_s": round(warm_solve_s, 3),
                "inmem_solve_s": round(inmem_solve_s, 3),
                "warm_setup_speedup": round(
                    inmem_prepare_s / max(warm_prepare_s, 1e-9), 2),
                "pass_warm_setup_faster": bool(
                    warm_prepare_s < inmem_prepare_s),
                "pass_parity": parity,
            }
            out["datasets"][name] = row
            print(f"[ingest] {name}: ingest {ingest_s:.2f}s "
                  f"({row['ingest_rows_per_s']} rows/s, "
                  f"{store.n_shards} shards)  "
                  f"prepare cold/warm/inmem "
                  f"{cold_prepare_s:.2f}/{warm_prepare_s:.2f}/"
                  f"{inmem_prepare_s:.2f}s  "
                  f"parity={parity}", flush=True)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
