"""§Roofline table (deliverable g) — consumes dryrun_results.json.

Per (arch × shape) on the single-pod 16×16 mesh:
  * three roofline terms from the compiled dry-run (per-device flops/bytes
    from cost_analysis, trip-count-corrected collective bytes from the HLO
    parser),
  * FLOPs/bytes corrected by the two-point layer extrapolation when present
    (cost_analysis counts scan bodies once — see roofline/analysis.py),
  * MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the useful-compute
    ratio vs compiled HLO FLOPs,
  * dominant bottleneck + one-line what-would-move-it-down note.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.configs import ARCH_IDS, get_config
from repro.models.config import SHAPES
from repro.roofline.analysis import model_flops, roofline_terms, two_point_total

CHIPS = 256

# N_active for MoE archs (routed top-k + shared + attention/embed), computed
# from the configs' analytic param counts.
def _active_params(arch: str) -> float:
    cfg = get_config(arch)
    total = cfg.param_count()
    if not cfg.n_experts:
        return total
    # replace the full expert stack with top_k + shared experts
    gated = 3 if cfg.act in ("swiglu", "geglu") else 2
    per_expert = gated * cfg.d_model * cfg.moe_d_ff
    moe_layers = cfg.n_layers - cfg.first_dense_layers
    total -= moe_layers * cfg.n_experts * per_expert
    total += moe_layers * cfg.top_k * per_expert
    return total


def _tokens(shape_name: str) -> float:
    s = SHAPES[shape_name]
    if s.kind in ("train", "prefill"):
        return s.global_batch * s.seq_len
    return s.global_batch * 1.0          # decode: one token per sequence


def _fix_note(bottleneck: str, arch: str, shape: str) -> str:
    if bottleneck == "compute":
        return "at compute roofline — gains need lower-precision matmuls or fewer FLOPs (e.g. less remat)"
    if bottleneck == "memory":
        return "HBM-bound — increase arithmetic intensity: larger fused blocks, bf16 state, fewer activations re-reads"
    return "ICI-bound — reshard to cut collective volume (reduce-scatter instead of all-reduce, or move the axis)"


# bytes of HBM traffic a step cannot avoid (structural lower bound):
# cost_analysis bytes assume ZERO fusion (every elementwise op round-trips
# HBM) and count VMEM-resident flash/scan tiles as HBM — a gross upper bound.
# Real TPU traffic lies between; matmul-heavy cells sit near this lower one.
_ACT_IO = 12  # per-layer activation r/w factor: residual save w+r, block io,
              # qkv/ffn intermediates across fwd + remat-recompute + bwd


def _struct_bytes(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    n_params = cfg.param_count()
    if s.kind == "train":
        # weights bf16 ×3 passes + adam m/v f32 r/w (adafactor ≈ 4B)
        opt_io = 16.0 if cfg.optimizer == "adamw" else 4.0
        params_io = n_params * (2 * 3 + opt_io) / CHIPS
        tok_loc = s.global_batch * s.seq_len / CHIPS * 16  # per-device tokens ×16 model-replication of batch shards
        act_io = cfg.n_layers * tok_loc * cfg.d_model * 2 * _ACT_IO
        return params_io + act_io
    if s.kind == "prefill":
        params_io = n_params * 2 / CHIPS
        tok_loc = s.global_batch * s.seq_len / CHIPS * 16
        act_io = cfg.n_layers * tok_loc * cfg.d_model * 2 * (_ACT_IO / 3)
        return params_io + act_io
    # decode: every live weight read once + cache read/write
    active = _active_params(arch)
    cache = (s.global_batch * s.seq_len * cfg.n_layers *
             2 * cfg.n_kv_heads * cfg.hd * 2) if cfg.n_kv_heads else 0
    return (active * 2 + cache * 1.5) / CHIPS


def build_table(dryrun_json: str, mesh: str = "16x16") -> Dict:
    data = json.load(open(dryrun_json))
    rows = []
    for r in data["results"]:
        if r["mesh"] != mesh or r["arch"] == "paper-lasso":
            continue
        arch, shape = r["arch"], r["shape"]
        cfg = get_config(arch)
        flops = r["flops"]
        bytes_ = r["bytes_accessed"]
        tp = r.get("two_point")
        if tp:
            flops = two_point_total(tp["l1"]["flops"], tp["l2"]["flops"],
                                    tp["l1"]["layers"], tp["l2"]["layers"],
                                    tp["l_full"])
            bytes_ = two_point_total(tp["l1"]["bytes"], tp["l2"]["bytes"],
                                     tp["l1"]["layers"], tp["l2"]["layers"],
                                     tp["l_full"])
        coll = sum(r["collective_bytes"].values())
        terms = roofline_terms(flops=flops, bytes_accessed=bytes_,
                               collective_bytes=coll, chips=CHIPS)
        kind = SHAPES[shape].kind
        mf = model_flops(cfg.param_count(), _tokens(shape),
                         active_params=_active_params(arch),
                         training=(kind == "train")) / CHIPS  # per-device
        # structural (fusion-aware) memory floor; the cost_analysis bytes are
        # the zero-fusion ceiling.  Bottleneck ranking uses the floor — real
        # TPU HBM traffic sits close to it for matmul-dominated cells.
        t_mem_floor = _struct_bytes(arch, shape) / 819e9
        eff = {"t_compute_s": terms["t_compute_s"],
               "t_mem_floor_s": t_mem_floor,
               "t_collective_s": terms["t_collective_s"]}
        bottleneck = max(eff, key=eff.get)
        bname = {"t_compute_s": "compute", "t_mem_floor_s": "memory",
                 "t_collective_s": "collective"}[bottleneck]
        t_bound = max(eff.values())
        rows.append({
            "arch": arch, "shape": shape,
            "flops_per_dev": flops, "bytes_per_dev": bytes_,
            "collective_bytes_per_dev": coll,
            **{k: v for k, v in terms.items()},
            "t_mem_floor_s": t_mem_floor,
            "bottleneck": bname,
            "t_bound_s": t_bound,
            "roofline_fraction": (terms["t_compute_s"] / t_bound
                                  if t_bound > 0 else 0.0),
            "model_flops_per_dev": mf,
            "useful_compute_ratio": mf / flops if flops else 0.0,
            "note": _fix_note(bname, arch, shape),
        })
    return {"mesh": mesh, "chips": CHIPS, "rows": rows}


def format_markdown(table: Dict) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound |"
           " roofline frac | useful/HLO |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in table["rows"]:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} "
            f"| {r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} "
            f"| {r['bottleneck']} | {r['roofline_fraction']:.2f} "
            f"| {r['useful_compute_ratio']:.2f} |")
    return hdr + "\n".join(lines)


def run(dryrun_json: str = "dryrun_results.json") -> Dict:
    try:
        table = build_table(dryrun_json)
    except FileNotFoundError:
        return {"table": "roofline", "skipped": f"{dryrun_json} not found — "
                "run `python -m repro.launch.dryrun --both-meshes` first"}
    return {"table": "roofline", **table, "markdown": format_markdown(table)}
