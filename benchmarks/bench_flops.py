"""Figures 2 & 4 — FLOPs-reduction factor of Alg 2 (+Alg 3 queue) vs Alg 1.

Claim reproduced: orders-of-magnitude fewer floating-point operations per
iteration once past the first (dense) iteration."""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import load_problem
from repro.core.fw_dense import dense_fw_flops
from repro.core.fw_sparse import sparse_fw


def run(datasets=("rcv1", "news20", "kdda"), steps: int = 300,
        lam: float = 50.0) -> Dict:
    out = {"figure": "2/4", "claim": "Alg2 needs orders of magnitude fewer FLOPs",
           "datasets": {}}
    for name in datasets:
        prob = load_problem(name)
        n, d = prob.X.shape
        r2 = sparse_fw(prob.X, prob.y, lam=lam, steps=steps, queue="fib_heap")
        alg1_flops = dense_fw_flops(n, d, prob.X.nnz, steps)
        ratio = alg1_flops / max(r2.flops, 1)
        # per-iteration ratio past the dense first iteration
        alg1_per_iter = (alg1_flops - 2 * prob.X.nnz) / steps
        alg2_tail = (r2.flops - (4 * prob.X.nnz + n + 3 * d)) / max(steps - 1, 1)
        out["datasets"][name] = {
            "alg1_flops": int(alg1_flops),
            "alg2_flops": int(r2.flops),
            "flops_reduction_total": float(ratio),
            "flops_reduction_per_iter_tail": float(alg1_per_iter / max(alg2_tail, 1)),
            "pass": bool(ratio > 5.0),
        }
    return out
