"""Beyond-table scaling study — DP-FW iteration speedup vs feature count D.

The paper's headline numbers (10×–2200×) come from D up to 20.2M where
Alg 1's O(D)-per-iteration term dominates utterly.  This bench sweeps D at
fixed N and nnz/row and shows the speedup growing ~linearly in D, which is
the mechanism behind Table 3 (and lets a reviewer extrapolate the CPU twins
to paper scale: twins top out at D≈0.8M here)."""
from __future__ import annotations

import time
from typing import Dict

from repro.core.fw_sparse import sparse_fw
from repro.data.synthetic import make_sparse_classification

from benchmarks.host_alg1 import host_alg1


def run(d_values=(10_000, 100_000, 400_000, 800_000), n: int = 2_000,
        nnz_per_row: float = 20.0, steps: int = 150,
        epsilon: float = 0.1, lam: float = 50.0) -> Dict:
    out = {"figure": "scaling (beyond-paper)",
           "claim": "speedup grows with D — Alg1 pays O(D)/iter, Alg2+4 pays O(√D + S_r·S_c)",
           "points": []}
    for d in d_values:
        X, y, _ = make_sparse_classification(n=n, d=d, nnz_per_row=nnz_per_row,
                                             informative=64, seed=1)
        t0 = time.time()
        host_alg1(X, y, lam=lam, steps=steps, epsilon=epsilon)
        t1 = time.time() - t0
        t0 = time.time()
        sparse_fw(X, y, lam=lam, steps=steps, queue="bsls", epsilon=epsilon)
        t24 = time.time() - t0
        out["points"].append({
            "d": d, "alg1_s": round(t1, 3), "alg2+4_s": round(t24, 3),
            "speedup": round(t1 / max(t24, 1e-9), 1),
        })
    sp = [p["speedup"] for p in out["points"]]
    out["monotone_in_d"] = bool(all(b >= a * 0.8 for a, b in zip(sp, sp[1:])))
    out["max_speedup"] = max(sp)
    return out
