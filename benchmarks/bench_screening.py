"""Screening bench — §13 DP iterative screening vs the plain chunked solve.

Two arms of the same private ``jax_sparse`` fit at **equal total ε** on a
held-out split of each dataset twin:

  * ``plain``    — the §9 chunked driver, full padded D every chunk;
  * ``screened`` — ``screen_every`` fires the privatized screening query at
    chunk boundaries, repacking the padded pair to the survivors, so later
    chunks pay O(D_surviving).

Reported per dataset: end-to-end wall time of each arm (steady-state — both
arms run twice and time the second pass, so every chunk shape the screened
schedule visits hits the XLA compile cache; the DP screening noise is
seeded per (config.seed, round), which makes the survivor sets — and hence
the compiled shapes — identical across passes), the speedup ratio, the
survivor count, and the **utility audit**: held-out accuracy of both arms
at the same total ε, with ``pass_utility`` asserting the screened fit gives
up at most ``UTILITY_TOL`` accuracy.  ``pass_coords`` pins the §13 result
contract — original-space coords, supp(w) inside the selected set.

The twins are ~300× smaller than the paper's datasets (benchmarks/common),
so ε is generous by paper standards: per-coordinate EM noise scales like
N·ε, and at twin N a paper-scale ε would drown the selection signal both
arms share.  The *comparison* is ε-fair — both arms spend the same total
budget (docs/BENCHMARKS.md).

Output: BENCH_screening.json (``run.py --only screening``; gated by
``check.py`` on ``screen_speedup`` and ``pass_utility``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.sparse.formats import HostCSR

UTILITY_TOL = 0.05       # max held-out accuracy give-up at equal total ε
TRAIN_FRACTION = 0.8


def _row_split(X: HostCSR, n_train: int):
    """Contiguous train/test row split of a HostCSR (twin rows are i.i.d.
    by construction, so a prefix split is already a random split)."""
    lo = X.indptr[:n_train + 1].copy()
    hi = X.indptr[n_train:].copy()
    train = HostCSR(lo, X.indices[: lo[-1]].copy(),
                    X.data[: lo[-1]].copy(), (n_train, X.shape[1]))
    test = HostCSR(hi - hi[0], X.indices[hi[0]:].copy(),
                   X.data[hi[0]:].copy(), (X.shape[0] - n_train, X.shape[1]))
    return train, test


def _timed_solve(backend, data, y, cfg):
    """Steady-state end-to-end wall: warm pass compiles every chunk shape
    the schedule visits, second pass is timed."""
    res = backend.fn(data, y, cfg)
    np.asarray(res.w)
    t0 = time.time()
    res = backend.fn(data, y, cfg)
    np.asarray(res.w)
    return res, time.time() - t0


def run(datasets=("rcv1", "url"), steps: int = 320, lam: float = 30.0,
        epsilon: float = 12.0, delta: float = 1e-6, chunk_steps: int = 40,
        screen_every: int = 1, screen_eps_frac: float = 0.25):
    from benchmarks.common import accuracy_auc, load_problem
    from repro.core.solvers import FWConfig, get_backend, resolve_queue

    out = {"steps": steps, "lam": lam, "epsilon": epsilon,
           "chunk_steps": chunk_steps, "screen_every": screen_every,
           "screen_eps_frac": screen_eps_frac, "datasets": {}}
    backend = get_backend("jax_sparse")
    for name in datasets:
        prob = load_problem(name)
        n, d = prob.X.shape
        n_train = int(n * TRAIN_FRACTION)
        X_train, X_test = _row_split(prob.X, n_train)
        y_train, y_test = prob.y[:n_train], prob.y[n_train:]
        data = backend.prepare(X_train)

        base = FWConfig(backend="jax_sparse", queue="bsls", lam=lam,
                        steps=steps, epsilon=epsilon, delta=delta,
                        chunk_steps=chunk_steps)
        plain_cfg = resolve_queue(backend, base)
        screen_cfg = resolve_queue(backend, FWConfig(
            backend="jax_sparse", queue="bsls", lam=lam, steps=steps,
            epsilon=epsilon, delta=delta, chunk_steps=chunk_steps,
            screen_every=screen_every, screen_eps_frac=screen_eps_frac))

        plain, t_plain = _timed_solve(backend, data, y_train, plain_cfg)
        scr, t_scr = _timed_solve(backend, data, y_train, screen_cfg)

        w_p, w_s = np.asarray(plain.w), np.asarray(scr.w)
        acc_p, auc_p = accuracy_auc(X_test, y_test, w_p)
        acc_s, auc_s = accuracy_auc(X_test, y_test, w_s)
        coords = np.asarray(scr.coords)
        survivors = int(len(set(coords[coords >= 0].tolist())))
        pass_coords = bool(
            w_s.shape == (d,)
            and ((coords >= -1) & (coords < d)).all()
            and set(np.flatnonzero(w_s).tolist())
            <= set(coords[coords >= 0].tolist()))
        row = {
            "n": n, "d": d, "train_rows": n_train,
            "seconds_plain": round(t_plain, 3),
            "seconds_screened": round(t_scr, 3),
            "per_iter_ms_plain": round(t_plain / steps * 1e3, 3),
            "per_iter_ms_screened": round(t_scr / steps * 1e3, 3),
            "screen_speedup": round(t_plain / max(t_scr, 1e-9), 2),
            "selected_coords": survivors,
            "acc_plain": round(acc_p, 4), "acc_screened": round(acc_s, 4),
            "auc_plain": round(auc_p, 4), "auc_screened": round(auc_s, 4),
            "pass_utility": bool(acc_s >= acc_p - UTILITY_TOL),
            "pass_coords": pass_coords,
        }
        out["datasets"][name] = row
        print(f"[screening] {name}: plain {row['seconds_plain']}s, "
              f"screened {row['seconds_screened']}s "
              f"({row['screen_speedup']}x)  acc {acc_p:.3f} -> {acc_s:.3f} "
              f"utility={row['pass_utility']} coords={pass_coords}",
              flush=True)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
