"""Shared benchmark plumbing: dataset twins at selectable scale, metrics."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.configs.paper_lasso import DATASETS, LassoDataset
from repro.core.sparse.formats import HostCSR
from repro.data.synthetic import make_sparse_classification

# CPU-sized twins of the paper's Table-2 datasets.  N shrinks hard (CPU
# budget); D shrinks less — the paper's speedups live in the D ≫ N regime
# (their D reaches 20.2M), so the twins keep D/N well above the originals'
# per-row sparsity structure while staying generable in seconds.
BENCH_SCALE = {
    "rcv1": (2_000, 4_800, 40.0, 64, 0),
    "news20": (1_000, 135_000, 110.0, 128, 0),
    "url": (4_000, 32_000, 30.0, 64, 24),      # keeps the dense block
    "web": (1_200, 166_000, 260.0, 128, 0),
    "kdda": (2_000, 202_000, 12.0, 64, 0),
}


@dataclasses.dataclass
class BenchProblem:
    name: str
    X: HostCSR
    y: np.ndarray
    full: LassoDataset        # the paper-scale stats this is a twin of


def load_problem(name: str, seed: int = 0) -> BenchProblem:
    n, d, nnz, info, dense = BENCH_SCALE[name]
    X, y, _ = make_sparse_classification(
        n=n, d=d, nnz_per_row=nnz, informative=info, dense_features=dense,
        seed=seed)
    return BenchProblem(name=name, X=X, y=y, full=DATASETS[name])


def accuracy_auc(X: HostCSR, y: np.ndarray, w: np.ndarray) -> Tuple[float, float]:
    m = np.asarray(X.matvec(np.asarray(w, np.float64)))
    acc = float(((m > 0) == (y > 0.5)).mean())
    # rank-based AUC
    order = np.argsort(m)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(m) + 1)
    pos = y > 0.5
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return acc, 0.5
    auc = (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)
    return acc, float(auc)


def sparsity_pct(w: np.ndarray) -> float:
    """Paper Table 4 convention: % of coordinates that are zero."""
    return 100.0 * float(np.mean(np.asarray(w) == 0.0))


def run_backend(prob: BenchProblem, backend: str, **cfg):
    """Run an Alg-2-style solve on a bench problem through the solver registry.

    ``cfg`` fields are FWConfig fields (lam, steps, queue, epsilon, delta...).
    Benches that only need weights/gaps/coords should go through here so
    ``benchmarks.run --backend`` can retarget them onto any registered engine;
    benches that read the host engine's audit counters (flops, heap pops)
    call ``repro.core.fw_sparse.sparse_fw`` directly and are pinned to the
    host backend by construction (see docs/BENCHMARKS.md).
    """
    from repro.core.solvers import FWConfig, solve
    return solve(prob.X, prob.y, FWConfig(backend=backend, **cfg))
