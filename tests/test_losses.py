"""Loss layer: values, gradients (vs numeric diff), split-grad identity,
Lipschitz bounds, the Objective registry, and numpy-twin consistency."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional: the seeded tests below always run
    HAVE_HYPOTHESIS = False

from repro.core.losses import (OBJECTIVES, Objective, get_loss,  # noqa: E402
                               get_objective, register_objective)

LOSSES = sorted(OBJECTIVES)                       # every registered objective
SEPARABLE = [n for n in LOSSES if OBJECTIVES[n].separable]
COUPLED = [n for n in LOSSES if not OBJECTIVES[n].separable]


def test_registry_contents():
    assert set(LOSSES) == {"logistic", "squared", "lad", "huber",
                           "smoothed_hinge"}
    assert SEPARABLE == ["logistic", "squared"]
    assert set(COUPLED) == {"lad", "huber", "smoothed_hinge"}


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(KeyError, match="unknown loss"):
        get_objective("hinge_of_theseus")
    with pytest.raises(ValueError, match="already registered"):
        register_objective(OBJECTIVES["logistic"])


def test_register_objective_roundtrip():
    probe = Objective(name="_probe", value=lambda m, y: m * 0.0,
                      grad=lambda m, y: m * 0.0, split_grad=None,
                      lipschitz=1.0, smooth=False)
    try:
        register_objective(probe)
        assert get_loss("_probe") is probe
        assert not probe.smooth and not probe.separable
    finally:
        OBJECTIVES.pop("_probe", None)


@pytest.mark.parametrize("name", LOSSES)
def test_grad_matches_numeric(name):
    loss = get_loss(name)
    m = jnp.linspace(-4, 4, 33)
    y = jnp.asarray(np.random.default_rng(0).integers(0, 2, 33), jnp.float32)
    eps = 1e-2  # f32 arithmetic: large step beats roundoff in central diff
    num = (loss.value(m + eps, y) - loss.value(m - eps, y)) / (2 * eps)
    np.testing.assert_allclose(loss.grad(m, y), num, atol=1e-2)


@pytest.mark.parametrize("name", SEPARABLE)
def test_split_grad_identity(name):
    """dL/dm must equal h(m) − y — the decomposition Alg 1/2 exploit."""
    loss = get_loss(name)
    m = jnp.linspace(-6, 6, 41)
    for yv in (0.0, 1.0):
        y = jnp.full_like(m, yv)
        np.testing.assert_allclose(loss.grad(m, y), loss.split_grad(m) - y,
                                   atol=1e-6)


@pytest.mark.parametrize("name", COUPLED)
def test_coupled_objectives_have_no_split_grad(name):
    loss = get_loss(name)
    assert loss.split_grad is None and not loss.separable
    assert loss.label_weight == 0.0
    with pytest.raises(ValueError, match="label-coupled"):
        loss.h(jnp.zeros(3))                      # labels required


@pytest.mark.parametrize("name", LOSSES)
def test_h_dispatch_equals_row_gradient_plus_label(name):
    """obj.h is the q̄ refresh the engines call: h(m) (separable) or
    grad(m, y) (coupled); either way q̄ − label_weight·y == grad(m, y)."""
    loss = get_loss(name)
    m = jnp.linspace(-3, 3, 17)
    y = jnp.asarray(np.random.default_rng(1).integers(0, 2, 17), jnp.float32)
    qbar = loss.h(m, y)
    np.testing.assert_allclose(qbar - loss.label_weight * y, loss.grad(m, y),
                               atol=1e-6)


@pytest.mark.parametrize("name", LOSSES)
def test_numpy_twins_match_jax(name):
    """The host backend (fw_sparse) computes gradients through the numpy
    twins — they must agree with the traced jnp definitions."""
    loss = get_loss(name)
    m = np.linspace(-5, 5, 29)
    y = np.random.default_rng(2).integers(0, 2, 29).astype(np.float64)
    np.testing.assert_allclose(loss.grad_np(m, y),
                               np.asarray(loss.grad(jnp.asarray(m),
                                                    jnp.asarray(y))),
                               atol=1e-6)
    if loss.separable:
        np.testing.assert_allclose(loss.split_grad_np(m),
                                   np.asarray(loss.split_grad(jnp.asarray(m))),
                                   atol=1e-6)


@pytest.mark.parametrize("name", LOSSES)
def test_registered_objectives_are_smooth(name):
    """Every builtin objective declares a valid gap certificate (LAD and the
    hinge ship *smoothed*; a genuinely non-smooth objective must register
    with smooth=False and is refused gap_tol by check_gap_certificate)."""
    assert get_loss(name).smooth


if HAVE_HYPOTHESIS:
    @given(st.floats(-30, 30), st.integers(0, 1))
    @settings(max_examples=50, deadline=None)
    def test_logistic_grad_bounded_by_lipschitz(m, y):
        loss = get_loss("logistic")
        g = float(loss.grad(jnp.asarray(m), jnp.asarray(float(y))))
        assert abs(g) <= loss.lipschitz + 1e-6


def test_logistic_value_stable_large_margin():
    loss = get_loss("logistic")
    v = loss.value(jnp.asarray([1e4, -1e4]), jnp.asarray([0.0, 1.0]))
    assert bool(jnp.all(jnp.isfinite(v)))


def test_huber_lipschitz_differs_from_logistic():
    """The per-loss sensitivity actually varies across the registry — what
    makes the DP-stats scale tests exercise the λ·L/N flow non-trivially."""
    assert get_loss("huber").lipschitz == 0.5
    assert get_loss("logistic").lipschitz == 1.0
