"""Loss layer: values, gradients (vs numeric diff), split-grad identity,
Lipschitz bounds."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.losses import get_loss

LOSSES = ["logistic", "squared"]


@pytest.mark.parametrize("name", LOSSES)
def test_grad_matches_numeric(name):
    loss = get_loss(name)
    m = jnp.linspace(-4, 4, 33)
    y = jnp.asarray(np.random.default_rng(0).integers(0, 2, 33), jnp.float32)
    eps = 1e-2  # f32 arithmetic: large step beats roundoff in central diff
    num = (loss.value(m + eps, y) - loss.value(m - eps, y)) / (2 * eps)
    np.testing.assert_allclose(loss.grad(m, y), num, atol=5e-3)


@pytest.mark.parametrize("name", LOSSES)
def test_split_grad_identity(name):
    """dL/dm must equal h(m) − y — the decomposition Alg 1/2 exploit."""
    loss = get_loss(name)
    m = jnp.linspace(-6, 6, 41)
    for yv in (0.0, 1.0):
        y = jnp.full_like(m, yv)
        np.testing.assert_allclose(loss.grad(m, y), loss.split_grad(m) - y,
                                   atol=1e-6)


@given(st.floats(-30, 30), st.integers(0, 1))
@settings(max_examples=50, deadline=None)
def test_logistic_grad_bounded_by_lipschitz(m, y):
    loss = get_loss("logistic")
    g = float(loss.grad(jnp.asarray(m), jnp.asarray(float(y))))
    assert abs(g) <= loss.lipschitz + 1e-6


def test_logistic_value_stable_large_margin():
    loss = get_loss("logistic")
    v = loss.value(jnp.asarray([1e4, -1e4]), jnp.asarray([0.0, 1.0]))
    assert bool(jnp.all(jnp.isfinite(v)))
