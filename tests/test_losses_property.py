"""Property tests of the Objective contract, per registered loss.

Four invariants every objective must satisfy (DESIGN.md §10):

  1. |dL/dm| ≤ lipschitz on the loss's valid margin domain — the bound the
     DP sensitivity Δu = λ·L/N (hence every noise scale) is derived from;
  2. the split-gradient law: ``grad(m, y) == split_grad(m) − y`` for
     separable objectives, and ``h(m, y) == grad(m, y)`` with
     ``label_weight == 0`` for label-coupled ones — the q̄ update contract
     every backend's inner loop relies on;
  3. finite-difference agreement of ``value``/``grad``;
  4. smooth objectives drive the FW loss trace down (dense backend).

Each invariant lives in a ``_check_*`` helper.  The always-on tests sweep
the helpers over dense seeded grids (so CI exercises them without extra
dependencies); when ``hypothesis`` is installed, `@given`-driven variants
of the same helpers also engage for adversarial float hunting.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import OBJECTIVES, get_loss

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container ships without hypothesis: the seeded
    HAVE_HYPOTHESIS = False  # sweeps below still cover every invariant

LOSSES = sorted(OBJECTIVES)

# Margin domain on which each loss's lipschitz constant is claimed.  The
# squared loss is only 1-Lipschitz on |m − y| ≤ 1 (its gradient is m − y);
# every other registered loss has a globally bounded gradient.
_GLOBAL_DOMAIN = (-30.0, 30.0)


def _margins_for(name: str, rng: np.ndarray, y: np.ndarray) -> np.ndarray:
    if name == "squared":
        return y + rng                       # residual r = rng ∈ [−1, 1]
    lo, hi = _GLOBAL_DOMAIN
    return lo + (rng + 1.0) * 0.5 * (hi - lo)


# ---------------------------------------------------------------------------
# invariant helpers (shared by the seeded sweeps and the hypothesis variants)
# ---------------------------------------------------------------------------


def _check_lipschitz(name: str, m: np.ndarray, y: np.ndarray) -> None:
    loss = get_loss(name)
    g = np.asarray(loss.grad(jnp.asarray(m, jnp.float32),
                             jnp.asarray(y, jnp.float32)))
    assert np.all(np.abs(g) <= loss.lipschitz + 1e-5), (
        f"{name}: |grad| max {np.abs(g).max()} > L={loss.lipschitz}")


def _check_split_grad(name: str, m: np.ndarray, y: np.ndarray) -> None:
    loss = get_loss(name)
    mj = jnp.asarray(m, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    if loss.separable:
        np.testing.assert_allclose(
            np.asarray(loss.grad(mj, yj)),
            np.asarray(loss.split_grad(mj) - yj), atol=1e-5,
            err_msg=f"{name}: grad != split_grad(m) - y")
    else:
        assert loss.label_weight == 0.0
        np.testing.assert_allclose(
            np.asarray(loss.h(mj, yj)), np.asarray(loss.grad(mj, yj)),
            atol=0.0, err_msg=f"{name}: h must be the full row gradient")


def _check_finite_difference(name: str, m: np.ndarray, y: np.ndarray) -> None:
    loss = get_loss(name)
    mj = jnp.asarray(m, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    eps = 1e-2   # f32: large step beats roundoff; C¹ corners cost O(eps)
    num = (loss.value(mj + eps, yj) - loss.value(mj - eps, yj)) / (2 * eps)
    np.testing.assert_allclose(np.asarray(loss.grad(mj, yj)),
                               np.asarray(num), atol=1e-2,
                               err_msg=f"{name}: grad vs central difference")


# ---------------------------------------------------------------------------
# always-on seeded sweeps
# ---------------------------------------------------------------------------


def _seeded_batch(name: str, seed: int, k: int = 257):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, k).astype(np.float64)
    r = rng.uniform(-1.0, 1.0, k)
    return _margins_for(name, r, y), y


@pytest.mark.parametrize("name", LOSSES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_grad_bounded_by_lipschitz(name, seed):
    m, y = _seeded_batch(name, seed)
    _check_lipschitz(name, m, y)


@pytest.mark.parametrize("name", LOSSES)
@pytest.mark.parametrize("seed", [3, 4])
def test_split_grad_consistency(name, seed):
    m, y = _seeded_batch(name, seed)
    _check_split_grad(name, m, y)


@pytest.mark.parametrize("name", LOSSES)
@pytest.mark.parametrize("seed", [5, 6])
def test_value_grad_finite_difference(name, seed):
    m, y = _seeded_batch(name, seed)
    _check_finite_difference(name, m, y)


@pytest.mark.parametrize("name", LOSSES)
def test_lipschitz_tight_somewhere(name):
    """L is a *useful* bound, not just safe: some margin attains ≥ L/4 —
    catches an objective registering a wildly inflated sensitivity (which
    would silently overdose the DP noise)."""
    loss = get_loss(name)
    m, y = _seeded_batch(name, 7, k=4097)
    g = np.abs(np.asarray(loss.grad(jnp.asarray(m, jnp.float32),
                                    jnp.asarray(y, jnp.float32))))
    assert g.max() >= loss.lipschitz / 4.0


@pytest.mark.parametrize("name", [n for n in LOSSES if OBJECTIVES[n].smooth])
def test_fw_drives_loss_down_per_smooth_objective(name):
    """The dense backend's per-iteration mean-loss trace must fall: FW with
    η_t = 2/(t+2) is not per-step monotone, but on a smooth objective the
    trace's running best strictly improves and the tail beats the head."""
    from repro.core.solvers import FWConfig, solve
    rng = np.random.default_rng(17)
    n, d = 60, 40
    X = rng.normal(size=(n, d)) / np.sqrt(d)
    w_star = np.zeros(d)
    w_star[rng.choice(d, 6, replace=False)] = rng.normal(0, 2, 6)
    y = (X @ w_star > 0).astype(np.float64)
    r = solve(X, y, FWConfig(backend="dense", lam=4.0, steps=60, loss=name))
    trace = np.asarray(r.losses)
    assert np.all(np.isfinite(trace)), name
    assert trace[-1] < trace[0], name
    # running best at the end improves on the first quarter's best
    q = len(trace) // 4
    assert trace[-q:].min() < trace[:q].min(), name
    assert trace[-q:].mean() < trace[:q].mean(), name


# ---------------------------------------------------------------------------
# hypothesis variants (engage when the dependency is present)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _unit = st.floats(-1.0, 1.0, allow_nan=False, allow_infinity=False)
    _label = st.integers(0, 1)

    @given(r=_unit, yv=_label)
    @settings(max_examples=60, deadline=None)
    @pytest.mark.parametrize("name", LOSSES)
    def test_hypothesis_lipschitz(name, r, yv):
        y = np.asarray([float(yv)])
        _check_lipschitz(name, _margins_for(name, np.asarray([r]), y), y)

    @given(r=_unit, yv=_label)
    @settings(max_examples=60, deadline=None)
    @pytest.mark.parametrize("name", LOSSES)
    def test_hypothesis_split_grad(name, r, yv):
        y = np.asarray([float(yv)])
        _check_split_grad(name, _margins_for(name, np.asarray([r]), y), y)

    @given(r=_unit, yv=_label)
    @settings(max_examples=60, deadline=None)
    @pytest.mark.parametrize("name", LOSSES)
    def test_hypothesis_finite_difference(name, r, yv):
        y = np.asarray([float(yv)])
        _check_finite_difference(name, _margins_for(name, np.asarray([r]), y),
                                 y)
