"""Cost-model planner (DESIGN.md §9): stats, backend choice, mode choice,
and the plan-independence of results through solve()/solve_many()/FitService.
"""
import numpy as np
import pytest

from repro.core.solvers import FWConfig, SolvePlan, grid, plan_for, solve, solve_many
from repro.core.solvers import planner


@pytest.fixture(scope="module")
def problem():
    from repro.data.synthetic import make_sparse_classification
    X, y, _ = make_sparse_classification(
        n=120, d=500, nnz_per_row=8, informative=12, seed=3)
    return X, y


def test_data_stats_layout_agnostic(problem):
    from repro.core.sparse.formats import host_to_padded
    X, _ = problem
    s_host = planner.data_stats(X)
    s_pair = planner.data_stats(host_to_padded(X))
    s_dense = planner.data_stats(X.to_dense())
    assert s_host.n == s_pair.n == s_dense.n == 120
    assert s_host.d == s_pair.d == s_dense.d == 500
    assert s_host.nnz == s_pair.nnz == s_dense.nnz == X.nnz
    assert s_host.kc == s_dense.kc and s_host.kr == s_dense.kr
    assert 0 < s_host.density < 1


def test_choose_backend_regimes():
    # the paper's regime: sparse, D ≫ N → the Alg-2 kernel pipeline
    sparse = planner.ProblemStats(n=2000, d=500_000, nnz=80_000, kc=64,
                                  kr=40)
    assert planner.choose_backend(sparse, FWConfig()) == "jax_sparse"
    # small dense designs: Alg 1's O(nnz + D) beats the padded tile
    dense = planner.ProblemStats(n=80, d=50, nnz=4000, kc=80, kr=50)
    assert planner.choose_backend(dense, FWConfig()) == "dense"
    # a real mesh always means the sharded engine
    assert planner.choose_backend(
        sparse, FWConfig(mesh=(2, 2))) == "jax_shard"


def test_group_mode_cpu_defaults_sequential():
    stats = planner.ProblemStats(n=2000, d=4800, nnz=80_000, kc=64, kr=40)
    planner.clear_costbook()
    assert planner.group_mode(stats, 8, platform="cpu") == "sequential"
    assert planner.group_mode(stats, 8, platform="tpu") == "vmap"
    assert planner.group_mode(stats, 1, platform="tpu") == "sequential"
    # measured costs override the platform default (first observation per
    # key is compile-tainted and discarded, so record twice)
    for _ in range(2):
        planner.record_cost("jax_sparse", "vmap", "cpu", stats, 0.001)
        planner.record_cost("jax_sparse", "sequential", "cpu", stats, 0.010)
    assert planner.group_mode(stats, 8, platform="cpu") == "vmap"
    planner.clear_costbook()


def test_costbook_ewma_and_warmup_discard():
    stats = planner.ProblemStats(n=1000, d=4000, nnz=50_000, kc=32, kr=32)
    planner.clear_costbook()
    assert planner.measured_cost("jax_sparse", "vmap", "cpu", stats) is None
    # first observation per key times a fresh compile — discarded
    planner.record_cost("jax_sparse", "vmap", "cpu", stats, 999.0)
    assert planner.measured_cost("jax_sparse", "vmap", "cpu", stats) is None
    planner.record_cost("jax_sparse", "vmap", "cpu", stats, 1.0)
    planner.record_cost("jax_sparse", "vmap", "cpu", stats, 0.0)
    got = planner.measured_cost("jax_sparse", "vmap", "cpu", stats)
    assert got == pytest.approx(0.7)
    planner.clear_costbook()


def test_plan_for_and_default_chunk(problem):
    X, _ = problem
    cfgs = grid(FWConfig(backend="jax_sparse", steps=64), lam=(1.0, 2.0))
    plan = plan_for(X, cfgs, platform="cpu")
    assert plan.resolved_mode("cpu") == "sequential"
    assert plan.chunk_steps == planner.default_chunk(64) == 8
    assert planner.default_chunk(4000) == 256
    assert planner.default_chunk(3) == 3
    assert "grid=2" in plan.notes


def test_cohort_widths_buckets():
    assert planner.cohort_widths(8) == (8, 4, 2, 1)
    assert planner.cohort_widths(6) == (6, 4, 2, 1)
    assert planner.cohort_widths(1) == (1,)


def test_solve_auto_backend_matches_explicit(problem):
    X, y = problem
    auto = solve(X, y, FWConfig(backend="auto", lam=8.0, steps=15))
    explicit = solve(X, y, FWConfig(
        backend=planner.choose_backend(planner.data_stats(X), FWConfig()),
        lam=8.0, steps=15))
    np.testing.assert_array_equal(np.asarray(auto.coords),
                                  np.asarray(explicit.coords))
    np.testing.assert_array_equal(np.asarray(auto.w), np.asarray(explicit.w))


def test_solve_many_rejects_bogus_plan(problem):
    X, y = problem
    with pytest.raises(ValueError, match="plan"):
        solve_many(X, y, [FWConfig(backend="jax_sparse", steps=2)],
                   plan="turbo")


def test_solve_many_plan_object_chunk_override(problem):
    X, y = problem
    cfgs = grid(FWConfig(backend="jax_sparse", steps=20, gap_tol=1e-30),
                lam=(4.0, 8.0, 12.0))
    a = solve_many(X, y, cfgs, plan=SolvePlan(mode="vmap", chunk_steps=5))
    b = solve_many(X, y, cfgs, plan=SolvePlan(mode="vmap", chunk_steps=20))
    c = solve_many(X, y, cfgs, plan="sequential")
    for ra, rb, rc in zip(a, b, c):
        np.testing.assert_array_equal(np.asarray(ra.w), np.asarray(rb.w))
        np.testing.assert_array_equal(np.asarray(ra.w), np.asarray(rc.w))
        np.testing.assert_array_equal(np.asarray(ra.coords),
                                      np.asarray(rc.coords))


def test_fit_service_auto_backend_charges_like_explicit(problem):
    """Per-request planning resolves backend='auto' at admission; ε-charging
    is identical to the explicitly-routed request (charge is by resolved
    queue, not engine)."""
    from repro.core.dp.accountant import PrivacyAccountant
    from repro.serve import FitRequest, FitService
    X, y = problem
    mk = lambda: {"t": PrivacyAccountant(epsilon=4.0, delta=1e-6,
                                         total_steps=200)}
    cfg = dict(lam=8.0, steps=20, queue="bsls", epsilon=1.0, delta=1e-6)
    svc_auto = FitService(X, y, accountants=mk())
    svc_auto.submit(FitRequest(uid=0, tenant="t",
                               config=FWConfig(backend="auto", **cfg)))
    done_auto = svc_auto.run()
    svc_exp = FitService(X, y, accountants=mk())
    svc_exp.submit(FitRequest(uid=0, tenant="t",
                              config=FWConfig(backend="jax_sparse", **cfg)))
    done_exp = svc_exp.run()
    assert done_auto[0].status == done_exp[0].status == "done"
    assert done_auto[0].config.backend in ("jax_sparse", "dense")
    assert (svc_auto.accountants["t"].spent_steps
            == svc_exp.accountants["t"].spent_steps)


def test_shard_observation_cannot_flip_jax_sparse_mode():
    """Cost-book keying regression: the batched drivers used to record every
    group under backend="jax_sparse", so sharded timings steered the kernel
    pipeline's vmap-vs-sequential choice.  Observations must stay siloed per
    backend."""
    stats = planner.ProblemStats(n=2000, d=4800, nnz=80_000, kc=64, kr=40)
    planner.clear_costbook()
    try:
        # a shard group that measured vmap as (absurdly) cheap...
        for _ in range(2):
            planner.record_cost("jax_shard", "vmap", "cpu", stats, 1e-6)
            planner.record_cost("jax_shard", "sequential", "cpu", stats, 1.0)
        # ...must not flip a jax_sparse group off the CPU default
        assert planner.group_mode(stats, 8, platform="cpu") == "sequential"
        # while the shard backend's own groups do read them
        assert planner.group_mode(stats, 8, platform="cpu",
                                  backend="jax_shard") == "vmap"
    finally:
        planner.clear_costbook()


def test_shard_group_records_under_its_own_key(problem):
    """solve_many shard groups feed the book under backend="jax_shard"."""
    X, y = problem
    planner.clear_costbook()
    try:
        stats = planner.data_stats(X)
        cfgs = grid(lam=(5.0, 9.0), backend="jax_shard", steps=4)
        for _ in range(2):           # first observation per key is discarded
            solve_many(X, y, cfgs)
        assert planner.measured_cost("jax_shard", "vmap", "cpu",
                                     stats) is not None
        assert planner.measured_cost("jax_sparse", "vmap", "cpu",
                                     stats) is None
    finally:
        planner.clear_costbook()


def test_store_stats_from_manifest_never_materializes(problem, tmp_path,
                                                      monkeypatch):
    """data_stats(store) must come from manifest metadata — the old path
    called to_host_csr(), materializing the whole store per admission."""
    from repro.data.store import DatasetStore
    X, y = problem
    store = DatasetStore.from_arrays(str(tmp_path / "ds"), X, y,
                                     rows_per_shard=40)
    expect = planner.data_stats(X)
    planner._STORE_STATS.clear()
    monkeypatch.setattr(
        DatasetStore, "to_host_csr",
        lambda self: (_ for _ in ()).throw(
            AssertionError("data_stats materialized the store")))
    got = planner.data_stats(store)
    assert (got.n, got.d, got.nnz, got.kc, got.kr) == \
        (expect.n, expect.d, expect.nnz, expect.kc, expect.kr)
    # cached per content hash
    assert planner.data_stats(store) is got


def test_store_stats_legacy_manifest_fallback(problem, tmp_path):
    """Stores written before the row/col max manifest keys still derive the
    same stats (col max off df counts, row max off mmap'd indptrs)."""
    from repro.data.store import DatasetStore
    X, y = problem
    store = DatasetStore.from_arrays(str(tmp_path / "ds"), X, y,
                                     rows_per_shard=40)
    fresh = planner.store_stats(store)
    planner._STORE_STATS.clear()
    store.manifest.pop("row_nnz_max")
    store.manifest.pop("col_nnz_max")
    legacy = planner.store_stats(store)
    planner._STORE_STATS.clear()
    assert legacy == fresh == planner.data_stats(X)


def test_fit_service_stats_come_from_source(problem, tmp_path, monkeypatch):
    """FitService admissions derive planner stats from the resolved source
    (O(1) for stores), not by re-walking the coerced padded pair."""
    from repro.data.store import DatasetStore
    from repro.serve.fit_service import FitService
    X, y = problem
    store = DatasetStore.from_arrays(str(tmp_path / "ds"), X, y,
                                     rows_per_shard=40)
    svc = FitService(store)
    planner._STORE_STATS.clear()
    monkeypatch.setattr(
        DatasetStore, "to_host_csr",
        lambda self: (_ for _ in ()).throw(
            AssertionError("admission materialized the store")))
    assert svc._planned_backend(FWConfig(backend="auto")) in (
        "dense", "jax_sparse")
