"""Distributed Frank-Wolfe (shard_map, 2×2 mesh in a subprocess — jax device
count is locked at first init, so multi-device runs get their own process).

The non-private solve goes through the *registry* (``backend="jax_shard"``,
``mesh=(2, 2)``) so the full production path — ShardSource coercion → block
build → setup/scan programs — is exercised on a genuinely sharded mesh, not
just the 1×1 parity harness of test_jax_shard.py."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.data.synthetic import make_sparse_classification
from repro.core.fw_sparse import sparse_fw
from repro.distributed.block_sparse import build_block_sparse
from repro.distributed.fw_shard import DistFWConfig, distributed_fw

X, y, _ = make_sparse_classification(n=120, d=400, nnz_per_row=10,
                                     informative=15, seed=5)
if hasattr(jax.sharding, "AxisType"):
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
else:
    mesh = jax.make_mesh((2, 2), ("data", "model"))
blocks = build_block_sparse(X, 2, 2)
y_pad = jnp.zeros(blocks.padded[0], jnp.float32).at[:len(y)].set(
    jnp.asarray(y, jnp.float32))

out = {}
from repro.core.solvers import FWConfig, solve
res = solve(X, y, FWConfig(backend="jax_shard", mesh=(2, 2), lam=8.0,
                           steps=80))
host = sparse_fw(X, y, lam=8.0, steps=80, queue="fib_heap")
out["coords_match"] = bool(
    (np.asarray(res.coords) == np.asarray(host.coords)).all())
out["w_maxdiff"] = float(np.abs(np.asarray(res.w) - np.asarray(host.w)).max())
out["gap_dist"] = float(res.gaps[-1])
out["gap_host"] = float(host.gaps[-1])

with mesh:
    wg, gg, cg, sg = distributed_fw(
        blocks, y_pad,
        DistFWConfig(lam=8.0, steps=60, selection="gumbel", epsilon=1.0), mesh)
out["dp_finite"] = bool(np.isfinite(np.asarray(wg)).all())
out["dp_unique_coords"] = len(set(np.asarray(cg).tolist()))
out["dp_stop_step"] = int(sg)

with mesh:
    wc, gc, _, _ = distributed_fw(
        blocks, y_pad,
        DistFWConfig(lam=8.0, steps=80, selection="argmax", compress_topk=8),
        mesh)
out["topk_gap"] = float(gc[-1])
out["topk_l1"] = float(np.abs(np.asarray(wc)).sum())
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                          text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_distributed_matches_host_oracle(dist_result):
    """Sharded FW takes the same steps as the faithful host Alg 2."""
    assert dist_result["coords_match"]
    assert dist_result["w_maxdiff"] < 1e-5


def test_distributed_gap_matches(dist_result):
    assert dist_result["gap_dist"] == pytest.approx(
        dist_result["gap_host"], rel=1e-3, abs=1e-5)


def test_distributed_dp_runs(dist_result):
    assert dist_result["dp_finite"]
    assert dist_result["dp_unique_coords"] > 10   # EM explores
    assert dist_result["dp_stop_step"] == 60      # no gap_tol → full T


def test_topk_compression_converges(dist_result):
    """Error-feedback top-k must stay close to the dense exchange and respect
    the L1 ball."""
    assert dist_result["topk_gap"] < 0.1
    assert dist_result["topk_l1"] <= 8.0 * (1 + 1e-5)
