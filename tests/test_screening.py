"""DP iterative screening between chunks (DESIGN.md §13).

Covers the screening subsystem end to end: the ε plan/round schedule, the
exactness of the geometry repack, trajectory parity when a round keeps
everything, the original-index map on screened results, the obs trail, and
the FitService admission charge for the composed release.
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.dp.accountant import PrivacyAccountant, per_step_epsilon
from repro.core.solvers import FWConfig, solve
from repro.core.solvers import screening
from repro.core.solvers.screening import (Screener, check_screen_config,
                                          repack_pair, screen_plan,
                                          screening_rounds, solve_epsilon)
from repro.core.sparse.formats import (TieredCSC, dense_to_host,
                                       host_to_padded, tiered_from_padded)
from repro.data.synthetic import make_sparse_classification


@pytest.fixture(scope="module")
def problem():
    return make_sparse_classification(n=150, d=600, nnz_per_row=10,
                                      informative=15, seed=11)


# ---------------------------------------------------------------------------
# plan / config validation
# ---------------------------------------------------------------------------


def test_screening_rounds_schedule():
    # 96 steps / chunk 16 -> 6 chunks -> 5 interior boundaries
    assert screening_rounds(96, 16, 1) == 5
    assert screening_rounds(96, 16, 2) == 2
    assert screening_rounds(96, 16, 5) == 1
    assert screening_rounds(96, 16, 6) == 0   # only the final boundary left
    assert screening_rounds(96, 96, 1) == 0   # single chunk: nothing interior
    assert screening_rounds(96, 16, 0) == 0


def test_screen_plan_epsilon_split():
    cfg = FWConfig(steps=96, chunk_steps=16, screen_every=2,
                   screen_eps_frac=0.25, epsilon=2.0, delta=1e-6)
    plan = screen_plan(cfg, private=True)
    assert plan.rounds == 2
    assert plan.eps_screen == pytest.approx(0.5)
    assert plan.eps_solve == pytest.approx(1.5)
    assert plan.eps_round == pytest.approx(
        per_step_epsilon(0.5, 1e-6, 2))
    assert solve_epsilon(cfg) == pytest.approx(1.5)
    # non-private: the whole ε stays with the solve
    np_plan = screen_plan(cfg, private=False)
    assert np_plan.eps_solve == pytest.approx(2.0)
    assert np_plan.eps_screen == 0.0 and np_plan.eps_round == 0.0
    # screening off: full ε, zero rounds
    off = dataclasses.replace(cfg, screen_every=0)
    assert solve_epsilon(off) == pytest.approx(2.0)
    assert screen_plan(off, private=True).rounds == 0


def test_check_screen_config_refusals():
    check_screen_config(FWConfig())                       # off: fine
    check_screen_config(FWConfig(screen_every=3))         # on, default frac
    with pytest.raises(ValueError, match="screen_every"):
        check_screen_config(FWConfig(screen_every=-1))
    for frac in (0.0, 1.0, 1.5, -0.2):
        with pytest.raises(ValueError, match="screen_eps_frac"):
            check_screen_config(
                FWConfig(screen_every=2, screen_eps_frac=frac))


def test_unsupported_backends_refuse_screening(problem):
    X, y, _ = problem
    for backend in ("host_sparse", "jax_dense", "jax_shard"):
        with pytest.raises(ValueError, match="screening"):
            solve(X, y, FWConfig(backend=backend, steps=8, screen_every=2))


# ---------------------------------------------------------------------------
# geometry repack exactness
# ---------------------------------------------------------------------------


def _random_pair(n=40, d=60, seed=0, density=0.15):
    rng = np.random.default_rng(seed)
    X = np.where(rng.random((n, d)) < density,
                 rng.standard_normal((n, d)), 0.0).astype(np.float32)
    return X, host_to_padded(dense_to_host(X))


def _csc_dense(pcsc) -> np.ndarray:
    """Densify a CSC layout through its own per-column accessors (not the
    repack's reconstruction helper — keeps the check independent)."""
    n, d = pcsc.shape
    out = np.zeros((n, d), np.float32)
    for j in range(d):
        if isinstance(pcsc, TieredCSC):
            idx, val, mask = (pcsc.col_heavy(j) if bool(pcsc.is_heavy(j))
                              else pcsc.col_light(j))
        else:
            idx, val, mask = pcsc.col(j)
        m = np.asarray(mask)
        out[np.asarray(idx)[m], j] = np.asarray(val)[m]
    return out


@pytest.mark.parametrize("tiered", [False, True])
def test_repack_pair_matches_dense_column_subset(tiered):
    X, (pcsr, pcsc) = _random_pair()
    if tiered:
        pcsc = tiered_from_padded(pcsc, max(1, pcsc.indices.shape[1] // 2))
    rng = np.random.default_rng(7)
    keep = rng.random(X.shape[1]) < 0.5
    keep[:3] = True                       # keep a deterministic prefix
    sel = np.flatnonzero(keep)
    p2, q2 = repack_pair(pcsr, pcsc, keep)
    ref = X[:, sel]
    assert p2.shape == (X.shape[0], sel.size)
    np.testing.assert_array_equal(np.asarray(p2.to_dense()), ref)
    np.testing.assert_array_equal(_csc_dense(q2), ref)
    # pad width shrinks to the survivors' true maxima
    assert p2.indices.shape[1] == max(1, int((ref != 0).sum(1).max()))
    # matvec/rmatvec agree with the dense subset
    w = np.random.default_rng(1).standard_normal(sel.size).astype(np.float32)
    np.testing.assert_allclose(np.asarray(p2.matvec(jnp.asarray(w))),
                               ref @ w, rtol=1e-5, atol=1e-5)


def test_repack_pair_retiers_wide_survivors():
    X, (pcsr, pcsc) = _random_pair(n=60, d=30, density=0.4)
    tiered = tiered_from_padded(pcsc, 2)   # narrow light tier, real heavy set
    keep = np.ones(X.shape[1], bool)
    keep[::3] = False
    p2, q2 = repack_pair(pcsr, tiered, keep)
    assert isinstance(q2, TieredCSC) and q2.width == 2
    np.testing.assert_array_equal(_csc_dense(q2), X[:, np.flatnonzero(keep)])


# ---------------------------------------------------------------------------
# trajectory contracts
# ---------------------------------------------------------------------------

BASE = dict(lam=30.0, steps=96, chunk_steps=16, seed=3)


def test_keep_all_rounds_are_trajectory_exact(problem, monkeypatch):
    """A round that keeps every coordinate still repacks/rebuilds the carry
    through the mutable-geometry path — and must not move the trajectory:
    same coords, same gaps, same iterate as the unscreened chunked run."""
    X, y, _ = problem
    monkeypatch.setattr(
        Screener, "screen",
        lambda self, scores, support: np.ones(scores.shape[0], bool))
    ref = solve(X, y, FWConfig(backend="jax_sparse", queue="group_argmax",
                               **BASE))
    res = solve(X, y, FWConfig(backend="jax_sparse", queue="group_argmax",
                               screen_every=2, **BASE))
    np.testing.assert_array_equal(np.asarray(res.coords),
                                  np.asarray(ref.coords))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))
    np.testing.assert_array_equal(np.asarray(res.gaps), np.asarray(ref.gaps))


def test_screened_coords_map_back_to_original_ids(problem):
    """Regression: a screened solve's FWResult lives in the *original*
    feature space — coords are original ids, w has length D₀, and the
    support sits inside the selected coordinates."""
    X, y, _ = problem
    d0 = X.shape[1]
    with obs.session() as tel:
        res = solve(X, y, FWConfig(backend="jax_sparse",
                                   queue="group_argmax", screen_every=1,
                                   **BASE))
    c = np.asarray(res.coords)
    w = np.asarray(res.w)
    assert w.shape == (d0,)
    assert ((c >= -1) & (c < d0)).all()
    assert set(np.flatnonzero(w).tolist()) <= set(c[c >= 0].tolist())
    rounds = [e["attrs"] for e in tel.events if e["name"] == "screen.round"]
    assert rounds, "screening never fired"
    fired = [a for a in rounds if a["repacked"]]
    assert fired and all(a["survivors"] < d0 for a in fired)
    assert any(e["name"] == "chunks.respec" for e in tel.events)


def test_private_screened_solve_is_sane(problem):
    X, y, _ = problem
    res = solve(X, y, FWConfig(backend="jax_sparse", queue="bsls",
                               epsilon=4.0, delta=1e-6, screen_every=2,
                               **BASE))
    w = np.asarray(res.w)
    assert w.shape == (X.shape[1],)
    assert np.isfinite(w).all()
    assert np.abs(w).sum() <= BASE["lam"] * (1 + 1e-5)
    c = np.asarray(res.coords)
    assert ((c >= -1) & (c < X.shape[1])).all()


def test_dense_screened_solve_matches_contracts(problem):
    X, y, _ = problem
    d0 = X.shape[1]
    res = solve(X, y, FWConfig(backend="dense", screen_every=2, **BASE))
    w = np.asarray(res.w)
    c = np.asarray(res.coords)
    assert w.shape == (d0,) and ((c >= -1) & (c < d0)).all()
    assert set(np.flatnonzero(w).tolist()) <= set(c[c >= 0].tolist())
    priv = solve(X, y, FWConfig(backend="dense", selection="gumbel",
                                epsilon=4.0, screen_every=2, **BASE))
    assert np.isfinite(np.asarray(priv.w)).all()


def test_screening_off_is_the_default_everywhere():
    cfg = FWConfig()
    assert cfg.screen_every == 0
    assert solve_epsilon(cfg) == cfg.epsilon


# ---------------------------------------------------------------------------
# fit-service admission: charge + audit trail
# ---------------------------------------------------------------------------


def _service(problem, budget_steps=20000, epsilon=8.0):
    from repro.serve.fit_service import FitService
    X, y, _ = problem
    acct = PrivacyAccountant(epsilon=epsilon, delta=1e-6,
                             total_steps=budget_steps)
    return FitService(X, y, accountants={"acme": acct}), acct


def test_fit_service_charges_solve_plus_screen(problem):
    from repro.serve.fit_service import FitRequest, FitService
    svc, acct = _service(problem)
    cfg = FWConfig(backend="jax_sparse", queue="bsls", epsilon=2.0,
                   delta=1e-6, screen_every=2, **BASE)
    svc.submit(FitRequest(uid=0, tenant="acme", config=cfg))
    done = svc.run()
    assert done[0].status == "done"
    plan = screen_plan(cfg, private=True)
    eps_step = per_step_epsilon(plan.eps_solve, cfg.delta, cfg.steps)
    expect = max(1, math.ceil(
        cfg.steps * (eps_step / acct.per_step) ** 2 - 1e-9))
    expect += max(1, math.ceil(
        plan.rounds * (plan.eps_round / acct.per_step) ** 2 - 1e-9))
    assert acct.spent_steps == expect
    # the screened charge exceeds the same request's unscreened charge for
    # the solve share alone, and the ledger replays bitwise
    assert FitService._charged_steps(
        acct, dataclasses.replace(cfg, screen_every=0)) > expect - \
        max(1, math.ceil(
            plan.rounds * (plan.eps_round / acct.per_step) ** 2 - 1e-9))
    svc.verify_ledger()
    entry = [e for e in svc.ledger.entries if e.get("kind") == "charge"][-1]
    assert entry["request"]["screen_every"] == 2
    assert entry["request"]["screen_eps_frac"] == cfg.screen_eps_frac


def test_fit_service_refuses_screening_misuse_charge_free(problem):
    from repro.serve.fit_service import FitRequest
    svc, acct = _service(problem)
    bad = [
        # engine without a mutable-geometry chunk loop
        FWConfig(backend="host_sparse", queue="bsls", epsilon=1.0,
                 screen_every=2, **BASE),
        # malformed ε split
        FWConfig(backend="jax_sparse", queue="bsls", epsilon=1.0,
                 screen_every=2, screen_eps_frac=1.5, **BASE),
    ]
    for uid, cfg in enumerate(bad):
        svc.submit(FitRequest(uid=uid, tenant="acme", config=cfg))
    done = svc.run()
    assert all(r.status == "rejected" for r in done)
    assert acct.spent_steps == 0
    svc.verify_ledger()
