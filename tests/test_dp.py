"""Privacy layer: accountant formulas, mechanism laws, budget enforcement."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp.accountant import (
    PrivacyAccountant, fw_noise_scale, per_step_epsilon)
from repro.core.dp.mechanisms import (
    em_logits, exponential_mechanism_probs, gumbel_argmax,
    laplace_noisy_argmax)


def test_per_step_epsilon_formula():
    eps, delta, t = 1.0, 1e-6, 4000
    got = per_step_epsilon(eps, delta, t)
    assert got == pytest.approx(eps / math.sqrt(8 * t * math.log(1 / delta)))


def test_advanced_composition_roundtrip():
    """Composing T steps of ε' must return the target ε (paper §B.2)."""
    eps, delta, t = 0.1, 1e-8, 400_000
    eps_step = per_step_epsilon(eps, delta, t)
    recomposed = 2 * eps_step * math.sqrt(2 * t * math.log(1 / delta))
    assert recomposed == pytest.approx(eps)


def test_noise_scale_matches_paper():
    """b = λ·L·sqrt(8T log(1/δ)) / (N·ε)  (paper Alg 1)."""
    b = fw_noise_scale(epsilon=1.0, delta=1e-6, steps=4000, lam=50.0,
                       lipschitz=1.0, n_rows=20_242)
    expect = 50.0 * 1.0 * math.sqrt(8 * 4000 * math.log(1e6)) / (20_242 * 1.0)
    assert b == pytest.approx(expect)


def test_accountant_budget_enforced():
    acct = PrivacyAccountant(epsilon=1.0, delta=1e-6, total_steps=100)
    acct.spend(100)
    with pytest.raises(RuntimeError):
        acct.spend(1)
    assert acct.spent_epsilon() == pytest.approx(1.0)


def test_accountant_serialization_roundtrip():
    acct = PrivacyAccountant(epsilon=0.5, delta=1e-7, total_steps=50)
    acct.spend(20)
    acct2 = PrivacyAccountant.from_state(acct.to_state())
    assert acct2.spent_steps == 20
    assert acct2.remaining_steps == 30


def test_accountant_lifecycle_restore_then_overspend_raises():
    """Checkpoint-restart lifecycle: a restored accountant keeps enforcing
    the *original* budget — spending past it raises, and the failed spend
    mutates nothing (refusals must be charge-free)."""
    acct = PrivacyAccountant(epsilon=1.0, delta=1e-6, total_steps=100)
    acct.spend(60)
    restored = PrivacyAccountant.from_state(acct.to_state())
    assert restored.per_step == pytest.approx(acct.per_step)
    assert restored.spent_epsilon() == pytest.approx(acct.spent_epsilon())
    restored.spend(40)                      # exactly exhausts the budget
    with pytest.raises(RuntimeError, match="privacy budget exhausted"):
        restored.spend(1)
    assert restored.spent_steps == 100      # failed spend left state intact
    assert restored.remaining_steps == 0
    # a second restore of the exhausted state still refuses
    again = PrivacyAccountant.from_state(restored.to_state())
    with pytest.raises(RuntimeError):
        again.spend(1)
    assert again.spent_epsilon() == pytest.approx(1.0)


def test_fit_service_refuses_exhausted_tenant(tiny_problem):
    """FitService admission control: a DP fit request whose tenant budget
    cannot cover its T selection steps is rejected, never run, never
    charged; the tenant's other (in-budget) request still completes."""
    from repro.core.solvers import FWConfig
    from repro.serve import FitRequest, FitService

    X, y, _ = tiny_problem
    svc = FitService(X, y, accountants={
        "t0": PrivacyAccountant(epsilon=1.0, delta=1e-6, total_steps=10)})
    svc.submit(FitRequest(uid=0, tenant="t0", config=FWConfig(
        backend="jax_sparse", lam=8.0, steps=10, queue="bsls")))
    svc.submit(FitRequest(uid=1, tenant="t0", config=FWConfig(
        backend="jax_sparse", lam=8.0, steps=10, queue="bsls")))
    done = {r.uid: r for r in svc.run()}
    assert done[0].status == "done" and done[0].result is not None
    assert done[1].status == "rejected" and done[1].result is None
    assert "budget exhausted" in done[1].reason
    assert svc.accountants["t0"].spent_steps == 10  # only uid 0 charged
    # a tenant with no accountant at all is refused for private fits
    svc.submit(FitRequest(uid=2, tenant="ghost", config=FWConfig(
        backend="jax_sparse", lam=8.0, steps=5, queue="bsls")))
    (r2,) = svc.run()
    assert r2.status == "rejected" and "no privacy budget" in r2.reason


def test_gumbel_argmax_samples_em_law():
    """Gumbel-max over EM logits must match the exponential mechanism's
    softmax law (chi-square)."""
    scores = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 30), jnp.float32)
    eps_step, sens = 0.8, 0.05
    logits = em_logits(scores, eps_step, sens)
    probs = np.asarray(exponential_mechanism_probs(scores, eps_step, sens))
    keys = jax.random.split(jax.random.PRNGKey(1), 20_000)
    draws = np.asarray(jax.vmap(lambda k: gumbel_argmax(k, logits))(keys))
    counts = np.bincount(draws, minlength=30)
    e = probs * len(draws)
    m = e >= 5
    chi2 = ((counts[m] - e[m]) ** 2 / e[m]).sum() / max(m.sum() - 1, 1)
    assert chi2 < 1.5


def test_laplace_noisy_max_prefers_max():
    scores = jnp.zeros(20).at[7].set(5.0)
    keys = jax.random.split(jax.random.PRNGKey(2), 500)
    draws = np.asarray(jax.vmap(
        lambda k: laplace_noisy_argmax(k, scores, 0.5))(keys))
    assert (draws == 7).mean() > 0.9


def test_dp_noise_decreases_with_n():
    b_small = fw_noise_scale(epsilon=1.0, delta=1e-6, steps=100, lam=10.0,
                             lipschitz=1.0, n_rows=1000)
    b_large = fw_noise_scale(epsilon=1.0, delta=1e-6, steps=100, lam=10.0,
                             lipschitz=1.0, n_rows=100_000)
    assert b_large < b_small
