"""Regularization-path (homotopy) solving (DESIGN.md §14).

Covers the path subsystem end to end: the planner budgets and the uniform
per-selection ε split, config validation and charge-free refusals, the
segment-0 bitwise parity contract with a standalone solve, fused-vs-
sequential group parity in ``solve_many``, the dense driver, the obs trail,
and the FitService admission charge for the composed mechanism.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro import obs
from repro.core.dp.accountant import PrivacyAccountant, per_step_epsilon
from repro.core.solvers import (FWConfig, grid, solve, solve_many,
                                solve_path)
from repro.core.solvers.path import (PathResult, check_path_config,
                                     path_plan, segment_config)
from repro.core.solvers.planner import SolvePlan, path_budgets
from repro.data.synthetic import make_sparse_classification


@pytest.fixture(scope="module")
def problem():
    return make_sparse_classification(n=150, d=600, nnz_per_row=10,
                                      informative=15, seed=11)


LAMBDAS = (40.0, 25.0, 15.0)
BASE = dict(lam=LAMBDAS[0], steps=48, chunk_steps=16, seed=5,
            lambdas=LAMBDAS)


# ---------------------------------------------------------------------------
# plan / config validation
# ---------------------------------------------------------------------------


def test_path_budgets_schedule():
    # first λ solves cold at the full budget, later λs get the warm fraction
    assert path_budgets(64, 1) == (64,)
    assert path_budgets(64, 3) == (64, 16, 16)
    assert path_budgets(240, 6) == (240, 60, 60, 60, 60, 60)
    assert path_budgets(12, 2) == (12, 8)     # warm floor
    assert path_budgets(4, 2) == (4, 4)       # floor capped at the budget


def test_path_plan_epsilon_split():
    cfg = FWConfig(steps=48, chunk_steps=16, epsilon=6.0, delta=1e-6,
                   lambdas=LAMBDAS)
    plan = path_plan(cfg, private=True)
    assert plan.lambdas == LAMBDAS
    assert plan.budgets == (48, 12, 12)
    assert plan.offsets == (0, 48, 60)
    assert plan.total_steps == 72
    # the split's defining identity: every segment runs at the single
    # uniform per-selection rate of the composed mechanism
    assert plan.eps_per_step == pytest.approx(
        per_step_epsilon(6.0, 1e-6, 72))
    for eps_k, t_k in zip(plan.eps_lambdas, plan.budgets):
        assert per_step_epsilon(eps_k, 1e-6, t_k) == pytest.approx(
            plan.eps_per_step)
    # ε_k = ε·√(T_k/T) ⇒ the shares compose back to exactly ε
    assert math.sqrt(sum(e * e for e in plan.eps_lambdas)) == \
        pytest.approx(6.0)
    # non-private plans price nothing and keep the full ε per segment
    np_plan = path_plan(cfg, private=False)
    assert np_plan.eps_per_step == 0.0
    assert np_plan.eps_lambdas == (6.0, 6.0, 6.0)
    assert np_plan.budgets == plan.budgets


def test_check_path_config_refusals():
    check_path_config(FWConfig(lambdas=LAMBDAS))          # fine
    with pytest.raises(ValueError, match="non-empty"):
        check_path_config(FWConfig(lambdas=()))
    with pytest.raises(ValueError, match="positive"):
        check_path_config(FWConfig(lambdas=(30.0, -2.0)))
    with pytest.raises(ValueError, match="decreasing"):
        check_path_config(FWConfig(lambdas=(20.0, 30.0)))
    with pytest.raises(ValueError, match="decreasing"):
        check_path_config(FWConfig(lambdas=(30.0, 30.0)))
    with pytest.raises(ValueError, match="screen"):
        check_path_config(FWConfig(lambdas=LAMBDAS, screen_every=2))
    with pytest.raises(ValueError, match="max_seconds"):
        check_path_config(FWConfig(lambdas=LAMBDAS, max_seconds=1.0))


def test_unsupported_backends_refuse_path(problem):
    X, y, _ = problem
    for backend in ("host_sparse", "jax_dense", "jax_shard"):
        with pytest.raises(ValueError, match="path"):
            solve(X, y, FWConfig(backend=backend, steps=8,
                                 lambdas=LAMBDAS))


def test_solve_path_requires_lambdas(problem):
    X, y, _ = problem
    with pytest.raises(ValueError, match="lambdas"):
        solve_path(X, y, config=FWConfig(steps=8))


def test_grid_lambdas_scalar_vs_sweep():
    # one λ-sequence is a value (a single path), a sequence of sequences
    # sweeps paths; lists normalize to hashable tuples
    one = grid(FWConfig(), lambdas=[40.0, 20.0])
    assert len(one) == 1 and one[0].lambdas == (40.0, 20.0)
    two = grid(FWConfig(), lambdas=((40.0, 20.0), (30.0, 15.0)), seed=(0, 1))
    assert len(two) == 4
    assert {c.lambdas for c in two} == {(40.0, 20.0), (30.0, 15.0)}


# ---------------------------------------------------------------------------
# trajectory contracts (jax_sparse)
# ---------------------------------------------------------------------------


def test_nonprivate_path_segment0_parity_and_obs(problem):
    """Segment 0 of a path is bit-identical to a standalone solve of
    ``segment_config(cfg, plan, 0)`` — and the path leaves a per-λ obs
    trail."""
    X, y, _ = problem
    cfg = FWConfig(backend="jax_sparse", queue="group_argmax", **BASE)
    with obs.session() as tel:
        path = solve_path(X, y, config=cfg)
    assert isinstance(path, PathResult)
    assert len(path) == len(LAMBDAS) and path.final is path[2]
    seg0 = solve(X, y, segment_config(cfg, path.plan, 0))
    np.testing.assert_array_equal(np.asarray(path[0].w), np.asarray(seg0.w))
    np.testing.assert_array_equal(np.asarray(path[0].gaps),
                                  np.asarray(seg0.gaps))
    np.testing.assert_array_equal(np.asarray(path[0].coords),
                                  np.asarray(seg0.coords))
    events = [e["attrs"] for e in tel.events if e["name"] == "path.lambda"]
    assert [e["lam"] for e in events] == list(LAMBDAS)
    assert [e["budget"] for e in events] == list(path.plan.budgets)
    assert [e["offset"] for e in events] == list(path.plan.offsets)


def test_private_path_segment0_parity_and_sanity(problem):
    """The ε split keeps one EM scale across segments, so private segment 0
    also matches its standalone single-λ solve bit-for-bit."""
    X, y, _ = problem
    cfg = FWConfig(backend="jax_sparse", queue="bsls", epsilon=6.0,
                   delta=1e-6, **BASE)
    path = solve_path(X, y, config=cfg)
    seg0 = solve(X, y, segment_config(cfg, path.plan, 0))
    np.testing.assert_array_equal(np.asarray(path[0].w), np.asarray(seg0.w))
    np.testing.assert_array_equal(np.asarray(path[0].coords),
                                  np.asarray(seg0.coords))
    for lam_k, res in zip(LAMBDAS, path):
        w = np.asarray(res.w)
        assert np.isfinite(w).all()
        # warm iterates are convex combos of the carry and ±λ_k vertices,
        # so no segment can leave the largest ball
        assert np.abs(w).sum() <= LAMBDAS[0] * (1 + 1e-5)


def test_solve_delegates_path_configs(problem):
    X, y, _ = problem
    cfg = FWConfig(backend="jax_sparse", queue="group_argmax", **BASE)
    via_solve = solve(X, y, cfg)
    direct = solve_path(X, y, config=cfg)
    assert isinstance(via_solve, PathResult)
    for a, b in zip(via_solve, direct):
        np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


def test_dense_path_segment0_parity(problem):
    X, y, _ = problem
    cfg = FWConfig(backend="dense", **BASE)
    path = solve_path(X, y, config=cfg)
    assert len(path) == len(LAMBDAS)
    seg0 = solve(X, y, segment_config(cfg, path.plan, 0))
    np.testing.assert_array_equal(np.asarray(path[0].w), np.asarray(seg0.w))
    np.testing.assert_array_equal(np.asarray(path[0].coords),
                                  np.asarray(seg0.coords))


# ---------------------------------------------------------------------------
# solve_many: fused-across-tenants parity + mixed groups
# ---------------------------------------------------------------------------


def test_solve_many_fused_path_group_matches_sequential(problem):
    """Fused lanes advance through the same fixed global step slots, so the
    vmapped group is bit-identical to per-config path drivers."""
    X, y, _ = problem
    cfgs = [FWConfig(backend="jax_sparse", queue="bsls", epsilon=eps,
                     delta=1e-6, **{**BASE, "seed": seed})
            for eps, seed in ((4.0, 0), (8.0, 1), (6.0, 2))]
    fused = solve_many(X, y, cfgs, plan=SolvePlan(mode="vmap"))
    seq = [solve_path(X, y, config=c) for c in cfgs]
    for f, s in zip(fused, seq):
        assert isinstance(f, PathResult)
        assert f.plan.budgets == s.plan.budgets
        for rf, rs in zip(f, s):
            np.testing.assert_array_equal(np.asarray(rf.w),
                                          np.asarray(rs.w))
            np.testing.assert_array_equal(np.asarray(rf.coords),
                                          np.asarray(rs.coords))


def test_solve_many_mixes_paths_and_plain_solves(problem):
    X, y, _ = problem
    path_cfg = FWConfig(backend="jax_sparse", queue="group_argmax", **BASE)
    plain_cfg = FWConfig(backend="jax_sparse", queue="group_argmax",
                         lam=25.0, steps=32, chunk_steps=16, seed=5)
    out = solve_many(X, y, [path_cfg, plain_cfg])
    assert isinstance(out[0], PathResult)
    assert not isinstance(out[1], PathResult)
    ref = solve(X, y, plain_cfg)
    np.testing.assert_array_equal(np.asarray(out[1].w), np.asarray(ref.w))


# ---------------------------------------------------------------------------
# fit-service admission: charge + audit trail
# ---------------------------------------------------------------------------


def _service(problem, budget_steps=20000, epsilon=8.0):
    from repro.serve.fit_service import FitService
    X, y, _ = problem
    acct = PrivacyAccountant(epsilon=epsilon, delta=1e-6,
                             total_steps=budget_steps)
    return FitService(X, y, accountants={"acme": acct}), acct


def test_fit_service_charges_path_as_one_mechanism(problem):
    from repro.serve.fit_service import FitRequest
    svc, acct = _service(problem)
    cfg = FWConfig(backend="jax_sparse", queue="bsls", epsilon=2.0,
                   delta=1e-6, **BASE)
    svc.submit(FitRequest(uid=0, tenant="acme", config=cfg))
    done = svc.run()
    assert done[0].status == "done"
    assert isinstance(done[0].result, PathResult)
    # the charge prices T_total selections at the path's uniform rate —
    # not the cfg.steps of a plain solve
    plan = path_plan(cfg, private=True)
    expect = max(1, math.ceil(
        plan.total_steps * (plan.eps_per_step / acct.per_step) ** 2 - 1e-9))
    assert acct.spent_steps == expect
    # T·(ε/√(8T·log(1/δ)))² = ε²/(8·log(1/δ)) is T-free: an ε-denominated
    # charge is invariant to how the path splits its steps, so the path
    # costs exactly a plain solve at the same ε — pin that identity
    plain = max(1, math.ceil(cfg.steps * (
        per_step_epsilon(cfg.epsilon, cfg.delta, cfg.steps)
        / acct.per_step) ** 2 - 1e-9))
    assert expect == plain
    svc.verify_ledger()
    entry = [e for e in svc.ledger.entries if e.get("kind") == "charge"][-1]
    assert entry["request"]["lambdas"] == list(LAMBDAS)


def test_fit_service_refuses_path_misuse_charge_free(problem):
    from repro.serve.fit_service import FitRequest
    svc, acct = _service(problem)
    bad = [
        # engine without a re-enterable chunked driver
        FWConfig(backend="host_sparse", queue="bsls", epsilon=1.0, **BASE),
        # malformed λ-sequence (not strictly decreasing)
        FWConfig(backend="jax_sparse", queue="bsls", epsilon=1.0,
                 **{**BASE, "lambdas": (15.0, 25.0)}),
        # screening cannot compose with a path
        FWConfig(backend="jax_sparse", queue="bsls", epsilon=1.0,
                 screen_every=2, **BASE),
    ]
    for uid, cfg in enumerate(bad):
        svc.submit(FitRequest(uid=uid, tenant="acme", config=cfg))
    done = svc.run()
    assert all(r.status == "rejected" for r in done)
    assert acct.spent_steps == 0
    svc.verify_ledger()
    refusals = [e for e in svc.ledger.entries if e.get("kind") == "refusal"]
    assert len(refusals) == len(bad)
    # refusal facts still record the raw λ-sequence without raising
    assert refusals[1]["request"]["lambdas"] == [15.0, 25.0]


def test_path_epsilon_shares_solve_like_standalone(problem):
    """Cross-check the whole accounting loop: charging the K segment configs
    as independent solves costs exactly the path's single charge (the split
    is composition-exact, not just approximately fair)."""
    cfg = FWConfig(backend="jax_sparse", queue="bsls", epsilon=2.0,
                   delta=1e-6, **BASE)
    acct = PrivacyAccountant(epsilon=8.0, delta=1e-6, total_steps=20000)
    plan = path_plan(cfg, private=True)
    per_seg = [
        seg.steps * (per_step_epsilon(seg.epsilon, seg.delta, seg.steps)
                     / acct.per_step) ** 2
        for seg in (segment_config(cfg, plan, k)
                    for k in range(len(plan.lambdas)))]
    whole = plan.total_steps * (plan.eps_per_step / acct.per_step) ** 2
    assert sum(per_seg) == pytest.approx(whole)
