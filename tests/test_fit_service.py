"""FitService end-to-end: submit → admit → batch → drain over solve_many.

Acceptance scenario: ≥16 fit requests across ≥2 tenants (DP and non-private
mixed) drain to completion in slot-packed vmapped batches; per-tenant
accountant state is exact; an over-budget request is refused without being
charged; responses match what sequential solve() would have produced.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.dp.accountant import PrivacyAccountant
from repro.core.solvers import FWConfig, grid, solve
from repro.serve import FitRequest, FitService, FitServiceConfig


@pytest.fixture(scope="module")
def service_problem():
    from repro.data.synthetic import make_sparse_classification
    X, y, _ = make_sparse_classification(
        n=120, d=500, nnz_per_row=10, informative=12, seed=21)
    return X, y


STEPS = 15

# Charging is ε²-equivalent (see FitService._charged_steps): a request at
# (ε_r, δ, T_r) consumes T_acct·(ε_r/ε_acct)² of the tenant's step pool.
#   acme   (ε=6, 144 steps): ε=0.5 fits cost 144/144 = 1, ε=2 fits cost 16.
#   globex (ε=1, 45 steps):  ε=0.5 fits cost 45/4 → 12 (ceil).


def _fresh_service(X, y, slots=4):
    return FitService(X, y, accountants={
        # affords its 4 ε=0.5 fits (4×1) + 4 ε=2.0 fits (4×16) = 68 ≤ 144
        "acme": PrivacyAccountant(epsilon=6.0, delta=1e-6, total_steps=144),
        # affords 3 ε=0.5 fits (3×12 = 36 ≤ 45); a 4th (48 > 45) is refused
        "globex": PrivacyAccountant(epsilon=1.0, delta=1e-6, total_steps=45),
    }, config=FitServiceConfig(slots=slots))


def test_fit_service_end_to_end(service_problem):
    X, y = service_problem
    svc = _fresh_service(X, y)
    dp_grid = grid(FWConfig(backend="jax_sparse", steps=STEPS, queue="bsls",
                            delta=1e-6),
                   lam=(4.0, 8.0, 16.0, 32.0), epsilon=(0.5, 2.0))
    uid = 0
    # 8 DP fits for acme (all in budget: 4×1 + 4×16 = 68 of 144)
    for cfg in dp_grid:
        svc.submit(FitRequest(uid=uid, tenant="acme", config=cfg)); uid += 1
    # 4 ε=0.5 DP fits for globex (12 each; only 3 fit -> exactly one refusal)
    for cfg in [c for c in dp_grid if c.epsilon == 0.5]:
        svc.submit(FitRequest(uid=uid, tenant="globex", config=cfg)); uid += 1
    # 4 non-private fits (no budget consumed, any tenant)
    for lam in (4.0, 8.0, 16.0, 32.0):
        svc.submit(FitRequest(uid=uid, tenant="globex", config=FWConfig(
            backend="jax_sparse", lam=lam, steps=STEPS))); uid += 1
    assert uid == 16

    done = svc.run()
    assert [r.uid for r in done] == list(range(16))
    by_status = {"done": [], "rejected": []}
    for r in done:
        by_status[r.status].append(r)
    assert len(by_status["rejected"]) == 1
    rej = by_status["rejected"][0]
    assert rej.tenant == "globex" and rej.uid == 11  # 4th globex DP fit
    assert "budget exhausted" in rej.reason and rej.result is None
    assert len(by_status["done"]) == 15
    for r in by_status["done"]:
        w = np.asarray(r.result.w)
        assert np.isfinite(w).all()
        assert np.asarray(r.result.gaps).shape == (STEPS,)
        assert r.finished_at >= r.submitted_at

    # per-tenant accounting is exact in ε²-equivalent steps; non-private
    # fits are free.  Composed ε spend: ε_acct·sqrt(spent/total).
    assert svc.accountants["acme"].spent_steps == 4 * 1 + 4 * 16
    assert svc.accountants["acme"].remaining_steps == 144 - 68
    assert svc.accountants["acme"].spent_epsilon() == pytest.approx(
        6.0 * math.sqrt(68 / 144))
    assert svc.accountants["globex"].spent_steps == 3 * 12
    assert svc.accountants["globex"].spent_epsilon() == pytest.approx(
        1.0 * math.sqrt(36 / 45))

    stats = svc.stats()
    assert stats["requests"] == 16 and stats["done"] == 15
    assert stats["rejected"] == 1
    assert stats["throughput_fits_per_s"] > 0
    assert stats["latency_s"]["max"] >= stats["latency_s"]["p50"] > 0
    # slot packing: no batch exceeds the compiled width
    assert stats["batches"] == len(stats["batch_sizes"])
    assert all(1 <= b <= 4 for b in stats["batch_sizes"])
    assert sum(stats["batch_sizes"]) == 15


def test_fit_service_matches_sequential_solve(service_problem):
    """A drained response carries the same FWResult sequential solve()
    produces for that config — serving adds batching, not different math."""
    X, y = service_problem
    svc = _fresh_service(X, y)
    cfgs = grid(FWConfig(backend="jax_sparse", steps=STEPS, queue="bsls",
                         epsilon=1.0), lam=(4.0, 8.0, 16.0))
    for i, cfg in enumerate(cfgs):
        svc.submit(FitRequest(uid=i, tenant="acme", config=cfg))
    done = svc.run()
    for r, cfg in zip(done, cfgs):
        ref = solve(X, y, cfg)
        np.testing.assert_array_equal(np.asarray(r.result.coords),
                                      np.asarray(ref.coords))
        np.testing.assert_allclose(np.asarray(r.result.w),
                                   np.asarray(ref.w), atol=1e-4)


def test_charged_steps_is_epsilon_squared_equivalent():
    """The tenant pool is a real ε budget: charges scale with (ε_r/ε_acct)²
    regardless of how many solver steps the request spreads its ε over, a
    hotter-than-budget request costs more than the whole pool, and a weaker
    δ is not expressible in the accountant's currency."""
    acct = PrivacyAccountant(epsilon=2.0, delta=1e-6, total_steps=64)
    charge = FitService._charged_steps
    # same ε_r at different T_req → same charge (= T_acct·(ε_r/ε_acct)²)
    assert charge(acct, FWConfig(epsilon=0.5, delta=1e-6, steps=10)) == 4
    assert charge(acct, FWConfig(epsilon=0.5, delta=1e-6, steps=1000)) == 4
    # running at exactly the accountant's own (ε, δ, T) costs exactly T
    assert charge(acct, FWConfig(epsilon=2.0, delta=1e-6, steps=64)) == 64
    # a hotter request costs more than the whole pool → unaffordable
    assert charge(acct, FWConfig(epsilon=4.0, delta=1e-6, steps=10)) == 256
    # weaker δ than the pool accounts for is refused outright
    with pytest.raises(ValueError, match="weaker than"):
        charge(acct, FWConfig(epsilon=0.5, delta=1e-3, steps=10))


def test_fit_service_dense_nonprivate_queue_not_charged(service_problem):
    """backend='dense' with an explicit non-private queue overriding a
    private selection runs argmax — and must not touch the budget."""
    X, y = service_problem
    svc = _fresh_service(X, y)
    svc.submit(FitRequest(uid=0, tenant="acme", config=FWConfig(
        backend="dense", steps=5, queue="argmax", selection="gumbel")))
    (r,) = svc.run()
    assert r.status == "done"
    assert svc.accountants["acme"].spent_steps == 0
    # without a queue, dense falls back to its selection rule → charged
    svc.submit(FitRequest(uid=1, tenant="acme", config=FWConfig(
        backend="dense", steps=5, selection="gumbel")))
    (r2,) = svc.run()
    assert r2.status == "done"
    assert svc.accountants["acme"].spent_steps > 0


def test_fit_service_rejects_bad_queue(service_problem):
    X, y = service_problem
    svc = _fresh_service(X, y)
    svc.submit(FitRequest(uid=0, tenant="acme", config=FWConfig(
        backend="jax_sparse", steps=5, queue="bogus")))
    (r,) = svc.run()
    assert r.status == "rejected" and "does not support queue" in r.reason
    assert svc.accountants["acme"].spent_steps == 0


def test_fit_service_drain_failure_does_not_strand_queue(service_problem, monkeypatch):
    """A solver crash mid-drain fails only its own batch: other batches
    still complete, and run() returns every request with a status."""
    import repro.serve.fit_service as fs

    X, y = service_problem
    svc = _fresh_service(X, y, slots=2)
    real_solve_many = fs.solve_many
    calls = {"n": 0}

    def flaky_solve_many(X, y, configs, **kwargs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected solver crash")
        return real_solve_many(X, y, configs, **kwargs)

    monkeypatch.setattr(fs, "solve_many", flaky_solve_many)
    for i, lam in enumerate((4.0, 8.0, 16.0, 32.0)):   # 2 batches of 2
        svc.submit(FitRequest(uid=i, tenant="acme", config=FWConfig(
            backend="jax_sparse", lam=lam, steps=5)))
    done = svc.run()
    statuses = [r.status for r in done]
    assert statuses == ["failed", "failed", "done", "done"]
    assert all("injected solver crash" in r.reason
               for r in done if r.status == "failed")
    assert svc.stats()["failed"] == 2 and svc.stats()["done"] == 2


def test_fit_service_rejects_invalid_dp_params_before_charging(service_problem):
    """ε ≤ 0 on a private fit is refused at admission — not charged, and
    never reaches the solver where it would raise mid-drain."""
    X, y = service_problem
    svc = _fresh_service(X, y)
    svc.submit(FitRequest(uid=0, tenant="acme", config=FWConfig(
        backend="jax_sparse", steps=5, queue="bsls", epsilon=0.0)))
    (r,) = svc.run()
    assert r.status == "rejected"
    assert svc.accountants["acme"].spent_steps == 0


def test_fit_service_slot_width_one(service_problem):
    """slots=1 degrades to sequential serving but still drains everything."""
    X, y = service_problem
    svc = _fresh_service(X, y, slots=1)
    for i, cfg in enumerate(grid(
            FWConfig(backend="jax_sparse", steps=STEPS), lam=(4.0, 8.0))):
        svc.submit(FitRequest(uid=i, tenant="acme", config=cfg))
    done = svc.run()
    assert all(r.status == "done" for r in done)
    assert svc.stats()["batch_sizes"] == [1, 1]
    with pytest.raises(ValueError, match="slots"):
        FitService(X, y, {}, dataclasses.replace(
            FitServiceConfig(), slots=0))


def test_fit_service_gap_gate_rejects_nonsmooth_charge_free(service_problem):
    """A gap_tol request on a registered-but-non-smooth objective is refused
    at admission (the FW gap certificate needs curvature) without charging
    the tenant; the same objective with fixed steps is admitted."""
    import jax.numpy as jnp

    from repro.core.losses import OBJECTIVES, Objective, register_objective

    X, y = service_problem
    probe = Objective(
        name="_svc_abs_probe",
        value=lambda m, yy: jnp.abs(m - yy),
        grad=lambda m, yy: jnp.sign(m - yy),
        split_grad=None,
        grad_np=lambda m, yy: np.sign(m - yy),
        lipschitz=1.0, smooth=False, curvature_note="|m-y| kink at 0")
    register_objective(probe)
    try:
        svc = _fresh_service(X, y)
        svc.submit(FitRequest(uid=0, tenant="acme", config=FWConfig(
            backend="jax_sparse", steps=STEPS, queue="bsls", epsilon=0.5,
            delta=1e-6, loss="_svc_abs_probe", gap_tol=1e-3)))
        # fixed-step run of the same objective: certificate never consulted
        svc.submit(FitRequest(uid=1, tenant="acme", config=FWConfig(
            backend="host_sparse", steps=5, loss="_svc_abs_probe")))
        done = {r.uid: r for r in svc.run()}
        assert done[0].status == "rejected"
        assert "not smooth" in done[0].reason
        assert done[1].status == "done"
        # the rejection was charge-free; the fixed run was non-private
        assert svc.accountants["acme"].spent_steps == 0
    finally:
        OBJECTIVES.pop("_svc_abs_probe", None)


def test_fit_service_nonlogistic_private_fit_charges_normally(service_problem):
    """Per-request losses flow through serving: a private huber fit is
    admitted, solved, and charged by the same ε²-equivalent law as logistic
    (the per-loss sensitivity enters the solver's EM scale, not the
    accountant's currency)."""
    X, y = service_problem
    svc = _fresh_service(X, y)
    cfg = FWConfig(backend="jax_sparse", steps=STEPS, queue="bsls",
                   epsilon=0.5, delta=1e-6, lam=8.0, loss="huber")
    svc.submit(FitRequest(uid=0, tenant="acme", config=cfg))
    (r,) = svc.run()
    assert r.status == "done"
    assert np.isfinite(np.asarray(r.result.w)).all()
    assert svc.accountants["acme"].spent_steps == 1   # ε=0.5 vs pool ε=6,T=144
    ref = solve(X, y, cfg)
    np.testing.assert_array_equal(np.asarray(r.result.coords),
                                  np.asarray(ref.coords))
