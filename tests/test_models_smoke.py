"""Per-arch smoke tests (deliverable f): every assigned architecture
instantiates at a reduced config and runs one forward/train step on CPU with
finite outputs; decode ≡ parallel forward for every decoder arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.registry import get_model, input_specs, supported_cells


def _smoke_batch(api, rng, b=2, s=16):
    if api.cfg.family == "encdec":
        return {
            "frames": jnp.asarray(rng.normal(size=(b, s // 2, api.cfg.d_model)),
                                  api.cfg.jdtype),
            "tokens": jnp.asarray(rng.integers(1, 50, (b, s // 2)), jnp.int32),
        }
    return {"tokens": jnp.asarray(rng.integers(1, 50, (b, s)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch, rng):
    api = get_model(arch, smoke=True)
    params = api.init(jax.random.PRNGKey(0))
    loss = api.loss(params, _smoke_batch(api, rng))
    assert np.isfinite(float(loss)), f"{arch} loss not finite"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates_and_finite(arch, rng):
    from repro.train.optimizer import get_optimizer
    from repro.train.trainer import TrainConfig, TrainState, make_train_step
    api = get_model(arch, smoke=True)
    opt = get_optimizer(api.cfg.optimizer)
    params = api.init(jax.random.PRNGKey(0))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    step_fn = make_train_step(api.loss, TrainConfig(optimizer=api.cfg.optimizer))
    new_state, metrics = jax.jit(step_fn)(state, _smoke_batch(api, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["skipped"]) == 0.0
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).family != "encdec"])
def test_decode_matches_forward(arch, rng):
    """The serving path must agree with the parallel forward — the invariant
    every KV-cache/state-cache layout is tested against."""
    api = get_model(arch, smoke=True)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(1, 80, (2, 10)), jnp.int32)
    full = api.forward(params, toks)
    cache = api.init_cache(2, 16)
    for t in range(10):
        logits, cache = api.decode_step(params, cache, toks[:, t:t + 1],
                                        jnp.asarray(t, jnp.int32))
    diff = float(jnp.abs(full[:, -1].astype(jnp.float32)
                         - logits[:, 0].astype(jnp.float32)).max())
    assert diff < 5e-4, f"{arch}: decode diverges from forward by {diff}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact published hyperparameters.

    seamless: the assignment's "12L" is 12 enc + 12 dec (enc-dec);
    falcon-mamba: attention-free — n_heads/d_ff are structural placeholders
    (1/0), the real capacity knobs are d_inner=2·d_model and ssm_state.
    """
    cfg = get_config(arch)
    expected = {
        "seamless-m4t-medium": (24, 1024, 16, 16, 4096, 256_206),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65_024),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128_256),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122_753),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32_000),
        "nemotron-4-15b": (32, 6144, 48, 8, 24_576, 256_000),
        "chameleon-34b": (48, 8192, 64, 8, 22_016, 65_536),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102_400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff if not cfg.n_experts else cfg.moe_d_ff, cfg.vocab)
    assert got == expected, f"{arch}: {got} != {expected}"
    if arch == "seamless-m4t-medium":
        assert (cfg.enc_layers, cfg.dec_layers) == (12, 12)
    if arch == "falcon-mamba-7b":
        assert (cfg.d_inner, cfg.ssm_state) == (8192, 16)


def test_moe_configs():
    dsv2 = get_config("deepseek-v2-236b")
    assert (dsv2.n_experts, dsv2.top_k, dsv2.n_shared_experts,
            dsv2.kv_lora, dsv2.use_mla) == (160, 6, 2, 512, True)
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.n_experts, kimi.top_k) == (384, 8)
    mamba = get_config("falcon-mamba-7b")
    assert mamba.ssm_state == 16 and mamba.family == "ssm"


def test_long_context_skips_documented():
    """long_500k runs only for sub-quadratic archs (brief requirement)."""
    runs_long = {a for a in ARCH_IDS if "long_500k" in supported_cells(a)}
    assert runs_long == {"falcon-mamba-7b", "recurrentgemma-2b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_are_abstract(arch):
    for shape in supported_cells(arch):
        specs = input_specs(arch, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-2b"])
def test_state_caches_constant_memory(arch):
    """SSM/hybrid decode caches must not grow with context length — the
    property that makes long_500k feasible."""
    api = get_model(arch, smoke=True)
    c_small = jax.eval_shape(lambda: api.init_cache(2, 128))
    c_large = jax.eval_shape(lambda: api.init_cache(2, 4096))
    def total(c):
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(c))
    if arch == "falcon-mamba-7b":
        assert total(c_small) == total(c_large)
    else:  # rglru: LRU/conv states constant; local-attn ring ≤ window
        assert total(c_large) <= total(c_small) * (
            api.cfg.window / 128 + 2)


def test_param_count_analytic_close():
    """Analytic param_count within 20% of actual init (catches config drift)."""
    for arch in ["tinyllama-1.1b", "llama3.2-1b"]:
        api = get_model(arch, smoke=True)
        params = api.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        # smoke config analytic count
        analytic = api.cfg.param_count()
        assert abs(actual - analytic) / actual < 0.2, (arch, actual, analytic)


def test_moe_local_dispatch_equivalent():
    """Pod-scale locality-aware MoE dispatch ≡ global dispatch when capacity
    doesn't bind (the §Perf fix for the 43–86 TB/step all-reduce storm)."""
    import dataclasses
    import jax
    from repro.models import common as cm
    cfg = smoke_config("deepseek-v2-236b")
    p = cm.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model), jnp.float32)
    y_global, _ = cm.moe_apply(p, x, cfg, capacity=32)
    cfg_local = dataclasses.replace(cfg, moe_local_groups=4)
    y_local, _ = cm.moe_apply(p, x, cfg_local, capacity=8)
    np.testing.assert_array_equal(np.asarray(y_global), np.asarray(y_local))
    # scatter-side combine ≡ gather-side, including under capacity drops
    cfg_scat = dataclasses.replace(cfg, moe_combine="scatter")
    y_drop_g, _ = cm.moe_apply(p, x, cfg, capacity=9)
    y_drop_s, _ = cm.moe_apply(p, x, cfg_scat, capacity=9)
    np.testing.assert_array_equal(np.asarray(y_drop_g), np.asarray(y_drop_s))
