"""Trainer + data pipeline: learning actually happens, NaN-step fault
tolerance, microbatch accumulation equivalence, synthetic data statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batches, make_sparse_classification
from repro.train.optimizer import clip_by_global_norm, get_optimizer, make_schedule
from repro.train.trainer import TrainConfig, TrainState, make_train_step


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_sparse_generator_stats():
    X, y, w_true = make_sparse_classification(n=500, d=2000, nnz_per_row=25,
                                              informative=30, seed=0)
    nnz_row = np.diff(X.indptr)
    assert abs(nnz_row.mean() - 25) < 5
    assert 0.2 < y.mean() < 0.8
    assert np.count_nonzero(w_true) == 30


def test_dense_block_generator():
    X, _, _ = make_sparse_classification(n=100, d=500, nnz_per_row=20,
                                         informative=10, dense_features=15,
                                         seed=1)
    dense = X.to_dense()
    # first 15 columns are (nearly) fully dense — URL-style
    assert (np.abs(dense[:, :15]) > 0).mean() > 0.9


def test_sharded_loader_close_unblocks_full_queue():
    """Regression: close() must not leave the worker blocked on q.put when
    the queue is full and the consumer is gone — it drains, signals stop,
    and joins the thread."""
    from repro.data.loader import ShardedLoader

    def infinite():
        while True:
            yield np.zeros(2)

    ld = ShardedLoader(infinite(), prefetch=1)
    next(ld)                      # worker is alive and producing
    import time
    time.sleep(0.2)               # let it fill the queue and block on put
    ld.close()
    assert not ld.thread.is_alive()
    with pytest.raises(StopIteration):
        next(ld)


def test_sharded_loader_drains_finite_iterator():
    from repro.data.loader import ShardedLoader
    ld = ShardedLoader(iter([np.ones(3), np.zeros(3)]), prefetch=4)
    got = list(ld)
    assert len(got) == 2
    ld.close()
    assert not ld.thread.is_alive()


def test_lm_batches_deterministic_and_shaped():
    a = next(lm_batches(100, 4, 32, seed=3))
    b = next(lm_batches(100, 4, 32, seed=3))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 32)
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100


def test_lm_batches_markov_structure():
    """Each token has ≤ branching successors — the structure an LM can learn."""
    it = lm_batches(50, 8, 64, seed=4)
    toks = np.concatenate([next(it)["tokens"] for _ in range(5)])
    succ = {}
    for row in toks:
        for t in range(len(row) - 1):
            succ.setdefault(int(row[t]), set()).add(int(row[t + 1]))
    assert max(len(s) for s in succ.values()) <= 8


# ---------------------------------------------------------------------------
# optimizer / schedule
# ---------------------------------------------------------------------------

def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


@pytest.mark.parametrize("kind", ["cosine", "wsd", "constant"])
def test_schedules_warmup_and_bounds(kind):
    sched = make_schedule(kind, peak_lr=1e-3, total_steps=100, warmup=10)
    lrs = np.array([float(sched(jnp.asarray(s))) for s in range(100)])
    assert lrs[0] < 1e-3 * 0.2
    assert lrs.max() <= 1e-3 * 1.0001
    if kind != "constant":
        assert lrs[-1] < lrs[15]


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = get_optimizer(name)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": params["w"]}          # ∇ of ½‖w‖²
        params, state = opt.update(grads, state, params, 5e-2)
    assert float(jnp.abs(params["w"]).max()) < 1.0


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

def _quadratic_loss(p, batch, remat=True):
    return jnp.mean((p["w"] - batch["target"]) ** 2)


def test_train_step_learns():
    tc = TrainConfig(optimizer="adamw", peak_lr=0.1, total_steps=200, warmup=1,
                     schedule="constant")
    step = jax.jit(make_train_step(_quadratic_loss, tc))
    params = {"w": jnp.asarray([4.0, 4.0])}
    opt = get_optimizer("adamw")
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    batch = {"target": jnp.asarray([1.0, -1.0])}
    for _ in range(200):
        state, m = step(state, batch)
    assert float(m["loss"]) < 0.1


def test_first_step_lr_nonzero():
    """Regression: warmup must not waste step 0 at lr = 0."""
    for kind in ("cosine", "wsd", "constant"):
        sched = make_schedule(kind, peak_lr=1e-3, total_steps=100, warmup=10)
        assert float(sched(jnp.asarray(0))) > 0.0


def test_nan_step_skipped():
    """Fault tolerance: a NaN batch must not poison parameters."""
    def loss_fn(p, batch, remat=True):
        return jnp.mean((p["w"] * batch["x"]) ** 2)
    tc = TrainConfig(optimizer="adamw", peak_lr=0.1, total_steps=10, warmup=1)
    step = jax.jit(make_train_step(loss_fn, tc))
    opt = get_optimizer("adamw")
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    state, m = step(state, {"x": jnp.asarray([jnp.nan, 1.0])})
    assert float(m["skipped"]) == 1.0
    np.testing.assert_array_equal(np.asarray(state.params["w"]), [1.0, 2.0])
    # next good step proceeds
    state, m = step(state, {"x": jnp.asarray([1.0, 1.0])})
    assert float(m["skipped"]) == 0.0


def test_microbatch_equivalence():
    """Gradient accumulation over 4 microbatches ≡ one full batch step."""
    def loss_fn(p, batch, remat=True):
        return jnp.mean((batch["x"] @ p["w"]) ** 2)
    opt = get_optimizer("adamw")
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(6, 1)),
                               jnp.float32)}
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 6)), jnp.float32)
    batch = {"x": x}
    outs = []
    for mb in (1, 4):
        tc = TrainConfig(optimizer="adamw", peak_lr=0.01, total_steps=10,
                         warmup=1, microbatches=mb)
        step = jax.jit(make_train_step(loss_fn, tc))
        state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                           opt_state=opt.init(params))
        new_state, m = step(state, batch)
        outs.append(np.asarray(new_state.params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-5)


def test_lm_smoke_training_loss_decreases():
    """End-to-end: 60 steps on the markov stream should beat the initial loss
    (integration test of model + data + optimizer + trainer)."""
    from repro.models.registry import get_model
    api = get_model("tinyllama-1.1b", smoke=True)
    opt = get_optimizer("adamw")
    params = api.init(jax.random.PRNGKey(0))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    tc = TrainConfig(optimizer="adamw", peak_lr=1e-3, total_steps=60, warmup=5)
    step = jax.jit(make_train_step(api.loss, tc))
    stream = lm_batches(api.cfg.vocab, 8, 32, seed=0)
    losses = []
    for _ in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.3
