"""Sparse containers: host CSR/CSC exactness, padded layouts vs dense,
round-trips along the batched coercion path, property-based COO roundtrips.

Hypothesis-driven tests skip when hypothesis is absent (requirements-dev);
everything else — including a seeded deterministic sweep of the same
round-trip property — runs unconditionally, so tier-1 keeps structural
coverage even in containers without the property-testing stack."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # pragma: no cover - exercised in bare containers
    HAVE_HYPOTHESIS = False

from repro.core.sparse.formats import (
    coo_to_host, dense_to_host, dense_to_padded, host_to_padded)


def _random_dense(rng, n, d, density=0.2):
    x = rng.normal(size=(n, d))
    x[rng.random((n, d)) > density] = 0.0
    return x


def test_host_roundtrip(rng):
    x = _random_dense(rng, 23, 17)
    csr = dense_to_host(x)
    np.testing.assert_allclose(csr.to_dense(), x)
    np.testing.assert_allclose(csr.tocsc().to_dense(), x)


def test_host_matvec_rmatvec(rng):
    x = _random_dense(rng, 31, 11)
    csr = dense_to_host(x)
    w = rng.normal(size=11)
    q = rng.normal(size=31)
    np.testing.assert_allclose(csr.matvec(w), x @ w, atol=1e-10)
    np.testing.assert_allclose(csr.rmatvec(q), x.T @ q, atol=1e-10)


def test_padded_matvec_rmatvec(rng):
    x = _random_dense(rng, 40, 25)
    pcsr, pcsc = dense_to_padded(x)
    w = jnp.asarray(rng.normal(size=25), jnp.float32)
    q = jnp.asarray(rng.normal(size=40), jnp.float32)
    np.testing.assert_allclose(pcsr.matvec(w), x @ np.asarray(w), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(pcsr.rmatvec(q), x.T @ np.asarray(q), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(pcsr.to_dense(), x, atol=1e-6)


def test_padded_csc_col(rng):
    x = _random_dense(rng, 12, 9)
    _, pcsc = dense_to_padded(x)
    for j in range(9):
        idx, val, mask = pcsc.col(j)
        got = np.zeros(12)
        got[np.asarray(idx)[np.asarray(mask)]] = np.asarray(val)[np.asarray(mask)]
        np.testing.assert_allclose(got, x[:, j], atol=1e-6)


if HAVE_HYPOTHESIS:
    @given(st.lists(
        st.tuples(st.integers(0, 7), st.integers(0, 9),
                  st.floats(-5, 5, allow_nan=False).filter(lambda v: abs(v) > 1e-9)),
        min_size=0, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_coo_to_host_sums_duplicates(triplets):
        dense = np.zeros((8, 10))
        for r, c, v in triplets:
            dense[r, c] += v
        rows = np.array([t[0] for t in triplets], np.int64)
        cols = np.array([t[1] for t in triplets], np.int64)
        vals = np.array([t[2] for t in triplets])
        csr = coo_to_host(rows, cols, vals, (8, 10))
        np.testing.assert_allclose(csr.to_dense(), dense, atol=1e-9)


def test_padding_overhead_reported(tiny_problem):
    X, _, _ = tiny_problem
    pcsr, _ = host_to_padded(X)
    assert pcsr.padding_overhead >= 1.0


# ---------------------------------------------------------------------------
# Round-trip properties along the batched coercion path (registry/solve_many):
# dense → HostCSR → (PaddedCSR, PaddedCSC) → HostCSR must preserve structure
# and values for arbitrary ragged sparsity, including all-empty rows/columns.
# Values pass through the padded layouts' float32 lanes, hence the 1e-5 atol.
# ---------------------------------------------------------------------------

def _check_roundtrip(dense):
    """The exact coercion chain solve_many walks, there and back again."""
    from repro.core.solvers.registry import as_host_csr

    csr = dense_to_host(dense)
    pair = host_to_padded(csr)
    back = as_host_csr(pair)
    assert back.shape == csr.shape
    assert back.nnz == csr.nnz == int((dense != 0).sum())
    np.testing.assert_allclose(back.to_dense(), dense, rtol=1e-5, atol=1e-5)
    # structure is preserved exactly (same nonzero pattern, no padding leaks)
    np.testing.assert_array_equal(back.to_dense() != 0, dense != 0)
    # padded per-row/per-column nnz audits equal the true counts — the FLOP
    # accounting and padding_overhead metric depend on them
    pcsr, pcsc = pair
    np.testing.assert_array_equal(np.asarray(pcsr.nnz), (dense != 0).sum(1))
    np.testing.assert_array_equal(np.asarray(pcsc.nnz), (dense != 0).sum(0))
    assert pcsr.padding_overhead >= 1.0


def test_roundtrip_seeded_ragged_sweep():
    """Deterministic sweep of the round-trip property (runs even without
    hypothesis): ragged shapes, varying density, empty rows/columns."""
    rng = np.random.default_rng(9)
    for n, d, density in [(1, 1, 1.0), (3, 17, 0.05), (12, 5, 0.3),
                          (8, 8, 0.9), (10, 40, 0.01), (6, 6, 0.0)]:
        dense = rng.normal(size=(n, d)) * 10
        dense[rng.random((n, d)) > density] = 0.0
        _check_roundtrip(dense)


if HAVE_HYPOTHESIS:
    # entries big enough to survive the float32 lane without vanishing
    _VALUES = st.floats(-1e4, 1e4, allow_nan=False).filter(
        lambda v: abs(v) > 1e-3)

    @st.composite
    def _ragged_sparse(draw):
        n = draw(st.integers(1, 12))
        d = draw(st.integers(1, 15))
        cells = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, d - 1), _VALUES),
            max_size=50, unique_by=lambda t: (t[0], t[1])))
        dense = np.zeros((n, d))
        for r, c, v in cells:
            dense[r, c] = v
        return dense

    @given(_ragged_sparse())
    @settings(max_examples=60, deadline=None)
    def test_dense_host_padded_host_roundtrip(dense):
        _check_roundtrip(dense)


def test_roundtrip_empty_matrix():
    """Degenerate but legal: a design matrix with no nonzeros at all."""
    from repro.core.solvers.registry import as_host_csr

    dense = np.zeros((4, 6))
    csr = dense_to_host(dense)
    assert csr.nnz == 0
    pair = host_to_padded(csr)
    back = as_host_csr(pair)
    assert back.nnz == 0 and back.shape == (4, 6)
    np.testing.assert_array_equal(back.to_dense(), dense)


def test_roundtrip_ragged_with_empty_rows():
    """Rows 0 and 3 empty, row 2 dense — classic ragged worst case."""
    from repro.core.solvers.registry import as_host_csr

    dense = np.zeros((4, 5))
    dense[1, 2] = 3.5
    dense[2, :] = np.arange(1.0, 6.0)
    csr = dense_to_host(dense)
    back = as_host_csr(host_to_padded(csr))
    assert back.nnz == 6
    np.testing.assert_allclose(back.to_dense(), dense, atol=1e-6)
