"""Sparse containers: host CSR/CSC exactness, padded layouts vs dense,
property-based COO roundtrips."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.sparse.formats import (
    coo_to_host, dense_to_host, dense_to_padded, host_to_padded)


def _random_dense(rng, n, d, density=0.2):
    x = rng.normal(size=(n, d))
    x[rng.random((n, d)) > density] = 0.0
    return x


def test_host_roundtrip(rng):
    x = _random_dense(rng, 23, 17)
    csr = dense_to_host(x)
    np.testing.assert_allclose(csr.to_dense(), x)
    np.testing.assert_allclose(csr.tocsc().to_dense(), x)


def test_host_matvec_rmatvec(rng):
    x = _random_dense(rng, 31, 11)
    csr = dense_to_host(x)
    w = rng.normal(size=11)
    q = rng.normal(size=31)
    np.testing.assert_allclose(csr.matvec(w), x @ w, atol=1e-10)
    np.testing.assert_allclose(csr.rmatvec(q), x.T @ q, atol=1e-10)


def test_padded_matvec_rmatvec(rng):
    x = _random_dense(rng, 40, 25)
    pcsr, pcsc = dense_to_padded(x)
    w = jnp.asarray(rng.normal(size=25), jnp.float32)
    q = jnp.asarray(rng.normal(size=40), jnp.float32)
    np.testing.assert_allclose(pcsr.matvec(w), x @ np.asarray(w), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(pcsr.rmatvec(q), x.T @ np.asarray(q), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(pcsr.to_dense(), x, atol=1e-6)


def test_padded_csc_col(rng):
    x = _random_dense(rng, 12, 9)
    _, pcsc = dense_to_padded(x)
    for j in range(9):
        idx, val, mask = pcsc.col(j)
        got = np.zeros(12)
        got[np.asarray(idx)[np.asarray(mask)]] = np.asarray(val)[np.asarray(mask)]
        np.testing.assert_allclose(got, x[:, j], atol=1e-6)


@given(st.lists(
    st.tuples(st.integers(0, 7), st.integers(0, 9),
              st.floats(-5, 5, allow_nan=False).filter(lambda v: abs(v) > 1e-9)),
    min_size=0, max_size=60))
@settings(max_examples=40, deadline=None)
def test_coo_to_host_sums_duplicates(triplets):
    dense = np.zeros((8, 10))
    for r, c, v in triplets:
        dense[r, c] += v
    rows = np.array([t[0] for t in triplets], np.int64)
    cols = np.array([t[1] for t in triplets], np.int64)
    vals = np.array([t[2] for t in triplets])
    csr = coo_to_host(rows, cols, vals, (8, 10))
    np.testing.assert_allclose(csr.to_dense(), dense, atol=1e-9)


def test_padding_overhead_reported(tiny_problem):
    X, _, _ = tiny_problem
    pcsr, _ = host_to_padded(X)
    assert pcsr.padding_overhead >= 1.0
