"""Gap-adaptive early stopping (DESIGN.md §9): prefix parity + stop reports.

The stopping contract every backend must honor:

  * the iteration that produces the certificate (g_t ≤ gap_tol) is applied,
    then the run freezes — so the returned ``w`` is **bit-identical** to a
    fixed-budget run of exactly ``stop_step`` iterations (same config, same
    keys), private or not;
  * ``stop_step`` equals the first index of the full run's gap trace at (or
    below) the tolerance, +1 — stopping is a pure function of the observable
    trace (compared at float32, the trace's own precision);
  * ``gaps``/``coords`` keep their full length with 0.0 / -1 sentinels past
    the stop, and ``stop_reason`` says why the run ended;
  * batched execution (``solve_many``) retires configs at their own stop
    steps under every planner mode, with results identical to sequential
    early-stopped ``solve()``.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.solvers import FWConfig, grid, solve, solve_many

ALL_BACKENDS = ("dense", "jax_dense", "host_sparse", "jax_sparse")
STEPS = 40


@pytest.fixture(scope="module")
def problem():
    from repro.data.synthetic import make_sparse_classification
    X, y, _ = make_sparse_classification(
        n=150, d=600, nnz_per_row=10, informative=15, seed=11)
    return X, y


def _tol_and_expected(gaps: np.ndarray, k: int):
    """A tolerance whose first crossing is well-defined even on noisy DP
    traces (prefix-minimum at step k), plus that expected stop step."""
    tol = max(float(np.min(gaps[: k + 1])), 1e-7)
    return tol, int(np.argmax(gaps <= np.float32(tol))) + 1


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("queue", [None, "bsls"])
def test_stopped_run_is_prefix_of_full_run(problem, backend, queue):
    """Acceptance: stopped iterate bit-identical to the corresponding
    prefix of a full run, on all four backends, private + non-private."""
    X, y = problem
    base = FWConfig(backend=backend, lam=8.0, steps=STEPS, queue=queue,
                    epsilon=1.0, delta=1e-6)
    full = solve(X, y, base)
    assert full.stop_step_or() == STEPS
    assert full.stop_reason == "max_steps"

    gaps = np.asarray(full.gaps)
    tol, expected = _tol_and_expected(gaps, STEPS // 3)
    stopped = solve(X, y, dataclasses.replace(base, gap_tol=tol))

    assert stopped.stop_step_or() == expected
    assert stopped.stop_reason == "gap_tol"
    ss = expected
    np.testing.assert_array_equal(np.asarray(stopped.coords)[:ss],
                                  np.asarray(full.coords)[:ss])
    np.testing.assert_array_equal(np.asarray(stopped.gaps)[:ss], gaps[:ss])
    # sentinels past the stop
    assert (np.asarray(stopped.coords)[ss:] == -1).all()
    assert (np.asarray(stopped.gaps)[ss:] == 0.0).all()
    # bit-identical to a run of exactly stop_step iterations
    prefix = solve(X, y, dataclasses.replace(base, steps=ss))
    np.testing.assert_array_equal(np.asarray(stopped.w),
                                  np.asarray(prefix.w))


def test_unreachable_tolerance_runs_full(problem):
    X, y = problem
    for backend in ALL_BACKENDS:
        r = solve(X, y, FWConfig(backend=backend, lam=8.0, steps=10,
                                 gap_tol=1e-30))
        assert r.stop_step_or() == 10
        assert r.stop_reason == "max_steps"
        assert (np.asarray(r.coords) != -1).all()


def test_negative_or_zero_tolerance_disables_stopping(problem):
    X, y = problem
    cfg = FWConfig(backend="jax_sparse", lam=8.0, steps=10, gap_tol=-1.0)
    assert not cfg.early_stopping
    r = solve(X, y, cfg)
    assert r.stop_step_or() == 10 and r.stop_reason == "max_steps"


@pytest.mark.parametrize("backend", ["host_sparse", "jax_sparse", "dense"])
def test_max_seconds_stops_early(problem, backend):
    X, y = problem
    r = solve(X, y, FWConfig(backend=backend, lam=8.0, steps=5000,
                             max_seconds=0.0))
    assert r.stop_reason == "max_seconds"
    assert 1 <= r.stop_step_or() < 5000
    # the partial run is still a valid FW iterate trace
    assert np.isfinite(np.asarray(r.w)).all()
    assert (np.asarray(r.coords)[r.stop_step_or():] == -1).all()


def test_single_scan_backends_reject_max_seconds(problem):
    X, y = problem
    for backend in ("jax_dense", "jax_shard"):
        with pytest.raises(ValueError, match="max_seconds"):
            solve(X, y, FWConfig(backend=backend, lam=8.0, steps=5,
                                 max_seconds=1.0))


def test_jax_shard_gap_tol_matches_prefix(problem):
    """The masked collective scan (1×1 mesh) freezes bit-identically."""
    X, y = problem
    base = FWConfig(backend="jax_shard", lam=8.0, steps=STEPS)
    full = solve(X, y, base)
    gaps = np.asarray(full.gaps)
    tol, expected = _tol_and_expected(gaps, STEPS // 3)
    stopped = solve(X, y, dataclasses.replace(base, gap_tol=tol))
    assert stopped.stop_step_or() == expected
    assert stopped.stop_reason == "gap_tol"
    np.testing.assert_array_equal(np.asarray(stopped.coords)[:expected],
                                  np.asarray(full.coords)[:expected])
    prefix = solve(X, y, dataclasses.replace(base, steps=expected))
    np.testing.assert_array_equal(np.asarray(stopped.w),
                                  np.asarray(prefix.w))


# ---------------------------------------------------------------------------
# batched: cohort retirement at per-config stop steps
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def adaptive_grid(problem):
    """A λ grid whose configs converge at spread-out steps, with the
    sequential early-stopped runs as the parity oracle."""
    X, y = problem
    configs = grid(FWConfig(backend="jax_sparse", steps=60, chunk_steps=8),
                   lam=(4.0, 6.0, 8.0, 12.0, 16.0, 24.0))
    seq_full = [solve(X, y, c) for c in configs]
    adaptive = []
    for i, (c, r) in enumerate(zip(configs, seq_full)):
        tol, _ = _tol_and_expected(np.asarray(r.gaps), 10 + 8 * i)
        adaptive.append(dataclasses.replace(c, gap_tol=tol))
    oracle = [solve(X, y, c) for c in adaptive]
    return X, y, adaptive, oracle


@pytest.mark.parametrize("plan", ["vmap", "sequential", None])
def test_solve_many_retires_configs_at_their_own_steps(adaptive_grid, plan):
    """Acceptance: a solve_many grid where configs converge at different
    steps — every planner mode reproduces the sequential stops exactly."""
    X, y, adaptive, oracle = adaptive_grid
    batched = solve_many(X, y, adaptive, plan=plan)
    stops = [r.stop_step_or() for r in batched]
    assert stops == [r.stop_step_or() for r in oracle]
    assert len(set(stops)) >= 3, "grid should converge at varied steps"
    for b, s in zip(batched, oracle):
        assert b.stop_reason == s.stop_reason == "gap_tol"
        np.testing.assert_array_equal(np.asarray(b.coords),
                                      np.asarray(s.coords))
        np.testing.assert_array_equal(np.asarray(b.w), np.asarray(s.w))
        np.testing.assert_array_equal(np.asarray(b.gaps),
                                      np.asarray(s.gaps))


def test_solve_many_private_adaptive_grid(problem):
    """DP sweep with per-config tolerances: batched == sequential, and the
    unconsumed post-stop noise draws never perturb the prefix."""
    X, y = problem
    base = grid(FWConfig(backend="jax_sparse", steps=30, queue="bsls",
                         delta=1e-6),
                lam=(4.0, 16.0), epsilon=(0.5, 2.0))
    seq_full = [solve(X, y, c) for c in base]
    adaptive = []
    for c, r in zip(base, seq_full):
        tol, _ = _tol_and_expected(np.asarray(r.gaps), 12)
        adaptive.append(dataclasses.replace(c, gap_tol=tol))
    oracle = [solve(X, y, c) for c in adaptive]
    batched = solve_many(X, y, adaptive)
    for b, s in zip(batched, oracle):
        assert b.stop_step_or() == s.stop_step_or()
        np.testing.assert_array_equal(np.asarray(b.coords),
                                      np.asarray(s.coords))
        np.testing.assert_array_equal(np.asarray(b.w), np.asarray(s.w))


def test_shard_group_adaptive(problem):
    """jax_shard grids stack gap_tol as a traced scalar (1×1 vmapped)."""
    X, y = problem
    base = grid(FWConfig(backend="jax_shard", steps=30), lam=(6.0, 12.0))
    seq_full = [solve(X, y, c) for c in base]
    adaptive = []
    for c, r in zip(base, seq_full):
        tol, _ = _tol_and_expected(np.asarray(r.gaps), 10)
        adaptive.append(dataclasses.replace(c, gap_tol=tol))
    oracle = [solve(X, y, c) for c in adaptive]
    batched = solve_many(X, y, adaptive)
    for b, s in zip(batched, oracle):
        assert b.stop_step_or() == s.stop_step_or()
        assert b.stop_reason == s.stop_reason
        np.testing.assert_array_equal(np.asarray(b.w), np.asarray(s.w))


def test_fit_service_refuses_unsupportable_max_seconds_charge_free(problem):
    """A max_seconds request for a single-scan backend must be refused at
    admission — before any DP charge — not explode its drained batch."""
    from repro.core.dp.accountant import PrivacyAccountant
    from repro.serve import FitRequest, FitService
    X, y = problem
    svc = FitService(X, y, accountants={
        "t": PrivacyAccountant(epsilon=4.0, delta=1e-6, total_steps=400)})
    bad = FitRequest(uid=0, tenant="t", config=FWConfig(
        backend="jax_dense", lam=8.0, steps=20, queue="bsls", epsilon=1.0,
        delta=1e-6, max_seconds=5.0))
    good = FitRequest(uid=1, tenant="t", config=FWConfig(
        backend="jax_dense", lam=8.0, steps=20, queue="bsls", epsilon=1.0,
        delta=1e-6))
    svc.submit(bad)
    svc.submit(good)
    done = {r.uid: r for r in svc.run()}
    assert done[0].status == "rejected"
    assert "max_seconds" in done[0].reason
    assert done[1].status == "done"        # batch-mate unharmed
    # only the good request was charged
    spent = svc.accountants["t"].spent_steps
    solo = FitService(X, y, accountants={
        "t": PrivacyAccountant(epsilon=4.0, delta=1e-6, total_steps=400)})
    solo.submit(FitRequest(uid=0, tenant="t", config=good.config))
    solo.run()
    assert spent == solo.accountants["t"].spent_steps


def test_fit_service_charges_full_T_for_early_stopped_fits(problem):
    """ε-accounting is untouched by stopping: budget is charged up-front for
    the requested T whether or not the certificate lands early."""
    from repro.core.dp.accountant import PrivacyAccountant
    from repro.serve import FitRequest, FitService
    X, y = problem
    mk = lambda: {"t": PrivacyAccountant(epsilon=4.0, delta=1e-6,
                                         total_steps=400)}
    fixed_svc = FitService(X, y, accountants=mk())
    fixed_svc.submit(FitRequest(uid=0, tenant="t", config=FWConfig(
        backend="jax_sparse", lam=8.0, steps=30, queue="bsls", epsilon=1.0,
        delta=1e-6)))
    fixed_svc.run()
    adaptive_svc = FitService(X, y, accountants=mk())
    adaptive_svc.submit(FitRequest(uid=0, tenant="t", config=FWConfig(
        backend="jax_sparse", lam=8.0, steps=30, queue="bsls", epsilon=1.0,
        delta=1e-6, gap_tol=1e30)))
    done = adaptive_svc.run()
    assert done[0].status == "done"
    assert done[0].result.stop_step_or() < 30
    assert (adaptive_svc.accountants["t"].spent_steps
            == fixed_svc.accountants["t"].spent_steps)


# ---------------------------------------------------------------------------
# chunked-driver clock and assembly contracts (§9 bugfix regressions)
# ---------------------------------------------------------------------------


class _FakeCarry:
    done = False
    stop_at = 0


class _FakeClock:
    """Deterministic time source for ``drive_chunks(clock=...)``: the
    ``advance`` stubs tick it instead of sleeping real wall time."""

    def __init__(self):
        self.now = 0.0

    def tick(self, seconds):
        self.now += seconds

    def __call__(self):
        return self.now


def _drive(advance, steps, chunk, max_seconds, clock=None):
    from repro.core.solvers.stopping import drive_chunks
    import time
    import jax.numpy as jnp
    calls = []

    def wrapped(carry, t0, c):
        advance(len(calls))
        calls.append(t0)
        return carry, (jnp.zeros(c), jnp.full(c, -1, jnp.int32))

    out = drive_chunks(wrapped, _FakeCarry(), steps=steps, chunk=chunk,
                       max_seconds=max_seconds,
                       done_of=lambda c: c.done, stop_at_of=lambda c: c.stop_at,
                       clock=clock or time.perf_counter)
    return out, calls


def test_compile_heavy_first_chunk_does_not_trip_max_seconds():
    """The wall-clock budget must not be charged for the cold chunk's XLA
    compile: a first chunk far over budget followed by instant chunks runs
    to completion (the old driver stopped after chunk 1, always)."""
    clock = _FakeClock()

    def advance(i):
        if i == 0:
            clock.tick(0.3)          # "compile": one-off process cost

    (carry, outs, stop, reason), calls = _drive(advance, steps=40, chunk=10,
                                                max_seconds=0.2, clock=clock)
    assert reason == "max_steps"
    assert stop == 40
    assert len(calls) == 4


def test_max_seconds_still_enforced_after_warm_chunk():
    """Steady-state chunks do count: the budget trips once warm wall time
    crosses it, and the partial trace keeps its sentinel contract."""
    import numpy as np
    clock = _FakeClock()

    def advance(i):
        if i > 0:
            clock.tick(0.12)

    (carry, outs, stop, reason), calls = _drive(advance, steps=500, chunk=10,
                                                max_seconds=0.2, clock=clock)
    assert reason == "max_seconds"
    assert stop == len(calls) * 10 < 500
    assert len(calls) >= 2           # never stops on the cold chunk alone
    from repro.core.solvers.stopping import assemble_outputs
    gaps, coords = assemble_outputs(outs, 500, (0.0, -1))
    assert gaps.shape == coords.shape == (500,)
    assert (np.asarray(coords)[stop:] == -1).all()


def test_assemble_outputs_zero_chunk_keeps_stream_dtypes():
    """The empty-stream fallback must honor each stream's dtype contract —
    int32 coords were silently promoted to float32 before."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.solvers.stopping import assemble_outputs
    gaps, coords = assemble_outputs([], 7, (0.0, -1))
    assert gaps.dtype == jnp.float32
    assert coords.dtype == jnp.int32
    assert (np.asarray(gaps) == 0.0).all()
    assert (np.asarray(coords) == -1).all()


def test_assemble_outputs_zero_chunk_dtypes_under_x64():
    """Same sentinel contract with jax_enable_x64 on: the dtype of the
    empty stream follows the sentinel's weak-type promotion (f64/i64 under
    x64), not a hard-coded 32-bit pick."""
    import jax
    import numpy as np
    from repro.core.solvers.stopping import assemble_outputs
    prev = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        gaps, coords = assemble_outputs([], 5, (0.0, -1))
        assert np.issubdtype(np.asarray(gaps).dtype, np.floating)
        assert np.issubdtype(np.asarray(coords).dtype, np.integer)
        assert (np.asarray(gaps) == 0.0).all()
        assert (np.asarray(coords) == -1).all()
        # filler concatenation onto a real chunk keeps its dtype too
        import jax.numpy as jnp
        chunk = (jnp.zeros(2, jnp.float64), jnp.full(2, 3, jnp.int64))
        gaps, coords = assemble_outputs([chunk], 5, (0.0, -1))
        assert gaps.dtype == jnp.float64 and coords.dtype == jnp.int64
        assert (np.asarray(coords)[2:] == -1).all()
    finally:
        jax.config.update("jax_enable_x64", prev)
