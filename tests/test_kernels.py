"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret mode — CPU container, TPU target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bsls_draw.ops import two_level_draw
from repro.kernels.bsls_draw.ref import two_level_draw_ref
from repro.kernels.coord_update.ops import coord_update
from repro.kernels.coord_update.ref import coord_update_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.spmv.kernel import ell_matvec_pallas, ell_rmatvec_pallas
from repro.kernels.spmv.ref import ell_matvec_ref, ell_rmatvec_ref


# ---------------------------------------------------------------------------
# spmv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,d", [(64, 5, 40), (300, 17, 1000), (1000, 64, 500)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_matvec(n, k, d, dtype, rng):
    idx = jnp.asarray(rng.integers(0, d, (n, k)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(n, k)), dtype)
    w = jnp.asarray(rng.normal(size=d), dtype)
    got = ell_matvec_pallas(idx, val, w)
    want = ell_matvec_ref(idx, val, w)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,k,d", [(64, 5, 40), (512, 16, 300), (100, 33, 2000)])
def test_ell_rmatvec(n, k, d, rng):
    idx = jnp.asarray(rng.integers(0, d, (n, k)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    q = jnp.asarray(rng.normal(size=n), jnp.float32)
    got = ell_rmatvec_pallas(idx, val, q, d)
    want = ell_rmatvec_ref(idx, val, q, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_spmv_vs_padded_csr(tiny_problem):
    """Kernel path ≡ the PaddedCSR ops used by fw_dense."""
    from repro.core.sparse.formats import host_to_padded
    from repro.kernels.spmv.ops import ell_matvec, ell_rmatvec
    X, y, _ = tiny_problem
    pcsr, _ = host_to_padded(X)
    w = jnp.asarray(np.random.default_rng(1).normal(size=X.shape[1]), jnp.float32)
    np.testing.assert_allclose(np.asarray(ell_matvec(pcsr, w)),
                               np.asarray(pcsr.matvec(w)), rtol=1e-5, atol=1e-5)
    q = jnp.asarray(np.random.default_rng(2).normal(size=X.shape[0]), jnp.float32)
    np.testing.assert_allclose(np.asarray(ell_rmatvec(pcsr, q)),
                               np.asarray(pcsr.rmatvec(q)), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bsls_draw
# ---------------------------------------------------------------------------

def test_two_level_draw_matches_ref(rng):
    from repro.core.samplers.bsls_jax import tl_init
    st = tl_init(jnp.asarray(rng.normal(0, 2, 200), jnp.float32))
    for seed in range(5):
        key = jax.random.PRNGKey(seed)
        kg, km = jax.random.split(key)
        gg = jax.random.gumbel(kg, st.c.shape, jnp.float32)
        gm = jax.random.gumbel(km, (st.v.shape[1],), jnp.float32)
        assert int(two_level_draw(st.c, st.v, key)) == int(
            two_level_draw_ref(st.c, st.v, gg, gm))


def test_two_level_draw_distribution(rng):
    from repro.core.samplers.bsls_jax import tl_init
    d = 120
    st = tl_init(jnp.asarray(rng.normal(0, 1.5, d), jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 4000)
    draws = np.array([int(two_level_draw(st.c, st.v, k)) for k in keys[:1500]])
    p = np.asarray(jax.nn.softmax(st.v.reshape(-1)[:d]))
    counts = np.bincount(draws, minlength=st.v.size)[:d]
    e = p * len(draws)
    m = e >= 5
    chi2 = ((counts[m] - e[m]) ** 2 / e[m]).sum() / max(m.sum() - 1, 1)
    assert chi2 < 1.6


# ---------------------------------------------------------------------------
# coord_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,kc,kr", [(100, 300, 17, 7), (200, 500, 37, 11),
                                       (50, 64, 5, 3), (400, 1000, 130, 20)])
def test_coord_update_matches_ref(n, d, kc, kr, rng):
    vbar = jnp.asarray(rng.normal(size=n), jnp.float32)
    qbar = jnp.asarray(jax.nn.sigmoid(vbar))
    alpha = jnp.asarray(rng.normal(size=d), jnp.float32)
    w = jnp.asarray(rng.normal(size=d) * 0.1, jnp.float32)
    rows = jnp.asarray(rng.choice(n, kc, replace=False), jnp.int32)
    x_col = jnp.asarray(rng.normal(size=kc), jnp.float32)
    mask = jnp.asarray(rng.random(kc) < 0.8)
    x_col = jnp.where(mask, x_col, 0.0)
    row_idx = jnp.asarray(rng.integers(0, d, (kc, kr)), jnp.int32)
    row_val = jnp.asarray(rng.normal(size=(kc, kr)), jnp.float32)
    kw = dict(eta=0.05, d_tilde=-8.0, w_m=0.9, inv_n=1.0 / n)
    ref = coord_update_ref(vbar, qbar, alpha, w, rows, x_col, mask,
                           row_idx, row_val, **kw)
    got = coord_update(vbar, qbar, alpha, w, rows, x_col, mask,
                       row_idx, row_val, **kw)
    for name, a, b in zip(("vbar", "qbar", "alpha"), ref[:3], got[:3]):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-5,
                                   atol=1e-6, err_msg=name)
    assert float(got[3]) == pytest.approx(float(ref[3]), abs=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,hd,causal,window", [
    (2, 128, 4, 2, 32, True, 0),
    (1, 256, 8, 8, 16, True, 0),
    (2, 128, 4, 1, 64, False, 0),
    (1, 256, 6, 2, 32, True, 64),
    (1, 128, 2, 2, 16, True, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, s, h, kv, hd, causal, window, dtype, rng):
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 0.06
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_kernel_vs_training_flash(rng):
    """Pallas kernel ≡ the pure-JAX custom-VJP flash used in training."""
    from repro.models.flash import flash_attention
    q = jnp.asarray(rng.normal(size=(2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 32)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64)
    want = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
