"""Roofline tooling: HLO collective parsing (incl. while-loop trip-count
multiplication) and the three-term model arithmetic."""
import textwrap

import pytest

from repro.roofline.hlo import collective_bytes_nested


TOY_HLO = textwrap.dedent("""\
    HloModule toy

    %body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
      %p = (s32[], f32[128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[128] get-tuple-element(%p), index=1
      %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[128]) tuple(%ni, %ar)
    }

    %cond (p: (s32[], f32[128])) -> pred[] {
      %p = (s32[], f32[128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %lim = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %lim), direction=LT
    }

    ENTRY %main (a: f32[128]) -> f32[128] {
      %a = f32[128] parameter(0)
      %ag = f32[256]{0} all-gather(%a), dimensions={0}
      %zero = s32[] constant(0)
      %init = (s32[], f32[128]) tuple(%zero, %a)
      %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[128] get-tuple-element(%w), index=1
    }
""")


def test_collective_bytes_nested_multiplies_trip_count():
    out = collective_bytes_nested(TOY_HLO)
    # all-gather outside the loop: 256·4 bytes, once
    assert out.get("all-gather", 0) == 256 * 4
    # all-reduce inside a 12-trip while: 128·4·12
    assert out.get("all-reduce", 0) == 128 * 4 * 12


def test_roofline_terms_math():
    """Inputs are PER-DEVICE (verified: cost_analysis() of an SPMD module
    reports the per-device program), so the per-chip rates divide directly."""
    from repro.roofline.analysis import roofline_terms
    terms = roofline_terms(flops=1.0e13, bytes_accessed=1.0e12,
                           collective_bytes=1.0e10, chips=256)
    assert terms["t_compute_s"] == pytest.approx(1.0e13 / 197e12)
    assert terms["t_memory_s"] == pytest.approx(1.0e12 / 819e9)
    assert terms["t_collective_s"] == pytest.approx(1.0e10 / (2 * 50e9))
    assert terms["bottleneck"] == "memory"
    assert 0 < terms["roofline_fraction"] <= 1.0


def test_model_flops_formula():
    from repro.roofline.analysis import model_flops
    # dense: 6·N·D
    assert model_flops(1.0e9, 1.0e6) == pytest.approx(6e15)
