"""Serving engine: continuous batching lifecycle, greedy determinism vs a
step-by-step reference decode, slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_model
from repro.serve.engine import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def tiny_lm():
    api = get_model("tinyllama-1.1b", smoke=True)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def _reference_generate(api, params, prompt, n_new, max_len=64):
    """Greedy decode, one request, straight through the model API."""
    cache = api.init_cache(1, max_len)
    toks = list(prompt)
    logits = None
    for t, tok in enumerate(toks):
        logits, cache = api.decode_step(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.asarray(t, jnp.int32))
    out = []
    pos = len(toks)
    cur = int(jnp.argmax(logits[0, 0]))
    out.append(cur)
    while len(out) < n_new:
        logits, cache = api.decode_step(
            params, cache, jnp.asarray([[cur]], jnp.int32), jnp.asarray(pos, jnp.int32))
        cur = int(jnp.argmax(logits[0, 0]))
        out.append(cur)
        pos += 1
    return out


def test_engine_matches_reference(tiny_lm):
    api, params = tiny_lm
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 100, int(rng.integers(3, 9))).astype(np.int32)
               for _ in range(4)]
    engine = ServingEngine(api, params, ServeConfig(slots=2, max_len=64,
                                                    prefill_bucket=16))
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=6))
    finished = {r.uid: r for r in engine.run()}
    assert len(finished) == 4
    for i, p in enumerate(prompts):
        want = _reference_generate(api, params, p, 6)
        assert finished[i].generated == want, f"request {i} diverged"


def test_slot_reuse_more_requests_than_slots(tiny_lm):
    api, params = tiny_lm
    rng = np.random.default_rng(4)
    engine = ServingEngine(api, params, ServeConfig(slots=2, max_len=32,
                                                    prefill_bucket=8))
    for i in range(7):
        engine.submit(Request(uid=i, prompt=rng.integers(1, 50, 4).astype(np.int32),
                              max_new_tokens=3))
    finished = engine.run()
    assert len(finished) == 7
    assert all(len(r.generated) == 3 for r in finished)


def test_eos_stops_early(tiny_lm):
    api, params = tiny_lm
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, 50, 4).astype(np.int32)
    # discover the first generated token, then use it as EOS
    probe = ServingEngine(api, params, ServeConfig(slots=1, max_len=32,
                                                   prefill_bucket=8))
    probe.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    first = probe.run()[0].generated[0]
    engine = ServingEngine(api, params, ServeConfig(slots=1, max_len=32,
                                                    prefill_bucket=8))
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=10, eos_id=first))
    out = engine.run()[0]
    assert len(out.generated) < 10


def test_mixed_archs_families():
    """The one engine serves a stacked-scan family and a per-layer-list
    family without layout hacks."""
    rng = np.random.default_rng(6)
    for arch in ("falcon-mamba-7b", "recurrentgemma-2b"):
        api = get_model(arch, smoke=True)
        params = api.init(jax.random.PRNGKey(0))
        engine = ServingEngine(api, params, ServeConfig(slots=2, max_len=32,
                                                        prefill_bucket=8))
        for i in range(3):
            engine.submit(Request(uid=i, prompt=rng.integers(1, 50, 5).astype(np.int32),
                                  max_new_tokens=3))
        assert len(engine.run()) == 3


def test_encdec_decode_matches_parallel():
    """Enc-dec serving path: cross-attention prefill + step decode must match
    the teacher-forced parallel decoder (seamless family)."""
    from repro.configs import smoke_config
    from repro.models import encdec
    cfg = smoke_config("seamless-m4t-medium")
    params = encdec.lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B, S_ENC = 2, 12
    frames = jnp.asarray(rng.normal(0, 1, (B, S_ENC, cfg.d_model)), cfg.jdtype)
    toks = jnp.asarray(rng.integers(1, 80, (B, 6)), jnp.int32)
    cache = encdec.lm_init_cache(cfg, B, 16)
    cache = encdec.prefill_cross(params, cache, frames, cfg)
    for t in range(toks.shape[1]):
        logits, cache = encdec.lm_decode_step(
            params, cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32), cfg)
    full = encdec.lm_forward(params, {"frames": frames, "tokens": toks}, cfg)
    diff = float(jnp.abs(full[:, -1].astype(jnp.float32)
                         - logits[:, 0].astype(jnp.float32)).max())
    assert diff < 5e-4, f"encdec decode diverges by {diff}"
