"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device (the dry-run sets its own flag in-process).
Multi-device tests spawn subprocesses (see test_distributed_fw.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_problem():
    """Small sparse classification problem shared across FW tests."""
    from repro.data.synthetic import make_sparse_classification
    X, y, w_true = make_sparse_classification(
        n=300, d=1200, nnz_per_row=15, informative=25, seed=7)
    return X, y, w_true


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
