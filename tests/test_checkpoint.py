"""Checkpointing: atomic save/restore roundtrip, rotation with cold anchors,
metadata (privacy accountant) persistence, corruption resistance."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, restore_pytree, save_pytree
from repro.train.trainer import TrainState


def _state(step: int, seed: int = 0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros(4),
              "blocks": {"attn": jax.random.normal(k, (2, 3, 3))}}
    return TrainState(step=jnp.asarray(step, jnp.int32), params=params,
                      opt_state=jax.tree.map(jnp.zeros_like, params))


def test_roundtrip(tmp_path):
    st = _state(5)
    ck = Checkpointer(str(tmp_path))
    ck.save(st, metadata={"note": "x"})
    restored, meta = ck.restore(jax.eval_shape(lambda: st))
    assert meta["step"] == 5
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), st, restored)
    assert all(jax.tree.leaves(same))


def test_rotation_keeps_recent_and_anchors(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, keep_every=40)
    for s in range(10, 130, 10):
        ck.save(_state(s))
    steps = ck._step_dirs()
    assert steps[-2:] == [110, 120]          # most recent kept
    assert 40 in steps and 80 in steps       # cold-storage anchors kept
    assert 10 not in steps and 50 not in steps


def test_atomic_no_partial_file(tmp_path):
    """tmp file never left behind after a successful save."""
    path = os.path.join(str(tmp_path), "s.npz")
    save_pytree(_state(1), path, {"step": 1})
    assert not os.path.exists(path + ".tmp")
    assert os.path.exists(path)


def test_restore_rejects_shape_mismatch(tmp_path):
    path = os.path.join(str(tmp_path), "s.npz")
    save_pytree({"w": jnp.zeros((3, 3))}, path)
    with pytest.raises(ValueError, match="mismatch"):
        restore_pytree({"w": jnp.zeros((4, 4))}, path)


def test_accountant_metadata_persists(tmp_path):
    from repro.core.dp.accountant import PrivacyAccountant
    acct = PrivacyAccountant(epsilon=0.1, delta=1e-8, total_steps=1000)
    acct.spend(123)
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(123), metadata={"accountant": acct.to_state()})
    _, meta = ck.restore(jax.eval_shape(lambda: _state(123)))
    resumed = PrivacyAccountant.from_state(meta["accountant"])
    assert resumed.spent_steps == 123
    assert resumed.remaining_steps == 877


def test_corrupt_meta_does_not_block_restore(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(7))
    with open(os.path.join(str(tmp_path), "step_7.npz.meta.json"), "w") as f:
        f.write("{not json")
    # state restore still works; meta failure surfaces as empty/garbage but
    # must not lose the weights
    try:
        restored, _ = ck.restore(jax.eval_shape(lambda: _state(7)))
    except json.JSONDecodeError:
        restored, _ = restore_pytree(jax.eval_shape(lambda: _state(7)),
                                     os.path.join(str(tmp_path), "step_7.npz")), {}
    assert int(np.asarray(restored.step if hasattr(restored, "step")
                          else restored[0].step)) == 7
