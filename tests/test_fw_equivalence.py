"""The paper's core claim: Alg 2 (sparse) ≡ Alg 1 (dense) — identical steps
for the linear-consistency part, matching convergence overall, and the host
(faithful) vs JAX (TPU-adapted) implementations take *identical* steps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fw_dense import FWConfig, dense_fw, dense_fw_flops
from repro.core.fw_jax import SparseJaxConfig, sparse_fw_jax
from repro.core.fw_sparse import sparse_fw
from repro.core.sparse.formats import host_to_padded

STEPS = 120
LAM = 8.0


@pytest.fixture(scope="module")
def runs(tiny_problem):
    X, y, _ = tiny_problem
    yj = jnp.asarray(y, jnp.float32)
    pcsr, pcsc = host_to_padded(X)
    dense = dense_fw(jnp.asarray(X.to_dense(), jnp.float32), yj,
                     FWConfig(lam=LAM, steps=STEPS, selection="argmax"))
    padded = dense_fw(pcsr, yj, FWConfig(lam=LAM, steps=STEPS, selection="argmax"))
    host2 = sparse_fw(X, y, lam=LAM, steps=STEPS, queue="fib_heap")
    jax2 = sparse_fw_jax(pcsr, pcsc, yj,
                         SparseJaxConfig(lam=LAM, steps=STEPS, queue="group_argmax"))
    return dense, padded, host2, jax2


def test_dense_vs_padded_identical(runs):
    dense, padded, _, _ = runs
    np.testing.assert_array_equal(np.asarray(dense.coords), np.asarray(padded.coords))
    np.testing.assert_allclose(np.asarray(dense.w), np.asarray(padded.w), atol=1e-6)


def test_host_alg2_vs_jax_alg2_identical_steps(runs):
    """The faithful sequential Alg 2 and its TPU port must take the SAME steps
    (both maintain the same lazily-refreshed state)."""
    _, _, host2, jax2 = runs
    np.testing.assert_array_equal(np.asarray(host2.coords), np.asarray(jax2.coords))
    np.testing.assert_allclose(np.asarray(host2.w), np.asarray(jax2.w),
                               atol=5e-5)


def test_alg1_alg2_same_convergence(tiny_problem, runs):
    """Gap traces converge to the same optimum (paper Fig. 1): early steps
    identical at equal precision, final gaps within 40% relative (near-tie
    divergence allowed, documented in DESIGN.md §2).  Alg 1 runs in f64 here
    (benchmarks/host_alg1) so near-ties aren't broken by f32 rounding."""
    from benchmarks.host_alg1 import host_alg1
    X, y, _ = tiny_problem
    a1 = host_alg1(X, y, lam=LAM, steps=STEPS)
    _, _, host2, _ = runs
    c1, c2 = np.asarray(a1.coords), np.asarray(host2.coords)
    # identical until the lazy q̄ refresh can first matter (the first repeat
    # touch of overlapping rows) — guaranteed for the first few steps only
    assert (c1[:3] == c2[:3]).all(), "first iterations must match exactly"
    # both collapse the duality gap...
    for r in (a1, host2):
        assert float(r.gaps[-1]) < float(r.gaps[0]) / 25.0
    # ...and reach the same objective value (the paper's "identical accuracy")
    def objective(w):
        m = X.to_dense() @ np.asarray(w, np.float64)
        return float(np.mean(np.log1p(np.exp(m)) - y * m))
    o1, o2 = objective(a1.w), objective(host2.w)
    assert abs(o1 - o2) / max(abs(o1), 1e-9) < 0.01, (o1, o2)


def test_solution_sparsity(runs):
    """FW guarantees ≤ T+1 nonzeros (paper §1)."""
    dense, _, host2, jax2 = runs
    for r in (dense, host2, jax2):
        assert int(np.sum(np.asarray(r.w) != 0)) <= STEPS + 1


def test_gap_decreases(runs):
    dense, *_ = runs
    gaps = np.asarray(dense.gaps)
    assert gaps[-1] < gaps[0] * 0.25


def test_l1_constraint_respected(runs):
    """Every iterate stays inside the λ-ball (convex combination of vertices)."""
    dense, _, host2, jax2 = runs
    for r in (dense, host2, jax2):
        assert float(np.abs(np.asarray(r.w)).sum()) <= LAM * (1 + 1e-5)


def test_fw_flops_accounting_subadditive(tiny_problem):
    """Alg 2's tracked FLOPs must undercut Alg 1's analytic count (Fig 2/4)."""
    X, y, _ = tiny_problem
    res = sparse_fw(X, y, lam=LAM, steps=STEPS, queue="fib_heap")
    alg1 = dense_fw_flops(X.shape[0], X.shape[1], X.nnz, STEPS)
    assert res.flops < alg1


def test_dp_noisy_max_runs(tiny_problem):
    X, y, _ = tiny_problem
    yj = jnp.asarray(y, jnp.float32)
    res = dense_fw(jnp.asarray(X.to_dense(), jnp.float32), yj,
                   FWConfig(lam=LAM, steps=40, selection="noisy_max",
                            epsilon=1.0, delta=1e-6))
    assert np.isfinite(np.asarray(res.w)).all()
    res_g = dense_fw(jnp.asarray(X.to_dense(), jnp.float32), yj,
                     FWConfig(lam=LAM, steps=40, selection="gumbel",
                              epsilon=1.0, delta=1e-6))
    assert np.isfinite(np.asarray(res_g.w)).all()


def test_dp_two_level_jax(tiny_problem):
    X, y, _ = tiny_problem
    pcsr, pcsc = host_to_padded(X)
    res = sparse_fw_jax(pcsr, pcsc, jnp.asarray(y, jnp.float32),
                        SparseJaxConfig(lam=LAM, steps=40, queue="two_level",
                                        epsilon=1.0, delta=1e-6))
    assert np.isfinite(np.asarray(res.w)).all()
    assert int(np.sum(np.asarray(res.w) != 0)) <= 41
