"""Unified solver engine: registry behavior + cross-backend parity.

Parity logic (DESIGN.md §4): on a *dense* design matrix every iteration
touches every row, so Algorithm 2's lazy q̄ refresh never goes stale and all
four backends must take identical steps — dense (Alg 1), jax_dense,
host_sparse and jax_sparse agree on coords exactly and on weights/gaps to
float tolerance.  On a genuinely sparse problem Alg 1 may diverge from Alg 2
at near-ties (lazy refresh, paper Fig 1), but the three Alg-2 backends are
the *same* state machine and must still agree with each other.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.solvers import (FWConfig, available_backends, get_backend,
                                resolve_queue, solve)

ALL_BACKENDS = ("dense", "jax_dense", "host_sparse", "jax_sparse")
ALG2_BACKENDS = ("jax_dense", "host_sparse", "jax_sparse")


@pytest.fixture(scope="module")
def dense_problem():
    rng = np.random.default_rng(3)
    n, d = 80, 48
    X = rng.normal(size=(n, d)) / np.sqrt(d)
    w_star = np.zeros(d)
    w_star[rng.choice(d, 8, replace=False)] = rng.normal(0, 2, 8)
    y = (X @ w_star + 0.1 * rng.normal(size=n) > 0).astype(np.float64)
    return X, y


@pytest.fixture(scope="module")
def dense_runs(dense_problem):
    X, y = dense_problem
    cfg = FWConfig(lam=6.0, steps=80)
    return {b: solve(X, y, dataclasses.replace(cfg, backend=b))
            for b in ALL_BACKENDS}


def test_registry_lists_all_builtins():
    assert set(ALL_BACKENDS) <= set(available_backends())


def test_registry_rejects_unknown_backend(dense_problem):
    X, y = dense_problem
    with pytest.raises(ValueError, match="unknown solver backend"):
        solve(X, y, FWConfig(backend="quantum_annealer", steps=2))
    with pytest.raises(ValueError):
        get_backend("nope")


def test_registry_rejects_unknown_queue(dense_problem):
    X, y = dense_problem
    with pytest.raises(ValueError, match="does not support queue"):
        solve(X, y, FWConfig(backend="jax_sparse", queue="bogus", steps=2))


def test_queue_alias_translation():
    # one config, retargeted across backends, resolves to the native names
    cfg = FWConfig(queue="bsls")
    assert resolve_queue(get_backend("host_sparse"), cfg).queue == "bsls"
    assert resolve_queue(get_backend("jax_sparse"), cfg).queue == "two_level"
    cfg = FWConfig(queue="fib_heap")
    assert resolve_queue(get_backend("jax_dense"), cfg).queue == "group_argmax"
    assert resolve_queue(get_backend("dense"), cfg).queue == "argmax"


def test_all_backends_parity_on_dense_problem(dense_runs):
    """Acceptance: non-private weights and gaps agree within 1e-4 (4 ways)."""
    ref = dense_runs["dense"]
    for b in ALL_BACKENDS:
        r = dense_runs[b]
        np.testing.assert_array_equal(
            np.asarray(r.coords), np.asarray(ref.coords),
            err_msg=f"{b}: coordinate sequence diverged from dense")
        np.testing.assert_allclose(np.asarray(r.w), np.asarray(ref.w),
                                   atol=1e-4, err_msg=f"{b}: weights")
        np.testing.assert_allclose(np.asarray(r.gaps), np.asarray(ref.gaps),
                                   atol=1e-4, err_msg=f"{b}: gaps")


def test_all_backends_shrink_gap(dense_runs):
    for b, r in dense_runs.items():
        gaps = np.asarray(r.gaps)
        assert gaps[-1] < gaps[0] / 20.0, b


def test_alg2_backends_identical_on_sparse_problem(tiny_problem):
    """The three Alg-2 engines are one state machine: same steps on real
    sparse data, where Alg 1 may legitimately diverge (lazy q̄ refresh)."""
    X, y, _ = tiny_problem
    cfg = FWConfig(lam=8.0, steps=60)
    runs = {b: solve(X, y, dataclasses.replace(cfg, backend=b))
            for b in ALG2_BACKENDS}
    ref = runs["host_sparse"]
    for b in ALG2_BACKENDS:
        r = runs[b]
        np.testing.assert_array_equal(np.asarray(r.coords),
                                      np.asarray(ref.coords), err_msg=b)
        np.testing.assert_allclose(np.asarray(r.w), np.asarray(ref.w),
                                   atol=1e-4, err_msg=b)
        np.testing.assert_allclose(np.asarray(r.gaps), np.asarray(ref.gaps),
                                   atol=1e-4, err_msg=b)
    # Alg 1 still collapses the gap toward the same optimum (paper Fig 1)
    dense = solve(X, y, dataclasses.replace(cfg, backend="dense"))
    assert float(dense.gaps[-1]) < float(dense.gaps[0]) / 4.0
    assert float(ref.gaps[-1]) < float(ref.gaps[0]) / 4.0


def test_private_queues_run_everywhere(tiny_problem):
    """queue='bsls' retargets to each backend's DP exponential mechanism."""
    X, y, _ = tiny_problem
    for b in ALL_BACKENDS:
        r = solve(X, y, FWConfig(backend=b, lam=8.0, steps=20, queue="bsls",
                                 epsilon=1.0, delta=1e-6))
        w = np.asarray(r.w)
        assert np.isfinite(w).all(), b
        assert int((w != 0).sum()) <= 21, b


def test_solve_accepts_padded_pair(tiny_problem):
    from repro.core.sparse.formats import host_to_padded
    X, y, _ = tiny_problem
    pair = host_to_padded(X)
    direct = solve(X, y, FWConfig(backend="jax_sparse", lam=8.0, steps=25))
    padded = solve(pair, y, FWConfig(backend="jax_sparse", lam=8.0, steps=25))
    np.testing.assert_array_equal(np.asarray(direct.coords),
                                  np.asarray(padded.coords))


def test_solve_kwarg_overrides(dense_problem):
    X, y = dense_problem
    r = solve(X, y, backend="host_sparse", lam=6.0, steps=10)
    assert np.asarray(r.gaps).shape == (10,)
