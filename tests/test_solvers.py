"""Unified solver engine: registry behavior + cross-backend parity.

Parity logic (DESIGN.md §4): on a *dense* design matrix every iteration
touches every row, so Algorithm 2's lazy q̄ refresh never goes stale and all
four backends must take identical steps — dense (Alg 1), jax_dense,
host_sparse and jax_sparse agree on coords exactly and on weights/gaps to
float tolerance.  On a genuinely sparse problem Alg 1 may diverge from Alg 2
at near-ties (lazy refresh, paper Fig 1), but the three Alg-2 backends are
the *same* state machine and must still agree with each other.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.solvers import (FWConfig, available_backends, get_backend,
                                grid, resolve_queue, solve, solve_many)

ALL_BACKENDS = ("dense", "jax_dense", "host_sparse", "jax_sparse")
ALG2_BACKENDS = ("jax_dense", "host_sparse", "jax_sparse")


@pytest.fixture(scope="module")
def dense_problem():
    rng = np.random.default_rng(3)
    n, d = 80, 48
    X = rng.normal(size=(n, d)) / np.sqrt(d)
    w_star = np.zeros(d)
    w_star[rng.choice(d, 8, replace=False)] = rng.normal(0, 2, 8)
    y = (X @ w_star + 0.1 * rng.normal(size=n) > 0).astype(np.float64)
    return X, y


@pytest.fixture(scope="module")
def dense_runs(dense_problem):
    X, y = dense_problem
    cfg = FWConfig(lam=6.0, steps=80)
    return {b: solve(X, y, dataclasses.replace(cfg, backend=b))
            for b in ALL_BACKENDS}


def test_registry_lists_all_builtins():
    assert set(ALL_BACKENDS) <= set(available_backends())


def test_registry_rejects_unknown_backend(dense_problem):
    X, y = dense_problem
    with pytest.raises(ValueError, match="unknown solver backend"):
        solve(X, y, FWConfig(backend="quantum_annealer", steps=2))
    with pytest.raises(ValueError):
        get_backend("nope")


def test_registry_rejects_unknown_queue(dense_problem):
    X, y = dense_problem
    with pytest.raises(ValueError, match="does not support queue"):
        solve(X, y, FWConfig(backend="jax_sparse", queue="bogus", steps=2))


def test_queue_alias_translation():
    # one config, retargeted across backends, resolves to the native names
    cfg = FWConfig(queue="bsls")
    assert resolve_queue(get_backend("host_sparse"), cfg).queue == "bsls"
    assert resolve_queue(get_backend("jax_sparse"), cfg).queue == "two_level"
    cfg = FWConfig(queue="fib_heap")
    assert resolve_queue(get_backend("jax_dense"), cfg).queue == "group_argmax"
    assert resolve_queue(get_backend("dense"), cfg).queue == "argmax"


def test_all_backends_parity_on_dense_problem(dense_runs):
    """Acceptance: non-private weights and gaps agree within 1e-4 (4 ways)."""
    ref = dense_runs["dense"]
    for b in ALL_BACKENDS:
        r = dense_runs[b]
        np.testing.assert_array_equal(
            np.asarray(r.coords), np.asarray(ref.coords),
            err_msg=f"{b}: coordinate sequence diverged from dense")
        np.testing.assert_allclose(np.asarray(r.w), np.asarray(ref.w),
                                   atol=1e-4, err_msg=f"{b}: weights")
        np.testing.assert_allclose(np.asarray(r.gaps), np.asarray(ref.gaps),
                                   atol=1e-4, err_msg=f"{b}: gaps")


def test_all_backends_shrink_gap(dense_runs):
    for b, r in dense_runs.items():
        gaps = np.asarray(r.gaps)
        assert gaps[-1] < gaps[0] / 20.0, b


def test_alg2_backends_identical_on_sparse_problem(tiny_problem):
    """The three Alg-2 engines are one state machine: same steps on real
    sparse data, where Alg 1 may legitimately diverge (lazy q̄ refresh)."""
    X, y, _ = tiny_problem
    cfg = FWConfig(lam=8.0, steps=60)
    runs = {b: solve(X, y, dataclasses.replace(cfg, backend=b))
            for b in ALG2_BACKENDS}
    ref = runs["host_sparse"]
    for b in ALG2_BACKENDS:
        r = runs[b]
        np.testing.assert_array_equal(np.asarray(r.coords),
                                      np.asarray(ref.coords), err_msg=b)
        np.testing.assert_allclose(np.asarray(r.w), np.asarray(ref.w),
                                   atol=1e-4, err_msg=b)
        np.testing.assert_allclose(np.asarray(r.gaps), np.asarray(ref.gaps),
                                   atol=1e-4, err_msg=b)
    # Alg 1 still collapses the gap toward the same optimum (paper Fig 1)
    dense = solve(X, y, dataclasses.replace(cfg, backend="dense"))
    assert float(dense.gaps[-1]) < float(dense.gaps[0]) / 4.0
    assert float(ref.gaps[-1]) < float(ref.gaps[0]) / 4.0


def test_private_queues_run_everywhere(tiny_problem):
    """queue='bsls' retargets to each backend's DP exponential mechanism."""
    X, y, _ = tiny_problem
    for b in ALL_BACKENDS:
        r = solve(X, y, FWConfig(backend=b, lam=8.0, steps=20, queue="bsls",
                                 epsilon=1.0, delta=1e-6))
        w = np.asarray(r.w)
        assert np.isfinite(w).all(), b
        assert int((w != 0).sum()) <= 21, b


def test_solve_accepts_padded_pair(tiny_problem):
    from repro.core.sparse.formats import host_to_padded
    X, y, _ = tiny_problem
    pair = host_to_padded(X)
    direct = solve(X, y, FWConfig(backend="jax_sparse", lam=8.0, steps=25))
    padded = solve(pair, y, FWConfig(backend="jax_sparse", lam=8.0, steps=25))
    np.testing.assert_array_equal(np.asarray(direct.coords),
                                  np.asarray(padded.coords))


def test_solve_kwarg_overrides(dense_problem):
    X, y = dense_problem
    r = solve(X, y, backend="host_sparse", lam=6.0, steps=10)
    assert np.asarray(r.gaps).shape == (10,)


# ---------------------------------------------------------------------------
# QUEUE_ALIASES regression pin — a registry edit cannot silently retarget a
# queue.  Every (backend × accepted alias) pair is written out literally; if
# the table changes, this test must change with it, on purpose.
# ---------------------------------------------------------------------------

EXPECTED_QUEUE_RESOLUTION = {
    "dense": {
        "argmax": "argmax", "fib_heap": "argmax", "group_argmax": "argmax",
        "noisy_max": "noisy_max",
        "gumbel": "gumbel", "bsls": "gumbel", "two_level": "gumbel",
    },
    "host_sparse": {
        "fib_heap": "fib_heap", "argmax": "argmax", "noisy_max": "noisy_max",
        "bsls": "bsls", "group_argmax": "fib_heap", "two_level": "bsls",
        "gumbel": "bsls",
    },
    "jax_dense": {
        "two_level": "two_level", "group_argmax": "group_argmax",
        "bsls": "two_level", "gumbel": "two_level",
        "fib_heap": "group_argmax", "argmax": "group_argmax",
    },
    "jax_sparse": {
        "two_level": "two_level", "group_argmax": "group_argmax",
        "bsls": "two_level", "gumbel": "two_level",
        "fib_heap": "group_argmax", "argmax": "group_argmax",
    },
}

EXPECTED_DEFAULT_QUEUE = {"dense": None, "host_sparse": "fib_heap",
                          "jax_dense": "group_argmax",
                          "jax_sparse": "group_argmax"}


@pytest.mark.parametrize("backend_name", sorted(EXPECTED_QUEUE_RESOLUTION))
def test_queue_alias_table_pinned(backend_name):
    backend = get_backend(backend_name)
    expected = EXPECTED_QUEUE_RESOLUTION[backend_name]
    # the accepted alias *set* is pinned too: a new/removed alias must show
    # up here, not slip through resolution silently
    assert set(backend.queues) == set(expected), backend_name
    for alias, native in expected.items():
        got = resolve_queue(backend, FWConfig(queue=alias)).queue
        assert got == native, f"{backend_name}: {alias} -> {got} != {native}"
    assert resolve_queue(backend, FWConfig(queue=None)).queue == \
        EXPECTED_DEFAULT_QUEUE[backend_name]


# ---------------------------------------------------------------------------
# batched sweeps: solve_many / grid
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sweep_problem():
    from repro.data.synthetic import make_sparse_classification
    X, y, _ = make_sparse_classification(
        n=150, d=600, nnz_per_row=10, informative=15, seed=11)
    return X, y


def _assert_same_result(b, s, msg):
    np.testing.assert_array_equal(np.asarray(b.coords), np.asarray(s.coords),
                                  err_msg=f"{msg}: coords")
    np.testing.assert_allclose(np.asarray(b.w), np.asarray(s.w), atol=1e-4,
                               err_msg=f"{msg}: w")
    np.testing.assert_allclose(np.asarray(b.gaps), np.asarray(s.gaps),
                               atol=1e-4, err_msg=f"{msg}: gaps")


def test_grid_cartesian_product():
    cfgs = grid(FWConfig(backend="jax_sparse", steps=10),
                lam=(1.0, 2.0, 3.0), epsilon=(0.1, 1.0), seed=7)
    assert len(cfgs) == 6
    assert [c.lam for c in cfgs] == [1.0, 1.0, 2.0, 2.0, 3.0, 3.0]
    assert [c.epsilon for c in cfgs] == [0.1, 1.0] * 3
    assert all(c.seed == 7 and c.steps == 10 for c in cfgs)
    with pytest.raises(ValueError, match="unknown FWConfig field"):
        grid(lambda_=(1.0,))
    assert len(grid(lam=5.0)) == 1  # scalars only -> a single config


def test_solve_many_private_sweep_matches_sequential(sweep_problem):
    """Acceptance: a vmapped ≥8-config λ/ε jax_sparse sweep takes the same
    steps as per-config sequential solve() on the same keys (1e-4)."""
    X, y = sweep_problem
    configs = grid(FWConfig(backend="jax_sparse", steps=30, queue="bsls",
                            delta=1e-6),
                   lam=(4.0, 8.0, 16.0, 32.0), epsilon=(0.5, 2.0))
    assert len(configs) == 8
    batched = solve_many(X, y, configs)
    for i, cfg in enumerate(configs):
        _assert_same_result(batched[i], solve(X, y, cfg), f"config {i} ({cfg.lam}, {cfg.epsilon})")


def test_solve_many_nonprivate_sweep_matches_sequential(sweep_problem):
    X, y = sweep_problem
    configs = grid(FWConfig(backend="jax_sparse", steps=30),
                   lam=(4.0, 8.0, 12.0))
    batched = solve_many(X, y, configs)
    for i, cfg in enumerate(configs):
        _assert_same_result(batched[i], solve(X, y, cfg), f"lam={cfg.lam}")


def test_solve_many_varied_seeds_use_distinct_keys(sweep_problem):
    """Each config's PRNG stream is its own — identical configs with
    different seeds must (generically) select different DP coordinates."""
    X, y = sweep_problem
    configs = grid(FWConfig(backend="jax_sparse", steps=25, queue="bsls",
                            lam=8.0, epsilon=1.0), seed=(0, 1, 2, 3))
    batched = solve_many(X, y, configs)
    for i, cfg in enumerate(configs):
        _assert_same_result(batched[i], solve(X, y, cfg), f"seed={cfg.seed}")
    coord_seqs = {tuple(np.asarray(r.coords)) for r in batched}
    assert len(coord_seqs) > 1


def test_solve_many_mixed_backends_preserve_order(sweep_problem):
    """Non-batchable backends drain through the sequential fallback; results
    come back in submission order regardless of grouping."""
    X, y = sweep_problem
    configs = [FWConfig(backend="host_sparse", lam=8.0, steps=12),
               FWConfig(backend="jax_sparse", lam=8.0, steps=12),
               FWConfig(backend="jax_sparse", lam=4.0, steps=12),
               FWConfig(backend="jax_dense", lam=8.0, steps=12)]
    results = solve_many(X, y, configs)
    assert len(results) == 4
    for cfg, res in zip(configs, results):
        _assert_same_result(res, solve(X, y, cfg), cfg.backend)
    # host_sparse/jax_sparse/jax_dense agree on this state machine anyway:
    _assert_same_result(results[0], results[1], "alg2 cross-check")


def test_solve_many_empty_and_singleton(sweep_problem):
    X, y = sweep_problem
    assert solve_many(X, y, []) == []
    one = solve_many(X, y, [FWConfig(backend="jax_sparse", lam=8.0, steps=10)])
    assert len(one) == 1
    _assert_same_result(
        one[0], solve(X, y, FWConfig(backend="jax_sparse", lam=8.0, steps=10)),
        "singleton")


# ---------------------------------------------------------------------------
# dataset-ref solving (DESIGN.md §7): solve(DatasetRef/DatasetStore) must be
# the *same state machine* as solve(X_in_memory) — the store hands back
# bit-identical arrays (mmap round trip) and replays the cached fw_setup
# state the in-memory path would have computed.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def stored_problem(sweep_problem, tmp_path_factory):
    from repro.data.store import DatasetStore
    X, y = sweep_problem
    root = tmp_path_factory.mktemp("solver_store") / "ds"
    store = DatasetStore.from_arrays(str(root), X, y, rows_per_shard=64)
    return store, X, y


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_solve_from_store_identical_iterates(stored_problem, backend):
    """Acceptance: identical coords and weights vs in-memory, per backend."""
    store, X, y = stored_problem
    cfg = FWConfig(backend=backend, lam=8.0, steps=25)
    from_store = solve(store, config=cfg)
    in_memory = solve(X, y, cfg)
    np.testing.assert_array_equal(np.asarray(from_store.coords),
                                  np.asarray(in_memory.coords))
    np.testing.assert_array_equal(np.asarray(from_store.w),
                                  np.asarray(in_memory.w))
    np.testing.assert_array_equal(np.asarray(from_store.gaps),
                                  np.asarray(in_memory.gaps))


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_solve_from_store_private_identical(stored_problem, backend):
    """DP queues too: same PRNG keys + same data ⇒ same draws."""
    store, X, y = stored_problem
    cfg = FWConfig(backend=backend, lam=8.0, steps=20, queue="bsls",
                   epsilon=1.0, delta=1e-6)
    from_store = solve(store, config=cfg)
    in_memory = solve(X, y, cfg)
    np.testing.assert_array_equal(np.asarray(from_store.coords),
                                  np.asarray(in_memory.coords))
    np.testing.assert_array_equal(np.asarray(from_store.w),
                                  np.asarray(in_memory.w))


def test_solve_from_store_warm_cache_identical(stored_problem):
    """A fresh open replays the persisted fw_setup state bit-for-bit."""
    from repro.data.store import DatasetStore
    store, X, y = stored_problem
    cfg = FWConfig(backend="jax_sparse", lam=8.0, steps=25)
    solve(store, config=cfg)                      # populates cache/
    warm = DatasetStore.open(store.root)
    r_warm = solve(warm, config=cfg)
    r_mem = solve(X, y, cfg)
    np.testing.assert_array_equal(np.asarray(r_warm.coords),
                                  np.asarray(r_mem.coords))
    np.testing.assert_array_equal(np.asarray(r_warm.w), np.asarray(r_mem.w))


def test_solve_dataset_ref_split_matches_subset(stored_problem):
    from repro.data.store import DatasetRef
    store, X, y = stored_problem
    ref = DatasetRef(path=store.root, split="train")
    cfg = FWConfig(backend="host_sparse", lam=8.0, steps=15)
    train_rows, _ = store.split(ref.test_frac, ref.salt)
    X_sub, y_sub = store.take(train_rows)
    _assert_same_result(solve(ref, config=cfg), solve(X_sub, y_sub, cfg),
                        "train split ref")


def test_solve_many_from_store_matches_sequential(stored_problem):
    store, X, y = stored_problem
    configs = grid(FWConfig(backend="jax_sparse", steps=20, queue="bsls",
                            delta=1e-6),
                   lam=(4.0, 8.0), epsilon=(0.5, 2.0))
    batched = solve_many(store, configs=configs)
    for i, cfg in enumerate(configs):
        _assert_same_result(batched[i], solve(X, y, cfg), f"store cfg {i}")


def test_solve_requires_labels_for_plain_matrices(sweep_problem):
    X, _ = sweep_problem
    with pytest.raises(TypeError, match="y is required"):
        solve(X, config=FWConfig(backend="host_sparse", steps=2))


# ---------------------------------------------------------------------------
# pluggable objectives (DESIGN.md §10): every registered loss must run on
# every backend with exact cross-backend step parity, match the straight-line
# reference oracle on both selection paths, and keep the fused batched sweep.
# ---------------------------------------------------------------------------

import jax.numpy as jnp  # noqa: E402

from repro.core.losses import OBJECTIVES, Objective  # noqa: E402

REGISTERED_LOSSES = sorted(OBJECTIVES)
FIVE_BACKENDS = ALL_BACKENDS + ("jax_shard",)


def _cfg_for(backend: str, **kw) -> FWConfig:
    if backend == "jax_shard":
        kw.setdefault("mesh", (1, 1))
    return FWConfig(backend=backend, **kw)


@pytest.mark.parametrize("loss", REGISTERED_LOSSES)
def test_all_backends_parity_per_loss(dense_problem, loss):
    """Acceptance: identical non-private steps 5 ways, for every objective
    (on a dense design, where Alg 1's lazy refresh never goes stale)."""
    X, y = dense_problem
    runs = {b: solve(X, y, _cfg_for(b, lam=6.0, steps=50, loss=loss))
            for b in FIVE_BACKENDS}
    ref = runs["dense"]
    for b, r in runs.items():
        np.testing.assert_array_equal(
            np.asarray(r.coords), np.asarray(ref.coords),
            err_msg=f"{loss}/{b}: coords diverged from dense")
        np.testing.assert_allclose(np.asarray(r.w), np.asarray(ref.w),
                                   atol=1e-4, err_msg=f"{loss}/{b}: weights")
        assert np.asarray(r.gaps)[-1] < np.asarray(r.gaps)[0], f"{loss}/{b}"


@pytest.mark.parametrize("loss", REGISTERED_LOSSES)
def test_private_parity_per_loss(dense_problem, loss):
    """DP path per loss: the two jit engines consume the same key stream and
    must take bit-identical steps; the host EM realization draws different
    bits of the same law (documented), so it is checked for validity only."""
    X, y = dense_problem
    kw = dict(lam=6.0, steps=25, loss=loss, queue="bsls", epsilon=1.0,
              delta=1e-6)
    a = solve(X, y, _cfg_for("jax_dense", **kw))
    b = solve(X, y, _cfg_for("jax_sparse", **kw))
    np.testing.assert_array_equal(np.asarray(a.coords), np.asarray(b.coords),
                                  err_msg=f"{loss}: private jax engines")
    host = solve(X, y, _cfg_for("host_sparse", **kw))
    assert np.isfinite(np.asarray(host.w)).all(), loss


@pytest.mark.parametrize("loss", REGISTERED_LOSSES)
@pytest.mark.parametrize("private", [False, True])
def test_jax_sparse_matches_reference_oracle(sweep_problem, loss, private):
    """Acceptance: the kernel pipeline replays the straight-line host oracle
    bit-for-bit on coords — per loss, private and non-private, on genuinely
    sparse data."""
    from repro.core.solvers.jax_sparse import em_scale_for
    from repro.core.solvers.reference import reference_fw
    from repro.core.sparse.formats import host_to_padded
    X, y = sweep_problem
    cfg = FWConfig(backend="jax_sparse", lam=8.0, steps=30, loss=loss,
                   queue="bsls" if private else None, epsilon=1.0,
                   delta=1e-6)
    r = solve(X, y, cfg)
    pcsr, pcsc = host_to_padded(X)
    resolved = resolve_queue(get_backend("jax_sparse"), cfg)
    w, gaps, coords = reference_fw(
        pcsr, pcsc, y, lam=cfg.lam, steps=cfg.steps, private=private,
        em_scale=em_scale_for(resolved, X.shape[0]), seed=cfg.seed, loss=loss)
    np.testing.assert_array_equal(np.asarray(r.coords), np.asarray(coords),
                                  err_msg=f"{loss} private={private}")
    np.testing.assert_allclose(np.asarray(r.w), np.asarray(w), atol=1e-4)
    np.testing.assert_allclose(np.asarray(r.gaps), np.asarray(gaps),
                               atol=1e-4)


@pytest.mark.parametrize("loss", REGISTERED_LOSSES)
def test_early_stop_prefix_identical_per_loss(sweep_problem, loss):
    """gap_tol runs are bit-identical prefixes of the fixed-T program, for
    every (smooth) objective."""
    X, y = sweep_problem
    full = solve(X, y, FWConfig(backend="jax_sparse", lam=8.0, steps=40,
                                loss=loss))
    tol = float(np.asarray(full.gaps)[len(np.asarray(full.gaps)) // 2])
    stopped = solve(X, y, FWConfig(backend="jax_sparse", lam=8.0, steps=40,
                                   loss=loss, gap_tol=tol))
    stop = stopped.stop_step_or()
    assert 0 < stop < 40, loss
    np.testing.assert_array_equal(
        np.asarray(stopped.coords)[:stop], np.asarray(full.coords)[:stop],
        err_msg=f"{loss}: early-stop prefix")
    assert np.all(np.asarray(stopped.coords)[stop:] == -1), loss


def test_solve_many_nonlogistic_grid_runs_fused(sweep_problem, monkeypatch):
    """Regression (ISSUE 6 satellite): a loss="squared" 8-config grid runs as
    ONE fused vmapped compiled scan — the old engine silently dropped every
    non-logistic group to the slow path (`fused = loss == "logistic"`) —
    with exact parity to per-config sequential solve()."""
    from repro.core.solvers import batched
    X, y = sweep_problem
    calls = []
    real = batched._sweep_scan_jit

    def counting(*args, **kwargs):
        calls.append(kwargs)
        return real(*args, **kwargs)

    monkeypatch.setattr(batched, "_sweep_scan_jit", counting)
    configs = grid(FWConfig(backend="jax_sparse", steps=20, loss="squared",
                            queue="bsls", delta=1e-6),
                   lam=(4.0, 8.0, 16.0, 32.0), epsilon=(0.5, 2.0))
    assert len(configs) == 8
    results = solve_many(X, y, configs, plan="vmap")
    assert len(calls) == 1, "grid must run as one compiled vmapped scan"
    assert calls[0]["loss"] == "squared" and calls[0]["fused"] is True
    for i, cfg in enumerate(configs):
        _assert_same_result(results[i], solve(X, y, cfg),
                            f"squared grid cfg {i}")


@pytest.mark.parametrize("loss", REGISTERED_LOSSES)
def test_solve_many_per_loss_matches_sequential(sweep_problem, loss):
    """The batched sweep takes the same steps as sequential solve() for
    every registered objective (private grid, mixed λ/ε)."""
    X, y = sweep_problem
    configs = grid(FWConfig(backend="jax_sparse", steps=15, loss=loss,
                            queue="bsls", delta=1e-6),
                   lam=(4.0, 16.0), epsilon=(0.5, 2.0))
    batched_rs = solve_many(X, y, configs)
    for i, cfg in enumerate(configs):
        _assert_same_result(batched_rs[i], solve(X, y, cfg),
                            f"{loss} cfg {i}")


def test_solve_from_store_warm_cache_huber(stored_problem):
    """DatasetRef/warm-cache replay for a label-coupled loss: a fresh open
    replays the per-loss persisted fw_setup state and labels bit-for-bit."""
    from repro.data.store import DatasetStore
    store, X, y = stored_problem
    for cfg in (FWConfig(backend="jax_sparse", lam=8.0, steps=20,
                         loss="huber"),
                FWConfig(backend="jax_sparse", lam=8.0, steps=20,
                         loss="huber", queue="bsls", epsilon=1.0,
                         delta=1e-6)):
        solve(store, config=cfg)                  # populates cache/
        warm = DatasetStore.open(store.root)
        r_warm = solve(warm, config=cfg)
        r_mem = solve(X, y, cfg)
        np.testing.assert_array_equal(np.asarray(r_warm.coords),
                                      np.asarray(r_mem.coords))
        np.testing.assert_array_equal(np.asarray(r_warm.w),
                                      np.asarray(r_mem.w))


# ---------------------------------------------------------------------------
# gap-certificate validity gate: a non-smooth objective has no FW duality-gap
# bound, so gap_tol early stopping must be refused up front.
# ---------------------------------------------------------------------------


def _nonsmooth_probe():
    return Objective(
        name="_abs_probe", value=lambda m, y: jnp.abs(m - y),
        grad=lambda m, y: jnp.sign(m - y), split_grad=None,
        grad_np=lambda m, y: np.sign(m - y), lipschitz=1.0,
        smooth=False, curvature_note="|r| has no curvature bound at 0")


def test_gap_tol_refused_for_nonsmooth_objective(sweep_problem):
    from repro.core.losses import register_objective
    X, y = sweep_problem
    register_objective(_nonsmooth_probe())
    try:
        with pytest.raises(ValueError, match="not smooth"):
            solve(X, y, FWConfig(backend="jax_sparse", steps=5,
                                 loss="_abs_probe", gap_tol=1e-3))
        with pytest.raises(ValueError, match="not smooth"):
            solve_many(X, y, [FWConfig(backend="jax_sparse", steps=5,
                                       loss="_abs_probe", gap_tol=1e-3)])
        # fixed-T (no certificate requested) is allowed
        r = solve(X, y, FWConfig(backend="host_sparse", steps=5,
                                 loss="_abs_probe"))
        assert np.isfinite(np.asarray(r.w)).all()
    finally:
        OBJECTIVES.pop("_abs_probe", None)


def test_gap_tol_allowed_for_every_registered_loss():
    from repro.core.solvers.config import check_gap_certificate
    for loss in REGISTERED_LOSSES:
        check_gap_certificate(FWConfig(loss=loss, gap_tol=1e-4))
    with pytest.raises(KeyError, match="unknown loss"):
        check_gap_certificate(FWConfig(loss="nope", gap_tol=0.0))
