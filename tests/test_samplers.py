"""Queue/sampler layer: Fibonacci heap exact-argmax invariant (property),
BSLS law-exactness (chi-square), two-level JAX sampler law + update
exactness, group-argmax lazy-bound invariant (property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.samplers.bsls import BSLSSampler
from repro.core.samplers.bsls_jax import (
    tl_exact_probs, tl_init, tl_sample, tl_update)
from repro.core.samplers.fib_heap import FibHeapQueue
from repro.core.samplers.group_argmax import ga_get_next, ga_init, ga_update


# ---------------------------------------------------------------------------
# Fibonacci heap (Alg 3)
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=30, deadline=None)
def test_fib_heap_returns_exact_argmax(data):
    """Alg 3 invariant: despite stale (over-estimating) priorities, getNext
    returns the exact argmax of |α| after arbitrary update sequences."""
    d = data.draw(st.integers(4, 40))
    alpha = np.array(data.draw(st.lists(
        st.floats(-10, 10, allow_nan=False), min_size=d, max_size=d)))
    q = FibHeapQueue(d, lambda j: abs(alpha[j]))
    q.add_all(np.abs(alpha))
    n_rounds = data.draw(st.integers(1, 5))
    for _ in range(n_rounds):
        ups = data.draw(st.lists(
            st.tuples(st.integers(0, d - 1), st.floats(-10, 10, allow_nan=False)),
            min_size=0, max_size=10))
        for i, v in ups:
            alpha[i] = v
            q.update(i, abs(v))
        j = q.get_next()
        assert abs(alpha[j]) == pytest.approx(np.abs(alpha).max())


def test_fib_heap_pop_count_bounded(tiny_problem):
    """Fig 3: pops per getNext stay ≪ D."""
    from repro.core.fw_sparse import sparse_fw
    X, y, _ = tiny_problem
    res = sparse_fw(X, y, lam=8.0, steps=80, queue="fib_heap")
    nnz = max(res.nnz, 1)
    assert res.pops / 80 <= max(3.0 * nnz, 50)


# ---------------------------------------------------------------------------
# BSLS (Alg 4) — law exactness
# ---------------------------------------------------------------------------

def _chi2_ratio(draws, probs):
    counts = np.bincount(draws, minlength=probs.shape[0])[: probs.shape[0]]
    e = probs * len(draws)
    m = e >= 5
    return float(((counts[m] - e[m]) ** 2 / e[m]).sum() / max(m.sum() - 1, 1))


def test_bsls_matches_exponential_mechanism():
    rng = np.random.default_rng(1)
    s = BSLSSampler(rng.normal(0, 2, 150), seed=9)
    draws = np.array([s.sample() for _ in range(25_000)])
    assert _chi2_ratio(draws, s.exact_probs()) < 1.5


def test_bsls_after_updates():
    rng = np.random.default_rng(2)
    s = BSLSSampler(rng.normal(0, 2, 120), seed=3)
    for _ in range(200):
        s.update(int(rng.integers(0, 120)), float(rng.normal(0, 2)))
    draws = np.array([s.sample() for _ in range(25_000)])
    assert _chi2_ratio(draws, s.exact_probs()) < 1.5


def test_bsls_sublinear_cost():
    d = 4096
    rng = np.random.default_rng(3)
    s = BSLSSampler(rng.normal(0, 1, d), seed=4)
    for _ in range(200):
        s.sample()
    # O(√D log D): far below a linear scan
    assert s.cost_per_draw() < d / 4


def test_bsls_extreme_weight_range():
    """log-sum-exp path must survive 4+ orders of magnitude (paper §3.3)."""
    v = np.array([-500.0, -100.0, 0.0, 50.0, 200.0] + [-300.0] * 45)
    s = BSLSSampler(v, seed=5)
    draws = [s.sample() for _ in range(500)]
    assert all(d_ == 4 for d_ in draws)  # weight 200 dominates utterly


# ---------------------------------------------------------------------------
# Two-level JAX sampler (TPU adaptation)
# ---------------------------------------------------------------------------

def test_two_level_law():
    rng = np.random.default_rng(4)
    st_ = tl_init(jnp.asarray(rng.normal(0, 2, 300), jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 25_000)
    draws = np.asarray(jax.vmap(lambda k: tl_sample(st_, k))(keys))
    assert _chi2_ratio(draws, np.asarray(tl_exact_probs(st_))) < 1.5


def test_two_level_update_exact():
    rng = np.random.default_rng(5)
    d = 77
    vals = rng.normal(0, 1, d)
    st_ = tl_init(jnp.asarray(vals, jnp.float32))
    idx = jnp.asarray([3, 50, 76, 200], jnp.int32)      # 200 = padding (> d)
    new = jnp.asarray([5.0, -2.0, 1.5, 99.0], jnp.float32)
    st2 = tl_update(st_, idx, new)
    vals[[3, 50, 76]] = [5.0, -2.0, 1.5]
    np.testing.assert_allclose(
        np.asarray(st2.v.reshape(-1)[:d]), vals, rtol=1e-6)
    # group sums must equal exact recomputation
    ref = tl_init(jnp.asarray(vals, jnp.float32))
    np.testing.assert_allclose(np.asarray(st2.c), np.asarray(ref.c), rtol=1e-5)


# ---------------------------------------------------------------------------
# Group-argmax (TPU form of Alg 3)
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=30, deadline=None)
def test_group_argmax_exact_under_updates(data):
    d = data.draw(st.integers(3, 60))
    pri = np.abs(np.array(data.draw(st.lists(
        st.floats(0, 10, allow_nan=False), min_size=d, max_size=d))))
    state = ga_init(jnp.asarray(pri, jnp.float32))
    for _ in range(data.draw(st.integers(1, 4))):
        k = data.draw(st.integers(1, 6))
        idx = np.array([data.draw(st.integers(0, d - 1)) for _ in range(k)])
        val = np.abs(np.array([data.draw(st.floats(0, 10, allow_nan=False))
                               for _ in range(k)]))
        for i, v in zip(idx, val):
            pri[i] = v
        state = ga_update(state, jnp.asarray(idx, jnp.int32),
                          jnp.asarray(val, jnp.float32))
        j, state = ga_get_next(state)
        assert pri[int(j)] == pytest.approx(pri.max(), rel=1e-6)
