"""Telemetry subsystem (DESIGN.md §12): tracing, metrics, audit ledger.

The two contracts that make observability safe to leave on:

  * **No perturbation** — telemetry on vs off yields bit-identical iterates
    on every backend, private and non-private (instrumentation is host-side
    only; it never enters traced code).
  * **True no-op when disabled** — the disabled path is one global read per
    call site; a solve with the collector off must not be measurably slower
    than one with the module never touched.

Plus the DP audit ledger's exactness contract: replaying the JSONL trail
recomputes every tenant's ε through ``PrivacyAccountant`` itself and must
match the live accountant bit-for-bit.
"""
import json
import math
import time

import numpy as np
import pytest

from repro import obs
from repro.core.dp.accountant import PrivacyAccountant
from repro.core.solvers import FWConfig, grid, solve, solve_many
from repro.obs.ledger import AuditLedger
from repro.obs.metrics import MetricsRegistry, quantile

FIVE_BACKENDS = ("dense", "host_sparse", "jax_dense", "jax_sparse",
                 "jax_shard")


@pytest.fixture(scope="module")
def problem():
    from repro.data.synthetic import make_sparse_classification
    X, y, _ = make_sparse_classification(
        n=80, d=300, nnz_per_row=8, informative=10, seed=7)
    return X, y


def _cfg(backend: str, **kw) -> FWConfig:
    if backend == "jax_shard":
        kw.setdefault("mesh", (1, 1))
    return FWConfig(backend=backend, **kw)


def _assert_bit_identical(a, b, msg=""):
    for field in ("coords", "w", "gaps"):
        x, y = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        assert x.shape == y.shape and x.tobytes() == y.tobytes(), \
            f"{msg}: {field} perturbed by telemetry"


# ---------------------------------------------------------------------------
# tentpole guard: telemetry must never perturb iterates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("private", (False, True),
                         ids=("nonprivate", "private"))
@pytest.mark.parametrize("backend", FIVE_BACKENDS)
def test_telemetry_no_perturbation(problem, backend, private):
    """Tier-1: telemetry on vs off is bit-identical on every backend."""
    X, y = problem
    kw = dict(lam=6.0, steps=12)
    if private:
        kw.update(queue="bsls", epsilon=1.0, delta=1e-6)
    off = solve(X, y, _cfg(backend, **kw))
    with obs.session():
        on = solve(X, y, _cfg(backend, **kw))
    assert not obs.enabled()
    _assert_bit_identical(on, off, f"{backend}/private={private}")


def test_telemetry_no_perturbation_chunked_and_cohort(problem):
    """The chunked early-stop driver and the cohort scheduler emit per-chunk
    events — and still replay the exact same state machine."""
    X, y = problem
    cfg = FWConfig(backend="jax_sparse", lam=6.0, steps=24, gap_tol=1e-6)
    off = solve(X, y, cfg)
    cfgs = grid(cfg, lam=(4.0, 8.0, 16.0))
    off_many = solve_many(X, y, cfgs, plan="vmap")
    with obs.session() as tel:
        on = solve(X, y, cfg)
        on_many = solve_many(X, y, cfgs, plan="vmap")
    _assert_bit_identical(on, off, "chunked")
    for a, b in zip(on_many, off_many):
        _assert_bit_identical(a, b, "cohort")
    assert on.stop_step == off.stop_step
    assert on.stop_reason == off.stop_reason
    # the instrumented run actually recorded its chunk lifecycle
    names = [e["name"] for e in tel.events if e["ev"] == "event"]
    assert "chunks.stop" in names


def test_disabled_path_overhead_bounded(problem):
    """Disabled primitives are a handful of ns each, and a warmed solve with
    the collector off is not slower than one with it on."""
    t0 = time.perf_counter()
    for _ in range(50_000):
        obs.count("x")
        with obs.span("y"):
            pass
    assert time.perf_counter() - t0 < 1.0     # ~100 sec/call budget of 10 µs

    X, y = problem
    cfg = FWConfig(backend="jax_sparse", lam=6.0, steps=10)
    solve(X, y, cfg)                          # warm the compile cache

    def best_of(n, fn):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = best_of(3, lambda: solve(X, y, cfg))
    with obs.session():
        t_on = best_of(3, lambda: solve(X, y, cfg))
    # generous band: CI wobble, but "off" must never cost more than "on"
    # plus noise — that would mean the disabled path does real work
    assert t_off <= t_on * 1.5 + 0.05, (t_off, t_on)


# ---------------------------------------------------------------------------
# metrics: interpolated quantiles, registry, exporters
# ---------------------------------------------------------------------------


def test_quantile_is_interpolated():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert quantile(vals, 0.0) == 1.0
    assert quantile(vals, 1.0) == 4.0
    assert quantile(vals, 0.5) == 2.5          # NOT vals[len//2] == 3.0
    assert quantile(vals, 0.25) == 1.75
    assert quantile([5.0], 0.9) == 5.0
    assert quantile([], 0.5) == 0.0
    assert quantile([3.0, 1.0, 2.0], 0.5) == 2.0    # unsorted input ok
    with pytest.raises(ValueError):
        quantile(vals, 1.5)


def test_metrics_registry_and_labels():
    reg = MetricsRegistry()
    reg.counter("hits", cache="padded").inc()
    reg.counter("hits", cache="padded").inc(2)
    reg.counter("hits", cache="setup").inc()
    reg.gauge("depth").set(7)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("lat").observe(v)
    snap = {(m["name"], tuple(sorted(m["labels"].items()))): m
            for m in reg.snapshot()}
    assert snap[("hits", (("cache", "padded"),))]["value"] == 3
    assert snap[("hits", (("cache", "setup"),))]["value"] == 1
    assert snap[("depth", ())]["value"] == 7
    h = snap[("lat", ())]
    assert h["count"] == 4 and h["p50"] == 2.5 and h["max"] == 4.0


def test_session_jsonl_round_trip_and_prometheus(tmp_path):
    from repro.obs.exporters import prometheus_text, read_jsonl
    path = tmp_path / "ev.jsonl"
    with obs.session(jsonl_path=str(path), meta={"suite": "t"}) as tel:
        with obs.span("outer", k=1):
            with obs.span("inner"):
                obs.count("c", lbl="a")
                obs.observe("h", 0.25)
                obs.gauge("g", 3.5)
        obs.event("e", detail="x")
        text = prometheus_text(tel)
    records = read_jsonl(str(path))
    assert records[0]["ev"] == "meta" and records[0]["suite"] == "t"
    spans = {r["name"]: r for r in records if r["ev"] == "span"}
    assert spans["inner"]["parent"] == spans["outer"]["id"]
    assert spans["outer"]["attrs"]["k"] == 1
    kinds = {r["ev"] for r in records}
    assert {"span", "event", "metric"} <= kinds
    assert 'repro_c_total{lbl="a"} 1' in text
    assert 'repro_h{quantile="0.5"}' in text and "repro_h_count 1" in text
    assert "repro_g 3.5" in text


def test_session_restores_previous_collector():
    with obs.session() as outer:
        with obs.session() as inner:
            assert obs.get() is inner
        assert obs.get() is outer
    assert obs.get() is None


def test_cache_counters_from_store(tmp_path, problem):
    """DatasetStore cache layers report hit/miss through obs."""
    from repro.data.store import DatasetStore
    X, y = problem
    store = DatasetStore.from_arrays(str(tmp_path / "ds"), X, y)
    cfg = FWConfig(backend="jax_sparse", lam=8.0, steps=5)
    with obs.session() as tel:
        solve(store, config=cfg)       # cold: padded + setup misses
        warm = DatasetStore.open(store.root)
        solve(warm, config=cfg)        # warm: both replayed from cache/
        counts = {(m["name"], m["labels"].get("cache"),
                   m["labels"].get("outcome")): m["value"]
                  for m in tel.metrics.snapshot() if m["type"] == "counter"}
    assert counts[("store.cache", "padded", "miss")] >= 1
    assert counts[("store.cache", "padded", "hit")] >= 1
    assert counts[("store.cache", "setup", "miss")] >= 1
    assert counts[("store.cache", "setup", "hit")] >= 1


# ---------------------------------------------------------------------------
# the ε-spend audit ledger
# ---------------------------------------------------------------------------


def _spend(ledger, tenant, acct, uid, steps):
    before = AuditLedger.state_of(acct)
    acct.spend(steps)
    ledger.charge(tenant=tenant, uid=uid, steps=steps, before=before,
                  acct=acct, request={"epsilon": 1.0})


def test_ledger_replay_exact_and_persistent(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    acct = PrivacyAccountant(epsilon=2.0, delta=1e-6, total_steps=64)
    led = AuditLedger(path)
    led.open_tenant("acme", acct)
    _spend(led, "acme", acct, uid=0, steps=10)
    _spend(led, "acme", acct, uid=1, steps=6)
    led.refusal(tenant="acme", uid=2, reason="budget exhausted", acct=acct)
    totals = led.totals()["acme"]
    assert totals["spent_steps"] == 16 and totals["refusals"] == 1
    # ε recomputed through the accountant's own formula: bitwise equal
    assert totals["spent_epsilon"] == acct.spent_epsilon()
    report = led.verify({"acme": acct})
    assert report["acme"]["exact"] is True

    # the file alone carries the whole chain — and a reopened ledger
    # continues it instead of truncating
    assert AuditLedger.replay(AuditLedger.load(path))["acme"][
        "spent_steps"] == 16
    led2 = AuditLedger(path)
    assert len(led2.entries) == len(led.entries)
    _spend(led2, "acme", acct, uid=3, steps=4)
    assert AuditLedger.replay(AuditLedger.load(path))["acme"][
        "spent_steps"] == 20


def test_ledger_detects_tampering():
    acct = PrivacyAccountant(epsilon=2.0, delta=1e-6, total_steps=64)
    led = AuditLedger()
    led.open_tenant("t", acct)
    _spend(led, "t", acct, uid=0, steps=8)
    # forged charge amount: after != before + steps
    bad = [dict(e) for e in led.entries]
    bad[1] = dict(bad[1], steps=4)
    with pytest.raises(ValueError, match="charge of 4 steps"):
        AuditLedger.replay(bad)
    # skipped entry: chain gap
    acct2 = PrivacyAccountant(epsilon=2.0, delta=1e-6, total_steps=64)
    led2 = AuditLedger()
    led2.open_tenant("t", acct2)
    _spend(led2, "t", acct2, uid=0, steps=8)
    _spend(led2, "t", acct2, uid=1, steps=8)
    with pytest.raises(ValueError, match="last known spend"):
        AuditLedger.replay([led2.entries[0], led2.entries[2]])
    # live accountant drifted from the trail
    acct.spend(1)
    with pytest.raises(ValueError, match="spent steps"):
        led.verify({"t": acct})


def test_ledger_checkpoint_restore_round_trip(tmp_path):
    accts = {
        "a": PrivacyAccountant(epsilon=2.0, delta=1e-6, total_steps=64),
        "b": PrivacyAccountant(epsilon=1.0, delta=1e-5, total_steps=32),
    }
    accts["a"].spend(12)
    led = AuditLedger()
    path = led.checkpoint(str(tmp_path), accts)
    back = AuditLedger.restore_accountants(path)
    assert set(back) == {"a", "b"}
    for t in accts:
        assert back[t].spent_steps == accts[t].spent_steps
        assert back[t].spent_epsilon() == accts[t].spent_epsilon()
        assert (back[t].epsilon, back[t].delta, back[t].total_steps) == \
            (accts[t].epsilon, accts[t].delta, accts[t].total_steps)


# ---------------------------------------------------------------------------
# FitService acceptance: drain under telemetry, audited end to end
# ---------------------------------------------------------------------------


def _service(X, y, **cfg_kw):
    from repro.serve import FitService, FitServiceConfig
    return FitService(X, y, accountants={
        "acme": PrivacyAccountant(epsilon=6.0, delta=1e-6, total_steps=144),
        "globex": PrivacyAccountant(epsilon=1.0, delta=1e-6, total_steps=45),
    }, config=FitServiceConfig(slots=4, **cfg_kw))


def _submit_mixed(svc):
    from repro.serve import FitRequest
    uid = 0
    for cfg in grid(FWConfig(backend="jax_sparse", steps=10, queue="bsls",
                             delta=1e-6), lam=(4.0, 8.0), epsilon=(0.5, 2.0)):
        svc.submit(FitRequest(uid=uid, tenant="acme", config=cfg))
        uid += 1
    for cfg in grid(FWConfig(backend="jax_sparse", steps=10, queue="bsls",
                             delta=1e-6, epsilon=0.5),
                    lam=(4.0, 8.0, 16.0, 32.0)):
        svc.submit(FitRequest(uid=uid, tenant="globex", config=cfg))
        uid += 1
    for lam in (4.0, 8.0):
        svc.submit(FitRequest(uid=uid, tenant="globex",
                              config=FWConfig(backend="jax_sparse",
                                              steps=10, lam=lam)))
        uid += 1


def test_fit_service_telemetry_acceptance(problem, tmp_path, monkeypatch):
    """ISSUE-8 acceptance: a full drain with telemetry enabled is (a) bit-
    identical to telemetry-off, (b) leaves a replayable ledger whose ε
    totals exactly match the accountants, (c) serves latency percentiles
    and queue depth through stats() and both exporters."""
    from repro.core.solvers import planner
    from repro.obs.exporters import prometheus_text
    # pin the group execution mode: the §9 planner picks vmap vs sequential
    # from its *measured* cost book, and the off-drain's own timings can
    # flip the choice for the on-drain — scheduling nondeterminism this
    # test must hold fixed to isolate the telemetry-perturbation contract
    # (vmap and sequential lowerings differ in float LSBs)
    monkeypatch.setattr(planner, "group_mode",
                        lambda *a, **k: "vmap")
    X, y = problem

    svc_off = _service(X, y)
    _submit_mixed(svc_off)
    done_off = svc_off.run()

    ledger_path = str(tmp_path / "ledger.jsonl")
    events_path = str(tmp_path / "events.jsonl")
    svc_on = _service(X, y, ledger_path=ledger_path)
    with obs.session(jsonl_path=events_path) as tel:
        _submit_mixed(svc_on)
        done_on = svc_on.run()
        prom = prometheus_text(tel)

    # (a) bit-identical responses, request by request
    assert [r.status for r in done_on] == [r.status for r in done_off]
    for a, b in zip(done_on, done_off):
        if a.status == "done":
            _assert_bit_identical(a.result, b.result, f"uid={a.uid}")

    # (b) the on-disk trail alone replays to the live accountants' ε,
    # bitwise (verify raises on any drift)
    report = svc_on.verify_ledger()
    for tenant, rec in report.items():
        assert rec["exact"] is True
        assert rec["spent_epsilon"] == \
            svc_on.accountants[tenant].spent_epsilon()
    disk = AuditLedger.replay(AuditLedger.load(ledger_path))
    for tenant, rec in disk.items():
        assert rec["spent_epsilon"] == \
            svc_on.accountants[tenant].spent_epsilon()
    # exactly one refusal (globex's 4th DP fit), attested in the trail
    assert disk["globex"]["refusals"] == 1

    # (c) percentiles + queue depth via stats() and both exporters
    stats = svc_on.stats()
    lat = stats["latency_s"]
    assert lat["p50"] <= lat["p90"] <= lat["p99"] <= lat["max"]
    assert lat["p50"] > 0 and stats["queue_depth"] == 0
    assert "repro_service_latency_s" in prom
    assert "repro_service_queue_depth" in prom
    with open(events_path) as f:
        records = [json.loads(line) for line in f]
    metric_names = {r["name"] for r in records if r["ev"] == "metric"}
    assert "service.latency_s" in metric_names
    assert "service.queue_depth" in metric_names

    # and the report CLI renders it all without error
    from repro.obs.report import render_path
    out = render_path(events_path, ledger_path)
    assert "service.run" in out and "tenant ε-spend ledger" in out


def test_fit_service_stats_percentiles_interpolated(problem):
    """The p50 is an order statistic of the latency sample, not an index."""
    svc = _service(*problem)
    from repro.serve import FitRequest
    for i, lam in enumerate((4.0, 8.0)):
        svc.submit(FitRequest(uid=i, tenant="acme", config=FWConfig(
            backend="jax_sparse", steps=5, lam=lam)))
    svc.run()
    lat = sorted(r.latency_s for r in svc.finished)
    got = svc.stats()["latency_s"]
    assert got["p50"] == pytest.approx(quantile(lat, 0.5))
    assert got["p50"] <= got["max"]   # even-length sample: mean of the two


# ---------------------------------------------------------------------------
# trainer: telemetry rides along, history and log sink unchanged
# ---------------------------------------------------------------------------


def test_trainer_fit_obs_and_log_sink():
    import jax
    import jax.numpy as jnp

    from repro.train.trainer import (TrainConfig, make_train_state,
                                     make_train_step)
    from repro.train.optimizer import get_optimizer
    from repro.train import trainer

    tc = TrainConfig(total_steps=8, warmup=1, peak_lr=1e-2)
    loss_fn = lambda p, batch, remat=True: jnp.sum((p["w"] - batch["x"]) ** 2)
    step_fn = make_train_step(loss_fn, tc)
    opt = get_optimizer(tc.optimizer)
    state0 = make_train_state(
        lambda k: {"w": jnp.zeros((4,), jnp.float32)}, opt,
        jax.random.PRNGKey(0))

    def batches():
        while True:
            yield {"x": jnp.ones((4,), jnp.float32)}

    lines = []
    with obs.session() as tel:
        state, history = trainer.fit(
            state0, step_fn, batches(), steps=8, log_every=2,
            log=lines.append)
    assert len(history) == 5                  # steps 0,2,4,6 + final
    assert all("loss=" in ln for ln in lines)  # sink got the text
    span_names = {e["name"] for e in tel.events if e["ev"] == "span"}
    assert "train.fit" in span_names
    hist = [m for m in tel.metrics.snapshot()
            if m["name"] == "train.step_seconds"]
    assert hist and hist[0]["count"] == 8
