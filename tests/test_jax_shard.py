"""``jax_shard`` backend: registry wiring, 1×1-mesh parity, ε unification.

Parity logic (DESIGN.md §8): on a 1×1 mesh every collective in the sharded
schedule is the identity, so the backend must reproduce single-device
oracles *exactly* —

  * non-private: identical coordinate steps to ``host_sparse``'s exact
    fib-heap argmax (true cross-implementation parity, the same bar the
    other Alg-2 engines meet);
  * private: identical coordinates to ``distributed.reference.reference_fw``,
    the straight-line replay of the schedule with the same key stream
    (cross-implementation parity is impossible for DP draws — equal *law*,
    different realization — so the oracle pins the collective plumbing:
    winner masking, psums, global-id reconstruction).

The grid/FitService tests then pin the batched and serving paths onto the
same trajectories, and the ε tests pin the distributed engine's (ε, δ, T)
semantics to ``core.dp.accountant`` so the two private paths cannot drift.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core.solvers import (FWConfig, available_backends, get_backend,
                                grid, resolve_queue, solve, solve_many)


@pytest.fixture(scope="module")
def shard_problem():
    from repro.data.synthetic import make_sparse_classification
    X, y, _ = make_sparse_classification(n=120, d=400, nnz_per_row=10,
                                         informative=15, seed=5)
    return X, y


# ---------------------------------------------------------------------------
# registry wiring
# ---------------------------------------------------------------------------


def test_registry_has_jax_shard():
    assert "jax_shard" in available_backends()
    backend = get_backend("jax_shard")
    assert backend.data_format == "blocks"
    # one config retargets across engines: DP names → gumbel, exact → argmax
    assert resolve_queue(backend, FWConfig(queue="bsls")).queue == "gumbel"
    assert resolve_queue(backend, FWConfig(queue="two_level")).queue == "gumbel"
    assert resolve_queue(backend, FWConfig(queue="fib_heap")).queue == "argmax"
    assert resolve_queue(backend, FWConfig(queue="group_argmax")).queue == "argmax"
    with pytest.raises(ValueError, match="does not support queue"):
        resolve_queue(backend, FWConfig(queue="noisy_max"))


def test_mesh_must_fit_devices(shard_problem):
    X, y = shard_problem
    with pytest.raises(ValueError, match="devices"):
        solve(X, y, FWConfig(backend="jax_shard", steps=2, mesh=(64, 64)))


def test_grid_treats_mesh_spec_as_scalar():
    cfgs = grid(backend="jax_shard", mesh=(1, 1), lam=(4.0, 8.0))
    assert len(cfgs) == 2 and all(c.mesh == (1, 1) for c in cfgs)
    swept = grid(backend="jax_shard", mesh=((1, 1), (2, 2)))
    assert [c.mesh for c in swept] == [(1, 1), (2, 2)]


# ---------------------------------------------------------------------------
# 1×1-mesh parity vs host oracles
# ---------------------------------------------------------------------------


def test_nonprivate_parity_vs_host_sparse(shard_problem):
    """Identity collectives ⇒ the sharded engine is the host Alg 2 exactly."""
    X, y = shard_problem
    cfg = FWConfig(lam=8.0, steps=60)
    shard = solve(X, y, dataclasses.replace(cfg, backend="jax_shard"))
    host = solve(X, y, dataclasses.replace(cfg, backend="host_sparse"))
    np.testing.assert_array_equal(np.asarray(shard.coords),
                                  np.asarray(host.coords))
    np.testing.assert_allclose(np.asarray(shard.w), np.asarray(host.w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(shard.gaps), np.asarray(host.gaps),
                               atol=1e-4)


def test_private_parity_vs_reference(shard_problem):
    """The DP path replays the straight-line oracle coordinate-for-coordinate."""
    import jax.numpy as jnp

    from repro.core.solvers.jax_shard import shard_em_scale
    from repro.distributed.block_sparse import build_block_sparse
    from repro.distributed.reference import reference_fw

    X, y = shard_problem
    n, d = X.shape
    cfg = resolve_queue(get_backend("jax_shard"),
                        FWConfig(backend="jax_shard", lam=8.0, steps=40,
                                 queue="bsls", epsilon=1.0, delta=1e-6,
                                 seed=3))
    res = solve(X, y, cfg)
    blocks = build_block_sparse(X, 1, 1)
    y_pad = jnp.zeros(blocks.padded[0], jnp.float32).at[:n].set(
        jnp.asarray(y, jnp.float32))
    w_ref, gaps_ref, coords_ref = reference_fw(
        blocks, y_pad, lam=8.0, steps=40, selection="gumbel",
        em_scale=shard_em_scale(cfg, n), seed=3)
    np.testing.assert_array_equal(np.asarray(res.coords),
                                  np.asarray(coords_ref))
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref)[:d],
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.gaps), np.asarray(gaps_ref),
                               atol=1e-5)
    assert len(set(np.asarray(res.coords).tolist())) > 5   # EM explores


# ---------------------------------------------------------------------------
# batched grid + store + service
# ---------------------------------------------------------------------------


def test_solve_many_grid_parity(shard_problem):
    """The vmapped sweep takes the same steps as sequential re-entries."""
    X, y = shard_problem
    configs = grid(FWConfig(backend="jax_shard", steps=25, queue="bsls",
                            delta=1e-6),
                   lam=(4.0, 8.0), epsilon=(0.5, 2.0), seed=(0, 1))
    assert len(configs) == 8
    batched = solve_many(X, y, configs)
    for cfg, b in zip(configs, batched):
        s = solve(X, y, cfg)
        np.testing.assert_array_equal(np.asarray(b.coords),
                                      np.asarray(s.coords))
        np.testing.assert_allclose(np.asarray(b.w), np.asarray(s.w),
                                   atol=1e-5)


def test_solve_from_dataset_ref_with_block_cache(shard_problem, tmp_path):
    from repro.data.store import DatasetRef, DatasetStore

    X, y = shard_problem
    root = str(tmp_path / "store")
    DatasetStore.from_arrays(root, X, y, rows_per_shard=48)  # 3 shards
    cfg = FWConfig(backend="jax_shard", lam=8.0, steps=30)
    mem = solve(X, y, cfg)
    ref = solve(DatasetRef(path=root), config=cfg)           # labels from store
    np.testing.assert_array_equal(np.asarray(ref.coords),
                                  np.asarray(mem.coords))
    np.testing.assert_allclose(np.asarray(ref.w), np.asarray(mem.w),
                               atol=1e-6)
    # the block layout persisted under cache/ and replays on a fresh open
    assert os.path.exists(os.path.join(root, "cache", "blocks-1x1-meta.json"))
    store = DatasetStore.open(root)
    cached = store.blocks_load(1, 1)
    assert cached is not None and cached.shape == X.shape
    warm = solve(store, config=cfg)
    np.testing.assert_array_equal(np.asarray(warm.coords),
                                  np.asarray(mem.coords))


def test_fit_service_from_store_on_jax_shard(shard_problem, tmp_path):
    """Mixed jax_shard/jax_sparse traffic against one store: per-request
    backend selection with unchanged ε-accounting."""
    from repro.core.dp.accountant import PrivacyAccountant
    from repro.data.store import DatasetStore
    from repro.serve.fit_service import FitRequest, FitService

    X, y = shard_problem
    store = DatasetStore.from_arrays(str(tmp_path / "store"), X, y)
    svc = FitService(store, accountants={
        "acme": PrivacyAccountant(epsilon=4.0, delta=1e-6, total_steps=4000)})
    reqs = [
        FitRequest(0, "acme", FWConfig(backend="jax_shard", lam=8.0, steps=20,
                                       queue="bsls", epsilon=1.0, delta=1e-6)),
        FitRequest(1, "acme", FWConfig(backend="jax_sparse", lam=8.0, steps=20,
                                       queue="bsls", epsilon=1.0, delta=1e-6)),
        FitRequest(2, "acme", FWConfig(backend="jax_shard", lam=8.0, steps=20)),
        FitRequest(3, "noone", FWConfig(backend="jax_shard", lam=8.0, steps=20,
                                        queue="bsls", epsilon=1.0,
                                        delta=1e-6)),
    ]
    for r in reqs:
        svc.submit(r)
    done = svc.run()
    by_uid = {r.uid: r for r in done}
    assert by_uid[0].status == "done" and by_uid[1].status == "done"
    assert by_uid[2].status == "done"                 # non-private: no budget
    assert by_uid[3].status == "rejected"             # unknown tenant
    # both private fits charged the same accountant currency
    acct = svc.accountants["acme"]
    assert acct.spent_steps == 2 * svc._charged_steps(acct, by_uid[0].config)
    # the drained jax_shard result equals a direct solve on the same store
    direct = solve(store, config=by_uid[2].config)
    np.testing.assert_array_equal(np.asarray(by_uid[2].result.coords),
                                  np.asarray(direct.coords))


# ---------------------------------------------------------------------------
# (ε, δ, T) unification across the private engines
# ---------------------------------------------------------------------------


def test_em_scale_semantics_pinned(shard_problem):
    """One accountant formula behind every private selection path."""
    import math

    from repro.core.dp.accountant import (em_log_weight_scale,
                                          per_step_epsilon)
    from repro.core.losses import get_loss
    from repro.core.solvers.jax_shard import shard_em_scale
    from repro.core.solvers.jax_sparse import em_scale_for
    from repro.distributed.fw_shard import DistFWConfig

    n, eps, delta, steps = 2048, 0.7, 1e-6, 500
    lip = get_loss("logistic").lipschitz
    expected = per_step_epsilon(eps, delta, steps) * n / (2.0 * lip)
    assert expected == pytest.approx(
        eps / math.sqrt(8.0 * steps * math.log(1.0 / delta)) * n / (2 * lip))
    # the shared helper
    assert em_log_weight_scale(epsilon=eps, delta=delta, steps=steps,
                               n_rows=n, lipschitz=lip) == expected
    # the single-device two-level sampler (native queue of jax_sparse)
    sparse_cfg = resolve_queue(
        get_backend("jax_sparse"),
        FWConfig(backend="jax_sparse", queue="bsls", epsilon=eps, delta=delta,
                 steps=steps))
    assert em_scale_for(sparse_cfg, n) == expected
    # the distributed gumbel schedule, via FWConfig and via DistFWConfig
    shard_cfg = resolve_queue(
        get_backend("jax_shard"),
        FWConfig(backend="jax_shard", queue="bsls", epsilon=eps, delta=delta,
                 steps=steps))
    assert shard_em_scale(shard_cfg, n) == expected
    assert DistFWConfig(epsilon=eps, delta=delta, steps=steps).em_scale(n) \
        == expected
    # non-private rules never scale priorities
    assert em_scale_for(dataclasses.replace(sparse_cfg, queue="group_argmax"),
                        n) == 1.0
    assert shard_em_scale(dataclasses.replace(shard_cfg, queue="argmax"),
                          n) == 1.0


# ---------------------------------------------------------------------------
# pluggable objectives (DESIGN.md §10): the sharded schedule serves every
# registered loss — label-coupled q̄ threading included — with 1×1-mesh
# parity against the host engine and the straight-line oracle.
# ---------------------------------------------------------------------------


from repro.core.losses import OBJECTIVES  # noqa: E402


@pytest.mark.parametrize("loss", sorted(OBJECTIVES))
def test_shard_parity_per_loss(shard_problem, loss):
    import jax.numpy as jnp

    from repro.core.solvers.jax_shard import shard_em_scale
    from repro.distributed.block_sparse import build_block_sparse
    from repro.distributed.reference import reference_fw

    X, y = shard_problem
    n, d = X.shape
    # non-private: exact cross-implementation parity with the host fib-heap
    shard = solve(X, y, FWConfig(backend="jax_shard", lam=8.0, steps=30,
                                 loss=loss))
    host = solve(X, y, FWConfig(backend="host_sparse", lam=8.0, steps=30,
                                loss=loss))
    np.testing.assert_array_equal(np.asarray(shard.coords),
                                  np.asarray(host.coords), err_msg=loss)
    np.testing.assert_allclose(np.asarray(shard.w), np.asarray(host.w),
                               atol=1e-4, err_msg=loss)
    # private: coordinate-for-coordinate replay of the eager oracle
    cfg = resolve_queue(get_backend("jax_shard"),
                        FWConfig(backend="jax_shard", lam=8.0, steps=30,
                                 loss=loss, queue="bsls", epsilon=1.0,
                                 delta=1e-6, seed=3))
    res = solve(X, y, cfg)
    blocks = build_block_sparse(X, 1, 1)
    y_pad = jnp.zeros(blocks.padded[0], jnp.float32).at[:n].set(
        jnp.asarray(y, jnp.float32))
    w_ref, _, coords_ref = reference_fw(
        blocks, y_pad, lam=8.0, steps=30, selection="gumbel",
        em_scale=shard_em_scale(cfg, n), seed=3, loss=loss)
    np.testing.assert_array_equal(np.asarray(res.coords),
                                  np.asarray(coords_ref), err_msg=loss)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref)[:d],
                               atol=1e-5, err_msg=loss)
