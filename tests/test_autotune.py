"""§11 layout/chunk autotuner: the tiered-CSC split, the exactness gate, the
store-persisted tuning cache, and the planner feed.

The hard invariant everything here orbits: **a tuned layout produces
bit-identical iterates to the untuned one, on every backend, private and
non-private** — the autotuner changes how fast the paper's iteration runs,
never which iterates it takes (so the DP selection distribution is
untouched, per Khanna et al.).
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core.solvers import FWConfig, solve
from repro.core.solvers.autotune import (TUNE_VERSION, TuningRecord, autotune,
                                         candidate_widths, probe_parity)
from repro.core.sparse.formats import (TieredCSC, host_to_padded,
                                       tiered_from_padded)
from repro.data.store import DatasetStore
from repro.data.synthetic import make_sparse_classification


@pytest.fixture(scope="module")
def problem():
    # heavy-tailed column popularity (the synthetic generator's power law)
    # so the padded CSC has a real tail for the tuner to split
    X, y, _ = make_sparse_classification(n=220, d=900, nnz_per_row=12,
                                         informative=20, seed=11)
    return X, y


@pytest.fixture(scope="module")
def padded(problem):
    X, _ = problem
    return host_to_padded(X)


@pytest.fixture()
def store(problem, tmp_path):
    X, y = problem
    return DatasetStore.from_arrays(str(tmp_path / "ds"), X, y,
                                    rows_per_shard=64)


# ---------------------------------------------------------------------------
# TieredCSC layout
# ---------------------------------------------------------------------------


def test_tiered_split_reconstructs_every_column(padded):
    _, pcsc = padded
    cn = np.asarray(pcsc.nnz)
    width = max(8, int(np.percentile(cn, 90)))
    tiered = tiered_from_padded(pcsc, width)
    assert isinstance(tiered, TieredCSC)
    assert tiered.width == width
    assert tiered.full_width == pcsc.indices.shape[1]
    np.testing.assert_array_equal(np.asarray(tiered.nnz), cn)  # never clamped
    for j in [0, 1, int(cn.argmax()), pcsc.shape[1] - 1]:
        heavy = cn[j] > width
        assert bool(tiered.is_heavy(j)) == heavy
        idx, val, mask = (tiered.col_heavy(j) if heavy
                          else tiered.col_light(j))
        k = int(cn[j])
        # real lanes match the flat layout; everything masked-off is padding
        np.testing.assert_array_equal(np.asarray(idx)[:k],
                                      np.asarray(pcsc.indices)[j, :k])
        np.testing.assert_array_equal(np.asarray(val)[:k],
                                      np.asarray(pcsc.values)[j, :k])
        assert bool(np.asarray(mask)[:k].all())
        assert not np.asarray(mask)[k:].any()
        assert not np.asarray(val)[k:].any()


def test_tiered_width_bounds_rejected(padded):
    _, pcsc = padded
    full = int(pcsc.indices.shape[1])
    with pytest.raises(ValueError):
        tiered_from_padded(pcsc, 0)
    with pytest.raises(ValueError):
        tiered_from_padded(pcsc, full)


def test_candidate_widths_bounded_and_below_full(padded):
    _, pcsc = padded
    cands = candidate_widths(pcsc)
    full = int(pcsc.indices.shape[1])
    assert len(cands) <= 4
    assert all(8 <= w < full for w in cands)
    assert cands == sorted(cands)


def test_probe_parity_gates_a_corrupted_layout(problem, padded):
    """The exactness gate must reject a layout that changes the arithmetic
    (here: every stored value scaled, so any selected column computes
    different sums)."""
    import jax.numpy as jnp
    X, y = problem
    pcsr, pcsc = padded
    width = candidate_widths(pcsc)[-1]
    good = tiered_from_padded(pcsc, width)
    assert probe_parity(pcsr, pcsc, good, y, loss="logistic", interpret=True,
                        steps=8)
    bad = dataclasses.replace(
        good, values=jnp.asarray(np.asarray(good.values) * 1.5),
        heavy_values=jnp.asarray(np.asarray(good.heavy_values) * 1.5))
    assert not probe_parity(pcsr, pcsc, bad, y, loss="logistic",
                            interpret=True, steps=8)


# ---------------------------------------------------------------------------
# tuned-layout parity across backends (the ISSUE's hard invariant)
# ---------------------------------------------------------------------------


def _bits(res):
    return tuple(np.asarray(a).tobytes() for a in (res.w, res.gaps,
                                                   res.coords))


@pytest.mark.parametrize("queue", ["group_argmax", "two_level"])
@pytest.mark.parametrize("backend", ["jax_sparse", "jax_dense", "dense",
                                     "host_sparse", "jax_shard"])
def test_tuned_store_bit_identical_on_every_backend(store, problem, backend,
                                                    queue):
    """Solving through the store before vs after autotuning is bitwise the
    same on every backend — private and non-private."""
    X, y = problem
    cfg = dict(backend=backend, steps=12, lam=15.0, queue=queue,
               epsilon=1.0, delta=1e-6, seed=3)
    before = solve(store, **cfg)
    rec = autotune(store, steps=6, probe_steps=8)
    assert rec.pass_parity
    # force a *new* PreparedDataset so the tuned path is really exercised
    store._prepared = None
    after = solve(store, **cfg)
    assert _bits(before) == _bits(after)


def test_tuned_layout_matches_raw_matrix_solve(store, problem):
    X, y = problem
    autotune(store, steps=6, probe_steps=8)
    store._prepared = None
    cfg = dict(backend="jax_sparse", steps=15, lam=20.0, queue="two_level",
               epsilon=1.0, delta=1e-6)
    assert _bits(solve(store, **cfg)) == _bits(solve(X, y, **cfg))


def test_tuned_chunked_driver_matches_default(store, problem):
    """gap_tol configs route through the chunked driver with the tuned
    chunk_steps default — still bit-identical to the untuned store."""
    X, y = problem
    cfg = dict(backend="jax_sparse", steps=24, lam=15.0, gap_tol=1e-9,
               queue="group_argmax")
    before = solve(store, **cfg)
    autotune(store, steps=6, probe_steps=8)
    store._prepared = None
    after = solve(store, **cfg)
    assert _bits(before) == _bits(after)


# ---------------------------------------------------------------------------
# persistence + replay
# ---------------------------------------------------------------------------


def test_warm_open_replays_record_without_research(store, monkeypatch):
    rec = autotune(store, steps=6, probe_steps=8)
    assert rec.content_hash == store.content_hash
    assert os.path.exists(os.path.join(
        store.root, "cache",
        f"autotune-jax_sparse-logistic-{rec.platform}.json"))
    # a re-opened store must replay the persisted record, never re-search
    import repro.core.solvers.autotune as at

    def boom(*a, **k):
        raise AssertionError("warm open re-ran the search")

    monkeypatch.setattr(at, "tune_jax_sparse", boom)
    reopened = DatasetStore.open(store.root)
    rec2 = autotune(reopened, steps=6, probe_steps=8)
    assert rec2 == rec
    # and the prepared dataset resolves it through the loader hook
    prep = reopened.prepared()
    assert prep.tuning_for("jax_sparse", "logistic",
                           platform=rec.platform) == rec


def test_force_retunes_and_content_hash_guards(store, tmp_path):
    rec = autotune(store, steps=6, probe_steps=8)
    # force=True ignores the cache (timings may differ; knobs are stable)
    rec2 = autotune(store, steps=6, probe_steps=8, force=True)
    assert rec2.ell_width == rec.ell_width
    # a record for different content must not replay
    stale = dataclasses.replace(rec, content_hash="0" * 64)
    store.autotune_save(stale)
    assert store.autotune_load("jax_sparse", "logistic",
                               rec.platform) is None


def test_tuning_record_json_round_trip():
    rec = TuningRecord(content_hash="abc", platform="cpu",
                       backend="jax_sparse", loss="logistic", ell_width=128,
                       chunk_steps=32, mesh=(2, 4),
                       per_iter_default_ms=2.0, per_iter_tuned_ms=1.0)
    back = TuningRecord.from_json(rec.to_json())
    assert back == rec
    assert back.speedup == pytest.approx(2.0)
    # unknown versions and junk refuse to deserialize rather than misread
    assert TuningRecord.from_json({**rec.to_json(),
                                   "version": TUNE_VERSION + 1}) is None
    assert TuningRecord.from_json({"nonsense": 1}) is None


def test_jax_shard_autotune_records_and_replays(store):
    rec = autotune(store, backend="jax_shard", steps=4)
    assert rec.backend == "jax_shard"
    assert rec.mesh is None          # single-device container: 1×1 wins
    assert autotune(store, backend="jax_shard", steps=4) == rec


# ---------------------------------------------------------------------------
# planner feed
# ---------------------------------------------------------------------------


def test_autotune_feeds_measured_costs_to_planner(store):
    from repro.core.solvers.planner import (clear_costbook, measured_cost,
                                            store_stats)
    clear_costbook()
    try:
        rec = autotune(store, steps=6, probe_steps=8, force=True)
        got = measured_cost("jax_sparse", "sequential", rec.platform,
                            store_stats(store))
        assert got == pytest.approx(rec.per_iter_tuned_ms / 1e3)
    finally:
        clear_costbook()
