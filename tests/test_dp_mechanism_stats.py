"""Statistical law tests for the exponential mechanism across every sampler.

The paper's privacy proof assumes each coordinate selection is *exactly* the
exponential mechanism P(j) ∝ exp(ε'·u(j)/(2Δu)).  Four implementations claim
that law — Gumbel-max (dense Alg 1), the host BSLS reservoir walk (Alg 4),
its vectorized two-level form, and the device two-level sampler behind the
bsls_draw Pallas kernel.  Here each one's empirical selection frequencies
over many seeded draws are chi-square-tested against the analytic softmax
computed by ``exponential_mechanism_probs`` — the same oracle the privacy
accounting is calibrated to.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp.mechanisms import (em_logits, exponential_mechanism_probs,
                                      gumbel_argmax)
from repro.core.samplers.bsls import BSLSSampler
from repro.core.samplers.bsls_jax import tl_init, tl_sample
from repro.kernels.bsls_draw.ops import two_level_draw

D = 24
EPS_STEP, SENS = 0.9, 0.06
N_DRAWS = 20_000


@pytest.fixture(scope="module")
def em_problem():
    """Scores + the analytic law every sampler must match."""
    scores = np.random.default_rng(5).uniform(0.0, 1.0, D)
    logits = np.asarray(em_logits(jnp.asarray(scores, jnp.float32),
                                  EPS_STEP, SENS))
    probs = np.asarray(exponential_mechanism_probs(
        jnp.asarray(scores, jnp.float32), EPS_STEP, SENS))
    return scores, logits, probs


def _chi2_ratio(draws: np.ndarray, probs: np.ndarray) -> float:
    counts = np.bincount(draws, minlength=probs.shape[0])[: probs.shape[0]]
    e = probs * len(draws)
    m = e >= 5
    return float(((counts[m] - e[m]) ** 2 / e[m]).sum() / max(m.sum() - 1, 1))


def _draw_gumbel(logits, n):
    keys = jax.random.split(jax.random.PRNGKey(101), n)
    lg = jnp.asarray(logits, jnp.float32)
    return np.asarray(jax.vmap(lambda k: gumbel_argmax(k, lg))(keys))


def _draw_two_level(logits, n):
    state = tl_init(jnp.asarray(logits, jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(102), n)
    return np.asarray(jax.vmap(lambda k: tl_sample(state, k))(keys))


def _draw_two_level_kernel(logits, n):
    """The jax_sparse selection path: big step in XLA + bsls_draw kernel."""
    state = tl_init(jnp.asarray(logits, jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(103), n)
    return np.asarray(jax.vmap(
        lambda k: two_level_draw(state.c, state.v, k, interpret=True))(keys))


def _draw_bsls_walk(logits, n):
    s = BSLSSampler(logits, seed=104)
    return np.asarray([s.sample() for _ in range(n)])


def _draw_bsls_fast(logits, n):
    s = BSLSSampler(logits, seed=105)
    return np.asarray([s.sample_fast() for _ in range(n)])


SAMPLERS = {
    "gumbel": _draw_gumbel,
    "two_level": _draw_two_level,
    "two_level_kernel": _draw_two_level_kernel,
    "bsls_walk": _draw_bsls_walk,
    "bsls_fast": _draw_bsls_fast,
}


@pytest.mark.parametrize("name", sorted(SAMPLERS))
def test_sampler_matches_analytic_em_law(em_problem, name):
    """Empirical selection frequencies agree with the analytic softmax."""
    _, logits, probs = em_problem
    draws = SAMPLERS[name](logits, N_DRAWS)
    assert draws.min() >= 0 and draws.max() < D, name
    assert _chi2_ratio(draws, probs) < 1.5, name
    # total-variation backstop: catches a sampler that passes chi-square on
    # the high-mass coordinates but starves the tail
    freq = np.bincount(draws, minlength=D) / len(draws)
    assert 0.5 * np.abs(freq - probs).sum() < 0.02, name


@pytest.mark.parametrize("name", sorted(SAMPLERS))
def test_sampler_concentrates_with_budget(em_problem, name):
    """More per-step budget ⇒ the top-scored coordinate wins more often —
    the qualitative privacy/utility dial every sampler must share."""
    scores, _, _ = em_problem
    top = int(np.argmax(scores))
    hits = {}
    for eps_step in (0.2, 5.0):
        logits = np.asarray(em_logits(jnp.asarray(scores, jnp.float32),
                                      eps_step, SENS))
        draws = SAMPLERS[name](logits, 4_000)
        hits[eps_step] = float((draws == top).mean())
    probs_tight = np.asarray(exponential_mechanism_probs(
        jnp.asarray(scores, jnp.float32), 5.0, SENS))
    assert hits[5.0] > hits[0.2] + 0.1, name
    assert hits[5.0] == pytest.approx(float(probs_tight[top]), abs=0.05), name


# ---------------------------------------------------------------------------
# per-loss sensitivity flow (DESIGN.md §10): every engine scores coordinate j
# with scale·|α_j| where scale = ε'·N/(2·L_loss) from
# ``accountant.em_log_weight_scale``.  That realizes the analytic mechanism
# P(j) ∝ exp(ε'·u/(2Δu)) with u = λ|α_j| and per-loss sensitivity
# Δu = λ·L_loss/N — pinned here empirically for each registered objective's
# Lipschitz constant, plus exact drift pins on the formula itself.
# ---------------------------------------------------------------------------

import dataclasses
import math

from repro.core.dp.accountant import em_log_weight_scale, per_step_epsilon
from repro.core.losses import OBJECTIVES
from repro.core.solvers.config import FWConfig

EPS_RUN, DELTA_RUN, T_RUN, N_ROWS, LAM = 1.0, 1e-6, 50, 400, 8.0


@pytest.fixture(scope="module")
def alpha_scores():
    """A fixed |α| surrogate; per-loss scales change its EM concentration."""
    return np.random.default_rng(9).uniform(0.0, 1.1, D)


@pytest.mark.parametrize("loss", sorted(OBJECTIVES))
def test_em_draws_match_per_loss_sensitivity_law(alpha_scores, loss):
    """Empirical two-level draws under scale·|α| agree (chi-square + TVD)
    with the analytic EM at utility λ|α| and sensitivity λ·L_loss/N."""
    lip = OBJECTIVES[loss].lipschitz
    scale = em_log_weight_scale(epsilon=EPS_RUN, delta=DELTA_RUN,
                                steps=T_RUN, n_rows=N_ROWS, lipschitz=lip)
    eps_step = per_step_epsilon(EPS_RUN, DELTA_RUN, T_RUN)
    probs = np.asarray(exponential_mechanism_probs(
        jnp.asarray(LAM * alpha_scores, jnp.float32), eps_step,
        LAM * lip / N_ROWS))
    state = tl_init(jnp.asarray(scale * alpha_scores, jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(106), N_DRAWS)
    draws = np.asarray(jax.vmap(lambda k: tl_sample(state, k))(keys))
    assert _chi2_ratio(draws, probs) < 1.5, loss
    freq = np.bincount(draws, minlength=D) / len(draws)
    assert 0.5 * np.abs(freq - probs).sum() < 0.02, loss


def test_logistic_em_scale_drift_pin():
    """Bit-identical to the formula the seed shipped: ε'·N/(2L), L = 1."""
    got = em_log_weight_scale(epsilon=1.3, delta=1e-5, steps=77,
                              n_rows=1234, lipschitz=1.0)
    expect = (1.3 / math.sqrt(8.0 * 77 * math.log(1.0 / 1e-5))) \
        * 1234 / (2.0 * 1.0)
    assert got == expect


def test_huber_em_scale_doubles_logistic():
    """L_huber = 0.5 halves the sensitivity, so the scale exactly doubles —
    the per-loss path is live, not a constant."""
    kw = dict(epsilon=0.9, delta=1e-6, steps=40, n_rows=500)
    s_log = em_log_weight_scale(lipschitz=OBJECTIVES["logistic"].lipschitz,
                                **kw)
    s_hub = em_log_weight_scale(lipschitz=OBJECTIVES["huber"].lipschitz,
                                **kw)
    assert s_hub == 2.0 * s_log


def test_engine_scales_agree_per_loss():
    """jax_sparse and jax_shard derive their EM scales from the same
    accountant formula — per loss, bit-identically."""
    from repro.core.solvers.jax_shard import shard_em_scale
    from repro.core.solvers.jax_sparse import em_scale_for
    for loss in sorted(OBJECTIVES):
        cfg = FWConfig(loss=loss, epsilon=1.0, delta=1e-6, steps=50,
                       queue="two_level")
        expect = em_log_weight_scale(
            epsilon=1.0, delta=1e-6, steps=50, n_rows=N_ROWS,
            lipschitz=OBJECTIVES[loss].lipschitz)
        assert em_scale_for(cfg, N_ROWS) == expect, loss
        shard_cfg = dataclasses.replace(cfg, queue="gumbel")
        assert shard_em_scale(shard_cfg, N_ROWS) == expect, loss
